//! Tables 3 & 4: HPC vs NDIF on the llama-8B / llama-70B simulated
//! configs — activation-patching runtime (Table 3) and weight-loading /
//! readiness time (Table 4).

#[path = "common.rs"]
mod common;

use nnscope::baselines::hooks::BaukitLike;
use nnscope::baselines::Framework;
use nnscope::client::{remote::NdifClient, Trace};
use nnscope::models::workload::IoiBatch;
use nnscope::models::{artifacts_dir, ModelWeights};
use nnscope::netsim::{Mode, NetSim};
use nnscope::runtime::Manifest;
use nnscope::scheduler::CoTenancy;
use nnscope::server::{NdifConfig, NdifServer};
use nnscope::tensor::Range1;
use nnscope::util::table::Table;

fn main() {
    let models: Vec<&str> = if common::quick() {
        vec!["tiny-sim"]
    } else {
        vec!["llama8b-sim", "llama70b-sim"]
    };
    let n = common::samples(5);

    for m in &models {
        let manifest = Manifest::load(&artifacts_dir(), m).unwrap();
        ModelWeights::ensure_on_disk(&manifest).unwrap();
    }

    common::section(&format!("Tables 3 & 4 — HPC vs NDIF on {models:?} (n={n})"));
    let cfg = NdifConfig { cotenancy: CoTenancy::Sequential, ..NdifConfig::local(&models) };
    let server = NdifServer::start(cfg).expect("server");

    let mut t3 = Table::new("Table 3 — Activation Patching (s)").header({
        let mut h = vec!["Framework".to_string()];
        h.extend(models.iter().map(|m| m.to_string()));
        h
    });
    let mut t4 = Table::new("Table 4 — Loading Weights (s)").header({
        let mut h = vec!["Framework".to_string()];
        h.extend(models.iter().map(|m| m.to_string()));
        h
    });

    let mut hpc_patch = vec!["NNsight (HPC)".to_string()];
    let mut ndif_patch = vec!["NNsight (NDIF)".to_string()];
    let mut hpc_load = vec!["NNsight (HPC)".to_string()];
    let mut ndif_load = vec!["NNsight (NDIF)".to_string()];

    for model in &models {
        let manifest = Manifest::load(&artifacts_dir(), model).unwrap();
        let batch = IoiBatch::generate(16, manifest.vocab, manifest.seq, 4);
        let layer = manifest.n_layers / 2;
        let seq = manifest.seq;

        // Table 4 HPC: weight loading from disk (read + deserialize)
        let wpath = manifest.dir.join("weights.bin");
        let load = common::bench(0, n, |_| {
            std::hint::black_box(ModelWeights::load(&wpath, model).unwrap());
        });
        hpc_load.push(load.pm());

        // Table 4 NDIF: remote readiness handshake (weights already live)
        let link = NetSim::paper_wan(Mode::Sleep);
        let client = NdifClient::new(server.addr()).with_link(link);
        let ndifload = common::bench(0, n, |_| {
            std::hint::black_box(client.models().unwrap());
        });
        ndif_load.push(ndifload.pm());

        // Table 3 HPC: local patch on a ready instance
        let fw = BaukitLike::setup(&artifacts_dir(), model).unwrap();
        let hpc = common::bench(1, n, |_| {
            std::hint::black_box(fw.activation_patch(&batch, layer).unwrap());
        });
        hpc_patch.push(hpc.pm());

        // Table 3 NDIF: remote patch over WAN
        let ndif = common::bench(1, n, |_| {
            let tokens = batch.interleaved_tokens();
            let mut tr = Trace::new(model, &tokens);
            let point = format!("layer.{layer}");
            let h = tr.output(&point);
            let mut patched = h;
            for i in (0..batch.len() * 2).step_by(2) {
                let src = tr.slice(h, &[Range1::one(i), Range1::one(seq - 1)]);
                patched = tr.assign(patched, &[Range1::one(i + 1), Range1::one(seq - 1)], src);
            }
            tr.set_output(&point, patched);
            let logits = tr.output("lm_head");
            for (i, e) in batch.examples.iter().enumerate() {
                let row = tr.slice(logits, &[Range1::one(2 * i + 1)]);
                let ld = tr.logit_diff(row, e.target, e.foil);
                tr.save(ld);
            }
            std::hint::black_box(tr.run_remote(&client).unwrap());
        });
        ndif_patch.push(ndif.pm());
    }

    t3.row(hpc_patch);
    t3.row(ndif_patch);
    t3.print();
    t4.row(hpc_load);
    t4.row(ndif_load);
    t4.print();

    common::shape_note("paper Table 3: NDIF ≈ HPC + constant comm overhead; gap shrinks (relatively) with model size");
    common::shape_note("paper Table 4: HPC load grows with size (5.99s→43.6s); NDIF flat (~0.5-0.7s)");
}
