//! Figure 6c: Petals vs NDIF over the measured 60 MB/s WAN.
//!
//! Standard remote inference (both systems return the final hidden state)
//! should be comparable; interventions should strongly favor NDIF, whose
//! server-side intervention graphs avoid shipping hidden states — Petals
//! must round-trip the activation to the client and back.

#[path = "common.rs"]
mod common;

use nnscope::baselines::patch_rows;
use nnscope::baselines::petals::PetalsSwarm;
use nnscope::client::{remote::NdifClient, Trace};
use nnscope::models::workload::IoiBatch;
use nnscope::models::artifacts_dir;
use nnscope::netsim::{Mode, NetSim};
use nnscope::runtime::Manifest;
use nnscope::scheduler::CoTenancy;
use nnscope::server::{NdifConfig, NdifServer};
use nnscope::tensor::Range1;
use nnscope::util::table::Table;

fn main() {
    let model = if common::quick() { "tiny-sim" } else { "llama8b-sim" };
    let n = common::samples(8);
    let manifest = Manifest::load(&artifacts_dir(), model).unwrap();
    let seq = manifest.seq;
    let layer = manifest.n_layers / 2;
    let pairs = 16usize.min(manifest.batches.iter().copied().max().unwrap_or(2) / 2);
    let batch = IoiBatch::generate(pairs, manifest.vocab, seq, 3);
    let tokens = batch.interleaved_tokens();

    common::section(&format!("Fig 6c — Petals vs NDIF on {model} (n={n}, 60 MB/s WAN)"));

    // Petals private swarm
    let swarm = PetalsSwarm::start(
        &artifacts_dir(),
        model,
        NetSim::paper_wan(Mode::Sleep),
    )
    .expect("swarm");

    // NDIF server + WAN client
    let cfg = NdifConfig { cotenancy: CoTenancy::Sequential, ..NdifConfig::local(&[model]) };
    let server = NdifServer::start(cfg).expect("server");
    let client = NdifClient::new(server.addr()).with_link(NetSim::paper_wan(Mode::Sleep));

    // --- standard inference: both return the final hidden state ---------
    let petals_inf = common::bench(1, n, |_| {
        std::hint::black_box(swarm.infer_hidden(&tokens).unwrap());
    });
    let last_layer = format!("layer.{}", manifest.n_layers - 1);
    let ndif_inf = common::bench(1, n, |_| {
        let mut tr = Trace::new(model, &tokens);
        let h = tr.output(&last_layer);
        tr.save(h);
        std::hint::black_box(tr.run_remote(&client).unwrap());
    });

    // --- intervention: activation patching + logit-diff metric ----------
    let petals_int = common::bench(1, n, |_| {
        let logits = swarm
            .patched_infer(&tokens, layer, |t| patch_rows(t, seq))
            .unwrap();
        // metric computed client-side (Petals has no server-side compute)
        std::hint::black_box(nnscope::baselines::base_row_logit_diffs(&logits, &batch));
    });
    let ndif_int = common::bench(1, n, |_| {
        let mut tr = Trace::new(model, &tokens);
        let point = format!("layer.{layer}");
        let h = tr.output(&point);
        let mut patched = h;
        for i in (0..batch.len() * 2).step_by(2) {
            let src = tr.slice(h, &[Range1::one(i), Range1::one(seq - 1)]);
            patched = tr.assign(patched, &[Range1::one(i + 1), Range1::one(seq - 1)], src);
        }
        tr.set_output(&point, patched);
        let logits = tr.output("lm_head");
        for (i, e) in batch.examples.iter().enumerate() {
            let row = tr.slice(logits, &[Range1::one(2 * i + 1)]);
            let ld = tr.logit_diff(row, e.target, e.foil);
            tr.save(ld); // only scalars cross the WAN
        }
        std::hint::black_box(tr.run_remote(&client).unwrap());
    });

    let mut table = Table::new("Fig 6c — runtime (s)").header(vec![
        "Task", "Petals", "NDIF", "Petals / NDIF",
    ]);
    table.row(vec![
        "standard inference".to_string(),
        petals_inf.pm(),
        ndif_inf.pm(),
        format!("{:.2}x", petals_inf.mean / ndif_inf.mean),
    ]);
    table.row(vec![
        "activation patching".to_string(),
        petals_int.pm(),
        ndif_int.pm(),
        format!("{:.2}x", petals_int.mean / ndif_int.mean),
    ]);
    table.print();

    common::shape_note("paper: comparable on standard inference; NDIF significantly faster on interventions");
    common::shape_note(&format!(
        "hidden-state bytes per intervention: Petals ships 4×{} = {} KB over the WAN; NDIF ships only the graph + {} scalars",
        manifest.hidden_bytes(tokens_rows(&batch)),
        4 * manifest.hidden_bytes(tokens_rows(&batch)) / 1024,
        batch.len()
    ));
}

fn tokens_rows(batch: &IoiBatch) -> usize {
    batch.len() * 2
}
