//! AOT plan-cache payoff: cold vs hot admission, planned vs per-node
//! allocation execution, and the cache hit rate under a repeated-shape
//! co-tenant burst.
//!
//! Three measurements, matching how the server actually amortizes the
//! plan layer:
//!
//! * **admission latency** — cold admission runs validate → parametric
//!   compile (DCE, folding, CSE, fusion) → schedule → arena plan → bind;
//!   hot admission is a structural-key lookup plus the constant rebind.
//!   The acceptance bar is hot strictly faster than cold.
//! * **execution throughput** — a warm planned engine (cache hit, arena
//!   slots) against the legacy per-request pipeline (validate + optimize
//!   + per-node allocation) on the same admission-heavy graph.
//! * **hit rate** — a burst of structurally identical submissions with
//!   fresh payloads, the shape a co-tenant dashboard fleet produces;
//!   every submission after the first per shape must hit.
//!
//! Emits `BENCH_plancache.json` (gated by `tools/bench_gate.rs` against
//! `benches/baselines/`).

#[path = "common.rs"]
mod common;

use std::sync::Arc;

use nnscope::client::Trace;
use nnscope::engine::{Engine, ExecSpec};
use nnscope::graph::plan::{self, PlanMode};
use nnscope::graph::plan_cache::PlanCache;
use nnscope::graph::InterventionGraph;
use nnscope::json::Json;
use nnscope::models::{artifacts_dir, ModelRunner};
use nnscope::tensor::Tensor;
use nnscope::util::stats::Summary;
use nnscope::util::table::Table;

/// An admission-heavy probe: per-layer duplicate reads (CSE), a folded
/// const projection chain, speculative dead reads (DCE), and fusable
/// scale→softmax lenses — the compiler does real work on every cold
/// admission of this graph.
/// `seed` varies only the token payload (bind-time data); `factor` is a
/// structural scale factor, so distinct factors are distinct plan-cache
/// shapes.
fn probe_graph(runner: &ModelRunner, seed: usize, factor: f32) -> InterventionGraph {
    let m = &runner.manifest;
    let tokens = Tensor::new(
        &[1, m.seq],
        (0..m.seq).map(|i| ((i * 7 + seed) % m.vocab) as f32).collect(),
    );
    let mut tr = Trace::new(&m.name, &tokens);
    let d = m.d_model;
    let mut chain = tr.constant(&Tensor::new(
        &[d, d],
        (0..d * d).map(|i| ((i % 13) as f32 - 6.0) * 0.01).collect(),
    ));
    for k in 0..4 {
        let w = tr.constant(&Tensor::new(
            &[d, d],
            (0..d * d).map(|i| (((i + k) % 11) as f32 - 5.0) * 0.01).collect(),
        ));
        chain = tr.matmul(chain, w);
    }
    for layer in 0..m.n_layers {
        let point = format!("layer.{layer}");
        let h = tr.output(&point);
        let h_dup = tr.output(&point); // duplicate read: CSE
        let _speculative = tr.output(&point); // dead read: DCE
        let flat = tr.reshape(h, &[m.seq, d]);
        let lensed = tr.matmul(flat, chain);
        let sc = tr.scale(lensed, factor);
        let sm = tr.softmax(sc); // Softmax-of-Scale: fused
        let mn = tr.mean(sm);
        tr.save(mn);
        let mn2 = tr.mean(h_dup);
        tr.save(mn2);
    }
    tr.into_graph()
}

fn main() {
    let quick = common::quick();
    let model = "tiny-sim";
    let runner = ModelRunner::load(&artifacts_dir(), model).unwrap();
    let fseq = runner.manifest.forward_sequence();
    let graph = probe_graph(&runner, 3, 1.7);

    // ---- measurement 1: cold vs hot admission latency ---------------------
    common::section(&format!("AOT plans — cold vs hot admission ({model})"));
    let batch = if quick { 20 } else { 100 };
    let reps = if quick { 5 } else { 15 };
    let key = plan::structural_key(&graph, PlanMode::Trace, true);

    let cold_once = || {
        nnscope::graph::validate::validate(&graph, &fseq).unwrap();
        let p = Arc::new(plan::compile(&graph, &fseq, PlanMode::Trace, true).unwrap());
        p.bind(&graph).unwrap()
    };
    let warm_cache = Arc::new(PlanCache::new(16));
    warm_cache.insert(model, key, Arc::new(plan::compile(&graph, &fseq, PlanMode::Trace, true).unwrap()));
    let hot_once = || {
        let k = plan::structural_key(&graph, PlanMode::Trace, true);
        let p = warm_cache.get(model, k).expect("warm cache must hit");
        p.bind(&graph).unwrap()
    };

    let time_batch = |f: &dyn Fn() -> nnscope::graph::opt::Prepared| {
        let t0 = std::time::Instant::now();
        for _ in 0..batch {
            let prepared = f();
            assert!(!prepared.graph.nodes.is_empty());
        }
        t0.elapsed().as_secs_f64() / batch as f64
    };
    let _ = (time_batch(&cold_once), time_batch(&hot_once)); // warmup
    let mut cold = Vec::with_capacity(reps);
    let mut hot = Vec::with_capacity(reps);
    for _ in 0..reps {
        cold.push(time_batch(&cold_once));
        hot.push(time_batch(&hot_once));
    }
    let cold_s = Summary::of(&cold).median;
    let hot_s = Summary::of(&hot).median;
    let admission_speedup_hot = cold_s / hot_s.max(1e-12);

    let mut table = Table::new("admission: cold compile vs cache hit").header(vec![
        "path", "median per admission (s)",
    ]);
    table.row(vec!["cold (validate+opt+plan+bind)".to_string(), format!("{cold_s:.7}")]);
    table.row(vec!["hot (lookup+rebind)".to_string(), format!("{hot_s:.7}")]);
    table.print();
    common::shape_note(&format!(
        "{} nodes: hot admission {admission_speedup_hot:.1}x faster \
         (acceptance bar: strictly faster)",
        graph.nodes.len()
    ));
    assert!(
        hot_s < cold_s,
        "hot admission ({hot_s:.7}s) must beat cold ({cold_s:.7}s)"
    );

    // ---- measurement 2: planned vs per-node-alloc execution ---------------
    common::section("AOT plans — planned vs legacy per-request execution");
    let exec_reps = if quick { 8 } else { 30 };
    let cache = Arc::new(PlanCache::new(16));
    let planned_eng = Engine::with_plans(&runner, Arc::clone(&cache));
    let plain_eng = Engine::new(&runner);
    planned_eng.run(ExecSpec::trace(&graph)).unwrap(); // warm the cache
    let time_exec = |eng: &Engine| {
        let t0 = std::time::Instant::now();
        for _ in 0..exec_reps {
            let out = eng.run(ExecSpec::trace(&graph)).unwrap();
            assert!(!out.result.values.is_empty());
        }
        t0.elapsed().as_secs_f64() / exec_reps as f64
    };
    let _ = (time_exec(&plain_eng), time_exec(&planned_eng)); // warmup
    let plain_s = time_exec(&plain_eng);
    let planned_s = time_exec(&planned_eng);
    let planned_exec_ratio = plain_s / planned_s.max(1e-12);
    let mut table = Table::new("request wall: legacy vs planned").header(vec![
        "path", "wall per request (s)",
    ]);
    table.row(vec!["legacy (validate+opt each request)".to_string(), format!("{plain_s:.6}")]);
    table.row(vec!["planned (warm cache)".to_string(), format!("{planned_s:.6}")]);
    table.print();
    common::shape_note(&format!(
        "planned/legacy request throughput ratio {planned_exec_ratio:.2}x"
    ));

    // ---- measurement 3: hit rate under a repeated-shape burst -------------
    common::section("AOT plans — repeated-shape co-tenant burst hit rate");
    let shapes = 4usize;
    let rounds = if quick { 8 } else { 32 };
    let burst_cache = Arc::new(PlanCache::new(64));
    let burst_eng = Engine::with_plans(&runner, Arc::clone(&burst_cache));
    for round in 0..rounds {
        for shape in 0..shapes {
            // same structure per shape, fresh payload per round — the
            // dashboard-fleet shape
            let g = probe_graph(&runner, shape * 1000 + round, 1.0 + shape as f32 * 0.25);
            let out = burst_eng.run(ExecSpec::trace(&g)).unwrap();
            assert!(!out.result.values.is_empty());
        }
    }
    let s = burst_cache.stats();
    let plan_hit_rate = s.hits as f64 / (s.hits + s.misses).max(1) as f64;
    common::shape_note(&format!(
        "{} submissions, {} hits / {} misses → hit rate {plan_hit_rate:.3}",
        shapes * rounds,
        s.hits,
        s.misses
    ));
    assert_eq!(s.misses, shapes as u64, "each shape compiles exactly once");

    let json = Json::obj(vec![
        ("bench", Json::from("plancache")),
        ("quick", Json::Bool(quick)),
        ("model", Json::from(model)),
        ("graph_nodes", Json::from(graph.nodes.len())),
        ("admission_cold_s", Json::from(cold_s)),
        ("admission_hot_s", Json::from(hot_s)),
        ("admission_speedup_hot", Json::from(admission_speedup_hot)),
        ("exec_wall_legacy_s", Json::from(plain_s)),
        ("exec_wall_planned_s", Json::from(planned_s)),
        ("planned_exec_ratio", Json::from(planned_exec_ratio)),
        ("plan_hit_rate", Json::from(plan_hit_rate)),
    ]);
    std::fs::write("BENCH_plancache.json", json.pretty()).expect("write BENCH_plancache.json");
    println!("\nwrote BENCH_plancache.json");
}
