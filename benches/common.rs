//! Shared harness for the paper-reproduction benchmarks (criterion is
//! unavailable offline; `cargo bench` runs these as harness=false
//! binaries).
//!
//! Environment knobs:
//!   NNSCOPE_BENCH_N      samples per measurement (default varies per bench)
//!   NNSCOPE_BENCH_QUICK  =1 → minimal samples / reduced sweeps (CI mode)

#![allow(dead_code)]

use nnscope::util::stats::Summary;
use nnscope::util::time;

pub fn quick() -> bool {
    std::env::var("NNSCOPE_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

pub fn samples(default: usize) -> usize {
    if let Ok(v) = std::env::var("NNSCOPE_BENCH_N") {
        return v.parse().expect("NNSCOPE_BENCH_N");
    }
    if quick() {
        2
    } else {
        default
    }
}

/// Measure a closure `n` times (after `warmup`) and summarize seconds.
pub fn bench(warmup: usize, n: usize, f: impl FnMut(usize)) -> Summary {
    Summary::of(&time::sample(warmup, n, f))
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n──────────────────────────────────────────────────");
    println!("{title}");
    println!("──────────────────────────────────────────────────");
}

/// Print a paper-vs-measured comparison line.
pub fn shape_note(s: &str) {
    println!("  ↳ {s}");
}
