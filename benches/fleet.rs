//! Fleet scaling: aggregate throughput for 1 → 2 → 4 replicas behind the
//! L3 coordinator, under the Fig. 9 load-test workload (≤24-token prompts,
//! each saving a uniformly-random layer's output).
//!
//! Each replica is a full `NdifServer` (sequential co-tenancy — one worker
//! per model, the configuration the paper's load test used), so replica
//! count is the only parallelism axis. The coordinator routes least-loaded
//! using heartbeat queue depths plus its own in-flight accounting.
//! Expectation: aggregate throughput increases monotonically with replica
//! count; perfect linearity is not expected when replicas share host cores.

#[path = "common.rs"]
mod common;

use std::time::{Duration, Instant};

use nnscope::client::{remote::NdifClient, Trace};
use nnscope::coordinator::{Coordinator, CoordinatorConfig, Policy};
use nnscope::models::{artifacts_dir, workload};
use nnscope::runtime::Manifest;
use nnscope::scheduler::CoTenancy;
use nnscope::server::{NdifConfig, NdifServer};
use nnscope::tensor::Tensor;
use nnscope::util::table::Table;
use nnscope::util::Prng;

fn main() {
    let model = if common::quick() { "tiny-sim" } else { "llama8b-sim" };
    let fleet_sizes = [1usize, 2, 4];
    let n_users = if common::quick() { 4 } else { 16 };
    let reqs_per_user = common::samples(8);

    let manifest = Manifest::load(&artifacts_dir(), model).unwrap();
    common::section(&format!(
        "Fleet — throughput vs replica count ({model}, {n_users} users × {reqs_per_user} reqs, least-loaded)"
    ));

    let mut table = Table::new("aggregate throughput by fleet size").header(vec![
        "replicas", "wall (s)", "req/s", "speedup", "per-replica completed",
    ]);
    let mut throughput = Vec::new();

    for &n in &fleet_sizes {
        let mut coord_cfg = CoordinatorConfig::local();
        coord_cfg.policy = Policy::LeastLoaded;
        coord_cfg.probe_interval = Duration::from_millis(50);
        let mut coord = Coordinator::start(coord_cfg).expect("coordinator");

        let mut replicas: Vec<NdifServer> = (0..n)
            .map(|_| {
                let mut cfg = NdifConfig::local(&[model]);
                cfg.cotenancy = CoTenancy::Sequential;
                cfg.coordinator = Some(coord.addr().to_string());
                cfg.heartbeat = Duration::from_millis(50);
                NdifServer::start(cfg).expect("replica")
            })
            .collect();

        // warm the fleet: n concurrent requests spread across all replicas
        // (in-flight-aware least-loaded), absorbing lazy first-run init
        {
            let addr = coord.addr();
            let warmers: Vec<_> = (0..n)
                .map(|_| {
                    let model = model.to_string();
                    let seq = manifest.seq;
                    std::thread::spawn(move || {
                        let client = NdifClient::new(addr);
                        let tokens = Tensor::new(&[1, seq], vec![1.0; seq]);
                        let mut tr = Trace::new(&model, &tokens);
                        let h = tr.output("layer.0");
                        tr.save(h);
                        tr.run_remote(&client).expect("warmup");
                    })
                })
                .collect();
            for w in warmers {
                w.join().unwrap();
            }
        }

        let addr = coord.addr();
        let t0 = Instant::now();
        let handles: Vec<_> = (0..n_users)
            .map(|u| {
                let model = model.to_string();
                let (vocab, seq, layers) = (manifest.vocab, manifest.seq, manifest.n_layers);
                std::thread::spawn(move || {
                    let client = NdifClient::new(addr);
                    let mut rng = Prng::new((n * 1000 + u) as u64);
                    for _ in 0..reqs_per_user {
                        let req = workload::load_test_request(&mut rng, vocab, seq, layers);
                        let tokens = Tensor::new(&[1, seq], req.tokens.clone());
                        let mut tr = Trace::new(&model, &tokens);
                        let h = tr.output(&format!("layer.{}", req.layer));
                        tr.save(h);
                        tr.run_remote(&client).expect("request");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let total = (n_users * reqs_per_user) as f64;
        throughput.push(total / wall);

        let completed: Vec<String> = replicas
            .iter()
            .map(|r| format!("{}", r.metrics(model).map(|m| m.1).unwrap_or(0)))
            .collect();
        table.row(vec![
            format!("{n}"),
            format!("{wall:.3}"),
            format!("{:.2}", total / wall),
            format!("{:.2}x", throughput.last().unwrap() / throughput[0]),
            completed.join(" / "),
        ]);

        for r in replicas.iter_mut() {
            r.shutdown();
        }
        coord.shutdown();
    }
    table.print();

    let monotone = throughput.windows(2).all(|w| w[1] >= w[0]);
    common::shape_note(&format!(
        "aggregate throughput {} req/s across 1 → 2 → 4 replicas (monotone non-decreasing: {monotone})",
        throughput
            .iter()
            .map(|t| format!("{t:.2}"))
            .collect::<Vec<_>>()
            .join(" → ")
    ));
}
