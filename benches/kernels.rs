//! Kernel micro-benchmarks: the optimized tensor kernels against the
//! retained seed implementations (`nnscope::tensor::ops::naive`).
//!
//! Covers the three kernel families of the compute layer: matmul
//! (cache-blocked + row-parallel), softmax (row-parallel large-vocab),
//! and broadcast elementwise (stride-walk). Results are printed as a
//! table and emitted to `BENCH_kernels.json`.
//!
//! **Tokens-equivalent throughput**: each kernel's natural per-token unit
//! of work is one processed row — an LHS row for matmul (one token's
//! hidden state against a weight matrix), one softmaxed vocab row (one
//! decode step's logits), one hidden-state row for the bias add. The
//! `tokens_equiv_per_s` field is rows processed per second at the
//! optimized median, comparable across kernels at the same hidden size.
//!
//! Quick mode (`NNSCOPE_BENCH_QUICK=1`, the CI smoke step) shrinks shapes
//! and sample counts; the full run includes the 512×512×512 matmul whose
//! ≥4× speedup over the seed kernel is this layer's acceptance bar.

#[path = "common.rs"]
mod common;

use std::hint::black_box;

use nnscope::json::Json;
use nnscope::tensor::{ops::naive, Tensor};
use nnscope::util::table::Table;
use nnscope::util::{Prng, Summary};

struct Measured {
    name: &'static str,
    shape: String,
    opt: Summary,
    naive: Summary,
    /// per-token work units (rows) processed per iteration.
    rows_per_iter: usize,
}

impl Measured {
    fn speedup(&self) -> f64 {
        self.naive.median / self.opt.median.max(1e-12)
    }
    fn tokens_equiv_per_s(&self) -> f64 {
        self.rows_per_iter as f64 / self.opt.median.max(1e-12)
    }
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.to_string())),
            ("shape", Json::Str(self.shape.clone())),
            ("optimized_median_s", Json::Num(self.opt.median)),
            ("naive_median_s", Json::Num(self.naive.median)),
            ("speedup", Json::Num(self.speedup())),
            ("tokens_equiv_per_s", Json::Num(self.tokens_equiv_per_s())),
        ])
    }
}

fn main() {
    let quick = common::quick();
    let n = common::samples(7);
    let n_naive = if quick { 1 } else { 3 };
    let mut rng = Prng::new(0xBE7C);
    let mut measured: Vec<Measured> = Vec::new();

    common::section(&format!(
        "Kernel micro-benchmarks (compute pool: {} threads)",
        nnscope::threadpool::compute_pool().size()
    ));

    // --- matmul: the model-compute analog --------------------------------
    let mm_sizes: &[(usize, usize, usize)] =
        if quick { &[(128, 128, 128)] } else { &[(256, 256, 256), (512, 512, 512)] };
    for &(m, k, nn) in mm_sizes {
        let a = Tensor::from_randn(&[m, k], &mut rng, 1.0);
        let b = Tensor::from_randn(&[k, nn], &mut rng, 1.0);
        let opt = common::bench(1, n, |_| {
            black_box(a.matmul(&b));
        });
        let nai = common::bench(0, n_naive, |_| {
            black_box(naive::matmul(&a, &b));
        });
        measured.push(Measured {
            name: "matmul",
            shape: format!("{m}x{k}x{nn}"),
            opt,
            naive: nai,
            rows_per_iter: m,
        });
    }

    // --- softmax: the large-vocab logits path ----------------------------
    let sm_sizes: &[(usize, usize)] = if quick { &[(64, 8192)] } else { &[(256, 50272)] };
    for &(rows, vocab) in sm_sizes {
        let t = Tensor::from_randn(&[rows, vocab], &mut rng, 2.0);
        let opt = common::bench(1, n, |_| {
            black_box(t.softmax_last());
        });
        let nai = common::bench(0, n_naive, |_| {
            black_box(naive::softmax_last(&t));
        });
        measured.push(Measured {
            name: "softmax",
            shape: format!("{rows}x{vocab}"),
            opt,
            naive: nai,
            rows_per_iter: rows,
        });
    }

    // --- broadcast: the bias-add / residual elementwise path -------------
    let bc_sizes: &[(usize, usize, usize)] =
        if quick { &[(4, 128, 1024)] } else { &[(8, 256, 4096)] };
    for &(b, seq, d) in bc_sizes {
        let x = Tensor::from_randn(&[b, seq, d], &mut rng, 1.0);
        let bias = Tensor::from_randn(&[d], &mut rng, 1.0);
        let opt = common::bench(1, n, |_| {
            black_box(x.add(&bias));
        });
        let nai = common::bench(0, n_naive, |_| {
            black_box(naive::binop(&x, &bias, |p, q| p + q));
        });
        measured.push(Measured {
            name: "broadcast_add",
            shape: format!("{b}x{seq}x{d}+{d}"),
            opt,
            naive: nai,
            rows_per_iter: b * seq,
        });
    }

    // --- report ----------------------------------------------------------
    let mut table = Table::new("optimized vs seed kernels (median s)").header(vec![
        "kernel",
        "shape",
        "optimized",
        "naive seed",
        "speedup",
        "tokens-eq/s",
    ]);
    for m in &measured {
        table.row(vec![
            m.name.to_string(),
            m.shape.clone(),
            format!("{:.6}", m.opt.median),
            format!("{:.6}", m.naive.median),
            format!("{:.2}x", m.speedup()),
            format!("{:.0}", m.tokens_equiv_per_s()),
        ]);
    }
    table.print();
    if let Some(mm) = measured.iter().rev().find(|m| m.name == "matmul") {
        common::shape_note(&format!(
            "largest matmul speedup vs seed kernel: {:.2}x (acceptance bar: ≥4x at 512³ on a multi-core host)",
            mm.speedup()
        ));
    }

    let json = Json::obj(vec![
        ("bench", Json::Str("kernels".to_string())),
        ("quick", Json::Bool(quick)),
        (
            "compute_threads",
            Json::Num(nnscope::threadpool::compute_pool().size() as f64),
        ),
        ("samples", Json::Num(n as f64)),
        ("kernels", Json::arr(measured.iter().map(Measured::to_json).collect())),
    ]);
    std::fs::write("BENCH_kernels.json", json.pretty()).expect("write BENCH_kernels.json");
    println!("\nwrote BENCH_kernels.json");
}
