//! Deep-profiler overhead: un-profiled traffic must run at full speed.
//!
//! The profiler's disarmed path is one thread-local `bool` read per
//! recording site (`obs::profile::armed()` — the same discipline as
//! `util/failpoint.rs`), so traffic that does not opt in should be
//! indistinguishable from a build without the profiler. This bench proves
//! that from first principles rather than a flaky A/B wall-clock diff:
//!
//! 1. measure the cost of one `armed()` check in a tight loop;
//! 2. count the recording sites one request actually crosses (executed
//!    graph ops from a profiled run, times a generous per-op multiplier
//!    covering set_point/set_step/alloc/value-lifecycle sites);
//! 3. assert `checks × ns_per_check` is ≤3% of the measured per-request
//!    service time. A violation means the disarmed path grew beyond the
//!    single branch — a lock, an allocation, a clock read.
//!
//! Alongside, it measures closed-loop throughput for disarmed and armed
//! traffic (`profile_off_rps` / `profiled_rps`) against one obs-enabled
//! server; both are floor-gated in CI by `tools/bench_gate.rs` via
//! `BENCH_profile.json`. Armed throughput is expected lower — profiled
//! jobs record every op, and the scheduler never co-tenancy-merges them —
//! which is exactly why profiling is per-request opt-in.

#[path = "common.rs"]
mod common;

use std::time::Instant;

use nnscope::client::{remote::NdifClient, Trace};
use nnscope::json::Json;
use nnscope::models::artifacts_dir;
use nnscope::runtime::Manifest;
use nnscope::server::{NdifConfig, NdifServer};
use nnscope::tensor::Tensor;
use nnscope::util::table::Table;

/// Generous bound on disarmed profiler checks per executed graph op:
/// exec_node's branch, the hook's set_point pair, the phase timer pair,
/// and the tensor-constructor / value-lifecycle notes an op can trigger.
const CHECKS_PER_OP: u64 = 16;
/// Flat per-request allowance for checks outside op execution (stream
/// step markers, phase records, warm-up allocations).
const CHECKS_FLAT: u64 = 256;

/// Logit-lens request: save every layer's output.
fn lens_trace(model: &str, m: &Manifest, v: f32) -> Trace {
    let tokens = Tensor::new(&[1, m.seq], vec![v; m.seq]);
    let mut tr = Trace::new(model, &tokens);
    for l in 0..m.n_layers {
        let h = tr.output(&format!("layer.{l}"));
        tr.save(h);
    }
    tr
}

/// Drive `users × reqs` closed-loop requests; returns wall seconds.
fn drive(
    addr: std::net::SocketAddr,
    model: &str,
    m: &Manifest,
    users: usize,
    reqs: usize,
    profiled: bool,
) -> f64 {
    let t0 = Instant::now();
    let handles: Vec<_> = (0..users)
        .map(|u| {
            let model = model.to_string();
            let m = m.clone();
            std::thread::spawn(move || {
                let client = NdifClient::new(addr);
                for r in 0..reqs {
                    let tr = lens_trace(&model, &m, (u * reqs + r) as f32);
                    if profiled {
                        let out = client
                            .run(tr.graph(), nnscope::client::ExecuteOptions::new().profiled())
                            .expect("profiled request");
                        let profile = out.profile.unwrap_or(Json::Null);
                        assert!(profile.get("ops").as_i64().unwrap_or(0) > 0);
                    } else {
                        tr.run_remote(&client).expect("request");
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    t0.elapsed().as_secs_f64()
}

fn main() {
    let model = "tiny-sim";
    let users = if common::quick() { 4 } else { 8 };
    let reqs = common::samples(8);
    let manifest = Manifest::load(&artifacts_dir(), model).unwrap();
    common::section(&format!(
        "Deep-profiler overhead — {model}, {users} users × {reqs} reqs, disarmed vs armed"
    ));

    // 1. the disarmed check, in isolation
    let iters: u64 = if common::quick() { 2_000_000 } else { 20_000_000 };
    let t0 = Instant::now();
    let mut acc = false;
    for _ in 0..iters {
        acc ^= std::hint::black_box(nnscope::obs::profile::armed());
    }
    let ns_per_check = t0.elapsed().as_nanos() as f64 / iters as f64;
    std::hint::black_box(acc);

    let server = NdifServer::start(NdifConfig::local(&[model])).expect("server");

    // warmup (lazy first-run init must not bill either side)
    drive(server.addr(), model, &manifest, users, 1, false);
    drive(server.addr(), model, &manifest, users, 1, true);

    // 2. ops per request, from a real profiled run
    let client = NdifClient::new(server.addr());
    let probe = client
        .run(
            lens_trace(model, &manifest, 0.0).graph(),
            nnscope::client::ExecuteOptions::new().profiled(),
        )
        .expect("profiled probe");
    let profile = probe.profile.unwrap_or(Json::Null);
    let ops = profile.get("ops").as_i64().unwrap_or(0).max(1) as u64;

    // 3. throughputs
    let wall_off = drive(server.addr(), model, &manifest, users, reqs, false);
    let wall_on = drive(server.addr(), model, &manifest, users, reqs, true);
    let total = (users * reqs) as f64;
    let (tp_off, tp_on) = (total / wall_off, total / wall_on);

    // service time per request, fleet-wide: 1/throughput. Smaller than
    // per-request latency under concurrency, which overstates the
    // overhead share — the conservative direction for this assertion.
    let request_ns = 1e9 / tp_off;
    let checks = ops * CHECKS_PER_OP + CHECKS_FLAT;
    let overhead_pct = checks as f64 * ns_per_check / request_ns * 100.0;

    let mut table = Table::new("disarmed-path accounting").header(vec!["quantity", "value"]);
    table.row(vec!["armed() check (ns)".into(), format!("{ns_per_check:.2}")]);
    table.row(vec!["graph ops / request".into(), format!("{ops}")]);
    table.row(vec!["bounded checks / request".into(), format!("{checks}")]);
    table.row(vec!["service time / request (us)".into(), format!("{:.1}", request_ns / 1e3)]);
    table.row(vec!["disarmed overhead (%)".into(), format!("{overhead_pct:.4}")]);
    table.row(vec!["disarmed req/s".into(), format!("{tp_off:.2}")]);
    table.row(vec!["profiled req/s".into(), format!("{tp_on:.2}")]);
    table.print();
    common::shape_note(&format!(
        "disarmed profiler overhead {overhead_pct:.4}% of service time (budget ≤3%)"
    ));
    assert!(
        overhead_pct <= 3.0,
        "disarmed profiler overhead {overhead_pct:.3}% exceeds the 3% budget \
         ({checks} checks × {ns_per_check:.2}ns against {request_ns:.0}ns/request) — \
         the disarmed path must stay a single thread-local branch per site"
    );

    let json = Json::obj(vec![
        ("bench", Json::from("profile")),
        ("quick", Json::Bool(common::quick())),
        ("model", Json::from(model)),
        ("ns_per_check", Json::from(ns_per_check)),
        ("ops_per_request", Json::from(ops as i64)),
        ("disarmed_overhead_pct", Json::from(overhead_pct)),
        ("profile_off_rps", Json::from(tp_off)),
        ("profiled_rps", Json::from(tp_on)),
    ]);
    std::fs::write("BENCH_profile.json", json.pretty()).expect("write BENCH_profile.json");
    println!("\nwrote BENCH_profile.json");
}
