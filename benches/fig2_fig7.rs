//! Figures 2 & 7: the §2 research-survey analyses, regenerated.
//!
//! Fig. 2: the capability gap between models studied by interpretability
//! papers and available frontier models (headline: 60.6% of post-Feb-2023
//! papers study <40% MMLU models; a small ≥70% group exists).
//!
//! Fig. 7: research-vs-released median model size ratio per year bucket
//! (headline: 2.7× in 2019–20 → 10.3× in 2024).

#[path = "common.rs"]
mod common;

use nnscope::survey::{self, data::DEFAULT_SEED};
use nnscope::util::table::Table;

fn main() {
    let (papers, released) = survey::survey_dataset(DEFAULT_SEED);

    common::section("Fig 2 — capability gap in interpretability research");
    let s = survey::fig2_stats(&papers);
    let mut t = Table::new("Fig 2 statistics").header(vec!["metric", "measured", "paper"]);
    t.row(vec!["papers surveyed".into(), format!("{}", s.total_papers), "184".to_string()]);
    t.row(vec![
        "% of post-Feb-2023 papers on <40% MMLU models".into(),
        format!("{:.1}%", 100.0 * s.frac_sub40_post_2023),
        "60.6%".to_string(),
    ]);
    t.row(vec![
        "papers on ≥70% MMLU models".into(),
        format!("{}", s.count_ge70),
        "a small group (Fig 2a)".to_string(),
    ]);
    t.row(vec![
        "mean MMLU gap vs frontier (post-2023)".into(),
        format!("{:.1} pts", s.mean_gap_post_2023),
        "large (Fig 2)".to_string(),
    ]);
    t.print();

    // the Fig. 2 scatter series (decimated) for plotting parity
    let mut series = Table::new("Fig 2 scatter (every 8th paper)").header(vec![
        "date", "params (B)", "MMLU",
    ]);
    for p in papers.iter().step_by(8) {
        series.row(vec![
            format!("{:.2}", p.date),
            format!("{:.2}", p.params_b),
            format!("{:.1}", p.mmlu),
        ]);
    }
    series.print();

    common::section("Fig 7 — research vs released model sizes");
    let mut t = Table::new("Fig 7 buckets").header(vec![
        "bucket",
        "research median (B)",
        "research IQR",
        "released median (B)",
        "released IQR",
        "ratio",
    ]);
    for b in survey::fig7_buckets(&papers, &released) {
        t.row(vec![
            b.label.to_string(),
            format!("{:.2}", b.research_median_b),
            format!("[{:.2}, {:.2}]", b.research_q25, b.research_q75),
            format!("{:.1}", b.released_median_b),
            format!("[{:.1}, {:.1}]", b.released_q25, b.released_q75),
            format!("{:.1}x", b.ratio),
        ]);
    }
    t.print();
    common::shape_note("paper endpoints: 2.7x (2019-2020) → 10.3x (2024), monotone growth between");
}
