//! Co-tenancy ablation (ours; §B.2 of the paper describes batch-grouped
//! parallel co-tenancy as future work — we implement it and measure what
//! it buys): throughput of the NDIF service under a burst of single-row
//! requests, sequential vs batch-grouped parallel execution.

#[path = "common.rs"]
mod common;

use std::time::Instant;

use nnscope::client::{remote::NdifClient, Trace};
use nnscope::models::artifacts_dir;
use nnscope::runtime::Manifest;
use nnscope::scheduler::CoTenancy;
use nnscope::server::{NdifConfig, NdifServer};
use nnscope::tensor::Tensor;
use nnscope::util::table::Table;

fn run_burst(model: &str, mode: CoTenancy, users: usize, manifest: &Manifest) -> (f64, u64) {
    let cfg = NdifConfig { cotenancy: mode, ..NdifConfig::local(&[model]) };
    let server = NdifServer::start(cfg).expect("server");
    let addr = server.addr();
    let seq = manifest.seq;
    let layers = manifest.n_layers;

    let t0 = Instant::now();
    let handles: Vec<_> = (0..users)
        .map(|u| {
            let model = model.to_string();
            std::thread::spawn(move || {
                let client = NdifClient::new(addr);
                let tokens = Tensor::new(&[1, seq], vec![(u % 50) as f32; seq]);
                let mut tr = Trace::new(&model, &tokens);
                let h = tr.output(&format!("layer.{}", u % layers));
                tr.save(h);
                tr.run_remote(&client).expect("request");
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let (_, done, failed, merged) = server.metrics(model).unwrap();
    assert_eq!(done as usize, users);
    assert_eq!(failed, 0);
    (wall, merged)
}

fn main() {
    let model = if common::quick() { "tiny-sim" } else { "llama8b-sim" };
    let user_counts: Vec<usize> = if common::quick() { vec![4] } else { vec![8, 16, 32] };
    let manifest = Manifest::load(&artifacts_dir(), model).unwrap();
    let max_merge = manifest.batches.iter().copied().max().unwrap_or(4);

    common::section(&format!(
        "Co-tenancy ablation — sequential vs batch-grouped parallel ({model}, max_merge={max_merge})"
    ));
    let mut table = Table::new("burst completion (s)").header(vec![
        "users", "sequential", "parallel (merged)", "speedup", "merged batches",
    ]);
    for &users in &user_counts {
        let (seq_wall, _) = run_burst(model, CoTenancy::Sequential, users, &manifest);
        let (par_wall, merged) =
            run_burst(model, CoTenancy::Parallel { max_merge }, users, &manifest);
        table.row(vec![
            format!("{users}"),
            format!("{seq_wall:.3}"),
            format!("{par_wall:.3}"),
            format!("{:.2}x", seq_wall / par_wall),
            format!("{merged}"),
        ]);
    }
    table.print();
    common::shape_note("batch-grouped merging amortizes forward passes across users (the §B.2 design)");
}
