//! Stateful sessions vs per-step round trips: the WAN cost of an N-step
//! in-fabric training loop (ISSUE 3 acceptance bench).
//!
//! Workload: the `probe_training` loop — train a d×d linear probe mapping
//! layer-0 activations to layer-1 activations, SGD, one step per epoch.
//! Two wire strategies over the paper's WAN profile (10 ms one-way,
//! 60 MB/s, `NetSim::paper_wan`):
//!
//! * **stateful session** — parameters live in server-side session state;
//!   the whole loop is ONE `POST /v1/session` (N+1 traces, the last one
//!   fetching the trained parameters). 2 transfers total; only per-epoch
//!   loss scalars + the final parameters come back.
//! * **stateless round trips** — the pre-session-state workflow: each step
//!   fetches layer-0/layer-1 activations (one trace request = 2 transfers)
//!   and updates the parameters client-side. 2N transfers, with full
//!   activations downloaded every step.
//!
//! The link runs in `Mode::Account`, so the simulated seconds are computed
//! from real payload byte counts without sleeping; wallclock additionally
//! shows the loopback execution cost. Emits `BENCH_sessions.json`.

#[path = "common.rs"]
mod common;

use nnscope::client::infabric::{probe_training_session, stable_lr};
use nnscope::client::{remote::NdifClient, Trace};
use nnscope::json::Json;
use nnscope::netsim::{Mode, NetSim};
use nnscope::runtime::Manifest;
use nnscope::scheduler::CoTenancy;
use nnscope::server::{NdifConfig, NdifServer};
use nnscope::tensor::optim::{mse, Sgd};
use nnscope::tensor::Tensor;
use nnscope::util::table::Table;
use nnscope::util::Prng;

struct Measured {
    name: &'static str,
    wall_s: f64,
    sim_s: f64,
    bytes: u64,
    transfers: usize,
    final_loss: f32,
}

impl Measured {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::from(self.name)),
            ("wall_s", Json::from(self.wall_s)),
            ("simulated_wan_s", Json::from(self.sim_s)),
            ("bytes", Json::from(self.bytes as i64)),
            ("transfers", Json::from(self.transfers as i64)),
            ("final_loss", Json::from(self.final_loss as f64)),
        ])
    }
}

fn init_probe(d: usize) -> (Tensor, Tensor) {
    let mut rng = Prng::new(8);
    let mut w = Tensor::zeros(&[d, d]);
    rng.fill_uniform_sym(w.data_mut(), 0.05);
    (w, Tensor::zeros(&[d]))
}

fn prompt(seq: usize, vocab: usize) -> Tensor {
    Tensor::new(&[1, seq], (0..seq).map(|i| ((i * 7 + 3) % vocab) as f32).collect())
}

/// One POST: the full loop in session state (the probe_training graph,
/// built by the shared `client::infabric` builder).
fn run_stateful(client: &NdifClient, model: &str, m: &Manifest, steps: usize, lr: f32) -> Measured {
    let (w0, b0) = init_probe(m.d_model);
    let tokens = prompt(m.seq, m.vocab);
    let plan =
        probe_training_session(model, &tokens, ("layer.0", "layer.1"), steps, lr, (&w0, &b0));

    let t0 = std::time::Instant::now();
    let results = plan.session.run_remote(client).expect("stateful session");
    let wall_s = t0.elapsed().as_secs_f64();
    let final_loss = results[steps - 1].get(plan.loss_saves[steps - 1]).item();
    Measured {
        name: "stateful_session",
        wall_s,
        sim_s: client.link.seconds_charged(),
        bytes: client.link.bytes_transferred(),
        transfers: 2,
        final_loss,
    }
}

/// 2N transfers: fetch activations per step, update the probe on the host.
fn run_stateless(
    client: &NdifClient,
    model: &str,
    m: &Manifest,
    steps: usize,
    lr: f32,
) -> Measured {
    let (seq, d) = (m.seq, m.d_model);
    let (mut w, mut b) = init_probe(d);
    let tokens = prompt(seq, m.vocab);
    let mut opt = Sgd::new(lr, 0.0);
    let mut final_loss = 0.0f32;

    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        let mut tr = Trace::new(model, &tokens);
        let h0 = tr.output("layer.0");
        let h1 = tr.output("layer.1");
        let s0 = tr.save(h0);
        let s1 = tr.save(h1);
        let res = tr.run_remote(client).expect("stateless trace");
        let x = Tensor::new(&[seq, d], res.get(s0).data().to_vec());
        let y = Tensor::new(&[seq, d], res.get(s1).data().to_vec());
        let pred = x.matmul(&w).add(&b);
        let (loss, gout) = mse(&pred, &y);
        final_loss = loss;
        let gw = x.transpose2().matmul(&gout);
        let gb = gout.mean_axis(0).scale(gout.dims()[0] as f32);
        let mut params = [
            std::mem::replace(&mut w, Tensor::scalar(0.0)),
            std::mem::replace(&mut b, Tensor::scalar(0.0)),
        ];
        opt.step(&mut params, &[gw, gb]);
        let [w2, b2] = params;
        w = w2;
        b = b2;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    Measured {
        name: "stateless_round_trips",
        wall_s,
        sim_s: client.link.seconds_charged(),
        bytes: client.link.bytes_transferred(),
        transfers: 2 * steps,
        final_loss,
    }
}

fn main() {
    let quick = common::quick();
    let model = "tiny-sim";
    let steps = if quick { 6 } else { 30 };

    let manifest = Manifest::load(&nnscope::models::artifacts_dir(), model).unwrap();
    common::section(&format!(
        "Sessions — {steps}-step in-fabric training loop vs per-step round trips \
         (paper WAN: 10 ms / 60 MB/s, {model})"
    ));

    let cfg = NdifConfig { cotenancy: CoTenancy::Sequential, ..NdifConfig::local(&[model]) };
    let server = NdifServer::start(cfg).expect("server");

    // stable SGD step size from the activation scale; measured outside
    // the timed strategies
    let lr = {
        let client = NdifClient::new(server.addr());
        let mut tr = Trace::new(model, &prompt(manifest.seq, manifest.vocab));
        let h0 = tr.output("layer.0");
        let s0 = tr.save(h0);
        let res = tr.run_remote(&client).expect("scale probe");
        stable_lr(res.get(s0), 0.5)
    };

    let measured: Vec<Measured> = ["stateful", "stateless"]
        .iter()
        .map(|which| {
            let link = NetSim::paper_wan(Mode::Account);
            let client = NdifClient::new(server.addr()).with_link(link);
            if *which == "stateful" {
                run_stateful(&client, model, &manifest, steps, lr)
            } else {
                run_stateless(&client, model, &manifest, steps, lr)
            }
        })
        .collect();

    let mut table = Table::new("WAN cost of the training loop").header(vec![
        "strategy", "transfers", "bytes", "simulated WAN (s)", "wall (s)", "final mse",
    ]);
    for m in &measured {
        table.row(vec![
            m.name.to_string(),
            m.transfers.to_string(),
            m.bytes.to_string(),
            format!("{:.4}", m.sim_s),
            format!("{:.3}", m.wall_s),
            format!("{:.5}", m.final_loss),
        ]);
    }
    table.print();

    let stateful = &measured[0];
    let stateless = &measured[1];
    let speedup = stateless.sim_s / stateful.sim_s.max(1e-12);
    common::shape_note(&format!(
        "stateful session cuts simulated WAN time {speedup:.2}x \
         ({} -> {} transfers; acceptance bar: stateful < stateless)",
        stateless.transfers, stateful.transfers
    ));
    assert!(
        stateful.sim_s < stateless.sim_s,
        "stateful session must beat per-step round trips on simulated WAN time"
    );

    let json = Json::obj(vec![
        ("bench", Json::from("sessions")),
        ("quick", Json::Bool(quick)),
        ("model", Json::from(model)),
        ("steps", Json::from(steps as i64)),
        ("wan_latency_s", Json::from(0.010)),
        ("wan_bandwidth_bps", Json::from(60.0e6)),
        ("speedup_simulated_wan", Json::from(speedup)),
        (
            "strategies",
            Json::Array(measured.iter().map(Measured::to_json).collect()),
        ),
    ]);
    std::fs::write("BENCH_sessions.json", json.pretty()).expect("write BENCH_sessions.json");
    println!("\nwrote BENCH_sessions.json");
}
