//! Figures 6a & 6b + Table 2: HPC vs NDIF across the OPT-sim family.
//!
//! 6a (setup): HPC must load weights from disk, upload them, and compile —
//! cost grows with parameter count. NDIF preloads models; client "setup"
//! is a metadata handshake — flat in model size.
//!
//! 6b (runtime): NDIF = HPC execution + a roughly constant communication
//! overhead (graph up, saved values down over the simulated WAN), so
//! remote execution wins beyond a crossover size (paper: ≥3B params).

#[path = "common.rs"]
mod common;

use nnscope::baselines::hooks::BaukitLike;
use nnscope::baselines::Framework;
use nnscope::client::{remote::NdifClient, Trace};
use nnscope::models::workload::IoiBatch;
use nnscope::models::{artifacts_dir, ModelWeights};
use nnscope::netsim::{Mode, NetSim};
use nnscope::runtime::Manifest;
use nnscope::scheduler::CoTenancy;
use nnscope::server::{NdifConfig, NdifServer};
use nnscope::tensor::Range1;
use nnscope::util::stats::linfit;
use nnscope::util::table::Table;

const OPT_FAMILY: [&str; 8] = [
    "opt-125m-sim",
    "opt-350m-sim",
    "opt-1.3b-sim",
    "opt-2.7b-sim",
    "opt-6.7b-sim",
    "opt-13b-sim",
    "opt-30b-sim",
    "opt-66b-sim",
];

fn patch_trace(model: &str, batch: &IoiBatch, layer: usize, seq: usize) -> Trace {
    let tokens = batch.interleaved_tokens();
    let mut tr = Trace::new(model, &tokens);
    let point = format!("layer.{layer}");
    let h = tr.output(&point);
    let mut patched = h;
    for i in (0..batch.len() * 2).step_by(2) {
        let src = tr.slice(h, &[Range1::one(i), Range1::one(seq - 1)]);
        patched = tr.assign(patched, &[Range1::one(i + 1), Range1::one(seq - 1)], src);
    }
    tr.set_output(&point, patched);
    let logits = tr.output("lm_head");
    // server-side metric: only scalars return
    for (i, e) in batch.examples.iter().enumerate() {
        let row = tr.slice(logits, &[Range1::one(2 * i + 1)]);
        let ld = tr.logit_diff(row, e.target, e.foil);
        tr.save(ld);
    }
    tr
}

fn main() {
    let models: Vec<&str> = if common::quick() {
        OPT_FAMILY[..2].to_vec()
    } else {
        OPT_FAMILY.to_vec()
    };
    let n = common::samples(5);

    for m in &models {
        let manifest = Manifest::load(&artifacts_dir(), m).unwrap();
        ModelWeights::ensure_on_disk(&manifest).unwrap();
    }

    common::section(&format!("Fig 6a/6b + Table 2 — HPC vs NDIF, OPT family (n={n})"));
    println!("preloading NDIF server with the whole family (untimed, once) …");
    let cfg = NdifConfig {
        cotenancy: CoTenancy::Sequential,
        ..NdifConfig::local(&models)
    };
    let server = NdifServer::start(cfg).expect("server");

    let mut table = Table::new("Table 2 — Setup Time and Runtime (s)").header(vec![
        "Model", "Params", "HPC Setup", "HPC Runtime", "NDIF Setup", "NDIF Runtime",
    ]);

    let mut params = Vec::new();
    let mut hpc_setup_means = Vec::new();
    let mut hpc_run_means = Vec::new();
    let mut ndif_run_means = Vec::new();

    for model in &models {
        let manifest = Manifest::load(&artifacts_dir(), model).unwrap();
        let pairs = 16; // 32 rows, the paper's IOI batch
        let batch = IoiBatch::generate(pairs, manifest.vocab, manifest.seq, 2);
        let layer = manifest.n_layers / 2;

        // HPC setup: cold load + compile, per sample
        let hpc_setup = common::bench(0, n, |_| {
            let f = BaukitLike::setup(&artifacts_dir(), model).expect("setup");
            std::hint::black_box(&f);
        });

        // HPC runtime: patching on a ready instance
        let fw = BaukitLike::setup(&artifacts_dir(), model).unwrap();
        let hpc_run = common::bench(1, n, |_| {
            std::hint::black_box(fw.activation_patch(&batch, layer).unwrap());
        });

        // NDIF setup: WAN handshake against the preloaded service
        let link = NetSim::paper_wan(Mode::Sleep);
        let client = NdifClient::new(server.addr()).with_link(link);
        let ndif_setup = common::bench(0, n, |_| {
            std::hint::black_box(client.models().unwrap());
        });

        // NDIF runtime: remote patch trace over the WAN
        let ndif_run = common::bench(1, n, |_| {
            let tr = patch_trace(model, &batch, layer, manifest.seq);
            std::hint::black_box(tr.run_remote(&client).unwrap());
        });

        params.push(manifest.param_count as f64);
        hpc_setup_means.push(hpc_setup.mean);
        hpc_run_means.push(hpc_run.mean);
        ndif_run_means.push(ndif_run.mean);
        table.row(vec![
            model.to_string(),
            format!("{}", manifest.param_count),
            hpc_setup.pm(),
            hpc_run.pm(),
            ndif_setup.pm(),
            ndif_run.pm(),
        ]);
    }
    table.print();

    // shape checks
    let (_, slope, r2) = linfit(&params, &hpc_setup_means);
    common::shape_note(&format!(
        "Fig 6a: HPC setup grows with params (slope {slope:.3e} s/param, r²={r2:.3}); NDIF setup flat"
    ));
    let overheads: Vec<f64> = hpc_run_means
        .iter()
        .zip(&ndif_run_means)
        .map(|(h, r)| r - h)
        .collect();
    let s = nnscope::util::Summary::of(&overheads);
    common::shape_note(&format!(
        "Fig 6b: NDIF − HPC runtime overhead ≈ constant: {} s across sizes (paper: roughly constant)",
        s.pm()
    ));
    let crossover = params
        .iter()
        .zip(hpc_setup_means.iter().zip(&overheads))
        .find(|(_, (setup, overhead))| **setup > **overhead)
        .map(|(p, _)| *p);
    match crossover {
        Some(p) => common::shape_note(&format!(
            "remote execution pays off (setup saved > comm overhead) from ~{:.1}M params (paper: ≥3B real params)",
            p / 1e6
        )),
        None => common::shape_note("no crossover in range — increase sizes"),
    }
}
