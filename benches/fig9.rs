//! Figure 9: NDIF response time vs concurrent user count.
//!
//! N ∈ {1..100} users each submit a request saving a uniformly-random
//! layer's output of the served model (≤24-token prompts). The paper finds
//! median response time grows approximately linearly in N (a FIFO queue
//! behind one shared instance) with variance growing too.

#[path = "common.rs"]
mod common;

use std::time::Instant;

use nnscope::client::{remote::NdifClient, Trace};
use nnscope::models::{artifacts_dir, workload};
use nnscope::runtime::Manifest;
use nnscope::scheduler::CoTenancy;
use nnscope::server::{NdifConfig, NdifServer};
use nnscope::tensor::Tensor;
use nnscope::util::stats::linfit;
use nnscope::util::table::Table;
use nnscope::util::{Prng, Summary};

fn main() {
    let model = if common::quick() { "tiny-sim" } else { "llama8b-sim" };
    let user_counts: Vec<usize> = if common::quick() {
        vec![1, 4, 8]
    } else {
        vec![1, 2, 5, 10, 20, 35, 50, 75, 100]
    };

    let manifest = Manifest::load(&artifacts_dir(), model).unwrap();
    common::section(&format!("Fig 9 — response time vs concurrent users ({model})"));
    // the paper's implementation queues each user and runs one forward per
    // request on a single shared instance
    let cfg = NdifConfig { cotenancy: CoTenancy::Sequential, ..NdifConfig::local(&[model]) };
    let server = NdifServer::start(cfg).expect("server");
    let addr = server.addr();

    // warm the service (first-execution lazy init must not pollute N=1)
    {
        let client = NdifClient::new(addr);
        let tokens = Tensor::new(&[1, manifest.seq], vec![1.0; manifest.seq]);
        let mut tr = Trace::new(model, &tokens);
        let h = tr.output("layer.0");
        tr.save(h);
        tr.run_remote(&client).expect("warmup");
    }

    let mut table = Table::new("response time by user count (s)").header(vec![
        "users", "median", "q25", "q75", "min", "max",
    ]);
    let mut xs = Vec::new();
    let mut medians = Vec::new();

    for &n_users in &user_counts {
        let handles: Vec<_> = (0..n_users)
            .map(|u| {
                let model = model.to_string();
                let (vocab, seq, layers) = (manifest.vocab, manifest.seq, manifest.n_layers);
                std::thread::spawn(move || -> f64 {
                    let client = NdifClient::new(addr);
                    let mut rng = Prng::new((n_users * 1000 + u) as u64);
                    let req = workload::load_test_request(&mut rng, vocab, seq, layers);
                    let tokens = Tensor::new(&[1, seq], req.tokens.clone());
                    let mut tr = Trace::new(&model, &tokens);
                    let h = tr.output(&format!("layer.{}", req.layer));
                    tr.save(h);
                    let t = Instant::now();
                    tr.run_remote(&client).expect("request");
                    t.elapsed().as_secs_f64()
                })
            })
            .collect();
        let times: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let s = Summary::of(&times);
        table.row(vec![
            format!("{n_users}"),
            format!("{:.3}", s.median),
            format!("{:.3}", s.q25),
            format!("{:.3}", s.q75),
            format!("{:.3}", s.min),
            format!("{:.3}", s.max),
        ]);
        xs.push(n_users as f64);
        medians.push(s.median);
    }
    table.print();

    let (intercept, slope, r2) = linfit(&xs, &medians);
    common::shape_note(&format!(
        "median response ≈ {intercept:.3} + {slope:.4}·N seconds (r² = {r2:.3}; paper: approximately linear)"
    ));
    let spread_first = medians.first().copied().unwrap_or(0.0);
    let spread_last = medians.last().copied().unwrap_or(0.0);
    common::shape_note(&format!(
        "median grew {:.1}x from N={} to N={} (queueing under a shared instance)",
        spread_last / spread_first.max(1e-9),
        user_counts.first().unwrap(),
        user_counts.last().unwrap()
    ));
}
