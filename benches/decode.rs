//! Continuous-batching decode engine: KV-cache step cost and batched
//! aggregate throughput (the decode-engine acceptance bench).
//!
//! Everything runs on `engine::NativeModel` over a synthetic manifest —
//! no artifacts, no server — so the numbers isolate the decode substrate
//! itself. Three questions, three metrics:
//!
//! * **kv_step_speedup** — per-token cost of a cached decode step vs a
//!   full-prefix recompute at the same position. This is the O(1)-vs-O(n)
//!   weight-matmul claim measured directly.
//! * **step_flatness** — mean per-step latency of the first quarter of a
//!   long decode over the last quarter. A cache-less engine degrades with
//!   generated length; the KV engine stays near 1.0 (attention still
//!   grows O(cache len), so slightly below).
//! * **batch_speedup_8x / tokens_per_s_8** — aggregate tokens/s of 8
//!   concurrent streams under the continuous-batching loop vs the same 8
//!   streams run back-to-back. The acceptance bar for the batching loop.
//!
//! Emits `BENCH_decode.json` (gated by `tools/bench_gate.rs`).

#[path = "common.rs"]
mod common;

use std::time::Instant;

use nnscope::client::Trace;
use nnscope::engine::{ContinuousBatch, KvStream, NativeModel};
use nnscope::graph::InterventionGraph;
use nnscope::json::Json;
use nnscope::models::NoHooks;
use nnscope::runtime::artifacts::Manifest;
use nnscope::tensor::Tensor;
use nnscope::util::table::Table;

/// A realistic co-tenant probe: step-hook the last layer's mean, so every
/// step re-enters a real intervention graph (executor build + hook + save
/// are all on the measured path, for both the batched and solo sides).
fn probe_graph(m: &NativeModel, seed: usize, prompt_len: usize) -> InterventionGraph {
    let vocab = m.manifest().vocab;
    let prompt: Vec<f32> =
        (0..prompt_len).map(|j| ((seed * 13 + j * 7) % vocab) as f32).collect();
    let t = Tensor::new(&[1, prompt_len], prompt);
    let mut tr = Trace::new(&m.manifest().name, &t);
    let h = tr.output(&format!("layer.{}", m.manifest().n_layers - 1));
    let mean = tr.mean(h);
    tr.step_hook(mean);
    tr.into_graph()
}

fn main() {
    let quick = common::quick();
    // big enough that a decode step's matmuls dominate per-tick thread
    // overhead; small enough that the full sweep stays in CI budget
    let m = NativeModel::new(Manifest::synthetic("decode-bench", 128, 4, 8, 512, 251, 320));
    let long_steps = if quick { 96 } else { 256 };
    let batch_steps = if quick { 32 } else { 96 };
    let streams = 8usize;
    common::section(&format!(
        "Decode engine — KV cache + continuous batching (d=128, 4 layers, \
         {streams} streams × {batch_steps} steps, long decode {long_steps} steps)"
    ));

    // 1. cached step vs full-prefix recompute at the same position -------
    let pos = 128usize; // cache length at which both sides are measured
    let reps = common::samples(8).max(2);
    let prompt: Vec<usize> = (0..pos).map(|i| (i * 11 + 5) % 251).collect();
    let mut cache = m.kv_cache();
    m.prefill(&prompt, &mut cache, &mut NoHooks).expect("prefill");
    let mut last = 3usize;
    let t0 = Instant::now();
    for _ in 0..reps {
        let logits = m.decode_step(last, &mut cache, &mut NoHooks).expect("decode");
        // data-dependent next token, so the loop cannot be hoisted
        last = (std::hint::black_box(logits.data()[0]).abs() as usize) % 251;
    }
    let t_step = t0.elapsed().as_secs_f64() / reps as f64;
    let t0 = Instant::now();
    for r in 0..reps {
        let mut fresh = m.kv_cache();
        let mut toks = prompt.clone();
        toks.push((r * 3) % 251); // the position the cached side decodes
        m.prefill(&toks, &mut fresh, &mut NoHooks).expect("recompute");
    }
    let t_full = t0.elapsed().as_secs_f64() / reps as f64;
    let kv_step_speedup = t_full / t_step.max(1e-12);

    // 2. per-step latency flatness over a long decode --------------------
    let mut s = KvStream::new(probe_graph(&m, 0, 24), &m, long_steps).expect("stream");
    let mut per_step = Vec::with_capacity(long_steps);
    while !s.finished() {
        let t = Instant::now();
        s.step(&m).expect("step");
        per_step.push(t.elapsed().as_secs_f64());
    }
    // drop step 0: that is the prefill pass, not a decode step
    let decode_steps = &per_step[1..];
    let q = decode_steps.len() / 4;
    let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len() as f64;
    let early = mean(&decode_steps[..q]);
    let late = mean(&decode_steps[decode_steps.len() - q..]);
    let step_flatness = early / late.max(1e-12);

    // 3. continuous batching: 8 concurrent streams vs back-to-back -------
    let t0 = Instant::now();
    for i in 0..streams {
        let mut s = KvStream::new(probe_graph(&m, i, 24), &m, batch_steps).expect("solo");
        while s.step(&m).expect("solo step").is_some() {}
    }
    let t_seq = t0.elapsed().as_secs_f64();

    let mut batch = ContinuousBatch::new();
    for i in 0..streams {
        batch.admit(i, KvStream::new(probe_graph(&m, i, 24), &m, batch_steps).expect("admit"));
    }
    let mut emitted = 0usize;
    let t0 = Instant::now();
    batch
        .run(true, |s: &mut KvStream| s.step(&m), &mut |_, _| emitted += 1)
        .expect("batched run");
    let t_batch = t0.elapsed().as_secs_f64();
    assert_eq!(emitted, streams * batch_steps);
    let batch_speedup = t_seq / t_batch.max(1e-12);
    let tokens_per_s_8 = emitted as f64 / t_batch.max(1e-12);

    let mut table = Table::new("decode engine").header(vec!["metric", "value"]);
    table.row(vec![
        format!("decode step @ cache {pos} (ms)"),
        format!("{:.4}", t_step * 1e3),
    ]);
    table.row(vec![
        format!("full recompute @ {pos} rows (ms)"),
        format!("{:.4}", t_full * 1e3),
    ]);
    table.row(vec!["kv_step_speedup".to_string(), format!("{kv_step_speedup:.2}x")]);
    table.row(vec![
        "step flatness (early/late quartile)".to_string(),
        format!("{step_flatness:.3}"),
    ]);
    table.row(vec![
        format!("{streams} streams back-to-back (s)"),
        format!("{t_seq:.4}"),
    ]);
    table.row(vec![
        format!("{streams} streams batched (s)"),
        format!("{t_batch:.4}"),
    ]);
    table.row(vec!["batch_speedup_8x".to_string(), format!("{batch_speedup:.2}x")]);
    table.row(vec!["tokens_per_s_8".to_string(), format!("{tokens_per_s_8:.0}")]);
    table.print();
    common::shape_note(&format!(
        "a cached step does {kv_step_speedup:.0}x less work than recomputing its prefix; \
         batching 8 streams yields {batch_speedup:.2}x the aggregate tokens/s of \
         running them back-to-back"
    ));

    // structural bars (the calibrated ones live in the bench gate):
    // caching must beat recompute decisively, and per-step cost must not
    // degrade with generated length the way a cache-less engine does
    assert!(
        kv_step_speedup > 2.0,
        "cached decode step must beat full recompute ({kv_step_speedup:.2}x)"
    );
    assert!(
        step_flatness > 0.3,
        "per-step cost degraded with generated length ({step_flatness:.3})"
    );
    assert!(
        batch_speedup > 1.0,
        "continuous batching must beat back-to-back execution ({batch_speedup:.2}x)"
    );

    let json = Json::obj(vec![
        ("bench", Json::from("decode")),
        ("quick", Json::Bool(quick)),
        ("d_model", Json::from(128usize)),
        ("n_layers", Json::from(4usize)),
        ("streams", Json::from(streams)),
        ("batch_steps", Json::from(batch_steps)),
        ("long_steps", Json::from(long_steps)),
        ("cache_pos", Json::from(pos)),
        ("decode_step_ms", Json::from(t_step * 1e3)),
        ("full_recompute_ms", Json::from(t_full * 1e3)),
        ("kv_step_speedup", Json::from(kv_step_speedup)),
        ("step_flatness", Json::from(step_flatness)),
        ("seq_8_streams_s", Json::from(t_seq)),
        ("batch_8_streams_s", Json::from(t_batch)),
        ("batch_speedup_8x", Json::from(batch_speedup)),
        ("tokens_per_s_8", Json::from(tokens_per_s_8)),
    ]);
    std::fs::write("BENCH_decode.json", json.pretty()).expect("write BENCH_decode.json");
    println!("\nwrote BENCH_decode.json");
}
