//! Fault-injection goodput: client-visible throughput of a fleet that is
//! actively being abused — probabilistic dispatch faults the whole run and
//! a replica crash a quarter of the way in.
//!
//! Every client runs under the unified `RetryPolicy` (backoff + jitter +
//! Retry-After honoring), so the number measured here is *goodput*: requests
//! that completed successfully end-to-end despite the chaos, per second of
//! wall clock. The chaos schedule is deterministic — the dispatch failpoint
//! draws from a seeded stream and the crash triggers at a fixed completion
//! fraction — so a regression in this number means the fault-tolerance
//! machinery (failover bookkeeping, retry policy, health hysteresis) got
//! slower or lossier, not that the dice rolled differently.
//!
//! Emits `BENCH_faults.json` (gated by `tools/bench_gate.rs`).

#[path = "common.rs"]
mod common;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use nnscope::client::{remote::NdifClient, RetryPolicy, Trace};
use nnscope::coordinator::{Coordinator, CoordinatorConfig, Policy};
use nnscope::json::Json;
use nnscope::server::{NdifConfig, NdifServer};
use nnscope::tensor::Tensor;
use nnscope::util::failpoint::{self, FailAction, Spec};
use nnscope::util::table::Table;

fn main() {
    let model = "tiny-sim";
    let (n_users, reqs_per_user) = if common::quick() { (4usize, 8usize) } else { (8, 25) };
    let total = (n_users * reqs_per_user) as u64;
    common::section(&format!(
        "Faults — goodput under chaos ({model}, {n_users} users × {reqs_per_user} reqs, \
         5% dispatch faults, 1 of 2 replicas crashes at 25%)"
    ));

    let mut coord_cfg = CoordinatorConfig::local();
    coord_cfg.policy = Policy::LeastLoaded;
    coord_cfg.probe_interval = Duration::from_millis(50);
    coord_cfg.health.degraded_after = Duration::from_millis(400);
    coord_cfg.health.dead_after = Duration::from_secs(2);
    let mut coord = Coordinator::start(coord_cfg).expect("coordinator");

    let mk_replica = || {
        let mut cfg = NdifConfig::local(&[model]);
        cfg.coordinator = Some(coord.addr().to_string());
        cfg.heartbeat = Duration::from_millis(50);
        NdifServer::start(cfg).expect("replica")
    };
    let victim = mk_replica();
    let mut survivor = mk_replica();
    let addr = coord.addr();

    // warm both replicas before the clock starts
    for i in 0..2 {
        let client = NdifClient::new(addr);
        let mut tr = Trace::new(model, &Tensor::new(&[1, 16], vec![i as f32; 16]));
        let h = tr.output("layer.0");
        tr.save(h);
        tr.run_remote(&client).expect("warmup");
    }

    // deterministic chaos: 5% of dispatches fault for the whole run
    failpoint::arm(
        "coord.dispatch",
        Spec::prob(0.05, 0xFA17, FailAction::Error("injected dispatch fault".into())),
    );

    let done = Arc::new(AtomicU64::new(0));
    let succeeded = Arc::new(AtomicU64::new(0));

    // crash one replica once a quarter of the workload has completed
    let killer = {
        let done = Arc::clone(&done);
        let mut victim = victim;
        std::thread::spawn(move || {
            while done.load(Ordering::Relaxed) < total / 4 {
                std::thread::sleep(Duration::from_millis(5));
            }
            let t = Instant::now();
            victim.kill();
            t
        })
    };

    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_users)
        .map(|u| {
            let done = Arc::clone(&done);
            let succeeded = Arc::clone(&succeeded);
            std::thread::spawn(move || {
                let client = NdifClient::new(addr);
                let policy = RetryPolicy::new(
                    8,
                    Duration::from_millis(20),
                    Duration::from_secs(1),
                    Duration::from_secs(20),
                    0xC0FFEE + u as u64,
                );
                for i in 0..reqs_per_user {
                    let mut tr =
                        Trace::new(model, &Tensor::new(&[1, 16], vec![(u * 100 + i) as f32; 16]));
                    let h = tr.output("layer.0");
                    tr.save(h);
                    let g = tr.into_graph();
                    let opts = nnscope::client::ExecuteOptions::new().retry(policy.clone());
                    if client.run(&g, opts).is_ok() {
                        succeeded.fetch_add(1, Ordering::Relaxed);
                    }
                    done.fetch_add(1, Ordering::Relaxed);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let kill_at = killer.join().unwrap().duration_since(t0).as_secs_f64();
    failpoint::reset();

    let ok = succeeded.load(Ordering::Relaxed);
    let goodput = ok as f64 / wall;
    let success_rate = ok as f64 / total as f64;
    let injected = failpoint::fired("coord.dispatch");

    let mut table = Table::new("goodput under chaos").header(vec![
        "requests", "succeeded", "wall (s)", "goodput (req/s)", "success rate", "crash at (s)",
    ]);
    table.row(vec![
        format!("{total}"),
        format!("{ok}"),
        format!("{wall:.3}"),
        format!("{goodput:.2}"),
        format!("{success_rate:.3}"),
        format!("{kill_at:.3}"),
    ]);
    table.print();
    common::shape_note(&format!(
        "{ok}/{total} requests survived a replica crash plus {injected} injected dispatch \
         faults — {goodput:.2} req/s goodput"
    ));

    survivor.shutdown();
    coord.shutdown();

    let json = Json::obj(vec![
        ("bench", Json::from("faults")),
        ("quick", Json::Bool(common::quick())),
        ("model", Json::from(model)),
        ("requests", Json::from(total as i64)),
        ("succeeded", Json::from(ok as i64)),
        ("injected_dispatch_faults", Json::from(injected as i64)),
        ("crash_at_s", Json::from(kill_at)),
        ("wall_s", Json::from(wall)),
        ("goodput_rps", Json::from(goodput)),
        ("success_rate", Json::from(success_rate)),
    ]);
    std::fs::write("BENCH_faults.json", json.pretty()).expect("write BENCH_faults.json");
    println!("\nwrote BENCH_faults.json");
}
