//! Intervention-graph compiler payoff: optimized vs `--no-opt` execution
//! (ISSUE 5 acceptance bench).
//!
//! Two workloads, both realistic compiler fodder:
//!
//! * **all-layers logit-lens stream** — a streaming generation whose
//!   graph reads every layer, decodes each hidden state through a
//!   `Const` projection chain, and step-hooks the result. Unoptimized,
//!   the `Const`-only chain re-evaluates at EVERY decode step and the
//!   speculative dead getters force extra hook work; the compiler folds
//!   the chain once at admission, eliminates the dead reads, hash-conses
//!   the duplicate getters, and fuses the softmax-of-scale lens.
//! * **CSE-heavy co-tenant burst** — a merged forward pass of graphs
//!   that each repeat an identical probe chain; the compiler collapses
//!   the duplicates so the shared forward carries one evaluation per
//!   chain instead of many.
//!
//! The acceptance bar is the stream strictly faster optimized than
//! `--no-opt`. Emits `BENCH_graphopt.json` (gated by
//! `tools/bench_gate.rs` against `benches/baselines/`).

#[path = "common.rs"]
mod common;

use nnscope::client::Trace;
use nnscope::graph::{opt, InterventionGraph};
use nnscope::interp;
use nnscope::json::Json;
use nnscope::models::{artifacts_dir, ModelRunner};
use nnscope::scheduler::execute_merged;
use nnscope::tensor::Tensor;
use nnscope::util::table::Table;

/// The all-layers logit-lens stream graph: per-layer lens through a
/// const projection chain, plus duplicate and speculative reads.
fn lens_stream_trace(runner: &ModelRunner) -> Trace {
    let m = &runner.manifest;
    let tokens = Tensor::new(
        &[1, m.seq],
        (0..m.seq).map(|i| ((i * 7 + 3) % m.vocab) as f32).collect(),
    );
    let mut tr = Trace::new(&m.name, &tokens);
    // a Const-only projection chain: chained 128×128 matmuls, sliced down
    // to d_model×d_model at the end. Unoptimized this re-evaluates at
    // EVERY decode step; the compiler folds it to one literal at
    // admission, so the stream pays it once per request.
    let d = m.d_model;
    let big = 128usize;
    let mut chain = tr.constant(&Tensor::new(
        &[big, big],
        (0..big * big).map(|i| ((i % 13) as f32 - 6.0) * 0.01).collect(),
    ));
    for k in 0..6 {
        let w = tr.constant(&Tensor::new(
            &[big, big],
            (0..big * big).map(|i| (((i + k) % 11) as f32 - 5.0) * 0.01).collect(),
        ));
        chain = tr.matmul(chain, w);
    }
    let proj = tr.slice(
        chain,
        &[nnscope::tensor::Range1::new(0, d), nnscope::tensor::Range1::new(0, d)],
    );
    for layer in 0..m.n_layers {
        let point = format!("layer.{layer}");
        let h = tr.output(&point);
        let h_dup = tr.output(&point); // duplicate read: CSE
        let _speculative = tr.output(&point); // dead read: DCE
        let flat = tr.reshape(h, &[m.seq, d]);
        let lensed = tr.matmul(flat, proj);
        let sc = tr.scale(lensed, 1.7);
        let sm = tr.softmax(sc); // Softmax-of-Scale: fused
        let mn = tr.mean(sm);
        tr.step_hook(mn);
        let mn2 = tr.mean(h_dup);
        tr.step_hook(mn2);
    }
    tr
}

fn time_stream(
    graph: &InterventionGraph,
    runner: &ModelRunner,
    steps: usize,
    optimize: bool,
) -> f64 {
    let t0 = std::time::Instant::now();
    let mut events = 0usize;
    let mut sink = |_: usize, _: interp::StepOutcome| {
        events += 1;
        true
    };
    let spec = if optimize {
        nnscope::engine::ExecSpec::trace(graph)
    } else {
        nnscope::engine::ExecSpec::raw(graph)
    };
    nnscope::engine::Engine::new(runner)
        .run_streaming(spec.stream(steps), &mut sink)
        .unwrap();
    assert_eq!(events, steps);
    t0.elapsed().as_secs_f64()
}

/// One CSE-heavy co-tenant graph: `k` copies of an identical probe chain
/// (read → project through a wide const → softmax → mean), which the
/// compiler hash-conses down to a single evaluation.
fn cotenant_graph(runner: &ModelRunner, k: usize, seed: usize) -> InterventionGraph {
    let m = &runner.manifest;
    let tokens = Tensor::new(
        &[1, m.seq],
        (0..m.seq).map(|i| ((i * 3 + seed) % m.vocab) as f32).collect(),
    );
    let mut tr = Trace::new(&m.name, &tokens);
    let (d, wide) = (m.d_model, 128usize);
    let w = tr.constant(&Tensor::new(
        &[d, wide],
        (0..d * wide).map(|i| ((i % 17) as f32 - 8.0) * 0.02).collect(),
    ));
    for _ in 0..k {
        let h = tr.output("layer.0");
        let flat = tr.reshape(h, &[m.seq, d]);
        let pr = tr.matmul(flat, w);
        let sc = tr.scale(pr, 2.0);
        let sm = tr.softmax(sc);
        let mn = tr.mean(sm);
        tr.save(mn);
    }
    tr.into_graph()
}

fn main() {
    let quick = common::quick();
    let model = "tiny-sim";
    let runner = ModelRunner::load(&artifacts_dir(), model).unwrap();
    let steps = if quick { 24 } else { 96 };
    let reps = if quick { 3 } else { 7 };

    // ---- workload 1: all-layers logit-lens stream -------------------------
    common::section(&format!(
        "Graph compiler — all-layers logit-lens stream, {steps} steps ({model})"
    ));
    let graph = lens_stream_trace(&runner).into_graph();
    let fseq = runner.manifest.forward_sequence();
    let report = opt::optimize(&graph, &fseq).unwrap().report;

    // warmup one short run each, then alternate measurements
    time_stream(&graph, &runner, 2, false);
    time_stream(&graph, &runner, 2, true);
    let mut noopt = Vec::with_capacity(reps);
    let mut opted = Vec::with_capacity(reps);
    for _ in 0..reps {
        noopt.push(time_stream(&graph, &runner, steps, false));
        opted.push(time_stream(&graph, &runner, steps, true));
    }
    let stream_noopt = nnscope::util::stats::Summary::of(&noopt).median;
    let stream_opt = nnscope::util::stats::Summary::of(&opted).median;
    let stream_speedup = stream_noopt / stream_opt.max(1e-12);

    let mut table = Table::new("stream: optimized vs --no-opt").header(vec![
        "path", "median wall (s)", "graph nodes",
    ]);
    table.row(vec![
        "--no-opt".to_string(),
        format!("{stream_noopt:.4}"),
        format!("{}", report.nodes_before),
    ]);
    table.row(vec![
        "optimized".to_string(),
        format!("{stream_opt:.4}"),
        format!("{}", report.nodes_after),
    ]);
    table.print();
    common::shape_note(&format!(
        "{} → {} nodes (dce {}, folded {}, cse {}, fused {}): {stream_speedup:.2}x faster \
         (acceptance bar: optimized strictly faster)",
        report.nodes_before,
        report.nodes_after,
        report.dce_removed,
        report.folded,
        report.cse_merged,
        report.fused
    ));
    assert!(
        stream_opt < stream_noopt,
        "optimized stream ({stream_opt:.4}s) must beat --no-opt ({stream_noopt:.4}s)"
    );

    // ---- workload 2: CSE-heavy co-tenant burst ----------------------------
    common::section("Graph compiler — CSE-heavy co-tenant merged burst");
    let chains = 8;
    let graphs: Vec<InterventionGraph> =
        (0..4).map(|i| cotenant_graph(&runner, chains, i)).collect();
    let optimized: Vec<opt::Optimized> = graphs
        .iter()
        .map(|g| opt::optimize(g, &fseq).unwrap())
        .collect();
    let opt_graphs: Vec<InterventionGraph> =
        optimized.iter().map(|o| o.graph.clone()).collect();
    let burst_reps = if quick { 6 } else { 20 };
    let run_burst = |gs: &[InterventionGraph]| {
        let t0 = std::time::Instant::now();
        for _ in 0..burst_reps {
            let results = execute_merged(gs, &runner).unwrap();
            assert!(results.iter().all(|r| r.is_ok()));
        }
        t0.elapsed().as_secs_f64() / burst_reps as f64
    };
    run_burst(&graphs); // warmup
    let cot_noopt = run_burst(&graphs);
    let cot_opt = run_burst(&opt_graphs);
    let cotenant_speedup = cot_noopt / cot_opt.max(1e-12);
    let creport = &optimized[0].report;
    let mut table = Table::new("co-tenant burst: optimized vs raw merge").header(vec![
        "path", "wall per merge (s)", "nodes per graph",
    ]);
    table.row(vec![
        "raw".to_string(),
        format!("{cot_noopt:.5}"),
        format!("{}", creport.nodes_before),
    ]);
    table.row(vec![
        "optimized".to_string(),
        format!("{cot_opt:.5}"),
        format!("{}", creport.nodes_after),
    ]);
    table.print();
    common::shape_note(&format!(
        "{chains} duplicate probe chains per co-tenant hash-consed to one: \
         {cotenant_speedup:.2}x faster merged execution"
    ));

    let json = Json::obj(vec![
        ("bench", Json::from("graphopt")),
        ("quick", Json::Bool(quick)),
        ("model", Json::from(model)),
        ("steps", Json::from(steps)),
        ("stream_wall_noopt_s", Json::from(stream_noopt)),
        ("stream_wall_opt_s", Json::from(stream_opt)),
        ("stream_speedup_opt", Json::from(stream_speedup)),
        ("stream_nodes_before", Json::from(report.nodes_before)),
        ("stream_nodes_after", Json::from(report.nodes_after)),
        ("cotenant_wall_noopt_s", Json::from(cot_noopt)),
        ("cotenant_wall_opt_s", Json::from(cot_opt)),
        ("cotenant_speedup_opt", Json::from(cotenant_speedup)),
    ]);
    std::fs::write("BENCH_graphopt.json", json.pretty()).expect("write BENCH_graphopt.json");
    println!("\nwrote BENCH_graphopt.json");
}
