//! Time-to-first-token vs full-generation latency over the paper WAN
//! (ISSUE 4 acceptance bench).
//!
//! Workload: a streaming generation (`POST /v1/stream`) whose graph
//! step-hooks a layer's hidden state — every decode step ships a real
//! tensor payload, like an interactive probing client. The WAN link is
//! [`NetSim::paper_wan`] in `Mode::Sleep`, so wallclock includes the
//! simulated 10 ms / 60 MB/s link: the request and the first event each
//! pay propagation latency, later events ride the open chunked pipeline
//! and pay bandwidth only.
//!
//! Two numbers per run:
//! * **time-to-first-token** — when the first `StepEvent` lands (what an
//!   interactive client waits before it can render anything);
//! * **full-generation latency** — when the `done` event lands (what a
//!   blocking whole-request client waits for the same work).
//!
//! The acceptance bar is TTFT strictly below the full-generation round
//! trip. Emits `BENCH_streaming.json` (gated by `tools/bench_gate.rs`).

#[path = "common.rs"]
mod common;

use nnscope::client::remote::{NdifClient, StreamEvent};
use nnscope::client::Trace;
use nnscope::json::Json;
use nnscope::netsim::{Mode, NetSim};
use nnscope::runtime::Manifest;
use nnscope::scheduler::CoTenancy;
use nnscope::server::{NdifConfig, NdifServer};
use nnscope::tensor::Tensor;
use nnscope::util::table::Table;

fn main() {
    let quick = common::quick();
    let model = "tiny-sim";
    let steps = if quick { 48 } else { 128 };

    let manifest = Manifest::load(&nnscope::models::artifacts_dir(), model).unwrap();
    common::section(&format!(
        "Streaming — time-to-first-token vs full generation, {steps} steps \
         (paper WAN: 10 ms / 60 MB/s, {model})"
    ));

    let cfg = NdifConfig { cotenancy: CoTenancy::Sequential, ..NdifConfig::local(&[model]) };
    let server = NdifServer::start(cfg).expect("server");
    let link = NetSim::paper_wan(Mode::Sleep);
    let client = NdifClient::new(server.addr()).with_link(link.clone());

    let tokens = Tensor::new(
        &[1, manifest.seq],
        (0..manifest.seq)
            .map(|i| ((i * 7 + 3) % manifest.vocab) as f32)
            .collect(),
    );
    // step-hook a whole hidden state so each event carries a real payload
    let mut tr = Trace::new(model, &tokens);
    let h = tr.output("layer.0");
    tr.step_hook(h);

    let t0 = std::time::Instant::now();
    let mut ttft_wall = None;
    let mut ttft_sim = None;
    let mut events = 0usize;
    let mut generated = 0usize;
    for item in tr.run_stream(&client, steps).expect("open stream") {
        match item.expect("stream event") {
            StreamEvent::Step { .. } => {
                if ttft_wall.is_none() {
                    ttft_wall = Some(t0.elapsed().as_secs_f64());
                    ttft_sim = Some(link.seconds_charged());
                }
                events += 1;
            }
            StreamEvent::Done { tokens, .. } => generated = tokens.len(),
        }
    }
    let full_wall = t0.elapsed().as_secs_f64();
    let full_sim = link.seconds_charged();
    let ttft_wall = ttft_wall.expect("no step event");
    let ttft_sim = ttft_sim.expect("no step event");
    assert_eq!(events, steps);
    assert_eq!(generated, steps);

    let stream_speedup = full_wall / ttft_wall.max(1e-12);
    let tokens_per_s = steps as f64 / full_wall.max(1e-12);

    let mut table = Table::new("first token vs full generation").header(vec![
        "milestone",
        "wall (s)",
        "simulated WAN share (s)",
    ]);
    table.row(vec![
        "first StepEvent".to_string(),
        format!("{ttft_wall:.4}"),
        format!("{ttft_sim:.4}"),
    ]);
    table.row(vec![
        format!("done ({steps} tokens)"),
        format!("{full_wall:.4}"),
        format!("{full_sim:.4}"),
    ]);
    table.print();
    common::shape_note(&format!(
        "first token after {:.0} ms; a blocking client waits {:.0} ms — {stream_speedup:.2}x \
         longer (acceptance bar: TTFT strictly below full-generation latency)",
        ttft_wall * 1e3,
        full_wall * 1e3
    ));
    assert!(
        ttft_wall < full_wall,
        "time-to-first-token must beat the full-generation round trip"
    );
    assert!(ttft_sim <= full_sim);

    let json = Json::obj(vec![
        ("bench", Json::from("streaming")),
        ("quick", Json::Bool(quick)),
        ("model", Json::from(model)),
        ("steps", Json::from(steps)),
        ("wan_latency_s", Json::from(0.010)),
        ("wan_bandwidth_bps", Json::from(60.0e6)),
        ("ttft_wall_s", Json::from(ttft_wall)),
        ("full_wall_s", Json::from(full_wall)),
        ("ttft_simulated_wan_s", Json::from(ttft_sim)),
        ("full_simulated_wan_s", Json::from(full_sim)),
        ("stream_speedup", Json::from(stream_speedup)),
        ("tokens_per_s", Json::from(tokens_per_s)),
    ]);
    std::fs::write("BENCH_streaming.json", json.pretty()).expect("write BENCH_streaming.json");
    println!("\nwrote BENCH_streaming.json");
}
