//! Observability overhead: throughput with the metrics/tracing layer on
//! versus off, same workload, same process.
//!
//! The obs subsystem is designed to be lock-free on the hot path (atomic
//! histogram adds, a fixed-size span vector moved with the job), so the
//! instrumented server should stay within a few percent of the stripped
//! one. Two servers run side by side — one with `obs: true`, one with
//! `obs: false` — and the closed-loop Fig. 9 workload alternates between
//! them in rounds so cache/thermal drift is charged to both equally.
//!
//! Emits `BENCH_obs.json` with the on/off throughput ratio (higher is
//! better, 1.0 = free observability), gated by `tools/bench_gate.rs`.
//! Note: `NNSCOPE_OBS=off` in the environment force-disables obs globally
//! and collapses the comparison to ~1.0 — leave it unset for a real
//! measurement.

#[path = "common.rs"]
mod common;

use std::time::Instant;

use nnscope::client::{remote::NdifClient, Trace};
use nnscope::json::Json;
use nnscope::models::{artifacts_dir, workload};
use nnscope::runtime::Manifest;
use nnscope::server::{NdifConfig, NdifServer};
use nnscope::tensor::Tensor;
use nnscope::util::table::Table;
use nnscope::util::Prng;

/// Drive `users × reqs` closed-loop requests at `addr`; returns wall seconds.
fn drive(
    addr: std::net::SocketAddr,
    model: &str,
    m: &Manifest,
    users: usize,
    reqs: usize,
    seed: u64,
) -> f64 {
    let t0 = Instant::now();
    let handles: Vec<_> = (0..users)
        .map(|u| {
            let model = model.to_string();
            let (vocab, seq, layers) = (m.vocab, m.seq, m.n_layers);
            std::thread::spawn(move || {
                let client = NdifClient::new(addr);
                let mut rng = Prng::new(seed * 1000 + u as u64);
                for _ in 0..reqs {
                    let req = workload::load_test_request(&mut rng, vocab, seq, layers);
                    let tokens = Tensor::new(&[1, seq], req.tokens.clone());
                    let mut tr = Trace::new(&model, &tokens);
                    let h = tr.output(&format!("layer.{}", req.layer));
                    tr.save(h);
                    tr.run_remote(&client).expect("request");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    t0.elapsed().as_secs_f64()
}

fn main() {
    let model = "tiny-sim";
    let users = if common::quick() { 4 } else { 8 };
    let reqs = common::samples(8);
    let rounds = if common::quick() { 2 } else { 4 };

    let manifest = Manifest::load(&artifacts_dir(), model).unwrap();
    common::section(&format!(
        "Observability overhead — {model}, {users} users × {reqs} reqs × {rounds} rounds, obs on vs off"
    ));

    let on = NdifServer::start(NdifConfig::local(&[model])).expect("obs-on server");
    let mut cfg = NdifConfig::local(&[model]);
    cfg.obs = false;
    let off = NdifServer::start(cfg).expect("obs-off server");

    // one warmup pass each (lazy first-run init must not bill either side)
    drive(on.addr(), model, &manifest, users, 1, 7);
    drive(off.addr(), model, &manifest, users, 1, 7);

    let (mut wall_on, mut wall_off) = (0.0f64, 0.0f64);
    for round in 0..rounds {
        wall_on += drive(on.addr(), model, &manifest, users, reqs, round as u64);
        wall_off += drive(off.addr(), model, &manifest, users, reqs, round as u64);
    }
    let total = (rounds * users * reqs) as f64;
    let (tp_on, tp_off) = (total / wall_on, total / wall_off);
    let ratio = tp_on / tp_off;

    let mut table = Table::new("throughput, instrumented vs stripped").header(vec![
        "config", "wall (s)", "req/s",
    ]);
    table.row(vec!["obs on".into(), format!("{wall_on:.3}"), format!("{tp_on:.2}")]);
    table.row(vec!["obs off".into(), format!("{wall_off:.3}"), format!("{tp_off:.2}")]);
    table.print();
    common::shape_note(&format!(
        "on/off throughput ratio {ratio:.3} (1.0 = free; target ≥ 0.95, i.e. ≤5% overhead)"
    ));
    if std::env::var("NNSCOPE_OBS").is_ok() {
        common::shape_note("NNSCOPE_OBS is set — the comparison may be degenerate");
    }

    let json = Json::obj(vec![
        ("bench", Json::from("obs")),
        ("quick", Json::Bool(common::quick())),
        ("model", Json::from(model)),
        ("throughput_on_rps", Json::from(tp_on)),
        ("throughput_off_rps", Json::from(tp_off)),
        ("obs_ratio_on_off", Json::from(ratio)),
    ]);
    std::fs::write("BENCH_obs.json", json.pretty()).expect("write BENCH_obs.json");
    println!("\nwrote BENCH_obs.json");
}
