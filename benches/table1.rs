//! Table 1: framework comparison — setup time and activation-patching
//! runtime for baukit / pyvene / TransformerLens / NNsight mechanisms on
//! the GPT2-XL / Gemma-7B / Llama-3.1-8B simulated configs.
//!
//! Paper's finding to reproduce: all frameworks patch at statistically
//! comparable speed; TransformerLens pays ≈3× setup for its weight-format
//! standardization pass. Absolute numbers differ (simulated models, CPU
//! testbed); the *shape* is the claim.

#[path = "common.rs"]
mod common;

use nnscope::baselines::hooks::{BaukitLike, NnsightLocal, PyveneLike};
use nnscope::baselines::tlens::TlensLike;
use nnscope::baselines::Framework;
use nnscope::models::workload::IoiBatch;
use nnscope::models::{artifacts_dir, ModelWeights};
use nnscope::runtime::Manifest;
use nnscope::util::table::Table;

fn bench_framework<F: Framework>(
    model: &str,
    n_setup: usize,
    n_patch: usize,
) -> (nnscope::util::Summary, nnscope::util::Summary) {
    let dir = artifacts_dir();
    let setup = common::bench(0, n_setup, |_| {
        let f = F::setup(&dir, model).expect("setup");
        std::hint::black_box(&f);
    });
    let m = Manifest::load(&dir, model).unwrap();
    let batch = IoiBatch::generate(16, m.vocab, m.seq, 1); // 16 pairs = 32 rows
    let fw = F::setup(&dir, model).expect("setup");
    let layer = m.n_layers / 2;
    let patch = common::bench(1, n_patch, |_| {
        let ld = fw.activation_patch(&batch, layer).expect("patch");
        std::hint::black_box(&ld);
    });
    (setup, patch)
}

fn main() {
    let models = if common::quick() {
        vec!["tiny-sim"]
    } else {
        vec!["gpt2xl-sim", "gemma7b-sim", "llama8b-sim"]
    };
    let n_setup = common::samples(3);
    let n_patch = common::samples(8);

    // make sure weight files exist (not part of the timed setup variance)
    for m in &models {
        let manifest = Manifest::load(&artifacts_dir(), m).unwrap();
        ModelWeights::ensure_on_disk(&manifest).unwrap();
    }

    common::section(&format!(
        "Table 1 — framework setup + activation patching (n_setup={n_setup}, n_patch={n_patch})"
    ));
    let mut setup_table = Table::new("Setup Time (s)").header({
        let mut h = vec!["Framework".to_string()];
        h.extend(models.iter().map(|m| m.to_string()));
        h
    });
    let mut patch_table = Table::new("Activation Patching (s)").header({
        let mut h = vec!["Framework".to_string()];
        h.extend(models.iter().map(|m| m.to_string()));
        h
    });

    let mut tl_ratio = Vec::new();
    for fw in ["baukit", "pyvene", "tlens", "nnsight"] {
        let mut setup_row = vec![fw.to_string()];
        let mut patch_row = vec![fw.to_string()];
        for model in &models {
            let (s, p) = match fw {
                "baukit" => bench_framework::<BaukitLike>(model, n_setup, n_patch),
                "pyvene" => bench_framework::<PyveneLike>(model, n_setup, n_patch),
                "tlens" => bench_framework::<TlensLike>(model, n_setup, n_patch),
                _ => bench_framework::<NnsightLocal>(model, n_setup, n_patch),
            };
            if fw == "tlens" {
                tl_ratio.push(s.mean);
            } else if fw == "baukit" {
                tl_ratio.push(-s.mean); // negative marks the baseline entries
            }
            setup_row.push(s.pm());
            patch_row.push(p.pm());
        }
        setup_table.row(setup_row);
        patch_table.row(patch_row);
    }
    setup_table.print();
    patch_table.print();

    // shape check: tlens setup vs baukit setup per model
    let baselines: Vec<f64> = tl_ratio.iter().filter(|v| **v < 0.0).map(|v| -v).collect();
    let tls: Vec<f64> = tl_ratio.iter().filter(|v| **v > 0.0).copied().collect();
    for (i, model) in models.iter().enumerate() {
        if i < baselines.len() && i < tls.len() {
            common::shape_note(&format!(
                "{model}: tlens setup / baukit setup = {:.2}x (paper: ~3x from weight preprocessing)",
                tls[i] / baselines[i]
            ));
        }
    }
    common::shape_note(
        "patching columns should be statistically comparable across frameworks (paper Table 1)",
    );

    // Decomposed setup: at simulated scale, XLA compilation (paid equally
    // by every framework) dominates total setup, compressing the tlens
    // ratio. Isolate the paper's effect: weight load vs load+standardize.
    println!();
    let mut decomp = Table::new("Setup decomposition (s): load vs load+standardize").header(vec![
        "Model", "load (all fw)", "load+standardize (tlens)", "ratio",
    ]);
    for model in &models {
        let manifest = Manifest::load(&artifacts_dir(), model).unwrap();
        let wpath = manifest.dir.join("weights.bin");
        let load = common::bench(1, n_patch, |_| {
            std::hint::black_box(ModelWeights::load(&wpath, model).unwrap());
        });
        let loadstd = common::bench(1, n_patch, |_| {
            let w = ModelWeights::load(&wpath, model).unwrap();
            std::hint::black_box(nnscope::baselines::tlens::standardize(&w, manifest.n_layers));
        });
        decomp.row(vec![
            model.to_string(),
            load.pm(),
            loadstd.pm(),
            format!("{:.2}x", loadstd.mean / load.mean),
        ]);
    }
    decomp.print();
    common::shape_note("paper: TL pays ~3x setup for weight-format conversion; the ratio above isolates that cost from compilation");
}
