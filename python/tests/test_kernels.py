"""L1 correctness: Pallas kernels vs the pure-jnp oracle (`ref.py`).

This is the core correctness signal for the compiled artifacts: every HLO
module the Rust runtime executes embeds these kernels, so kernel==oracle
plus oracle-level model tests imply artifact-level correctness.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import flash_attention, layernorm
from compile.kernels.ref import attention_ref, layernorm_ref

TOL = 2e-5


def randn(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,h,s,d", [(1, 1, 16, 8), (2, 3, 32, 16), (1, 8, 32, 32), (4, 2, 64, 16)])
def test_attention_matches_ref(b, h, s, d):
    rng = np.random.default_rng(b * 1000 + h * 100 + s + d)
    q, k, v = (randn(rng, b, h, s, d) for _ in range(3))
    out = flash_attention(q, k, v)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=TOL, rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    s=st.sampled_from([16, 32, 48, 64]),
    d=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 2**31),
)
def test_attention_hypothesis_sweep(b, h, s, d, seed):
    rng = np.random.default_rng(seed)
    q, k, v = (randn(rng, b, h, s, d) for _ in range(3))
    out = flash_attention(q, k, v)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=TOL, rtol=1e-4)


@pytest.mark.parametrize("bq,bk", [(8, 8), (16, 8), (8, 16), (32, 16), (16, 16)])
def test_attention_block_size_invariance(bq, bk):
    """The result must not depend on the tiling — a flash-attention invariant."""
    rng = np.random.default_rng(7)
    q, k, v = (randn(rng, 2, 2, 32, 16) for _ in range(3))
    base = flash_attention(q, k, v, block_q=32, block_k=32)
    tiled = flash_attention(q, k, v, block_q=bq, block_k=bk)
    np.testing.assert_allclose(np.asarray(tiled), np.asarray(base), atol=TOL, rtol=1e-4)


def test_attention_is_causal():
    """Changing future tokens must not change past outputs."""
    rng = np.random.default_rng(3)
    q, k, v = (randn(rng, 1, 2, 32, 16) for _ in range(3))
    out1 = np.asarray(flash_attention(q, k, v))
    k2 = k.at[:, :, 20:, :].set(99.0)
    v2 = v.at[:, :, 20:, :].set(-99.0)
    out2 = np.asarray(flash_attention(q, k2, v2))
    np.testing.assert_allclose(out1[:, :, :20], out2[:, :, :20], atol=TOL)
    assert np.abs(out1[:, :, 20:] - out2[:, :, 20:]).max() > 0.1


def test_attention_first_token_is_v0():
    """Token 0 attends only to itself: output row 0 == v[..,0,:]."""
    rng = np.random.default_rng(11)
    q, k, v = (randn(rng, 2, 2, 16, 8) for _ in range(3))
    out = np.asarray(flash_attention(q, k, v))
    np.testing.assert_allclose(out[:, :, 0, :], np.asarray(v)[:, :, 0, :], atol=TOL)


def test_attention_uniform_values():
    """If V is constant, attention output equals that constant."""
    rng = np.random.default_rng(5)
    q, k = (randn(rng, 1, 1, 16, 8) for _ in range(2))
    v = jnp.full((1, 1, 16, 8), 2.5, dtype=jnp.float32)
    out = np.asarray(flash_attention(q, k, v))
    np.testing.assert_allclose(out, 2.5, atol=TOL)


def test_attention_large_logits_stable():
    """Online softmax must survive large score magnitudes."""
    rng = np.random.default_rng(9)
    q = randn(rng, 1, 1, 16, 8) * 30.0
    k = randn(rng, 1, 1, 16, 8) * 30.0
    v = randn(rng, 1, 1, 16, 8)
    out = np.asarray(flash_attention(q, k, v))
    assert np.isfinite(out).all()
    ref = np.asarray(attention_ref(q, k, v))
    np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# LayerNorm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(4, 8), (2, 16, 32), (1, 32, 64), (3, 7, 48)])
def test_layernorm_matches_ref(shape):
    rng = np.random.default_rng(sum(shape))
    x = randn(rng, *shape)
    g = randn(rng, shape[-1])
    b = randn(rng, shape[-1])
    out = layernorm(x, g, b)
    ref = layernorm_ref(x, g, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=TOL, rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 70),
    d=st.sampled_from([8, 32, 64, 128]),
    seed=st.integers(0, 2**31),
)
def test_layernorm_hypothesis_sweep(rows, d, seed):
    """Row counts deliberately not multiples of the block to hit padding."""
    rng = np.random.default_rng(seed)
    x = randn(rng, rows, d)
    g = randn(rng, d)
    b = randn(rng, d)
    out = layernorm(x, g, b)
    ref = layernorm_ref(x, g, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=TOL, rtol=1e-4)


def test_layernorm_output_is_normalized():
    rng = np.random.default_rng(2)
    x = randn(rng, 8, 64) * 5.0 + 3.0
    ones = jnp.ones(64, jnp.float32)
    zeros = jnp.zeros(64, jnp.float32)
    y = np.asarray(layernorm(x, ones, zeros))
    np.testing.assert_allclose(y.mean(axis=-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(y.std(axis=-1), 1.0, atol=1e-3)


def test_layernorm_block_rows_invariance():
    rng = np.random.default_rng(4)
    x = randn(rng, 48, 32)
    g = randn(rng, 32)
    b = randn(rng, 32)
    a1 = np.asarray(layernorm(x, g, b, block_rows=4))
    a2 = np.asarray(layernorm(x, g, b, block_rows=16))
    a3 = np.asarray(layernorm(x, g, b, block_rows=48))
    np.testing.assert_allclose(a1, a2, atol=TOL)
    np.testing.assert_allclose(a2, a3, atol=TOL)
