"""Cross-language PRNG contract tests (mirror of rust/src/util/prng.rs)."""

import numpy as np

from compile.prng import Prng, fnv1a


def test_known_answers_match_rust():
    """Shared known-answer test — the same values are asserted in
    `util::prng::tests::cross_language_known_answers` on the Rust side."""
    p = Prng.from_name("xcheck")
    assert p.next_u64() == 0x1C801F4C48A0B4EC
    assert p.next_u64() == 0xA6B3EE2BB4A9612C
    assert p.next_u64() == 0x3FF86E8D2FEA04D6
    assert p.next_u64() == 0x09274F6ED2DBF80F


def test_uniform_sym_known_answers():
    p = Prng.from_name("xcheck")
    got = p.fill_uniform_sym(4, 0.5)
    expect = np.array([-0.38867, 0.15118302, -0.25011548, -0.46424392], dtype=np.float32)
    np.testing.assert_array_equal(got, expect)


def test_fnv1a_distinct():
    assert fnv1a("a") != fnv1a("b")
    assert fnv1a("tiny-sim/layer.0/wq") != fnv1a("tiny-sim/layer.1/wq")


def test_uniform_in_range():
    p = Prng(42)
    for _ in range(1000):
        u = p.uniform()
        assert 0.0 <= u < 1.0


def test_streams_independent():
    a = Prng.from_name("x")
    b = Prng.from_name("y")
    assert [a.next_u64() for _ in range(8)] != [b.next_u64() for _ in range(8)]
