"""L2 correctness: module shape contracts, patch-equivalence, TP shard
equivalence, and gradient-module correctness against `jax.grad` on the
composed model.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import configs, model, weights

CFG = configs.by_name("tiny-sim")


@pytest.fixture(scope="module")
def w():
    return weights.gen_model(CFG)


def tokens_for(batch):
    t = np.arange(batch * CFG.seq, dtype=np.float32).reshape(batch, CFG.seq)
    return jnp.asarray(t % CFG.vocab)


def jw(w, key):
    return [jnp.asarray(a) for a in w[key]]


# ---------------------------------------------------------------------------
# Shape contracts
# ---------------------------------------------------------------------------


def test_module_shapes(w):
    b = 2
    x = model.embed_fn(CFG)(tokens_for(b), *jw(w, "embed"))
    assert x.shape == (b, CFG.seq, CFG.d_model)
    h = model.layer_fn(CFG)(x, *jw(w, "layer.0"))
    assert h.shape == (b, CFG.seq, CFG.d_model)
    logits = model.lm_head_fn(CFG)(h, *jw(w, "lm_head"))
    assert logits.shape == (b, CFG.seq, CFG.vocab)


def test_param_schema_matches_generated(w):
    for (name, shape), arr in zip(model.layer_params(CFG), w["layer.0"]):
        assert arr.shape == shape, name
    for (name, shape), arr in zip(model.embed_params(CFG), w["embed"]):
        assert arr.shape == shape, name


def test_weights_are_deterministic():
    w1 = weights.gen_model(CFG)
    w2 = weights.gen_model(CFG)
    for k in w1:
        for a, b in zip(w1[k], w2[k]):
            np.testing.assert_array_equal(a, b)


def test_layers_have_distinct_weights(w):
    # same schema, different name-keyed streams
    assert not np.array_equal(w["layer.0"][2], w["layer.1"][2])


def test_gains_ones_biases_zeros(w):
    names = [n for n, _ in model.layer_params(CFG)]
    for name, arr in zip(names, w["layer.0"]):
        if weights.is_gain(name):
            assert (arr == 1.0).all(), name
        if weights.is_bias(name):
            assert (arr == 0.0).all(), name


# ---------------------------------------------------------------------------
# Kernel path vs reference path on the full layer
# ---------------------------------------------------------------------------


def test_layer_kernel_vs_reference_path(w):
    x = model.embed_fn(CFG)(tokens_for(2), *jw(w, "embed"))
    hk = model.layer_fn(CFG, use_kernel=True)(x, *jw(w, "layer.0"))
    hr = model.layer_fn(CFG, use_kernel=False)(x, *jw(w, "layer.0"))
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr), atol=5e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# Patch-equivalence: composing modules == full forward, and a patched
# composition changes downstream exactly as the oracle says.
# ---------------------------------------------------------------------------


def test_full_forward_composition(w):
    logits = model.full_forward(CFG, w, tokens_for(1))
    x = model.embed_fn(CFG)(tokens_for(1), *jw(w, "embed"))
    for i in range(CFG.n_layers):
        x = model.layer_fn(CFG)(x, *jw(w, f"layer.{i}"))
    manual = model.lm_head_fn(CFG)(x, *jw(w, "lm_head"))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(manual), atol=1e-6)


def test_patching_changes_only_patched_row(w):
    """Batch row isolation: patching row 0 must not affect row 1 — the
    numeric foundation of safe parallel co-tenancy (§B.2)."""
    b = 2
    x = model.embed_fn(CFG)(tokens_for(b), *jw(w, "embed"))
    x = model.layer_fn(CFG)(x, *jw(w, "layer.0"))
    xp = x.at[0, -1, :].set(1.0)
    for i in range(1, CFG.n_layers):
        x = model.layer_fn(CFG)(x, *jw(w, f"layer.{i}"))
        xp = model.layer_fn(CFG)(xp, *jw(w, f"layer.{i}"))
    base = np.asarray(model.lm_head_fn(CFG)(x, *jw(w, "lm_head")))
    patched = np.asarray(model.lm_head_fn(CFG)(xp, *jw(w, "lm_head")))
    np.testing.assert_allclose(base[1], patched[1], atol=1e-6)
    assert np.abs(base[0, -1] - patched[0, -1]).max() > 1e-3


# ---------------------------------------------------------------------------
# Tensor-parallel shard equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shards", [2])
def test_tp_sharding_matches_full_layer(w, shards):
    x = model.embed_fn(CFG)(tokens_for(2), *jw(w, "embed"))
    full = model.layer_fn(CFG)(x, *jw(w, "layer.0"))

    shard_w = weights.shard_layer_weights(CFG, w["layer.0"], shards)
    attn_fn = model.attn_tp_fn(CFG, shards)
    mlp_fn = model.mlp_tp_fn(CFG, shards)
    h = x
    delta = sum(attn_fn(x, *[jnp.asarray(a) for a in aw]) for aw, _ in shard_w)
    h = x + delta
    delta2 = sum(mlp_fn(h, *[jnp.asarray(a) for a in mw]) for _, mw in shard_w)
    out = h + delta2
    np.testing.assert_allclose(np.asarray(out), np.asarray(full), atol=5e-5, rtol=1e-4)


def test_tp_shard_param_shapes():
    shards = 2
    sw = weights.shard_layer_weights(CFG, weights.gen_model(CFG)["layer.0"], shards)
    attn_schema = model.attn_tp_params(CFG, shards)
    mlp_schema = model.mlp_tp_params(CFG, shards)
    for attn, mlp in sw:
        for (name, shape), arr in zip(attn_schema, attn):
            assert arr.shape == shape, name
        for (name, shape), arr in zip(mlp_schema, mlp):
            assert arr.shape == shape, name


def test_tp_bias_only_on_shard0(w):
    # with nonzero biases the equivalence test would catch double-adds, but
    # our synthetic biases are zero; check the slicing logic explicitly.
    lw = [a.copy() for a in w["layer.0"]]
    lw[6] = np.full_like(lw[6], 0.5)  # bo
    sw = weights.shard_layer_weights(CFG, lw, 2)
    assert (sw[0][0][6] == 0.5).all()
    assert (sw[1][0][6] == 0.0).all()


# ---------------------------------------------------------------------------
# Gradient modules
# ---------------------------------------------------------------------------


def test_lm_head_grad_matches_jax_grad(w):
    b = 2
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(b, CFG.seq, CFG.d_model)).astype(np.float32))
    targets = jnp.asarray(np.array([1.0, 3.0], dtype=np.float32))
    loss, gx = model.lm_head_grad_fn(CFG)(x, *jw(w, "lm_head"), targets)
    assert loss.shape == ()
    assert gx.shape == x.shape

    def ref_loss(xx):
        from compile.kernels.ref import layernorm_ref
        logits = layernorm_ref(xx, *jw(w, "lm_head")[:2]) @ jw(w, "lm_head")[2]
        last = logits[:, -1, :]
        logp = jax.nn.log_softmax(last, axis=-1)
        ids = targets.astype(jnp.int32)
        return -jnp.take_along_axis(logp, ids[:, None], axis=1)[:, 0].mean()

    ref_val, ref_gx = jax.value_and_grad(ref_loss)(x)
    np.testing.assert_allclose(float(loss), float(ref_val), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(ref_gx), atol=1e-5)


def test_layer_vjp_matches_jax_vjp(w):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(1, CFG.seq, CFG.d_model)).astype(np.float32))
    g = jnp.asarray(rng.normal(size=(1, CFG.seq, CFG.d_model)).astype(np.float32))
    gx = model.layer_vjp_fn(CFG)(x, *jw(w, "layer.0"), g)

    fwd = model.layer_fn(CFG, use_kernel=False)
    _, vjp = jax.vjp(lambda xx: fwd(xx, *jw(w, "layer.0")), x)
    ref_gx = vjp(g)[0]
    np.testing.assert_allclose(np.asarray(gx), np.asarray(ref_gx), atol=1e-5)


def test_layer_vjp_of_zero_cotangent_is_zero(w):
    x = jnp.zeros((1, CFG.seq, CFG.d_model), jnp.float32)
    g = jnp.zeros_like(x)
    gx = model.layer_vjp_fn(CFG)(x, *jw(w, "layer.0"), g)
    np.testing.assert_allclose(np.asarray(gx), 0.0, atol=1e-7)


# ---------------------------------------------------------------------------
# Embedding behaviour
# ---------------------------------------------------------------------------


def test_embed_gathers_correct_rows(w):
    t = jnp.asarray(np.full((1, CFG.seq), 5.0, dtype=np.float32))
    x = np.asarray(model.embed_fn(CFG)(t, *jw(w, "embed")))
    wte, wpe = w["embed"]
    expect = wte[5][None, None, :] + wpe[None, : CFG.seq, :]
    np.testing.assert_allclose(x, expect, atol=1e-6)
