"""AOT exporter: lower every model module to HLO text + write manifests.

Interchange format is **HLO text**, not serialized `HloModuleProto`: jax
≥ 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Output layout (consumed by `runtime::artifacts` on the Rust side):

    artifacts/<config>/manifest.json
    artifacts/<config>/<module>_b<batch>.hlo.txt
    artifacts/tiny-sim/check.json      # cross-language reference vectors

`make artifacts` runs this once; it is a no-op when inputs are unchanged
(Makefile stamp). Python never runs on the request path.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, model, weights

F32 = jnp.float32


def to_hlo_text(lowered, return_tuple: bool) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser).

    `return_tuple=False` for single-output modules: the executable's output
    is then a plain array buffer that the Rust runner chains directly into
    the next module via `execute_b` (no host round-trip between layers).
    Multi-output modules (lm_head_grad) keep the tuple root.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def spec(shape):
    return jax.ShapeDtypeStruct(tuple(shape), F32)


def lower_to_file(fn, arg_shapes, path: str, return_tuple: bool = False):
    lowered = jax.jit(fn, keep_unused=True).lower(*[spec(s) for s in arg_shapes])
    text = to_hlo_text(lowered, return_tuple)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


# ---------------------------------------------------------------------------
# Module table: everything exported per config.
# Shapes use -1 as the batch placeholder, resolved per exported batch size.
# ---------------------------------------------------------------------------


def module_table(cfg):
    """name -> (fn, inputs[(name, shape)], params[(name, shape)], extra_inputs)"""
    d, s = cfg.d_model, cfg.seq
    mods = {
        "embed": (model.embed_fn(cfg), [("tokens", (-1, s))], model.embed_params(cfg), []),
        "layer": (model.layer_fn(cfg), [("x", (-1, s, d))], model.layer_params(cfg), []),
        "lm_head": (model.lm_head_fn(cfg), [("x", (-1, s, d))], model.lm_head_params(cfg), []),
    }
    if cfg.grad:
        mods["lm_head_grad"] = (
            model.lm_head_grad_fn(cfg),
            [("x", (-1, s, d))],
            model.lm_head_params(cfg),
            [("targets", (-1,))],
        )
        mods["layer_vjp"] = (
            model.layer_vjp_fn(cfg),
            [("x", (-1, s, d))],
            model.layer_params(cfg),
            [("g_out", (-1, s, d))],
        )
    for tp in cfg.tp:
        mods[f"attn_tp{tp}"] = (
            model.attn_tp_fn(cfg, tp),
            [("x", (-1, s, d))],
            model.attn_tp_params(cfg, tp),
            [],
        )
        mods[f"mlp_tp{tp}"] = (
            model.mlp_tp_fn(cfg, tp),
            [("h", (-1, s, d))],
            model.mlp_tp_params(cfg, tp),
            [],
        )
    return mods


def resolve(shape, batch):
    return tuple(batch if x == -1 else x for x in shape)


def export_config(cfg, out_dir: str, quiet: bool = False) -> dict:
    cfg_dir = os.path.join(out_dir, cfg.name)
    os.makedirs(cfg_dir, exist_ok=True)
    mods = module_table(cfg)
    manifest_modules = {}
    for mod_name, (fn, inputs, params, extra_inputs) in mods.items():
        # lm_head_grad returns (loss, grad): needs a tuple root
        n_outputs = 2 if mod_name == "lm_head_grad" else 1
        files = {}
        for b in cfg.batches:
            arg_shapes = (
                [resolve(shape, b) for _, shape in inputs]
                + [shape for _, shape in params]
                + [resolve(shape, b) for _, shape in extra_inputs]
            )
            fname = f"{mod_name}_b{b}.hlo.txt"
            nbytes = lower_to_file(
                fn, arg_shapes, os.path.join(cfg_dir, fname), return_tuple=n_outputs > 1
            )
            files[str(b)] = fname
            if not quiet:
                print(f"  {cfg.name}/{fname}: {nbytes} bytes", file=sys.stderr)
        args = (
            [{"kind": "input", "name": n, "shape": list(s)} for n, s in inputs]
            + [{"kind": "param", "name": n, "shape": list(s)} for n, s in params]
            + [{"kind": "input", "name": n, "shape": list(s)} for n, s in extra_inputs]
        )
        manifest_modules[mod_name] = {"files": files, "args": args, "outputs": n_outputs}

    manifest = {
        "name": cfg.name,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "d_ff": cfg.d_ff,
        "vocab": cfg.vocab,
        "seq": cfg.seq,
        "batches": list(cfg.batches),
        "grad": cfg.grad,
        "tp": list(cfg.tp),
        "simulates": cfg.simulates,
        "param_count": cfg.param_count(),
        "weight_std": weights.WEIGHT_STD,
        "modules": manifest_modules,
    }
    with open(os.path.join(cfg_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def export_check_vectors(cfg, out_dir: str):
    """Cross-language reference vectors for the smallest config.

    The Rust integration suite regenerates the same weights, runs the same
    module sequence through PJRT, and asserts these numbers — proving the
    weight contract, the artifact bridge, and the runner end to end.
    """
    w = weights.gen_model(cfg)
    b = cfg.batches[0]
    tokens = np.arange(b * cfg.seq, dtype=np.float32).reshape(b, cfg.seq) % cfg.vocab
    x = model.embed_fn(cfg)(jnp.asarray(tokens), *[jnp.asarray(a) for a in w["embed"]])
    hidden_after = {}
    lf = model.layer_fn(cfg)
    for i in range(cfg.n_layers):
        x = lf(x, *[jnp.asarray(a) for a in w[f"layer.{i}"]])
        hidden_after[f"layer.{i}"] = np.asarray(x)
    logits = np.asarray(model.lm_head_fn(cfg)(x, *[jnp.asarray(a) for a in w["lm_head"]]))

    # a patched run: overwrite layer.0 output row 0, last token with 1.0s
    xp = model.embed_fn(cfg)(jnp.asarray(tokens), *[jnp.asarray(a) for a in w["embed"]])
    xp = lf(xp, *[jnp.asarray(a) for a in w["layer.0"]])
    xp = xp.at[0, cfg.seq - 1, :].set(1.0)
    for i in range(1, cfg.n_layers):
        xp = lf(xp, *[jnp.asarray(a) for a in w[f"layer.{i}"]])
    patched = np.asarray(model.lm_head_fn(cfg)(xp, *[jnp.asarray(a) for a in w["lm_head"]]))

    check = {
        "tokens": tokens.flatten().tolist(),
        "batch": b,
        "logits_sample": logits[0, -1, :8].astype(float).tolist(),
        "logits_norm": float(np.linalg.norm(logits)),
        "hidden_l0_sample": hidden_after["layer.0"][0, -1, :8].astype(float).tolist(),
        "patched_logits_sample": patched[0, -1, :8].astype(float).tolist(),
        "tol": 2e-4,
    }
    with open(os.path.join(out_dir, cfg.name, "check.json"), "w") as f:
        json.dump(check, f, indent=2)
    print(f"  {cfg.name}/check.json written", file=sys.stderr)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--only", default=None, help="export a single config by name")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()

    cfgs = [configs.by_name(args.only)] if args.only else configs.ALL
    os.makedirs(args.out, exist_ok=True)
    for cfg in cfgs:
        print(f"exporting {cfg.name} ({cfg.param_count():,} params)", file=sys.stderr)
        export_config(cfg, args.out, quiet=args.quiet)
        if cfg.name == "tiny-sim":
            export_check_vectors(cfg, args.out)
    print("aot export complete", file=sys.stderr)


if __name__ == "__main__":
    main()
