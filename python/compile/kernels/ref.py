"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: `pytest python/tests` asserts the
Pallas kernels match these to tight tolerances across hypothesis-generated
shape/seed sweeps. They are intentionally the most direct possible
transcription of the math.
"""

import jax.numpy as jnp


def attention_ref(q, k, v):
    """Causal softmax attention; q,k,v: [batch, heads, seq, d_head]."""
    b, h, s, d = q.shape
    scale = 1.0 / (d ** 0.5)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def layernorm_ref(x, gain, bias, eps: float = 1e-5):
    """LayerNorm over the last axis with affine parameters."""
    xf = x.astype(jnp.float32)
    mean = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mean) ** 2).mean(axis=-1, keepdims=True)
    y = (xf - mean) / jnp.sqrt(var + eps) * gain + bias
    return y.astype(x.dtype)
