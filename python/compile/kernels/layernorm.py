"""Fused layer normalization as a Pallas kernel.

LayerNorm is the memory-bound op of the decoder block (one read + one
write per element, negligible FLOPs); fusing mean/variance/normalize/affine
into one VMEM pass is the standard TPU treatment. The kernel processes
`BLOCK_ROWS` rows per program instance; the feature dimension stays whole
(d_model ≤ 512 in every simulated config, far under VMEM limits).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 128


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # [rows, d]
    mean = x.mean(axis=1, keepdims=True)
    xc = x - mean
    var = (xc * xc).mean(axis=1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    y = xc * inv * g_ref[...].astype(jnp.float32) + b_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def layernorm(x, gain, bias, *, eps: float = 1e-5, block_rows: int = BLOCK_ROWS):
    """LayerNorm over the last axis of `x` ([..., d]) with affine params."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for dim in orig_shape[:-1]:
        rows *= dim
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    # pad rows to a multiple of the block (interpret mode requires exact grid)
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.concatenate([x2, jnp.zeros((pad, d), x2.dtype)], axis=0)
    padded_rows = rows + pad

    kernel = functools.partial(_ln_kernel, eps=eps)
    y = pl.pallas_call(
        kernel,
        grid=(padded_rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded_rows, d), x.dtype),
        interpret=True,
    )(x2, gain, bias)
    if pad:
        y = y[:rows]
    return y.reshape(orig_shape)
