"""Layer-1 Pallas kernels (build-time only).

The model's compute hot-spots — causal multi-head attention and layer
normalization — are implemented as Pallas kernels with the HBM↔VMEM
schedule expressed via `BlockSpec`s and an online-softmax inner loop.
All kernels run with ``interpret=True``: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute (see DESIGN.md
§Hardware-Adaptation for the TPU projection).
"""

from .attention import flash_attention
from .layernorm import layernorm

__all__ = ["flash_attention", "layernorm"]
