"""Tiled causal flash attention as a Pallas kernel.

The paper's hosted models spend their FLOPs in attention + MLP matmuls; on
GPU those run as fused CUDA kernels inside PyTorch. The TPU-shaped
adaptation (DESIGN.md §2) tiles the computation for VMEM and the MXU:

* grid over query tiles of ``BLOCK_Q`` rows; each program instance owns a
  `[batch, heads, BLOCK_Q, d_head]` query tile;
* the kernel walks KV tiles of ``BLOCK_K`` columns with the online-softmax
  recurrence (running max `m`, normalizer `l`, accumulator `acc`), so the
  S×S score matrix is never materialized;
* causal masking is applied per tile, and fully-masked tiles are skipped
  by bounding the KV loop at the query tile's diagonal.

Grid-axis placement (a §Perf decision, EXPERIMENTS.md §Perf/L1): on a real
TPU the batch and head axes are *parallel* grid dimensions; under
``interpret=True`` every grid step executes sequentially on the CPU, which
made a `(batch, heads, q_tiles)` grid serialize thousands of tiny steps
(11.6 s/forward at batch 32 on the largest config). The batch/head axes
are therefore folded *into* the kernel as vectorized einsums — exactly the
work a TPU would run in parallel program instances — keeping the KV-tile
recurrence as the explicit loop structure. Same math (verified against
``ref.py``), ~40× less interpret overhead.

``interpret=True`` everywhere: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT client cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. On TPU these would be multiples of the (8, 128)
# VREG / (128, 128) MXU tiles; on CPU-interpret they bound the VMEM-like
# working set and the loop trip counts.
BLOCK_Q = 16
BLOCK_K = 16

NEG_INF = -1.0e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, scale: float):
    """One query tile (all batches/heads) vs. causally-visible KV tiles.

    Shapes as delivered by the BlockSpecs:
      q_ref: [B, H, BLOCK_Q, d_head] — this program's query tile
      k_ref: [B, H, S, d_head]       — full keys
      v_ref: [B, H, S, d_head]       — full values
      o_ref: [B, H, BLOCK_Q, d_head] — output tile
    """
    qi = pl.program_id(0)  # query-tile index within the sequence
    b, h, block_q, d_head = q_ref.shape

    q = q_ref[...].astype(jnp.float32) * scale

    # Online-softmax state (per batch/head/query-row).
    m = jnp.full((b, h, block_q), NEG_INF, dtype=jnp.float32)
    l = jnp.zeros((b, h, block_q), dtype=jnp.float32)
    acc = jnp.zeros((b, h, block_q, d_head), dtype=jnp.float32)

    # Causality: query row (qi*block_q + r) attends keys <= its own index;
    # KV tiles strictly beyond the diagonal contribute nothing. Ceil-divide
    # so a partially-visible tile is still processed (masked below).
    num_kv_tiles = ((qi + 1) * block_q + block_k - 1) // block_k

    def body(kv, carry):
        m, l, acc = carry
        k_tile = pl.load(
            k_ref, (slice(None), slice(None), pl.dslice(kv * block_k, block_k), slice(None))
        ).astype(jnp.float32)
        v_tile = pl.load(
            v_ref, (slice(None), slice(None), pl.dslice(kv * block_k, block_k), slice(None))
        ).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", q, k_tile)  # [b, h, block_q, block_k]

        # causal mask within the tile
        q_idx = qi * block_q + jax.lax.iota(jnp.int32, block_q)
        k_idx = kv * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = q_idx[:, None] >= k_idx[None, :]
        s = jnp.where(mask[None, None], s, NEG_INF)

        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, v_tile)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, num_kv_tiles, body, (m, l, acc))
    o_ref[...] = (acc / l[..., None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, block_q: int = BLOCK_Q, block_k: int = BLOCK_K):
    """Causal multi-head attention, `softmax(q kᵀ / sqrt(d)) v`.

    Args:
      q, k, v: [batch, heads, seq, d_head]
    Returns:
      [batch, heads, seq, d_head]
    """
    b, h, s, d = q.shape
    assert k.shape == (b, h, s, d) and v.shape == (b, h, s, d)
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)

    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(_attn_kernel, block_k=block_k, scale=scale)

    grid = (s // block_q,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, h, block_q, d), lambda iq: (0, 0, iq, 0)),
            pl.BlockSpec((b, h, s, d), lambda iq: (0, 0, 0, 0)),
            pl.BlockSpec((b, h, s, d), lambda iq: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((b, h, block_q, d), lambda iq: (0, 0, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        interpret=True,
    )(q, k, v)
