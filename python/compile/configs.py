"""Model configuration zoo — the single source of truth for the simulated
model family.

The paper evaluates on real open-weight checkpoints (OPT 125M-66B, GPT2-XL,
Gemma-7B, Llama-3.1 8B/70B); this testbed has no GPUs or HuggingFace access,
so we substitute a *scaled family*: OPT-style architectures whose parameter
counts grow geometrically, preserving every relative effect the paper
measures (setup time ~ bytes loaded, runtime ~ FLOPs, communication overhead
~ constant). See DESIGN.md §3.

The Rust side never imports this file: `aot.py` bakes everything it needs
into `artifacts/<name>/manifest.json`.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    vocab: int
    seq: int
    # batch sizes to export module executables for
    batches: tuple = (1, 32)
    # export gradient modules (lm_head_grad, layer_vjp)?
    grad: bool = False
    # tensor-parallel shard counts to export (attn_tp{S}, mlp_tp{S})
    tp: tuple = ()
    # the real model this config simulates (documentation only)
    simulates: str = ""

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        d, f, v, s = self.d_model, self.d_ff, self.vocab, self.seq
        per_layer = (
            4 * d * d  # wq wk wv wo
            + d        # bo
            + 2 * d * f + f + d  # w1 b1 w2 b2
            + 4 * d    # ln1/ln2 gains+biases
        )
        return v * d + s * d + self.n_layers * per_layer + 2 * d + d * v


# The OPT-suite analog (Fig. 6a/6b, Table 2): geometric growth in params.
OPT_FAMILY = [
    ModelConfig("opt-125m-sim", 64, 2, 2, 256, 512, 32, simulates="facebook/opt-125m"),
    ModelConfig("opt-350m-sim", 96, 3, 3, 384, 512, 32, simulates="facebook/opt-350m"),
    ModelConfig("opt-1.3b-sim", 128, 4, 4, 512, 512, 32, simulates="facebook/opt-1.3b"),
    ModelConfig("opt-2.7b-sim", 160, 5, 5, 640, 512, 32, simulates="facebook/opt-2.7b"),
    ModelConfig("opt-6.7b-sim", 224, 6, 7, 896, 512, 32, simulates="facebook/opt-6.7b"),
    ModelConfig("opt-13b-sim", 288, 7, 9, 1152, 512, 32, simulates="facebook/opt-13b"),
    ModelConfig("opt-30b-sim", 384, 8, 12, 1536, 512, 32, simulates="facebook/opt-30b"),
    ModelConfig("opt-66b-sim", 512, 9, 16, 2048, 512, 32, simulates="facebook/opt-66b"),
]

# Table 1 / Table 3-4 model analogs.
NAMED = [
    ModelConfig("gpt2xl-sim", 160, 6, 5, 640, 512, 32, simulates="gpt2-xl"),
    ModelConfig("gemma7b-sim", 256, 7, 8, 1024, 512, 32, simulates="google/gemma-7b"),
    ModelConfig(
        "llama8b-sim", 256, 8, 8, 1024, 512, 32,
        # intermediate batches let the co-tenancy scheduler merge bursts
        # without padding straight to 32 (see benches/cotenancy.rs)
        batches=(1, 4, 8, 32),
        grad=True, tp=(2, 4), simulates="meta-llama/Meta-Llama-3.1-8B",
    ),
    ModelConfig("llama70b-sim", 512, 10, 16, 2048, 512, 32, simulates="meta-llama/Meta-Llama-3.1-70B"),
]

# Small config for fast unit/integration tests across the whole stack.
TEST = [
    ModelConfig("tiny-sim", 32, 2, 2, 128, 64, 16, batches=(1, 4), grad=True, tp=(2,)),
]

ALL = TEST + OPT_FAMILY + NAMED


def by_name(name: str) -> ModelConfig:
    for c in ALL:
        if c.name == name:
            return c
    raise KeyError(name)
