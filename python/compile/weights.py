"""Synthetic weight generation — Python mirror of `models::weights` (Rust).

No weight files ship with the repo: every parameter tensor is generated
deterministically from its fully-qualified name
(``"<config>/<module>/<param>"``) with the shared xoshiro256++ stream
(see `prng.py`). The Rust runtime generates weights the same way, so both
languages agree bit-for-bit — verified by the `check.json` reference
vectors exported for the tiny config.

Init rules (the shared contract):
* layernorm gains (``*_g``): ones;
* biases (``*_b``, ``bo``, ``b1``, ``b2``): zeros;
* everything else: symmetric uniform with std 0.02 (a = 0.02·√3);
* tensor-parallel shard slices are *views of the full weights* (columns of
  wq/wk/wv/w1, rows of wo/w2), so sharded numerics equal unsharded; the
  once-only biases (bo, b2) go to shard 0, zeros elsewhere.
"""

import numpy as np

from . import model
from .prng import Prng

WEIGHT_STD = 0.02
_A = WEIGHT_STD * np.sqrt(3.0)


def is_gain(param: str) -> bool:
    return param.endswith("_g")


def is_bias(param: str) -> bool:
    return param.endswith("_b") or param in ("bo", "b1", "b2")


def gen_param(cfg_name: str, module: str, param: str, shape) -> np.ndarray:
    """Generate one parameter tensor by the shared contract."""
    n = int(np.prod(shape))
    if is_gain(param):
        return np.ones(shape, dtype=np.float32)
    if is_bias(param):
        return np.zeros(shape, dtype=np.float32)
    rng = Prng.from_name(f"{cfg_name}/{module}/{param}")
    return rng.fill_uniform_sym(n, float(_A)).reshape(shape)


def gen_module(cfg, module: str, params) -> list:
    return [gen_param(cfg.name, module, name, shape) for name, shape in params]


def gen_model(cfg) -> dict:
    """All weights for a config, keyed by module path."""
    w = {"embed": gen_module(cfg, "embed", model.embed_params(cfg))}
    for i in range(cfg.n_layers):
        # all layers share one executable but have distinct weights
        w[f"layer.{i}"] = gen_module(cfg, f"layer.{i}", model.layer_params(cfg))
    w["lm_head"] = gen_module(cfg, "lm_head", model.lm_head_params(cfg))
    return w


def shard_layer_weights(cfg, layer_weights, shards: int):
    """Slice one layer's full weights into per-shard (attn, mlp) arg lists.

    Returns `[(attn_args, mlp_args), ...]` of length `shards`, matching
    `model.attn_tp_params` / `model.mlp_tp_params` argument order.
    """
    (ln1_g, ln1_b, wq, wk, wv, wo, bo, ln2_g, ln2_b, w1, b1, w2, b2) = layer_weights
    d, f = cfg.d_model, cfg.d_ff
    ds, fs = d // shards, f // shards
    out = []
    for s in range(shards):
        cs, ce = s * ds, (s + 1) * ds
        bo_s = bo if s == 0 else np.zeros_like(bo)
        attn = [ln1_g, ln1_b, wq[:, cs:ce], wk[:, cs:ce], wv[:, cs:ce], wo[cs:ce, :], bo_s]
        hs, he = s * fs, (s + 1) * fs
        b2_s = b2 if s == 0 else np.zeros_like(b2)
        mlp = [ln2_g, ln2_b, w1[:, hs:he], b1[hs:he], w2[hs:he, :], b2_s]
        out.append((attn, mlp))
    return out
