"""Layer-2: the hosted foundation model, as per-module JAX functions.

NNsight interleaves intervention subgraphs with model execution by hooking
PyTorch module boundaries (§B.1 of the paper). In the AOT three-layer
architecture there is no Python on the request path, so module boundaries
become *artifact boundaries*: each function below is lowered to its own HLO
executable, and the Rust `ModelRunner` executes them in sequence, running
intervention subgraphs between calls — the exact interleaving semantics of
the paper, realized at the XLA level.

Architecture: OPT-style pre-LN decoder-only transformer.

    h0       = wte[tokens] + wpe[positions]            (embed)
    h_{i+1}  = h_i + attn(ln1(h_i)) ; + mlp(ln2(·))    (layer × n_layers)
    logits   = ln_f(h_N) @ w_out                        (lm_head)

All decoder layers share one executable (identical shapes) and differ only
in their weight arguments, so artifact count is O(1) in depth.

Weight argument orders are frozen here and recorded in the manifest; the
Rust side is driven entirely by the manifest.

Gradient modules (for GradProtocol / attribution patching / probe
training) and tensor-parallel shard modules (for the NDIF multi-shard
deployment simulation, Fig. 4) are exported for configs that request them.
"""

import jax
import jax.numpy as jnp

from .kernels import flash_attention, layernorm
from .kernels.ref import attention_ref, layernorm_ref

# ---------------------------------------------------------------------------
# Weight schema: (name, shape) per module. Shapes depend only on config.
# ---------------------------------------------------------------------------


def embed_params(cfg):
    return [
        ("wte", (cfg.vocab, cfg.d_model)),
        ("wpe", (cfg.seq, cfg.d_model)),
    ]


def layer_params(cfg):
    d, f = cfg.d_model, cfg.d_ff
    return [
        ("ln1_g", (d,)), ("ln1_b", (d,)),
        ("wq", (d, d)), ("wk", (d, d)), ("wv", (d, d)),
        ("wo", (d, d)), ("bo", (d,)),
        ("ln2_g", (d,)), ("ln2_b", (d,)),
        ("w1", (d, f)), ("b1", (f,)),
        ("w2", (f, d)), ("b2", (d,)),
    ]


def lm_head_params(cfg):
    d, v = cfg.d_model, cfg.vocab
    return [("lnf_g", (d,)), ("lnf_b", (d,)), ("wout", (d, v))]


def attn_tp_params(cfg, shards):
    """Column-parallel attention shard: a contiguous block of heads.

    wq/wk/wv keep full input dim, produce d/S columns; wo maps those back
    up (row-parallel), so shard outputs sum to the full projection. The
    output bias must be added exactly once — the weight generator gives
    shard 0 the real bias and the other shards zeros.
    """
    d = cfg.d_model
    ds = d // shards
    return [
        ("ln1_g", (d,)), ("ln1_b", (d,)),
        ("wq_s", (d, ds)), ("wk_s", (d, ds)), ("wv_s", (d, ds)),
        ("wo_s", (ds, d)), ("bo_s", (d,)),
    ]


def mlp_tp_params(cfg, shards):
    d, f = cfg.d_model, cfg.d_ff
    fs = f // shards
    return [
        ("ln2_g", (d,)), ("ln2_b", (d,)),
        ("w1_s", (d, fs)), ("b1_s", (fs,)),
        ("w2_s", (fs, d)), ("b2_s", (d,)),
    ]


# ---------------------------------------------------------------------------
# Forward modules
# ---------------------------------------------------------------------------


def embed_fn(cfg):
    def fn(tokens, wte, wpe):
        # tokens arrive as f32 (simplest literal dtype for the rust side)
        ids = tokens.astype(jnp.int32)
        pos = jnp.arange(cfg.seq, dtype=jnp.int32)
        return jnp.take(wte, ids, axis=0) + wpe[pos][None, :, :]

    return fn


def _attention_block(cfg, x_norm, wq, wk, wv, wo, bo, heads=None, use_kernel=True):
    """Multi-head causal attention over normalized input, output proj.

    `use_kernel=False` swaps in the pure-jnp reference attention: the
    Pallas interpret kernel has no reverse-mode autodiff rule, so gradient
    modules (`layer_vjp`, `lm_head_grad`) differentiate the mathematically
    identical reference path. Forward modules always use the L1 kernel.
    """
    b, s, _ = x_norm.shape
    h = heads if heads is not None else cfg.n_heads
    dh = cfg.d_head
    q = (x_norm @ wq).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    k = (x_norm @ wk).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    v = (x_norm @ wv).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    attn = flash_attention if use_kernel else attention_ref
    o = attn(q, k, v)  # L1 Pallas kernel on the forward path
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * dh)
    return o @ wo + bo


def _mlp_block(x_norm, w1, b1, w2, b2):
    return jax.nn.gelu(x_norm @ w1 + b1, approximate=True) @ w2 + b2


def layer_fn(cfg, use_kernel=True):
    ln = layernorm if use_kernel else layernorm_ref

    def fn(x, ln1_g, ln1_b, wq, wk, wv, wo, bo, ln2_g, ln2_b, w1, b1, w2, b2):
        a = _attention_block(cfg, ln(x, ln1_g, ln1_b), wq, wk, wv, wo, bo, use_kernel=use_kernel)
        h = x + a
        m = _mlp_block(ln(h, ln2_g, ln2_b), w1, b1, w2, b2)
        return h + m

    return fn


def lm_head_fn(cfg):
    def fn(x, lnf_g, lnf_b, wout):
        return layernorm(x, lnf_g, lnf_b) @ wout

    return fn


# ---------------------------------------------------------------------------
# Gradient modules (GradProtocol substrate)
# ---------------------------------------------------------------------------


def lm_head_grad_fn(cfg):
    """Loss + gradient w.r.t. the final hidden state.

    Loss = mean over batch of cross-entropy of the last-token prediction
    against `targets` (f32-encoded ids). This is the backward *root*; the
    chain continues through `layer_vjp` modules back to any layer the
    user's graph touched with `.grad`.
    """

    def loss(x, lnf_g, lnf_b, wout, targets):
        logits = layernorm_ref(x, lnf_g, lnf_b) @ wout  # [B,S,V]
        last = logits[:, -1, :]
        logp = jax.nn.log_softmax(last, axis=-1)
        ids = targets.astype(jnp.int32)
        nll = -jnp.take_along_axis(logp, ids[:, None], axis=1)[:, 0]
        return nll.mean()

    def fn(x, lnf_g, lnf_b, wout, targets):
        val, gx = jax.value_and_grad(loss)(x, lnf_g, lnf_b, wout, targets)
        return val, gx

    return fn


def layer_vjp_fn(cfg):
    """Backward through one decoder layer: (x, weights…, g_out) → g_x."""
    fwd = layer_fn(cfg, use_kernel=False)  # reference path is differentiable

    def fn(x, ln1_g, ln1_b, wq, wk, wv, wo, bo, ln2_g, ln2_b, w1, b1, w2, b2, g_out):
        _, vjp = jax.vjp(
            lambda xx: fwd(xx, ln1_g, ln1_b, wq, wk, wv, wo, bo, ln2_g, ln2_b, w1, b1, w2, b2),
            x,
        )
        return vjp(g_out)[0]

    return fn


# ---------------------------------------------------------------------------
# Tensor-parallel shard modules (NDIF multi-shard deployment, Fig. 4)
# ---------------------------------------------------------------------------


def attn_tp_fn(cfg, shards):
    """Partial attention delta for one shard's heads.

    full layer step 1:  h = x + Σ_s attn_tp(x, weights_s)
    (the Rust coordinator performs the all-reduce / residual add).
    """
    h = cfg.n_heads // shards
    assert h >= 1, (cfg.name, shards)

    def fn(x, ln1_g, ln1_b, wq_s, wk_s, wv_s, wo_s, bo_s):
        xn = layernorm(x, ln1_g, ln1_b)
        return _attention_block(cfg, xn, wq_s, wk_s, wv_s, wo_s, bo_s, heads=h)

    return fn


def mlp_tp_fn(cfg, shards):
    """Partial MLP delta for one shard's hidden columns.

    full layer step 2:  out = h + Σ_s mlp_tp(h, weights_s)
    """
    del shards

    def fn(h, ln2_g, ln2_b, w1_s, b1_s, w2_s, b2_s):
        hn = layernorm(h, ln2_g, ln2_b)
        return _mlp_block(hn, w1_s, b1_s, w2_s, b2_s)

    return fn


# ---------------------------------------------------------------------------
# Whole-model composition (used by the pytest oracle + check vectors only;
# never exported — the Rust runner composes modules itself)
# ---------------------------------------------------------------------------


def full_forward(cfg, weights, tokens):
    """Compose the modules exactly as the Rust ModelRunner does."""
    x = embed_fn(cfg)(tokens, *weights["embed"])
    lf = layer_fn(cfg)
    for i in range(cfg.n_layers):
        x = lf(x, *weights[f"layer.{i}"])
    return lm_head_fn(cfg)(x, *weights["lm_head"])
