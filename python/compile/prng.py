"""Bit-exact Python mirror of the Rust weight PRNG (`util::prng`).

Synthetic model weights are generated deterministically from parameter
names on the Rust side (no weight files ship with the repo). The pytest
suite regenerates the same weights here to (a) run the pure-JAX oracle
model on identical parameters and (b) emit `check.json` reference logits
that the Rust integration tests verify, proving the whole
python-AOT → rust-PJRT bridge end to end.

Bit-exactness requirements:
* xoshiro256++ over u64 with wrapping arithmetic (masked here);
* SplitMix64 seeding from an FNV-1a hash of the parameter name;
* uniform doubles via `(x >> 11) * 2^-53` (exact in IEEE f64);
* symmetric-uniform weight init `(2u - 1) * a` computed in f64 and then
  rounded once to f32 — both languages round identically.
"""

import numpy as np

MASK = (1 << 64) - 1


def fnv1a(name: str) -> int:
    h = 0xCBF29CE484222325
    for b in name.encode():
        h ^= b
        h = (h * 0x100000001B3) & MASK
    return h


class Prng:
    """xoshiro256++ seeded via SplitMix64 (mirrors rust/src/util/prng.rs)."""

    def __init__(self, seed: int):
        s = seed & MASK
        self.s = []
        for _ in range(4):
            s = (s + 0x9E3779B97F4A7C15) & MASK
            z = s
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
            self.s.append(z ^ (z >> 31))

    @classmethod
    def from_name(cls, name: str) -> "Prng":
        return cls(fnv1a(name))

    def next_u64(self) -> int:
        s = self.s
        x = (s[0] + s[3]) & MASK
        result = (((x << 23) | (x >> 41)) + s[0]) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = ((s[3] << 45) | (s[3] >> 19)) & MASK
        return result

    def uniform(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def fill_uniform_sym(self, n: int, a: float) -> np.ndarray:
        """n samples of `(2u - 1) * a`, rounded once to f32."""
        out = np.empty(n, dtype=np.float32)
        for i in range(n):
            out[i] = np.float32((2.0 * self.uniform() - 1.0) * a)
        return out
