//! End-to-end serving driver (the repository's E2E validation run).
//!
//! Starts a real NDIF server preloaded with a model, then drives it with
//! concurrent clients submitting batched IOI activation-patching
//! experiments over HTTP (through a simulated WAN). Reports
//! latency/throughput and the patching effect (logit-difference shift),
//! and verifies remote results equal local execution.
//!
//! Run: `cargo run --release --example serve_ioi -- \
//!           [--model llama8b-sim] [--clients 4] [--requests 3] [--batch 16]`
//!
//! Results are recorded in EXPERIMENTS.md §E2E.

use std::time::Instant;

use nnscope::client::{remote::NdifClient, Trace};
use nnscope::models::workload::IoiBatch;
use nnscope::models::{artifacts_dir, ModelRunner};
use nnscope::netsim::{Mode, NetSim};
use nnscope::scheduler::CoTenancy;
use nnscope::server::{NdifConfig, NdifServer};
use nnscope::tensor::{Range1, Tensor};
use nnscope::util::cli::Args;
use nnscope::util::Summary;

fn patching_trace(
    model: &str,
    batch: &IoiBatch,
    layer: usize,
    seq: usize,
) -> (Trace, nnscope::client::SavedRef) {
    // interleaved rows [src, base, src, base, ...]; patch src→base at the
    // last token of `layer`, return per-example logit diffs (server-side
    // metric: only scalars come back over the WAN).
    let tokens = batch.interleaved_tokens();
    let mut tr = Trace::new(model, &tokens);
    let point = format!("layer.{layer}");
    let h = tr.output(&point);
    let mut patched = h;
    for i in (0..batch.len() * 2).step_by(2) {
        let src = tr.slice(h, &[Range1::one(i), Range1::one(seq - 1)]);
        patched = tr.assign(patched, &[Range1::one(i + 1), Range1::one(seq - 1)], src);
    }
    tr.set_output(&point, patched);
    let logits = tr.output("lm_head");
    // per-example metric on base rows, packed into one saved vector
    let zeros = Tensor::zeros(&[batch.len()]);
    let mut acc = tr.constant(&zeros);
    for (i, e) in batch.examples.iter().enumerate() {
        let row = tr.slice(logits, &[Range1::one(2 * i + 1)]);
        let ld = tr.logit_diff(row, e.target, e.foil);
        acc = tr.assign(acc, &[Range1::one(i)], ld);
    }
    let saved = tr.save(acc);
    (tr, saved)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(1);
    let model = args.str_or("model", "llama8b-sim");
    let clients = args.usize_or("clients", 4);
    let requests = args.usize_or("requests", 3);
    let examples = args.usize_or("batch", 16); // pairs => 2× rows

    println!("== nnscope end-to-end serving driver ==");
    println!("starting NDIF server with {model} preloaded …");
    let t0 = Instant::now();
    let mut cfg = NdifConfig::local(&[&model]);
    cfg.cotenancy = CoTenancy::Sequential;
    let server = NdifServer::start(cfg)?;
    println!("  server up at {} in {:.2}s", server.addr(), t0.elapsed().as_secs_f64());

    let manifest = nnscope::runtime::Manifest::load(&artifacts_dir(), &model)?;
    let seq = manifest.seq;
    let vocab = manifest.vocab;
    let layer = manifest.n_layers / 2;

    // sanity: remote == local on one request
    {
        let lm = ModelRunner::load(&artifacts_dir(), &model)?;
        let batch = IoiBatch::generate(examples, vocab, seq, 0xE2E);
        let (tr, s) = patching_trace(&model, &batch, layer, seq);
        let local = tr.run_local(&lm)?;
        let client = NdifClient::new(server.addr());
        let (tr, s2) = patching_trace(&model, &batch, layer, seq);
        let remote = tr.run_remote(&client)?;
        let diff = local.get(s).max_abs_diff(remote.get(s2));
        println!("remote == local check: max |Δlogit-diff| = {diff:.2e}");
        assert!(diff < 1e-4, "remote/local divergence!");
        let mean_ld: f32 =
            local.get(s).data().iter().sum::<f32>() / local.get(s).numel() as f32;
        println!("patched logit-diff (target − foil), mean over batch: {mean_ld:+.4}");
    }

    // concurrent clients over a simulated WAN
    println!("\ndriving {clients} clients × {requests} requests (batch {examples} pairs) …");
    let addr = server.addr();
    let wall = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let model = model.clone();
            std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
                let link = NetSim::paper_wan(Mode::Sleep);
                let client = NdifClient::new(addr).with_link(link);
                let mut lat = Vec::new();
                for r in 0..requests {
                    let batch =
                        IoiBatch::generate(examples, vocab, seq, (c * 1000 + r) as u64);
                    let (tr, s) = patching_trace(&model, &batch, layer, seq);
                    let t = Instant::now();
                    let res = tr.run_remote(&client)?;
                    let dt = t.elapsed().as_secs_f64();
                    assert_eq!(res.get(s).numel(), examples);
                    lat.push(dt);
                }
                Ok(lat)
            })
        })
        .collect();

    let mut latencies: Vec<f64> = Vec::new();
    for h in handles {
        latencies.extend(h.join().expect("client thread")?);
    }
    let wall = wall.elapsed().as_secs_f64();
    let s = Summary::of(&latencies);
    let total_reqs = clients * requests;
    let total_examples = total_reqs * examples;

    println!("\n== results ==");
    println!("requests completed : {total_reqs}");
    println!("wall time          : {wall:.2}s");
    println!(
        "throughput         : {:.2} req/s  ({:.1} patched examples/s)",
        total_reqs as f64 / wall,
        total_examples as f64 / wall
    );
    println!("latency mean ± std : {}s", s.pm());
    println!(
        "latency median     : {:.3}s  (p25 {:.3}, p75 {:.3}, max {:.3})",
        s.median, s.q25, s.q75, s.max
    );
    let (enq, done, failed, _) = server.metrics(&model).unwrap();
    println!("server metrics     : enqueued={enq} completed={done} failed={failed}");
    assert_eq!(done as usize, total_reqs + 1); // +1 sanity request
    println!("\nE2E OK");
    Ok(())
}
