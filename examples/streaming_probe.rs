//! Streaming generation with per-step interventions: a logit lens probed
//! at EVERY decode step, with events arriving while the rest of the
//! generation is still running.
//!
//! Each step event carries, per layer, the token the unembedding would
//! decode from that layer's last-position hidden state — watch the
//! prediction form across depth, token by token, without waiting for the
//! full generation (the latency gap `benches/streaming.rs` measures).
//!
//! Run: `cargo run --release --example streaming_probe -- [--model tiny-sim] [--steps 8]`

use std::time::Instant;

use nnscope::client::remote::{NdifClient, StreamEvent};
use nnscope::client::{Trace, TraceResult};
use nnscope::models::artifacts_dir;
use nnscope::scheduler::CoTenancy;
use nnscope::server::{NdifConfig, NdifServer};
use nnscope::tensor::{Range1, Tensor};
use nnscope::util::cli::Args;
use nnscope::util::table::Table;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(1);
    let model = args.str_or("model", "tiny-sim");
    let steps = args.usize_or("steps", 8);

    let m = nnscope::runtime::Manifest::load(&artifacts_dir(), &model)?;
    let wout = nnscope::models::weights::gen_param(
        &m.name,
        "lm_head",
        "wout",
        &[m.d_model, m.vocab],
    );

    println!("starting NDIF server with {model} …");
    let cfg = NdifConfig { cotenancy: CoTenancy::Sequential, ..NdifConfig::local(&[&model]) };
    let server = NdifServer::start(cfg)?;
    let client = NdifClient::new(server.addr());

    let tokens = Tensor::new(
        &[1, m.seq],
        (0..m.seq).map(|i| ((i * 7 + 3) % m.vocab) as f32).collect(),
    );

    // the per-step probe: at every decode step, decode each layer's
    // last-position hidden state through the unembedding; step_hook makes
    // the per-layer argmax ids ride that step's event
    let mut tr = Trace::new(&m.name, &tokens);
    let w = tr.constant(&wout);
    let mut lens_hooks = Vec::new();
    for l in 0..m.n_layers {
        let h = tr.output(&format!("layer.{l}"));
        let last = tr.slice(h, &[Range1::one(0), Range1::one(m.seq - 1)]);
        let lens = tr.matmul(last, w);
        let top = tr.argmax(lens);
        lens_hooks.push((l, tr.step_hook(top)));
    }

    let mut header = vec!["step".to_string(), "token".to_string()];
    header.extend((0..m.n_layers).map(|l| format!("lens L{l}")));
    let mut table =
        Table::new(&format!("per-step logit lens — {model}, {steps} steps")).header(header);

    let t0 = Instant::now();
    let mut first_event = None;
    let mut generated = Vec::new();
    for item in tr.run_stream(&client, steps)? {
        match item? {
            StreamEvent::Step { step, token, values, .. } => {
                if first_event.is_none() {
                    first_event = Some(t0.elapsed());
                }
                let res = TraceResult::from_graph_result(values);
                let mut row = vec![format!("{step}"), format!("{token}")];
                for (_, hook) in &lens_hooks {
                    row.push(format!("{}", res.get(*hook).data()[0] as usize));
                }
                table.row(row);
            }
            StreamEvent::Done { tokens, .. } => generated = tokens,
        }
    }
    let total = t0.elapsed();
    table.print();

    let first = first_event.expect("no step event arrived");
    println!(
        "\ngenerated {:?}\nfirst StepEvent after {:.1} ms; full generation took {:.1} ms \
         ({:.1}x the wait a blocking client pays)",
        generated,
        first.as_secs_f64() * 1e3,
        total.as_secs_f64() * 1e3,
        total.as_secs_f64() / first.as_secs_f64().max(1e-9),
    );
    assert!(
        first < total,
        "first event must arrive before the generation completes"
    );
    Ok(())
}
