//! Gradient access remotely: attribution-patching-style per-layer scores
//! (activation · gradient) computed **server-side** via the GradProtocol,
//! with only the scalar attributions returning to the client — the
//! experiment class that Petals-style client-side intervention cannot do
//! without shipping every hidden state and gradient across the WAN.
//!
//! Run: `cargo run --release --example remote_probe -- [--model tiny-sim]`

use nnscope::client::{remote::NdifClient, Trace};
use nnscope::models::artifacts_dir;
use nnscope::scheduler::CoTenancy;
use nnscope::server::{NdifConfig, NdifServer};
use nnscope::tensor::Tensor;
use nnscope::util::cli::Args;
use nnscope::util::table::Table;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(1);
    let model = args.str_or("model", "tiny-sim");

    let manifest = nnscope::runtime::Manifest::load(&artifacts_dir(), &model)?;
    if !manifest.grad {
        anyhow::bail!("model {model} exported without grad modules (use tiny-sim or llama8b-sim)");
    }
    let m = manifest.clone();

    println!("starting NDIF server with {model} …");
    let cfg = NdifConfig { cotenancy: CoTenancy::Sequential, ..NdifConfig::local(&[&model]) };
    let server = NdifServer::start(cfg)?;
    let client = NdifClient::new(server.addr());

    let tokens = Tensor::new(
        &[1, m.seq],
        (0..m.seq).map(|i| ((i * 3 + 2) % m.vocab) as f32).collect(),
    );
    let target = 5.0f32;

    // one remote trace: per-layer attribution = Σ (h ⊙ ∂L/∂h)
    let mut tr = Trace::new(&m.name, &tokens);
    tr.targets(&[target]);
    let mut saves = Vec::new();
    for l in 0..m.n_layers {
        let point = format!("layer.{l}");
        let h = tr.output(&point);
        let g = tr.grad(&point);
        let prod = tr.mul(h, g);
        let attr = tr.sum(prod);
        saves.push((l, tr.save(attr)));
    }
    let res = tr.run_remote(&client)?;

    let mut table = Table::new(&format!(
        "server-side attribution (h·∂L/∂h), {model}, target token {target}"
    ))
    .header(vec!["layer", "attribution"]);
    for (l, s) in &saves {
        table.row(vec![format!("layer.{l}"), format!("{:+.5}", res.get(*s).item())]);
    }
    table.print();
    println!("only {} scalar(s) crossed the wire for gradients of {} parameters’ activations",
        saves.len(), m.param_count);
    Ok(())
}
