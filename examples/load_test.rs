//! Interactive load-test driver (the Fig. 9 scenario, standalone).
//!
//! Two driving modes:
//!
//! * **closed loop** (default) — N concurrent users each submit
//!   back-to-back requests. Simple, but self-throttling: when the server
//!   slows, users issue fewer requests and tail latency is understated.
//! * **open loop** (`--open-loop`) — requests arrive on a schedule drawn
//!   from an [`nnscope::netsim::Arrivals`] process regardless of how the
//!   server keeps up. `--arrivals lognormal --sigma 1.5` produces the
//!   heavy-tailed burst-then-lull clustering of real inference traffic,
//!   which is what actually stresses queue-wait percentiles.
//!
//! Either way the report ends with the *server-side* latency breakdown —
//! p50/p95/p99 of end-to-end, queue-wait, and execution time, read from
//! the mergeable histograms behind `GET /v1/metrics` — next to the
//! client-observed response-time summary.
//!
//! A third mode finds the **capacity knee**: `--sweep` steps the
//! open-loop arrival rate geometrically (`--rate` start, `--rate-growth`
//! factor) and, after each round, reads the e2e p99 *for that round
//! alone* — the server histograms are cumulative, so the round's counts
//! are the per-bucket difference between consecutive snapshots, fed to
//! the same [`percentile_from_counts`] the fleet merge uses. The sweep
//! stops at the first rate whose p99 exceeds `--slo-ms` and reports the
//! last rate that stayed under it: the capacity knee. A closed-loop
//! driver cannot find this point — it self-throttles exactly when the
//! queue starts growing.
//!
//! Run: `cargo run --release --example load_test -- \
//!           [--model llama8b-sim] [--users 16] [--requests 2] \
//!           [--open-loop --rate 20 --arrivals lognormal --sigma 1.5 --count 64] \
//!           [--sweep --rate 4 --rate-growth 1.5 --slo-ms 250 --count 48]`

use std::time::Instant;

use nnscope::client::{remote::NdifClient, Trace};
use nnscope::models::{artifacts_dir, workload};
use nnscope::netsim::Arrivals;
use nnscope::obs::{percentile_from_counts, HistSnapshot, BUCKETS};
use nnscope::scheduler::CoTenancy;
use nnscope::server::{http, NdifConfig, NdifServer};
use nnscope::tensor::Tensor;
use nnscope::util::cli::Args;
use nnscope::util::{Prng, Summary};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(1);
    let model = args.str_or("model", "llama8b-sim");
    let parallel = args.flag("parallel-cotenancy");

    let manifest = nnscope::runtime::Manifest::load(&artifacts_dir(), &model)?;
    let m = manifest.clone();

    println!(
        "starting NDIF server with {model} ({} co-tenancy) …",
        if parallel { "parallel" } else { "sequential" }
    );
    let mut cfg = NdifConfig::local(&[&model]);
    cfg.cotenancy = if parallel {
        CoTenancy::Parallel { max_merge: 8 }
    } else {
        CoTenancy::Sequential
    };
    let server = NdifServer::start(cfg)?;
    let addr = server.addr();

    if args.flag("sweep") {
        return run_sweep(&server, addr, &model, &m, &args);
    }

    let wall = Instant::now();
    let all = if args.flag("open-loop") {
        let count = args.usize_or("count", 64);
        let rate = args.f64_or("rate", 20.0);
        let sigma = args.f64_or("sigma", 1.5);
        let kind = args.str_or("arrivals", "lognormal");
        let Some(arrivals) = Arrivals::parse(&kind, rate, sigma) else {
            anyhow::bail!("unknown arrival process '{kind}' (uniform | poisson | lognormal)");
        };
        println!(
            "open loop: {count} requests, {kind} arrivals @ {rate:.1}/s (mean gap {:.1} ms) …",
            arrivals.mean_gap() * 1e3
        );
        run_open_loop(addr, &model, &m, arrivals, count)?
    } else {
        let users = args.usize_or("users", 16);
        let requests = args.usize_or("requests", 2);
        println!("closed loop: {users} concurrent users × {requests} requests …");
        run_closed_loop(addr, &model, &m, users, requests)?
    };

    let s = Summary::of(&all);
    println!(
        "\nwall {:.2}s | client response time: mean±std {}s | median {:.3}s | q25 {:.3} q75 {:.3} | min {:.3} max {:.3}",
        wall.elapsed().as_secs_f64(),
        s.pm(),
        s.median,
        s.q25,
        s.q75,
        s.min,
        s.max
    );
    let (enq, done, failed, merged) = server.metrics(&model).unwrap();
    println!("server: enqueued={enq} completed={done} failed={failed} merged_batches={merged}");
    print_server_histograms(addr, &model)?;
    Ok(())
}

/// N users, each issuing back-to-back requests (the original Fig. 9 mode).
fn run_closed_loop(
    addr: std::net::SocketAddr,
    model: &str,
    m: &nnscope::runtime::Manifest,
    users: usize,
    requests: usize,
) -> anyhow::Result<Vec<f64>> {
    let handles: Vec<_> = (0..users)
        .map(|u| {
            let model = model.to_string();
            let (vocab, seq, n_layers) = (m.vocab, m.seq, m.n_layers);
            std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
                let client = NdifClient::new(addr);
                let mut rng = Prng::new(u as u64 + 1);
                let mut times = Vec::new();
                for _ in 0..requests {
                    times.push(one_request(&client, &model, &mut rng, vocab, seq, n_layers)?);
                }
                Ok(times)
            })
        })
        .collect();
    let mut all = Vec::new();
    for h in handles {
        all.extend(h.join().expect("user thread")?);
    }
    Ok(all)
}

/// Fire `count` requests on the arrival schedule, one thread per request,
/// without waiting for earlier requests to finish (open loop).
fn run_open_loop(
    addr: std::net::SocketAddr,
    model: &str,
    m: &nnscope::runtime::Manifest,
    arrivals: Arrivals,
    count: usize,
) -> anyhow::Result<Vec<f64>> {
    let mut gaps = Prng::new(0xa221_11a1);
    let mut handles = Vec::with_capacity(count);
    for i in 0..count {
        if i > 0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(arrivals.next_gap(&mut gaps)));
        }
        let model = model.to_string();
        let (vocab, seq, n_layers) = (m.vocab, m.seq, m.n_layers);
        handles.push(std::thread::spawn(move || -> anyhow::Result<f64> {
            let client = NdifClient::new(addr);
            let mut rng = Prng::new(i as u64 + 1);
            one_request(&client, &model, &mut rng, vocab, seq, n_layers)
        }));
    }
    let mut all = Vec::new();
    for h in handles {
        all.push(h.join().expect("request thread")?);
    }
    Ok(all)
}

/// Step the open-loop arrival rate geometrically until one round's e2e
/// p99 exceeds the SLO; the last rate that stayed under it is the
/// capacity knee. Round-local percentiles come from diffing consecutive
/// cumulative histogram snapshots bucket-by-bucket — the same
/// [`percentile_from_counts`] path the coordinator's fleet merge uses.
fn run_sweep(
    server: &NdifServer,
    addr: std::net::SocketAddr,
    model: &str,
    m: &nnscope::runtime::Manifest,
    args: &Args,
) -> anyhow::Result<()> {
    let slo_ms = args.f64_or("slo-ms", 250.0);
    let count = args.usize_or("count", 48);
    let growth = args.f64_or("rate-growth", 1.5);
    let rounds = args.usize_or("rounds", 10);
    let sigma = args.f64_or("sigma", 1.5);
    let kind = args.str_or("arrivals", "poisson");
    let start_rate = args.f64_or("rate", 4.0);

    // warm the lazy first-request path so round 1 is not billed for it
    run_closed_loop(addr, model, m, 2, 1)?;

    println!(
        "sweep: {kind} arrivals, {count} requests/round, rate ×{growth:.2} per round, SLO e2e p99 ≤ {slo_ms:.0} ms"
    );
    let mut prev = fetch_e2e(addr, model, 0)?;
    let mut rate = start_rate;
    let mut knee: Option<(f64, f64)> = None;
    let mut first_over: Option<(f64, f64)> = None;
    for round in 1..=rounds {
        let Some(arrivals) = Arrivals::parse(&kind, rate, sigma) else {
            anyhow::bail!("unknown arrival process '{kind}' (uniform | poisson | lognormal)");
        };
        let wall = Instant::now();
        run_open_loop(addr, model, m, arrivals, count)?;
        let achieved = count as f64 / wall.elapsed().as_secs_f64();
        let cur = fetch_e2e(addr, model, prev.count + count as u64)?;
        let mut delta = [0u64; BUCKETS];
        for (d, (c, p)) in delta.iter_mut().zip(cur.counts.iter().zip(prev.counts.iter())) {
            *d = c.saturating_sub(*p);
        }
        let p50 = percentile_from_counts(&delta, 0.50) * 1e3;
        let p99 = percentile_from_counts(&delta, 0.99) * 1e3;
        let under = p99 <= slo_ms;
        println!(
            "  round {round:>2}: offered {rate:>7.2}/s achieved {achieved:>7.2}/s | e2e p50 {p50:>9.2} ms p99 {p99:>9.2} ms | {}",
            if under { "under SLO" } else { "OVER SLO" }
        );
        if !under {
            first_over = Some((rate, p99));
            break;
        }
        knee = Some((rate, p99));
        prev = cur;
        rate *= growth;
    }
    match (knee, first_over) {
        (Some((r, p99)), Some((over_r, over_p99))) => println!(
            "\ncapacity knee ≈ {r:.1} req/s (e2e p99 {p99:.1} ms ≤ SLO {slo_ms:.0} ms); \
             saturated at {over_r:.1} req/s (p99 {over_p99:.1} ms)"
        ),
        (Some((r, p99)), None) => println!(
            "\nno knee found: p99 still {p99:.1} ms ≤ SLO {slo_ms:.0} ms at {r:.1} req/s — \
             raise --rounds or --rate-growth"
        ),
        (None, Some((over_r, over_p99))) => println!(
            "\nknee is below the starting rate: p99 already {over_p99:.1} ms > SLO {slo_ms:.0} ms \
             at {over_r:.1} req/s — lower --rate"
        ),
        (None, None) => println!("\nsweep ran zero rounds (check --rounds)"),
    }
    let (enq, done, failed, merged) = server.metrics(model).unwrap();
    println!("server: enqueued={enq} completed={done} failed={failed} merged_batches={merged}");
    Ok(())
}

/// Cumulative e2e snapshot from `GET /v1/metrics`, polling until its
/// count reaches `min_count`: the worker records e2e as it publishes a
/// result, so a client that just received its answer can be one beat
/// ahead of the histogram.
fn fetch_e2e(
    addr: std::net::SocketAddr,
    model: &str,
    min_count: u64,
) -> anyhow::Result<HistSnapshot> {
    let deadline = Instant::now() + std::time::Duration::from_secs(5);
    loop {
        let (status, body) = http::get(addr, "/v1/metrics")?;
        anyhow::ensure!(status == 200, "metrics endpoint returned {status}");
        let j = nnscope::json::parse(std::str::from_utf8(&body)?)?;
        let s = HistSnapshot::from_json(j.get(model).get("latency").get("e2e")).unwrap_or_default();
        if s.count >= min_count || Instant::now() >= deadline {
            return Ok(s);
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
}

/// Submit one random-layer save request; returns the response time.
fn one_request(
    client: &NdifClient,
    model: &str,
    rng: &mut Prng,
    vocab: usize,
    seq: usize,
    n_layers: usize,
) -> anyhow::Result<f64> {
    let req = workload::load_test_request(rng, vocab, seq, n_layers);
    let tokens = Tensor::new(&[1, seq], req.tokens.clone());
    let mut tr = Trace::new(model, &tokens);
    let h = tr.output(&format!("layer.{}", req.layer));
    tr.save(h);
    let t = Instant::now();
    tr.run_remote(client)?;
    Ok(t.elapsed().as_secs_f64())
}

/// Print the server's own latency percentiles: e2e, queue wait, and
/// execution, straight from the `GET /v1/metrics` histograms.
fn print_server_histograms(addr: std::net::SocketAddr, model: &str) -> anyhow::Result<()> {
    let (status, body) = http::get(addr, "/v1/metrics")?;
    anyhow::ensure!(status == 200, "metrics endpoint returned {status}");
    let j = nnscope::json::parse(std::str::from_utf8(&body)?)?;
    let latency = j.get(model).get("latency");
    println!("server histograms ({model}):");
    for kind in ["e2e", "queue_wait", "exec"] {
        match HistSnapshot::from_json(latency.get(kind)) {
            Some(h) if h.count > 0 => println!(
                "  {kind:<10} n={:<5} p50 {:>8.3} ms | p95 {:>8.3} ms | p99 {:>8.3} ms | mean {:>8.3} ms",
                h.count,
                h.percentile(0.50) * 1e3,
                h.percentile(0.95) * 1e3,
                h.percentile(0.99) * 1e3,
                h.mean_s() * 1e3
            ),
            _ => println!("  {kind:<10} (no observations)"),
        }
    }
    Ok(())
}
