//! Interactive load-test driver (the Fig. 9 scenario, standalone).
//!
//! Two driving modes:
//!
//! * **closed loop** (default) — N concurrent users each submit
//!   back-to-back requests. Simple, but self-throttling: when the server
//!   slows, users issue fewer requests and tail latency is understated.
//! * **open loop** (`--open-loop`) — requests arrive on a schedule drawn
//!   from an [`nnscope::netsim::Arrivals`] process regardless of how the
//!   server keeps up. `--arrivals lognormal --sigma 1.5` produces the
//!   heavy-tailed burst-then-lull clustering of real inference traffic,
//!   which is what actually stresses queue-wait percentiles.
//!
//! Either way the report ends with the *server-side* latency breakdown —
//! p50/p95/p99 of end-to-end, queue-wait, and execution time, read from
//! the mergeable histograms behind `GET /v1/metrics` — next to the
//! client-observed response-time summary.
//!
//! Run: `cargo run --release --example load_test -- \
//!           [--model llama8b-sim] [--users 16] [--requests 2] \
//!           [--open-loop --rate 20 --arrivals lognormal --sigma 1.5 --count 64]`

use std::time::Instant;

use nnscope::client::{remote::NdifClient, Trace};
use nnscope::models::{artifacts_dir, workload};
use nnscope::netsim::Arrivals;
use nnscope::obs::HistSnapshot;
use nnscope::scheduler::CoTenancy;
use nnscope::server::{http, NdifConfig, NdifServer};
use nnscope::tensor::Tensor;
use nnscope::util::cli::Args;
use nnscope::util::{Prng, Summary};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(1);
    let model = args.str_or("model", "llama8b-sim");
    let parallel = args.flag("parallel-cotenancy");

    let manifest = nnscope::runtime::Manifest::load(&artifacts_dir(), &model)?;
    let m = manifest.clone();

    println!(
        "starting NDIF server with {model} ({} co-tenancy) …",
        if parallel { "parallel" } else { "sequential" }
    );
    let mut cfg = NdifConfig::local(&[&model]);
    cfg.cotenancy = if parallel {
        CoTenancy::Parallel { max_merge: 8 }
    } else {
        CoTenancy::Sequential
    };
    let server = NdifServer::start(cfg)?;
    let addr = server.addr();

    let wall = Instant::now();
    let all = if args.flag("open-loop") {
        let count = args.usize_or("count", 64);
        let rate = args.f64_or("rate", 20.0);
        let sigma = args.f64_or("sigma", 1.5);
        let kind = args.str_or("arrivals", "lognormal");
        let Some(arrivals) = Arrivals::parse(&kind, rate, sigma) else {
            anyhow::bail!("unknown arrival process '{kind}' (uniform | poisson | lognormal)");
        };
        println!(
            "open loop: {count} requests, {kind} arrivals @ {rate:.1}/s (mean gap {:.1} ms) …",
            arrivals.mean_gap() * 1e3
        );
        run_open_loop(addr, &model, &m, arrivals, count)?
    } else {
        let users = args.usize_or("users", 16);
        let requests = args.usize_or("requests", 2);
        println!("closed loop: {users} concurrent users × {requests} requests …");
        run_closed_loop(addr, &model, &m, users, requests)?
    };

    let s = Summary::of(&all);
    println!(
        "\nwall {:.2}s | client response time: mean±std {}s | median {:.3}s | q25 {:.3} q75 {:.3} | min {:.3} max {:.3}",
        wall.elapsed().as_secs_f64(),
        s.pm(),
        s.median,
        s.q25,
        s.q75,
        s.min,
        s.max
    );
    let (enq, done, failed, merged) = server.metrics(&model).unwrap();
    println!("server: enqueued={enq} completed={done} failed={failed} merged_batches={merged}");
    print_server_histograms(addr, &model)?;
    Ok(())
}

/// N users, each issuing back-to-back requests (the original Fig. 9 mode).
fn run_closed_loop(
    addr: std::net::SocketAddr,
    model: &str,
    m: &nnscope::runtime::Manifest,
    users: usize,
    requests: usize,
) -> anyhow::Result<Vec<f64>> {
    let handles: Vec<_> = (0..users)
        .map(|u| {
            let model = model.to_string();
            let (vocab, seq, n_layers) = (m.vocab, m.seq, m.n_layers);
            std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
                let client = NdifClient::new(addr);
                let mut rng = Prng::new(u as u64 + 1);
                let mut times = Vec::new();
                for _ in 0..requests {
                    times.push(one_request(&client, &model, &mut rng, vocab, seq, n_layers)?);
                }
                Ok(times)
            })
        })
        .collect();
    let mut all = Vec::new();
    for h in handles {
        all.extend(h.join().expect("user thread")?);
    }
    Ok(all)
}

/// Fire `count` requests on the arrival schedule, one thread per request,
/// without waiting for earlier requests to finish (open loop).
fn run_open_loop(
    addr: std::net::SocketAddr,
    model: &str,
    m: &nnscope::runtime::Manifest,
    arrivals: Arrivals,
    count: usize,
) -> anyhow::Result<Vec<f64>> {
    let mut gaps = Prng::new(0xa221_11a1);
    let mut handles = Vec::with_capacity(count);
    for i in 0..count {
        if i > 0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(arrivals.next_gap(&mut gaps)));
        }
        let model = model.to_string();
        let (vocab, seq, n_layers) = (m.vocab, m.seq, m.n_layers);
        handles.push(std::thread::spawn(move || -> anyhow::Result<f64> {
            let client = NdifClient::new(addr);
            let mut rng = Prng::new(i as u64 + 1);
            one_request(&client, &model, &mut rng, vocab, seq, n_layers)
        }));
    }
    let mut all = Vec::new();
    for h in handles {
        all.push(h.join().expect("request thread")?);
    }
    Ok(all)
}

/// Submit one random-layer save request; returns the response time.
fn one_request(
    client: &NdifClient,
    model: &str,
    rng: &mut Prng,
    vocab: usize,
    seq: usize,
    n_layers: usize,
) -> anyhow::Result<f64> {
    let req = workload::load_test_request(rng, vocab, seq, n_layers);
    let tokens = Tensor::new(&[1, seq], req.tokens.clone());
    let mut tr = Trace::new(model, &tokens);
    let h = tr.output(&format!("layer.{}", req.layer));
    tr.save(h);
    let t = Instant::now();
    tr.run_remote(client)?;
    Ok(t.elapsed().as_secs_f64())
}

/// Print the server's own latency percentiles: e2e, queue wait, and
/// execution, straight from the `GET /v1/metrics` histograms.
fn print_server_histograms(addr: std::net::SocketAddr, model: &str) -> anyhow::Result<()> {
    let (status, body) = http::get(addr, "/v1/metrics")?;
    anyhow::ensure!(status == 200, "metrics endpoint returned {status}");
    let j = nnscope::json::parse(std::str::from_utf8(&body)?)?;
    let latency = j.get(model).get("latency");
    println!("server histograms ({model}):");
    for kind in ["e2e", "queue_wait", "exec"] {
        match HistSnapshot::from_json(latency.get(kind)) {
            Some(h) if h.count > 0 => println!(
                "  {kind:<10} n={:<5} p50 {:>8.3} ms | p95 {:>8.3} ms | p99 {:>8.3} ms | mean {:>8.3} ms",
                h.count,
                h.percentile(0.50) * 1e3,
                h.percentile(0.95) * 1e3,
                h.percentile(0.99) * 1e3,
                h.mean_s() * 1e3
            ),
            _ => println!("  {kind:<10} (no observations)"),
        }
    }
    Ok(())
}
