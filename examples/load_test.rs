//! Interactive load-test driver (the Fig. 9 scenario, standalone).
//!
//! Simulates N concurrent users, each submitting a request that saves the
//! output of a uniformly-random layer of the served model, and reports the
//! response-time distribution. `benches/fig9.rs` runs the full sweep; this
//! example drives one configuration for exploration.
//!
//! Run: `cargo run --release --example load_test -- \
//!           [--model llama8b-sim] [--users 16] [--requests 2]`

use std::time::Instant;

use nnscope::client::{remote::NdifClient, Trace};
use nnscope::models::{artifacts_dir, workload};
use nnscope::scheduler::CoTenancy;
use nnscope::server::{NdifConfig, NdifServer};
use nnscope::tensor::Tensor;
use nnscope::util::cli::Args;
use nnscope::util::{Prng, Summary};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(1);
    let model = args.str_or("model", "llama8b-sim");
    let users = args.usize_or("users", 16);
    let requests = args.usize_or("requests", 2);
    let parallel = args.flag("parallel-cotenancy");

    let manifest = nnscope::runtime::Manifest::load(&artifacts_dir(), &model)?;
    let m = manifest.clone();

    println!("starting NDIF server with {model} ({} co-tenancy) …",
        if parallel { "parallel" } else { "sequential" });
    let mut cfg = NdifConfig::local(&[&model]);
    cfg.cotenancy = if parallel {
        CoTenancy::Parallel { max_merge: 8 }
    } else {
        CoTenancy::Sequential
    };
    let server = NdifServer::start(cfg)?;
    let addr = server.addr();

    println!("simulating {users} concurrent users × {requests} requests …");
    let wall = Instant::now();
    let handles: Vec<_> = (0..users)
        .map(|u| {
            let model = model.clone();
            let (vocab, seq, n_layers) = (m.vocab, m.seq, m.n_layers);
            std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
                let client = NdifClient::new(addr);
                let mut rng = Prng::new(u as u64 + 1);
                let mut times = Vec::new();
                for _ in 0..requests {
                    let req = workload::load_test_request(&mut rng, vocab, seq, n_layers);
                    let tokens = Tensor::new(&[1, seq], req.tokens.clone());
                    let mut tr = Trace::new(&model, &tokens);
                    let h = tr.output(&format!("layer.{}", req.layer));
                    tr.save(h);
                    let t = Instant::now();
                    tr.run_remote(&client)?;
                    times.push(t.elapsed().as_secs_f64());
                }
                Ok(times)
            })
        })
        .collect();

    let mut all = Vec::new();
    for h in handles {
        all.extend(h.join().expect("user thread")?);
    }
    let s = Summary::of(&all);
    println!("\nwall {:.2}s | response time: mean±std {}s | median {:.3}s | q25 {:.3} q75 {:.3} | min {:.3} max {:.3}",
        wall.elapsed().as_secs_f64(), s.pm(), s.median, s.q25, s.q75, s.min, s.max);
    let (enq, done, failed, merged) = server.metrics(&model).unwrap();
    println!("server: enqueued={enq} completed={done} failed={failed} merged_batches={merged}");
    Ok(())
}
