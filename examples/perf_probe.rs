//! Perf probe: raw forward-pass wallclock for the largest configs — the
//! measurement driving the §Perf iteration log in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example perf_probe [-- --model X --batch N --iters K]`

use nnscope::models::{artifacts_dir, ModelRunner};
use nnscope::tensor::Tensor;
use nnscope::util::cli::Args;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(1);
    let iters = args.usize_or("iters", 3);
    let models: Vec<String> = match args.get("model") {
        Some(m) => vec![m.to_string()],
        None => vec!["opt-66b-sim".into(), "llama8b-sim".into()],
    };
    for model in &models {
        let lm = ModelRunner::load(&artifacts_dir(), model)?;
        let m = lm.manifest.clone();
        let batches: Vec<usize> = match args.get("batch") {
            Some(b) => vec![b.parse()?],
            None => m.batches.clone(),
        };
        for b in batches {
            let tokens = Tensor::zeros(&[b, m.seq]);
            lm.forward_plain(&tokens)?; // warmup + compile
            let t0 = Instant::now();
            for _ in 0..iters {
                lm.forward_plain(&tokens)?;
            }
            let per = t0.elapsed().as_secs_f64() / iters as f64;
            let gflop = 2.0 * m.param_count as f64 * (b * m.seq) as f64 / 1e9;
            println!(
                "{model} b={b}: {per:.3}s/forward  (~{:.1} GFLOP, {:.1} GFLOPS effective)",
                gflop,
                gflop / per
            );
        }
    }
    Ok(())
}
