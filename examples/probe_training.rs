//! Remote linear-probe training — the paper's Code Example 8 analog.
//!
//! Train a probe to predict layer-1 hidden states from layer-0 hidden
//! states: activations are fetched from a (remote) NDIF server via
//! intervention graphs (a Session batches the epoch's traces into one
//! request); the probe's parameters and optimizer live client-side in the
//! host tensor engine.
//!
//! Run: `cargo run --release --example probe_training -- \
//!           [--model tiny-sim] [--epochs 30] [--remote]`

use nnscope::client::{remote::NdifClient, Session, Trace};
use nnscope::models::{artifacts_dir, ModelRunner};
use nnscope::scheduler::CoTenancy;
use nnscope::server::{NdifConfig, NdifServer};
use nnscope::tensor::optim::{mse, Adam, LinearProbe};
use nnscope::tensor::Tensor;
use nnscope::util::cli::Args;
use nnscope::util::Prng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(1);
    let model = args.str_or("model", "tiny-sim");
    let epochs = args.usize_or("epochs", 30);
    let remote = args.flag("remote");

    let manifest = nnscope::runtime::Manifest::load(&artifacts_dir(), &model)?;
    let m = manifest.clone();
    let d = m.d_model;

    // execution backends
    let local_runner = if remote { None } else { Some(ModelRunner::load(&artifacts_dir(), &model)?) };
    let server;
    let client = if remote {
        println!("starting NDIF server with {model} …");
        let cfg = NdifConfig { cotenancy: CoTenancy::Sequential, ..NdifConfig::local(&[&model]) };
        server = NdifServer::start(cfg)?;
        Some(NdifClient::new(server.addr()))
    } else {
        None
    };

    let mut rng = Prng::new(8);
    let mut probe = LinearProbe::new(d, d, &mut rng);
    let mut opt = Adam::new(0.01);

    println!("training a {d}×{d} probe: layer.0 output → layer.1 output ({} mode)",
        if remote { "remote" } else { "local" });
    let mut first_loss = None;
    let mut last_loss = 0.0;
    for epoch in 0..epochs {
        // one batch of random prompts, activations fetched via a session
        let mut session = Session::new();
        let mut saves = Vec::new();
        for _ in 0..4 {
            let tokens = Tensor::new(
                &[1, m.seq],
                (0..m.seq).map(|_| rng.range(1, m.vocab) as f32).collect(),
            );
            let mut tr = Trace::new(&model, &tokens);
            let h0 = tr.output("layer.0");
            let h1 = tr.output("layer.1");
            let s0 = tr.save(h0);
            let s1 = tr.save(h1);
            saves.push((s0, s1));
            session.add(tr);
        }
        let results = match (&local_runner, &client) {
            (Some(r), _) => session.run_local(r)?,
            (_, Some(c)) => session.run_remote(c)?,
            _ => unreachable!(),
        };

        // stack the fetched activations into training rows
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for (res, (s0, s1)) in results.iter().zip(&saves) {
            xs.extend_from_slice(res.get(*s0).data());
            ys.extend_from_slice(res.get(*s1).data());
        }
        let rows = xs.len() / d;
        let x = Tensor::new(&[rows, d], xs);
        let y = Tensor::new(&[rows, d], ys);

        let loss = probe.train_step(&x, &y, &mut opt);
        if first_loss.is_none() {
            first_loss = Some(loss);
        }
        last_loss = loss;
        if epoch % 5 == 0 || epoch + 1 == epochs {
            println!("  epoch {epoch:>3}: mse {loss:.5}");
        }
    }

    let first = first_loss.unwrap();
    println!("\nloss {first:.5} → {last_loss:.5} ({:.1}% reduction)",
        100.0 * (1.0 - last_loss / first));
    // evaluate on a held-out prompt
    let tokens = Tensor::new(&[1, m.seq], (0..m.seq).map(|i| ((i * 11) % m.vocab) as f32).collect());
    let eval_runner = ModelRunner::load(&artifacts_dir(), &model)?;
    let mut tr = Trace::new(&model, &tokens);
    let h0 = tr.output("layer.0");
    let h1 = tr.output("layer.1");
    let s0 = tr.save(h0);
    let s1 = tr.save(h1);
    let res = tr.run_local(&eval_runner)?;
    let x = Tensor::new(&[m.seq, d], res.get(s0).data().to_vec());
    let y = Tensor::new(&[m.seq, d], res.get(s1).data().to_vec());
    let (holdout, _) = mse(&probe.forward(&x), &y);
    println!("held-out mse: {holdout:.5}");
    assert!(last_loss < first, "probe failed to learn");
    Ok(())
}
