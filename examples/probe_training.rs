//! Remote linear-probe training — the paper's Code Example 5/8 analog,
//! now fully *in-fabric*.
//!
//! Train a probe to predict layer-1 hidden states from layer-0 hidden
//! states. Unlike the host-side version (which fetched activations every
//! epoch and updated parameters on the client), the probe's weights live
//! in **server-side session state**: every epoch is one trace that loads
//! `probe.w`/`probe.b` from state, computes the forward + MSE gradients +
//! SGD update *as intervention-graph ops*, and stores the new parameters
//! back (see [`nnscope::client::infabric`]). The whole training loop ships
//! as a single `POST /v1/session` — one upload, one download, zero
//! per-step WAN round trips — and only per-epoch loss scalars (plus the
//! final parameters) ever cross the wire.
//!
//! Run: `cargo run --release --example probe_training -- \
//!           [--model tiny-sim] [--epochs 40] [--lr-mult 0.5] [--local]`

use nnscope::client::infabric::{probe_training_session, stable_lr};
use nnscope::client::{remote::NdifClient, Trace};
use nnscope::models::{artifacts_dir, ModelRunner};
use nnscope::scheduler::CoTenancy;
use nnscope::server::{NdifConfig, NdifServer};
use nnscope::tensor::optim::mse;
use nnscope::tensor::Tensor;
use nnscope::util::cli::Args;
use nnscope::util::Prng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(1);
    let model = args.str_or("model", "tiny-sim");
    let epochs = args.usize_or("epochs", 40);
    let lr_mult = args.f64_or("lr-mult", 0.5) as f32;
    let local = args.flag("local");

    let manifest = nnscope::runtime::Manifest::load(&artifacts_dir(), &model)?;
    let (seq, d) = (manifest.seq, manifest.d_model);

    // client-side init only: the parameters never come back until training
    // is done
    let mut rng = Prng::new(8);
    let mut w0 = Tensor::zeros(&[d, d]);
    rng.fill_uniform_sym(w0.data_mut(), 0.05);
    let b0 = Tensor::zeros(&[d]);

    // one fixed prompt = full-batch gradient descent in the fabric
    let tokens = Tensor::new(
        &[1, seq],
        (0..seq).map(|i| ((i * 7 + 3) % manifest.vocab) as f32).collect(),
    );

    // execution backends
    let local_runner =
        if local { Some(ModelRunner::load(&artifacts_dir(), &model)?) } else { None };
    let server;
    let client = if local {
        None
    } else {
        println!("starting NDIF server with {model} ...");
        let cfg = NdifConfig { cotenancy: CoTenancy::Sequential, ..NdifConfig::local(&[&model]) };
        server = NdifServer::start(cfg)?;
        Some(NdifClient::new(server.addr()))
    };

    // setup trace: fetch the training activations once to pick a stable
    // step size from the activation scale
    let mut tr = Trace::new(&model, &tokens);
    let h0 = tr.output("layer.0");
    let s0 = tr.save(h0);
    let res = match (&local_runner, &client) {
        (Some(r), _) => tr.run_local(r)?,
        (_, Some(c)) => tr.run_remote(c)?,
        _ => unreachable!(),
    };
    let lr = stable_lr(res.get(s0), lr_mult);

    let plan = probe_training_session(
        &model,
        &tokens,
        ("layer.0", "layer.1"),
        epochs,
        lr,
        (&w0, &b0),
    );
    println!(
        "training a {d}x{d} probe in-fabric: layer.0 -> layer.1, {epochs} epochs, lr {lr:.4}, \
         {} traces in one session ({} mode)",
        plan.session.len(),
        if local { "local" } else { "remote, single POST /v1/session" }
    );

    let results = match (&local_runner, &client) {
        (Some(r), _) => plan.session.run_local(r)?,
        // the entire loop is ONE request: parameters never cross the wire
        (_, Some(c)) => plan.session.run_remote(c)?,
        _ => unreachable!(),
    };

    let losses: Vec<f32> = plan
        .loss_saves
        .iter()
        .zip(&results)
        .map(|(s, r)| r.get(*s).item())
        .collect();
    for (e, l) in losses.iter().enumerate() {
        if e % 5 == 0 || e + 1 == losses.len() {
            println!("  epoch {e:>3}: mse {l:.5}");
        }
    }
    let (first, last) = (losses[0], *losses.last().unwrap());
    println!(
        "\nloss {first:.5} -> {last:.5} ({:.1}% reduction)",
        100.0 * (1.0 - last / first)
    );

    // held-out evaluation with the fetched parameters
    let final_res = results.last().unwrap();
    let w = final_res.get(plan.w_save).clone();
    let b = final_res.get(plan.b_save).clone();
    let eval_tokens = Tensor::new(
        &[1, seq],
        (0..seq).map(|i| ((i * 11) % manifest.vocab) as f32).collect(),
    );
    let eval_runner = ModelRunner::load(&artifacts_dir(), &model)?;
    let mut tr = Trace::new(&model, &eval_tokens);
    let h0 = tr.output("layer.0");
    let h1 = tr.output("layer.1");
    let s0 = tr.save(h0);
    let s1 = tr.save(h1);
    let res = tr.run_local(&eval_runner)?;
    let x = Tensor::new(&[seq, d], res.get(s0).data().to_vec());
    let y = Tensor::new(&[seq, d], res.get(s1).data().to_vec());
    let (holdout, _) = mse(&x.matmul(&w).add(&b), &y);
    println!("held-out mse: {holdout:.5}");
    assert!(last < first, "probe failed to learn in-fabric");
    Ok(())
}
