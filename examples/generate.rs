//! Steered generation: greedy decoding with a persistent intervention —
//! the Fig. 3 neuron activation applied at every decode step, changing
//! what the model writes.
//!
//! Run: `cargo run --release --example generate -- [--model tiny-sim] [--steps 8]`

use nnscope::models::{artifacts_dir, Hooks, ModelRunner};
use nnscope::tensor::{Range1, Tensor};
use nnscope::util::cli::Args;

struct Steer {
    layer: String,
    neurons: Vec<usize>,
    strength: f32,
}

impl Hooks for Steer {
    fn wants(&self, p: &str) -> bool {
        p == self.layer
    }
    fn on_output(&mut self, _p: &str, t: &mut Tensor) -> bool {
        let seq = t.dims()[1];
        for &n in &self.neurons {
            t.slice_fill(
                &[Range1::all(), Range1::one(seq - 1), Range1::one(n)],
                self.strength,
            );
        }
        true
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(1);
    let model = args.str_or("model", "tiny-sim");
    let steps = args.usize_or("steps", 8);

    let lm = ModelRunner::load(&artifacts_dir(), &model)?;
    let m = lm.manifest.clone();
    let prompt = Tensor::new(
        &[1, m.seq],
        (0..m.seq).map(|i| ((i * 3 + 1) % m.vocab) as f32).collect(),
    );

    let plain = lm.generate_plain(&prompt, steps)?;
    println!("plain   : {:?}", plain.tokens);

    let mut steer = Steer {
        layer: format!("layer.{}", m.n_layers / 2),
        neurons: vec![3, 5, 9],
        strength: args.f64_or("strength", 8.0) as f32,
    };
    let steered = lm.generate(&prompt, steps, &mut steer)?;
    println!("steered : {:?}", steered.tokens);
    println!(
        "{} of {steps} generated tokens changed under the persistent intervention",
        plain
            .tokens
            .iter()
            .zip(&steered.tokens)
            .filter(|(a, b)| a != b)
            .count()
    );
    Ok(())
}
