//! Logit lens: decode every layer's hidden state through the unembedding
//! and watch the prediction form across depth — a classic interpretability
//! recipe expressed as a single intervention graph (one forward pass, all
//! layers read server-side; only the per-layer argmax ids return).
//!
//! Run: `cargo run --release --example logit_lens -- [--model tiny-sim] [--remote]`

use nnscope::client::{remote::NdifClient, Trace};
use nnscope::models::{artifacts_dir, ModelRunner};
use nnscope::scheduler::CoTenancy;
use nnscope::server::{NdifConfig, NdifServer};
use nnscope::tensor::{Range1, Tensor};
use nnscope::util::cli::Args;
use nnscope::util::table::Table;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(1);
    let model = args.str_or("model", "tiny-sim");
    let remote = args.flag("remote");

    let manifest = nnscope::runtime::Manifest::load(&artifacts_dir(), &model)?;
    let m = manifest.clone();
    let wout = nnscope::models::weights::gen_param(
        &m.name,
        "lm_head",
        "wout",
        &[m.d_model, m.vocab],
    );

    let tokens = Tensor::new(
        &[1, m.seq],
        (0..m.seq).map(|i| ((i * 7 + 3) % m.vocab) as f32).collect(),
    );

    // one trace reading every layer; lens = argmax(h_l @ W_U) at last token
    let mut tr = Trace::new(&m.name, &tokens);
    let w = tr.constant(&wout);
    let mut saves = Vec::new();
    for l in 0..m.n_layers {
        let h = tr.output(&format!("layer.{l}"));
        let last = tr.slice(h, &[Range1::one(0), Range1::one(m.seq - 1)]);
        let lens = tr.matmul(last, w);
        let top = tr.argmax(lens);
        saves.push((l, tr.save(top)));
    }
    let logits = tr.output("lm_head");
    let last = tr.slice(logits, &[Range1::one(0), Range1::one(m.seq - 1)]);
    let final_top = tr.argmax(last);
    let final_save = tr.save(final_top);

    let res = if remote {
        println!("starting a local NDIF server for remote execution …");
        let cfg = NdifConfig { cotenancy: CoTenancy::Sequential, ..NdifConfig::local(&[&model]) };
        let server = NdifServer::start(cfg)?;
        let client = NdifClient::new(server.addr());
        tr.run_remote(&client)?
    } else {
        let lm = ModelRunner::load(&artifacts_dir(), &model)?;
        tr.run_local(&lm)?
    };

    let mut table = Table::new(&format!("logit lens — {model}")).header(vec!["layer", "top token (lens)"]);
    for (l, s) in &saves {
        table.row(vec![format!("layer.{l}"), format!("{}", res.get(*s).data()[0] as usize)]);
    }
    table.row(vec!["final (lm_head)".to_string(), format!("{}", res.get(final_save).data()[0] as usize)]);
    table.print();
    Ok(())
}
