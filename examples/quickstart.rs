//! Quickstart: the paper's Fig. 3(b) experiment, in nnscope.
//!
//! Load a model, open a tracing context, set three neurons at the last
//! token of a layer's output to a large value, and observe that the
//! model's next-token prediction changes — all in a handful of lines, with
//! the same code able to run remotely by swapping `run_local` for
//! `run_remote`.
//!
//! Run: `cargo run --release --example quickstart [-- --model tiny-sim]`

use nnscope::client::Trace;
use nnscope::models::{artifacts_dir, ModelRunner};
use nnscope::tensor::{Range1, Tensor};
use nnscope::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(1);
    let model = args.str_or("model", "tiny-sim");

    println!("loading {model} …");
    let lm = ModelRunner::load(&artifacts_dir(), &model)?;
    let m = lm.manifest.clone();
    println!(
        "  {} ({} params, {} layers, d_model {}, simulates {})",
        m.name, m.param_count, m.n_layers, m.d_model, m.simulates
    );

    // a prompt: token ids over the model's vocabulary
    let tokens = Tensor::new(
        &[1, m.seq],
        (0..m.seq).map(|i| ((i * 5 + 1) % m.vocab) as f32).collect(),
    );

    // baseline prediction
    let logits = lm.forward_plain(&tokens)?;
    let baseline = logits
        .slice(&[Range1::one(0), Range1::one(m.seq - 1)])
        .argmax_last()
        .data()[0] as usize;
    println!("baseline prediction: token {baseline}");

    // the Fig. 3 intervention: activate three neurons at the last token
    let neurons = [3usize, 5, 9];
    let layer = format!("layer.{}", m.n_layers / 2);
    let mut tr = Trace::new(&m.name, &tokens);
    let mut h = tr.output(&layer);
    for &n in &neurons {
        h = tr.fill(h, &[Range1::one(0), Range1::one(m.seq - 1), Range1::one(n)], 10.0);
    }
    tr.set_output(&layer, h);
    let out = tr.output("lm_head");
    let last = tr.slice(out, &[Range1::one(0), Range1::one(m.seq - 1)]);
    let pred = tr.argmax(last);
    let saved = tr.save(pred);

    let res = tr.run_local(&lm)?;
    let intervened = res.get(saved).data()[0] as usize;
    println!("after activating neurons {neurons:?} at {layer}: token {intervened}");
    if intervened != baseline {
        println!("the intervention changed the model's prediction ✓");
    } else {
        println!("(prediction unchanged for this prompt — try other neurons)");
    }
    Ok(())
}
