//! Neuron-group ablation sweep: zero successive spans of hidden units at
//! one layer and measure the impact on the model's IOI logit difference —
//! a causal-localization experiment run as a Session of traces.
//!
//! Run: `cargo run --release --example neuron_ablation -- [--model tiny-sim] [--layer 1]`

use nnscope::client::{Session, Trace};
use nnscope::models::workload::IoiBatch;
use nnscope::models::{artifacts_dir, ModelRunner};
use nnscope::tensor::Range1;
use nnscope::util::cli::Args;
use nnscope::util::table::Table;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(1);
    let model = args.str_or("model", "tiny-sim");

    let lm = ModelRunner::load(&artifacts_dir(), &model)?;
    let m = lm.manifest.clone();
    let layer = args.usize_or("layer", m.n_layers / 2);
    let groups = args.usize_or("groups", 8);
    let span = m.d_model / groups;

    let batch = IoiBatch::generate(4, m.vocab, m.seq, 7);
    let e = batch.examples[0].clone();
    let tokens = nnscope::tensor::Tensor::new(&[1, m.seq], e.base.clone());

    // baseline + one trace per ablated group, bundled in a session
    let mut session = Session::new();
    let mut saves = Vec::new();
    for g in 0..=groups {
        let mut tr = Trace::new(&m.name, &tokens);
        if g > 0 {
            let h = tr.output(&format!("layer.{layer}"));
            let from = (g - 1) * span;
            let ablated = tr.fill(
                h,
                &[Range1::all(), Range1::all(), Range1::new(from, from + span)],
                0.0,
            );
            tr.set_output(&format!("layer.{layer}"), ablated);
        }
        let logits = tr.output("lm_head");
        let ld = tr.logit_diff(logits, e.target, e.foil);
        let s = tr.save(ld);
        saves.push(s);
        session.add(tr);
    }

    let results = session.run_local(&lm)?;
    let baseline = results[0].get(saves[0]).data()[0];

    let mut table = Table::new(&format!(
        "neuron ablation — {model} layer.{layer}, spans of {span} units"
    ))
    .header(vec!["ablated units", "logit diff", "Δ vs baseline"]);
    table.row(vec!["(none)".to_string(), format!("{baseline:+.4}"), String::new()]);
    for g in 1..=groups {
        let v = results[g].get(saves[g]).data()[0];
        table.row(vec![
            format!("[{}, {})", (g - 1) * span, g * span),
            format!("{v:+.4}"),
            format!("{:+.4}", v - baseline),
        ]);
    }
    table.print();
    Ok(())
}
