//! In-fabric training-loop builders (paper §B.1 Code Example 5).
//!
//! Builds the canonical "train a linear probe between two module points"
//! workload as a stateful [`Session`]: every epoch is one trace that loads
//! the probe parameters from server-side session state, computes the
//! forward pass, MSE gradients, and SGD update as intervention-graph ops,
//! and stores the updated parameters back — so an N-epoch loop costs one
//! request, with only per-epoch loss scalars (and the final parameters,
//! fetched by a last trace) crossing the wire. Shared by
//! `examples/probe_training.rs`, `benches/sessions.rs`, and the
//! session-state integration tests so they all measure the same graph.

use crate::tensor::Tensor;

use super::{SavedRef, Session, Trace};

/// Session-state keys the probe parameters live under.
pub const W_KEY: &str = "probe.w";
pub const B_KEY: &str = "probe.b";

/// A built in-fabric training session plus the handles needed to read its
/// outcome: per-epoch losses and the final parameters (saved by a last,
/// extra trace).
pub struct ProbeTrainingPlan {
    pub session: Session,
    pub loss_saves: Vec<SavedRef>,
    pub w_save: SavedRef,
    pub b_save: SavedRef,
}

/// A stable full-batch SGD step size from the activation scale: GD on the
/// probe's quadratic objective converges for `lr < 2/λ_max`, and
/// `λ_max ≤ 2·E[x²]` bounds the curvature whatever the activation scale of
/// the source module is — so `mult` up to ~1.0 is safe, 0.5 comfortable.
pub fn stable_lr(h_src: &Tensor, mult: f32) -> f32 {
    let data = h_src.data();
    let x_ms = data.iter().map(|v| v * v).sum::<f32>() / data.len().max(1) as f32;
    mult / x_ms.max(1e-6)
}

/// Build the training loop: probe `dst = src @ w + b` between module
/// outputs `(src, dst)`, `epochs` SGD steps on one fixed prompt, all
/// parameter state server-side. `w0` must be `[d, d]` and `b0` `[d]` for
/// the model's hidden size `d`; `tokens` is one `[1, seq]` prompt.
/// `epochs` is clamped to at least 1 — the final fetch trace loads the
/// stored parameters, so a zero-epoch plan would be load-before-store.
pub fn probe_training_session(
    model: &str,
    tokens: &Tensor,
    points: (&str, &str),
    epochs: usize,
    lr: f32,
    init: (&Tensor, &Tensor),
) -> ProbeTrainingPlan {
    let epochs = epochs.max(1);
    let (src, dst) = points;
    let (w0, b0) = init;
    let seq = tokens.dims()[1];
    let d = w0.dims()[0];
    let n = (seq * d) as f32;

    let mut session = Session::new();
    let mut loss_saves = Vec::with_capacity(epochs);
    for epoch in 0..epochs {
        let mut tr = Trace::new(model, tokens);
        let h0 = tr.output(src);
        let h1 = tr.output(dst);
        let x = tr.reshape(h0, &[seq, d]);
        let y = tr.reshape(h1, &[seq, d]);
        // epoch 0 ships the init as constants; later epochs continue from
        // the parameters the previous epoch stored
        let (w, b) = if epoch == 0 {
            (tr.constant(w0), tr.constant(b0))
        } else {
            (tr.from_state(W_KEY), tr.from_state(B_KEY))
        };
        // forward + MSE loss
        let xw = tr.matmul(x, w);
        let pred = tr.add(xw, b);
        let diff = tr.sub(pred, y);
        let sq = tr.mul(diff, diff);
        let loss = tr.mean(sq);
        loss_saves.push(tr.save(loss));
        // gradients: dL/dpred = 2·diff/n ; dW = xᵀ·gout ; db = Σ_rows gout
        let gout = tr.scale(diff, 2.0 / n);
        let xt = tr.transpose(x);
        let dw = tr.matmul(xt, gout);
        let gcol = tr.mean_axis(gout, 0);
        let db = tr.scale(gcol, seq as f32);
        // SGD step, stored for the next epoch
        let wstep = tr.scale(dw, lr);
        let bstep = tr.scale(db, lr);
        let w2 = tr.sub(w, wstep);
        let b2 = tr.sub(b, bstep);
        tr.save_to_state(W_KEY, w2);
        tr.save_to_state(B_KEY, b2);
        session.add(tr);
    }
    // final trace: bring the trained parameters home
    let mut tr = Trace::new(model, tokens);
    let w = tr.from_state(W_KEY);
    let b = tr.from_state(B_KEY);
    let w_save = tr.save(w);
    let b_save = tr.save(b);
    session.add(tr);

    ProbeTrainingPlan { session, loss_saves, w_save, b_save }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_shape_and_state_threading() {
        let tokens = Tensor::zeros(&[1, 16]);
        let w0 = Tensor::zeros(&[32, 32]);
        let b0 = Tensor::zeros(&[32]);
        let plan = probe_training_session(
            "tiny-sim",
            &tokens,
            ("layer.0", "layer.1"),
            3,
            0.1,
            (&w0, &b0),
        );
        assert_eq!(plan.session.len(), 4); // 3 epochs + fetch trace
        assert_eq!(plan.loss_saves.len(), 3);
    }

    #[test]
    fn stable_lr_scales_inversely_with_activation_power() {
        let small = Tensor::full(&[4, 4], 0.5); // E[x²] = 0.25
        let big = Tensor::full(&[4, 4], 2.0); // E[x²] = 4
        assert!((stable_lr(&small, 0.5) - 2.0).abs() < 1e-5);
        assert!((stable_lr(&big, 0.5) - 0.125).abs() < 1e-6);
        assert!(stable_lr(&small, 0.5) > stable_lr(&big, 0.5));
    }
}
