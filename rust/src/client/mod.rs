//! The client tracing API — nnscope's analog of NNsight (§3.2).
//!
//! A [`Trace`] is a deferred-execution builder: operations on module
//! activations record intervention-graph nodes instead of computing, and
//! nothing touches the model until the trace is executed — locally against
//! a [`ModelRunner`], or remotely by serializing the graph to the NDIF
//! server ([`remote`]). `save()` marks values to be returned (the
//! LockProtocol), mirroring the `.save()` of the paper's API.
//!
//! [`scan`] provides the FakeTensor-style shape pre-flight (§B.1
//! "Scanning and Validation"): node shapes are inferred from the model
//! manifest without executing anything, catching shape bugs before the
//! forward pass runs.
//!
//! # Examples
//!
//! Building a trace records intervention-graph nodes without touching
//! any model (deferred execution), so the graph can be inspected,
//! validated, and serialized before anything runs:
//!
//! ```
//! use nnscope::client::Trace;
//! use nnscope::graph::validate::validate;
//! use nnscope::tensor::Tensor;
//!
//! let fseq: Vec<String> = vec!["embed".into(), "layer.0".into(), "lm_head".into()];
//! let mut tr = Trace::new("tiny-sim", &Tensor::zeros(&[1, 16]));
//! let h = tr.output("layer.0");      // getter proxy — nothing executes
//! let scaled = tr.scale(h, 2.0);
//! tr.set_output("layer.0", scaled);  // setter edge back into the model
//! let logits = tr.output("lm_head");
//! let saved = tr.save(logits);       // LockProtocol: returned to the user
//!
//! let g = tr.graph();
//! validate(g, &fseq).unwrap();
//! assert_eq!(g.saves().len(), 1);
//! assert_eq!(g.setter_points(), vec!["layer.0"]);
//! # let _ = saved;
//! ```
//!
//! Executing against a loaded model (requires built artifacts):
//!
//! ```no_run
//! # use nnscope::client::Trace;
//! # use nnscope::models::{ModelRunner, artifacts_dir};
//! # use nnscope::tensor::{Range1, Tensor};
//! let runner = ModelRunner::load(&artifacts_dir(), "tiny-sim").unwrap();
//! let tokens = Tensor::zeros(&[1, 16]);
//! let mut tr = Trace::new("tiny-sim", &tokens);
//! let h = tr.output("layer.0");
//! let patched = tr.fill(h, &[Range1::one(0), Range1::one(15)], 1.0);
//! tr.set_output("layer.0", patched);
//! let logits = tr.output("lm_head");
//! let saved = tr.save(logits);
//! let res = tr.run_local(&runner).unwrap();
//! let _logits = res.get(saved);
//! ```

pub mod infabric;
pub mod remote;
pub mod retry;
pub mod scan;
pub mod session;

pub use remote::{ExecOutcome, ExecuteOptions};
pub use retry::RetryPolicy;
pub use session::Session;

use anyhow::Result;

use crate::graph::{GraphResult, InterventionGraph, NodeId, Op, Port};
use crate::models::ModelRunner;
use crate::tensor::{Range1, Tensor};

/// Handle to a deferred value inside a trace (a proxy, in NNsight terms).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NodeRef(pub(crate) NodeId);

/// Handle to a `.save()`d value, redeemable against a [`TraceResult`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SavedRef(pub(crate) NodeId);

/// A tracing context: builds an intervention graph via deferred ops.
pub struct Trace {
    graph: InterventionGraph,
}

impl Trace {
    /// Start a trace for `model` over `[batch, seq]` token rows.
    pub fn new(model: &str, tokens: &Tensor) -> Trace {
        assert_eq!(tokens.rank(), 2, "tokens must be [batch, seq]");
        let mut graph = InterventionGraph::new(model);
        graph.batch = tokens.dims()[0];
        graph.tokens = tokens.data().to_vec();
        Trace { graph }
    }

    /// Request a sharded (tensor-parallel) forward pass.
    pub fn shards(&mut self, s: usize) -> &mut Self {
        self.graph.shards = s.max(1);
        self
    }

    /// Provide per-example target token ids (enables `grad`).
    pub fn targets(&mut self, ids: &[f32]) -> &mut Self {
        self.graph.targets = Some(ids.to_vec());
        self
    }

    /// Restrict this trace to a row slice of a shared batch (parallel
    /// co-tenancy; normally set by the scheduler, not end users).
    pub fn batch_group(&mut self, offset: usize, rows: usize) -> &mut Self {
        self.graph.batch_group = Some((offset, rows));
        self
    }

    // ---- attachment points -------------------------------------------------

    /// Proxy for a module's output activation.
    pub fn output(&mut self, module: &str) -> NodeRef {
        NodeRef(self.graph.push(Op::Getter { module: module.into(), port: Port::Output }))
    }

    /// Proxy for a module's input activation (the previous module's
    /// output, as in NNsight's `.input`).
    pub fn input(&mut self, module: &str) -> NodeRef {
        NodeRef(self.graph.push(Op::Getter { module: module.into(), port: Port::Input }))
    }

    /// Proxy for ∂loss/∂(module output); requires [`Trace::targets`].
    pub fn grad(&mut self, module: &str) -> NodeRef {
        NodeRef(self.graph.push(Op::Grad { module: module.into() }))
    }

    /// Replace a module's output with a computed value.
    pub fn set_output(&mut self, module: &str, v: NodeRef) {
        self.graph
            .push(Op::Setter { module: module.into(), port: Port::Output, arg: v.0 });
    }

    /// Replace a module's input (= previous module's output).
    pub fn set_input(&mut self, module: &str, v: NodeRef) {
        self.graph
            .push(Op::Setter { module: module.into(), port: Port::Input, arg: v.0 });
    }

    // ---- ops ----------------------------------------------------------------

    pub fn constant(&mut self, t: &Tensor) -> NodeRef {
        NodeRef(self.graph.push(Op::Const {
            dims: t.dims().to_vec(),
            data: t.data().to_vec(),
        }))
    }

    pub fn slice(&mut self, x: NodeRef, ranges: &[Range1]) -> NodeRef {
        NodeRef(self.graph.push(Op::Slice { arg: x.0, ranges: ranges.to_vec() }))
    }

    pub fn assign(&mut self, dst: NodeRef, ranges: &[Range1], src: NodeRef) -> NodeRef {
        NodeRef(self.graph.push(Op::Assign { dst: dst.0, ranges: ranges.to_vec(), src: src.0 }))
    }

    pub fn fill(&mut self, dst: NodeRef, ranges: &[Range1], value: f32) -> NodeRef {
        NodeRef(self.graph.push(Op::Fill { dst: dst.0, ranges: ranges.to_vec(), value }))
    }

    pub fn add(&mut self, a: NodeRef, b: NodeRef) -> NodeRef {
        NodeRef(self.graph.push(Op::Add { a: a.0, b: b.0 }))
    }

    pub fn sub(&mut self, a: NodeRef, b: NodeRef) -> NodeRef {
        NodeRef(self.graph.push(Op::Sub { a: a.0, b: b.0 }))
    }

    pub fn mul(&mut self, a: NodeRef, b: NodeRef) -> NodeRef {
        NodeRef(self.graph.push(Op::Mul { a: a.0, b: b.0 }))
    }

    pub fn matmul(&mut self, a: NodeRef, b: NodeRef) -> NodeRef {
        NodeRef(self.graph.push(Op::Matmul { a: a.0, b: b.0 }))
    }

    pub fn scale(&mut self, x: NodeRef, factor: f32) -> NodeRef {
        NodeRef(self.graph.push(Op::Scale { arg: x.0, factor }))
    }

    pub fn gelu(&mut self, x: NodeRef) -> NodeRef {
        NodeRef(self.graph.push(Op::Gelu { arg: x.0 }))
    }

    pub fn softmax(&mut self, x: NodeRef) -> NodeRef {
        NodeRef(self.graph.push(Op::Softmax { arg: x.0 }))
    }

    pub fn argmax(&mut self, x: NodeRef) -> NodeRef {
        NodeRef(self.graph.push(Op::Argmax { arg: x.0 }))
    }

    pub fn mean(&mut self, x: NodeRef) -> NodeRef {
        NodeRef(self.graph.push(Op::Mean { arg: x.0 }))
    }

    pub fn sum(&mut self, x: NodeRef) -> NodeRef {
        NodeRef(self.graph.push(Op::Sum { arg: x.0 }))
    }

    /// 2-D transpose (`xᵀ` for in-graph weight gradients).
    pub fn transpose(&mut self, x: NodeRef) -> NodeRef {
        NodeRef(self.graph.push(Op::Transpose { arg: x.0 }))
    }

    pub fn reshape(&mut self, x: NodeRef, dims: &[usize]) -> NodeRef {
        NodeRef(self.graph.push(Op::Reshape { arg: x.0, dims: dims.to_vec() }))
    }

    pub fn mean_axis(&mut self, x: NodeRef, axis: usize) -> NodeRef {
        NodeRef(self.graph.push(Op::MeanAxis { arg: x.0, axis }))
    }

    // ---- session state ------------------------------------------------------

    /// Proxy for a named session-state variable (server-side parameter
    /// state). Valid only when an earlier trace of the same session stored
    /// the key — loading first is a validation error. The value observed
    /// is the key's value as of trace start.
    pub fn from_state(&mut self, key: &str) -> NodeRef {
        NodeRef(self.graph.push(Op::LoadState { key: key.into() }))
    }

    /// Store a value into a named session-state variable; the update
    /// commits when the trace completes and is visible to later traces of
    /// the session. Returns a proxy for the stored value.
    pub fn save_to_state(&mut self, key: &str, v: NodeRef) -> NodeRef {
        NodeRef(self.graph.push(Op::StoreState { key: key.into(), arg: v.0 }))
    }

    /// The standard patching metric (server-side; only the scalar per row
    /// crosses the wire on remote execution — the Fig. 6c advantage).
    pub fn logit_diff(&mut self, logits: NodeRef, target: usize, foil: usize) -> NodeRef {
        NodeRef(self.graph.push(Op::LogitDiff { logits: logits.0, target, foil }))
    }

    /// LockProtocol: make this value available after execution.
    pub fn save(&mut self, x: NodeRef) -> SavedRef {
        SavedRef(self.graph.push(Op::Save { arg: x.0 }))
    }

    /// Per-step emission for streaming generation: the value is computed
    /// and returned at EVERY decode step (in that step's `StepEvent`),
    /// not once per request. Only valid when the trace is executed as a
    /// stream ([`remote::NdifClient::run_stream`]).
    pub fn step_hook(&mut self, x: NodeRef) -> SavedRef {
        SavedRef(self.graph.push(Op::StepHook { arg: x.0 }))
    }

    // ---- execution ----------------------------------------------------------

    /// Pre-flight shape check (FakeTensor analog); returns per-node shapes.
    pub fn scan(&self, manifest: &crate::runtime::Manifest) -> Result<Vec<Vec<usize>>> {
        scan::scan(&self.graph, manifest)
    }

    /// Execute locally against a loaded model. The graph runs through the
    /// same admission compiler a server would apply ([`crate::graph::opt`]);
    /// the report is available via [`TraceResult::opt_report`].
    pub fn run_local(self, runner: &ModelRunner) -> Result<TraceResult> {
        let out = crate::engine::Engine::new(runner)
            .run(crate::engine::ExecSpec::trace(&self.graph))?;
        Ok(TraceResult { result: out.result, opt_report: out.report })
    }

    /// Execute remotely against an NDIF server.
    pub fn run_remote(self, client: &remote::NdifClient) -> Result<TraceResult> {
        let out = client.run(&self.graph, remote::ExecuteOptions::new().detailed())?;
        Ok(TraceResult { result: out.result, opt_report: out.report })
    }

    /// Execute remotely as a streaming generation: greedy-decode `steps`
    /// tokens with this trace's interventions re-run at every step,
    /// yielding per-step events as they arrive.
    pub fn run_stream(
        self,
        client: &remote::NdifClient,
        steps: usize,
    ) -> Result<remote::StreamIter> {
        client.run_stream(&self.graph, steps, remote::ExecuteOptions::new())
    }

    /// The underlying graph (for the scheduler / tests / serialization).
    pub fn into_graph(self) -> InterventionGraph {
        self.graph
    }

    pub fn graph(&self) -> &InterventionGraph {
        &self.graph
    }
}

/// Saved values from an executed trace.
#[derive(Debug, Clone)]
pub struct TraceResult {
    result: GraphResult,
    /// What the executing fabric's graph compiler did (None when the
    /// request ran unoptimized or the path doesn't surface a report).
    opt_report: Option<crate::graph::opt::OptReport>,
}

impl TraceResult {
    pub fn from_graph_result(result: GraphResult) -> TraceResult {
        TraceResult { result, opt_report: None }
    }

    /// The per-request optimization report, when the executing side ran
    /// the graph through [`crate::graph::opt`] (local runs always do;
    /// remote runs surface the server's `/v1/result` `"opt"` metadata —
    /// absent under `--no-opt`).
    pub fn opt_report(&self) -> Option<&crate::graph::opt::OptReport> {
        self.opt_report.as_ref()
    }

    /// Get a saved value; panics if the handle is not from this trace.
    pub fn get(&self, s: SavedRef) -> &Tensor {
        self.result
            .get(s.0)
            .expect("saved value missing from result")
    }

    pub fn try_get(&self, s: SavedRef) -> Option<&Tensor> {
        self.result.get(s.0)
    }

    pub fn inner(&self) -> &GraphResult {
        &self.result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_patching_graph() {
        let tokens = Tensor::zeros(&[2, 16]);
        let mut tr = Trace::new("tiny-sim", &tokens);
        let h = tr.output("layer.0");
        let src = tr.slice(h, &[Range1::one(0)]);
        let patched = tr.assign(h, &[Range1::one(1)], src);
        tr.set_output("layer.0", patched);
        let logits = tr.output("lm_head");
        let ld = tr.logit_diff(logits, 3, 5);
        let _s = tr.save(ld);
        let g = tr.into_graph();
        assert_eq!(g.batch, 2);
        assert_eq!(g.nodes.len(), 7);
        assert_eq!(g.setter_points(), vec!["layer.0"]);
        assert_eq!(g.saves().len(), 1);
    }

    #[test]
    fn trace_serializes_and_deserializes() {
        let tokens = Tensor::zeros(&[1, 16]);
        let mut tr = Trace::new("tiny-sim", &tokens);
        let h = tr.output("layer.1");
        tr.save(h);
        let g = tr.into_graph();
        let j = crate::graph::serde::to_json(&g);
        let back = crate::graph::serde::from_json(&j).unwrap();
        assert_eq!(back.nodes, g.nodes);
    }
}
