//! Remote execution transport: the client side of the NDIF protocol.
//!
//! Adding `remote=True` in NNsight sends the experiment to NDIF; here,
//! [`NdifClient::run`] serializes the intervention graph, POSTs it,
//! long-polls the result, and deserializes the saved values — with one
//! [`ExecuteOptions`] selecting metadata detail, deep profiling, and
//! retry. All payload bytes are charged against a [`NetSim`] link so
//! benchmarks measure the paper's WAN conditions on loopback hardware.

use std::net::SocketAddr;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::graph::{opt::OptReport, serde as gserde, GraphResult, InterventionGraph};
use crate::json::{parse, Json};
use crate::netsim::NetSim;
use crate::server::http;

/// What kind of service answers at an address. The trace/session/result
/// surface is identical either way — discovery only matters to tools that
/// want fleet topology (status dashboards, load generators).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Endpoint {
    /// A single [`crate::server::NdifServer`] deployment.
    Single,
    /// An L3 [`crate::coordinator::Coordinator`] fronting many replicas.
    Fleet,
}

/// Options for one remote execution — the single knob set behind
/// [`NdifClient::run`] / [`NdifClient::run_session`] /
/// [`NdifClient::run_stream`], replacing the old
/// `execute`/`execute_detailed`/`execute_observed`/`execute_profiled`/
/// `*_with_retry` method matrix.
#[derive(Default)]
pub struct ExecuteOptions {
    detailed: bool,
    profiled: bool,
    retry: Option<crate::client::RetryPolicy>,
}

impl ExecuteOptions {
    pub fn new() -> ExecuteOptions {
        ExecuteOptions::default()
    }

    /// Populate the outcome's metadata: the server's per-request
    /// optimization report (`"opt"`; `None` when the server ran with
    /// `--no-opt`) and the request's `"timing"` trace (`None` when the
    /// server runs without observability).
    pub fn detailed(mut self) -> ExecuteOptions {
        self.detailed = true;
        self
    }

    /// Arm the deep execution profiler (the `x-nnscope-profile` header,
    /// honored by replicas directly or through a coordinator). The
    /// outcome's `profile` carries per-op self-times, phase totals and
    /// allocation accounting; the full Chrome trace is retained
    /// server-side under the outcome's `id`
    /// ([`NdifClient::profile_trace_events`]). The run errors if the
    /// server executed unprofiled, so callers never silently read an
    /// empty profile.
    pub fn profiled(mut self) -> ExecuteOptions {
        self.profiled = true;
        self
    }

    /// Run under a [`crate::client::RetryPolicy`]: replica deaths, 429
    /// throttles, and load sheds are retried with backoff + jitter
    /// (honoring `Retry-After`); request faults fail immediately. Safe
    /// because submission is idempotent from the client's view — each
    /// attempt is a fresh request id. For streams the policy covers
    /// opening the stream; a mid-stream death surfaces through the
    /// iterator ([`is_retryable_stream_err`]) and restarting is the
    /// caller's loop.
    pub fn retry(mut self, policy: crate::client::RetryPolicy) -> ExecuteOptions {
        self.retry = Some(policy);
        self
    }
}

/// Everything a remote execution can return. `result` is always
/// populated; the metadata blocks mirror what [`ExecuteOptions`] asked
/// for (and what the server attached).
pub struct ExecOutcome {
    /// Saved values, keyed by the ids of the graph as built.
    pub result: GraphResult,
    /// Admission-compile report ([`ExecuteOptions::detailed`]).
    pub report: Option<OptReport>,
    /// End-to-end `"timing"` trace ([`ExecuteOptions::detailed`]).
    pub timing: Option<Json>,
    /// Deep-profiler summary ([`ExecuteOptions::profiled`]).
    pub profile: Option<Json>,
    /// Server-side request id (keys retained debug artifacts).
    pub id: String,
}

/// Client handle to an NDIF server.
#[derive(Clone)]
pub struct NdifClient {
    addr: SocketAddr,
    /// Simulated WAN between this client and the service.
    pub link: NetSim,
    /// Auth token presented for gated models.
    pub token: Option<String>,
    /// Long-poll bound per result fetch.
    pub poll_timeout: Duration,
}

impl NdifClient {
    pub fn new(addr: SocketAddr) -> NdifClient {
        NdifClient {
            addr,
            link: NetSim::ideal(),
            token: None,
            poll_timeout: Duration::from_secs(300),
        }
    }

    pub fn with_link(mut self, link: NetSim) -> NdifClient {
        self.link = link;
        self
    }

    pub fn with_token(mut self, token: &str) -> NdifClient {
        self.token = Some(token.to_string());
        self
    }

    fn headers(&self) -> Vec<(&str, &str)> {
        let mut h = vec![("Content-Type", "application/json")];
        if let Some(t) = &self.token {
            h.push(("x-ndif-auth", t.as_str()));
        }
        h
    }

    /// Request headers carrying a client-minted trace id — the id the
    /// whole pipeline (coordinator retries included) stamps its spans
    /// under, echoed back in the result's `"timing"` metadata.
    fn headers_traced<'a>(&'a self, trace_id: &'a str) -> Vec<(&'a str, &'a str)> {
        let mut h = self.headers();
        h.push((crate::obs::TRACE_HEADER, trace_id));
        h
    }

    /// Health check.
    pub fn health(&self) -> Result<bool> {
        let (status, _) = http::get(self.addr, "/health")?;
        Ok(status == 200)
    }

    /// Coordinator discovery: is this address a single NDIF server or a
    /// fleet coordinator? Existing clients need not care — the NDIF API is
    /// mirrored — but fleet-aware tools branch on this.
    pub fn discover(&self) -> Result<Endpoint> {
        let (status, _) = http::get(self.addr, "/v1/fleet/status")?;
        Ok(if status == 200 { Endpoint::Fleet } else { Endpoint::Single })
    }

    /// Fleet topology and health, as reported by a coordinator's
    /// `/v1/fleet/status`. Errors against a single server (404).
    pub fn fleet_status(&self) -> Result<Json> {
        let (status, body) = http::get(self.addr, "/v1/fleet/status")?;
        if status != 200 {
            return Err(anyhow!("fleet status returned {status} (not a coordinator?)"));
        }
        Ok(parse(std::str::from_utf8(&body)?)?)
    }

    /// Server metrics snapshot from `/v1/metrics` — per-model queue and
    /// latency counters plus the `_plan` AOT plan-cache gauges (hits,
    /// misses, evictions, arena slots). Single-server endpoint; against a
    /// coordinator use `/v1/fleet/metrics` (see [`NdifClient::fleet_status`]
    /// for topology).
    pub fn metrics(&self) -> Result<Json> {
        let (status, body) = http::get(self.addr, "/v1/metrics")?;
        if status != 200 {
            return Err(anyhow!("metrics endpoint returned {status}"));
        }
        Ok(parse(std::str::from_utf8(&body)?)?)
    }

    /// Fetch hosted model metadata — the NDIF "setup" step measured by
    /// Fig. 6a (no weights move; this is why NDIF setup time is flat).
    pub fn models(&self) -> Result<Vec<String>> {
        self.link.send(64); // request
        let (status, body) = http::get(self.addr, "/v1/models")?;
        self.link.send(body.len());
        if status != 200 {
            return Err(anyhow!("models endpoint returned {status}"));
        }
        let j = parse(std::str::from_utf8(&body)?)?;
        Ok(j.get("models")
            .as_array()
            .unwrap_or(&[])
            .iter()
            .filter_map(|m| m.get("name").as_str().map(String::from))
            .collect())
    }

    /// Execute one intervention graph remotely — the one door for remote
    /// one-shot execution. `opts` selects everything that used to be a
    /// separate method: metadata detail ([`ExecuteOptions::detailed`]),
    /// deep profiling ([`ExecuteOptions::profiled`]), and retry
    /// ([`ExecuteOptions::retry`]). The trace id is minted here and
    /// propagated end to end via the `x-nnscope-trace` header; through a
    /// coordinator the timing metadata also carries routing attempt
    /// counts.
    ///
    /// ```ignore
    /// let out = client.run(&graph, ExecuteOptions::new().detailed())?;
    /// println!("{} values, opt: {:?}", out.result.values.len(), out.report);
    /// ```
    pub fn run(&self, graph: &InterventionGraph, opts: ExecuteOptions) -> Result<ExecOutcome> {
        match &opts.retry {
            Some(p) => p.call(|_| self.run_once(graph, &opts)),
            None => self.run_once(graph, &opts),
        }
    }

    /// One submit + long-poll attempt of [`NdifClient::run`].
    fn run_once(&self, graph: &InterventionGraph, opts: &ExecuteOptions) -> Result<ExecOutcome> {
        let trace_id = crate::obs::mint_trace_id();
        let payload = gserde::to_json(graph).to_string();
        // upstream: the graph + tokens
        self.link.send(payload.len());
        let mut headers = self.headers_traced(&trace_id);
        if opts.profiled {
            headers.push((crate::obs::PROFILE_HEADER, "1"));
        }
        let (status, body) =
            http::http_request(self.addr, "POST", "/v1/trace", payload.as_bytes(), &headers)?;
        if status != 202 {
            return Err(anyhow!(
                "trace submit failed ({status}): {}",
                String::from_utf8_lossy(&body)
            ));
        }
        let j = parse(std::str::from_utf8(&body)?)?;
        let id = j
            .get("id")
            .as_str()
            .ok_or_else(|| anyhow!("submit response missing id"))?
            .to_string();
        let j = self.poll_result_json(&id)?;
        Self::outcome_from_json(&j, id, opts)
    }

    /// Assemble an [`ExecOutcome`] from the raw result envelope.
    fn outcome_from_json(j: &Json, id: String, opts: &ExecuteOptions) -> Result<ExecOutcome> {
        let profile = if opts.profiled {
            let p = j.get("profile");
            if p.is_null() {
                return Err(anyhow!(
                    "result {id} carries no profile (server observability disabled?)"
                ));
            }
            Some(p.clone())
        } else {
            None
        };
        let (report, timing) = if opts.detailed {
            let timing = match j.get("timing") {
                Json::Null => None,
                t => Some(t.clone()),
            };
            (OptReport::from_json(j.get("opt")), timing)
        } else {
            (None, None)
        };
        Ok(ExecOutcome { result: gserde::result_from_json(j)?, report, timing, profile, id })
    }

    #[deprecated(note = "use run(graph, ExecuteOptions::new()) and take .result")]
    #[doc(hidden)]
    pub fn execute(&self, graph: &InterventionGraph) -> Result<GraphResult> {
        Ok(self.run(graph, ExecuteOptions::new())?.result)
    }

    #[deprecated(note = "use run(graph, ExecuteOptions::new().detailed())")]
    #[doc(hidden)]
    pub fn execute_detailed(
        &self,
        graph: &InterventionGraph,
    ) -> Result<(GraphResult, Option<OptReport>)> {
        let o = self.run(graph, ExecuteOptions::new().detailed())?;
        Ok((o.result, o.report))
    }

    #[deprecated(note = "use run(graph, ExecuteOptions::new().detailed())")]
    #[doc(hidden)]
    pub fn execute_observed(
        &self,
        graph: &InterventionGraph,
    ) -> Result<(GraphResult, Option<OptReport>, Option<Json>)> {
        let o = self.run(graph, ExecuteOptions::new().detailed())?;
        Ok((o.result, o.report, o.timing))
    }

    #[deprecated(note = "use run(graph, ExecuteOptions::new().profiled())")]
    #[doc(hidden)]
    pub fn execute_profiled(
        &self,
        graph: &InterventionGraph,
    ) -> Result<(GraphResult, Json, String)> {
        let o = self.run(graph, ExecuteOptions::new().profiled())?;
        Ok((o.result, o.profile.unwrap_or(Json::Null), o.id))
    }

    /// Fetch the retained Chrome/Perfetto trace-event JSON of a profiled
    /// request (`GET /v1/debug/profile/<id>` against the serving replica).
    /// Errors once the bounded profile ring has evicted the id.
    pub fn profile_trace_events(&self, id: &str) -> Result<Json> {
        let (status, body) = http::get(self.addr, &format!("/v1/debug/profile/{id}"))?;
        if status != 200 {
            return Err(anyhow!("profile {id} not retained (ring evicted, or wrong server?)"));
        }
        Ok(parse(std::str::from_utf8(&body)?)?)
    }

    /// The hot-op table: cumulative per-op self-time across every profiled
    /// request. Against a coordinator this is the fleet-merged
    /// `/v1/fleet/hotops`; against a single server, its `/v1/debug/hotops`.
    pub fn hotops(&self) -> Result<Json> {
        let path = match self.discover()? {
            Endpoint::Fleet => "/v1/fleet/hotops",
            Endpoint::Single => "/v1/debug/hotops",
        };
        let (status, body) = http::get(self.addr, path)?;
        if status != 200 {
            return Err(anyhow!("hotops endpoint returned {status}"));
        }
        Ok(parse(std::str::from_utf8(&body)?)?)
    }

    /// Long-poll a previously submitted result id until completion.
    /// `opts` selects metadata exactly as for [`NdifClient::run`] (the
    /// `retry` field is ignored — the poll already rides the long-poll
    /// loop).
    pub fn fetch(&self, id: &str, opts: ExecuteOptions) -> Result<ExecOutcome> {
        let j = self.poll_result_json(id)?;
        Self::outcome_from_json(&j, id.to_string(), &opts)
    }

    #[deprecated(note = "use fetch(id, ExecuteOptions::new()) and take .result")]
    #[doc(hidden)]
    pub fn fetch_result(&self, id: &str) -> Result<GraphResult> {
        Ok(self.fetch(id, ExecuteOptions::new())?.result)
    }

    #[deprecated(note = "use fetch(id, ExecuteOptions::new().detailed())")]
    #[doc(hidden)]
    pub fn fetch_result_detailed(&self, id: &str) -> Result<(GraphResult, Option<OptReport>)> {
        let o = self.fetch(id, ExecuteOptions::new().detailed())?;
        Ok((o.result, o.report))
    }

    #[deprecated(note = "use fetch(id, ExecuteOptions::new().detailed())")]
    #[doc(hidden)]
    pub fn fetch_result_observed(
        &self,
        id: &str,
    ) -> Result<(GraphResult, Option<OptReport>, Option<Json>)> {
        let o = self.fetch(id, ExecuteOptions::new().detailed())?;
        Ok((o.result, o.report, o.timing))
    }

    /// Long-poll `/v1/result/<id>` to completion and return the raw result
    /// envelope — values plus whatever metadata blocks the server attached
    /// (`opt`, `timing`, `profile`). Shared by the typed fetchers above.
    fn poll_result_json(&self, id: &str) -> Result<Json> {
        let deadline = std::time::Instant::now() + self.poll_timeout;
        loop {
            let remaining = deadline.saturating_duration_since(std::time::Instant::now());
            if remaining.is_zero() {
                return Err(anyhow!("result {id} timed out"));
            }
            let path = format!(
                "/v1/result/{id}?timeout_ms={}",
                remaining.as_millis().min(30_000)
            );
            let (status, body) = http::get(self.addr, &path)?;
            match status {
                200 => {
                    // downstream: only the saved values (the Fig. 6c
                    // server-side-intervention advantage)
                    self.link.send(body.len());
                    return Ok(parse(std::str::from_utf8(&body)?)?);
                }
                202 => continue,
                500 => {
                    return Err(anyhow!(
                        "remote execution failed: {}",
                        String::from_utf8_lossy(&body)
                    ))
                }
                other => return Err(anyhow!("result fetch returned {other}")),
            }
        }
    }

    /// Execute a session: multiple traces in order, one request, one
    /// bundled response (§B.1 "Remote Execution and Session"). With
    /// `session: None` state is ephemeral — cross-trace variables are
    /// dropped server-side once the response is sent. With a named
    /// session, state created by this bundle survives for follow-up
    /// bundles under the same id (until [`NdifClient::drop_session`] or
    /// TTL expiry); a coordinator pins the session to the replica holding
    /// its state, and if that replica dies mid-session the error carries
    /// `retryable` ([`is_retryable_session_err`]) — restart the session.
    ///
    /// Of `opts`, `retry` re-submits the whole bundle (the correct
    /// recovery for a replica death mid-session, and only appropriate
    /// when the bundle does not read state written by *earlier* bundles
    /// of the same named session); `detailed`/`profiled` have no effect
    /// on the bundled result shape.
    pub fn run_session(
        &self,
        graphs: &[InterventionGraph],
        session: Option<&str>,
        opts: ExecuteOptions,
    ) -> Result<Vec<GraphResult>> {
        match &opts.retry {
            Some(p) => p.call(|_| self.session_once(graphs, session)),
            None => self.session_once(graphs, session),
        }
    }

    #[deprecated(note = "use run_session(graphs, None, ExecuteOptions::new())")]
    #[doc(hidden)]
    pub fn execute_session(&self, graphs: &[InterventionGraph]) -> Result<Vec<GraphResult>> {
        self.run_session(graphs, None, ExecuteOptions::new())
    }

    #[deprecated(note = "use run_session(graphs, session, ExecuteOptions::new())")]
    #[doc(hidden)]
    pub fn execute_session_in(
        &self,
        graphs: &[InterventionGraph],
        session: Option<&str>,
    ) -> Result<Vec<GraphResult>> {
        self.run_session(graphs, session, ExecuteOptions::new())
    }

    /// One bundled submit of [`NdifClient::run_session`].
    fn session_once(
        &self,
        graphs: &[InterventionGraph],
        session: Option<&str>,
    ) -> Result<Vec<GraphResult>> {
        let traces: Vec<crate::json::Json> = graphs.iter().map(gserde::to_json).collect();
        let mut fields = vec![("traces", crate::json::Json::Array(traces))];
        if let Some(s) = session {
            fields.push(("session", crate::json::Json::from(s)));
        }
        let payload = crate::json::Json::obj(fields).to_string();
        let trace_id = crate::obs::mint_trace_id();
        self.link.send(payload.len());
        let (status, body) = http::http_request(
            self.addr,
            "POST",
            "/v1/session",
            payload.as_bytes(),
            &self.headers_traced(&trace_id),
        )?;
        self.link.send(body.len());
        if status != 200 {
            return Err(anyhow!(
                "session failed ({status}): {}",
                String::from_utf8_lossy(&body)
            ));
        }
        let j = parse(std::str::from_utf8(&body)?)?;
        j.get("results")
            .as_array()
            .ok_or_else(|| anyhow!("session response missing results"))?
            .iter()
            .map(gserde::result_from_json)
            .collect()
    }

    /// Start a streaming generation (`POST /v1/stream`): greedy-decode
    /// `steps` tokens, re-running the graph's interventions at every step.
    /// Returns a blocking [`StreamIter`] that yields [`StreamEvent`]s as
    /// the server produces them — the first event arrives while the rest
    /// of the generation is still running, which is the whole point.
    ///
    /// Works identically against a single server or a coordinator (which
    /// proxies the stream and converts a mid-stream replica death into a
    /// retryable tail error — see [`is_retryable_stream_err`]).
    ///
    /// Of `opts`, `retry` covers *opening* the stream (submit rejections,
    /// throttles); once the iterator is live, a mid-stream death surfaces
    /// through it and restarting from step 0 is the caller's loop.
    /// `detailed`/`profiled` have no effect on the event stream.
    pub fn run_stream(
        &self,
        graph: &InterventionGraph,
        steps: usize,
        opts: ExecuteOptions,
    ) -> Result<StreamIter> {
        match &opts.retry {
            Some(p) => p.call(|_| self.stream_once(graph, steps)),
            None => self.stream_once(graph, steps),
        }
    }

    #[deprecated(note = "use run_stream(graph, steps, ExecuteOptions::new())")]
    #[doc(hidden)]
    pub fn execute_stream(&self, graph: &InterventionGraph, steps: usize) -> Result<StreamIter> {
        self.run_stream(graph, steps, ExecuteOptions::new())
    }

    /// One stream-open attempt of [`NdifClient::run_stream`].
    fn stream_once(&self, graph: &InterventionGraph, steps: usize) -> Result<StreamIter> {
        let mut payload = gserde::to_json(graph);
        payload.set("steps", Json::from(steps));
        let payload = payload.to_string();
        let trace_id = crate::obs::mint_trace_id();
        self.link.send(payload.len());
        let (status, mut stream) = http::http_request_stream(
            self.addr,
            "POST",
            "/v1/stream",
            payload.as_bytes(),
            &self.headers_traced(&trace_id),
            Duration::from_secs(10),
            self.poll_timeout,
        )?;
        if status != 200 {
            let body = stream.read_body().unwrap_or_default();
            return Err(anyhow!(
                "stream submit failed ({status}): {}",
                String::from_utf8_lossy(&body)
            ));
        }
        Ok(StreamIter { stream, link: self.link.clone(), opened: false, finished: false })
    }

    /// State summary of a live persistent session:
    /// `(keys, bytes, idle_ms)`. Errors on unknown/expired sessions.
    pub fn session_info(&self, session: &str) -> Result<(Vec<String>, usize, u64)> {
        let (status, body) = http::http_request(
            self.addr,
            "GET",
            &format!("/v1/session/{session}"),
            b"",
            &self.headers(),
        )?;
        if status != 200 {
            return Err(anyhow!("session info returned {status}"));
        }
        let j = parse(std::str::from_utf8(&body)?)?;
        let keys = j
            .get("keys")
            .as_array()
            .unwrap_or(&[])
            .iter()
            .filter_map(|k| k.as_str().map(String::from))
            .collect();
        Ok((
            keys,
            j.get("bytes").as_usize().unwrap_or(0),
            j.get("idle_ms").as_i64().unwrap_or(0).max(0) as u64,
        ))
    }

    /// End a persistent session, dropping its server-side state.
    pub fn drop_session(&self, session: &str) -> Result<bool> {
        let (status, _) = http::http_request(
            self.addr,
            "DELETE",
            &format!("/v1/session/{session}"),
            b"",
            &self.headers(),
        )?;
        Ok(status == 200)
    }

    #[deprecated(note = "use run(graph, ExecuteOptions::new().retry(policy.clone()))")]
    #[doc(hidden)]
    pub fn execute_with_retry(
        &self,
        graph: &InterventionGraph,
        policy: &crate::client::RetryPolicy,
    ) -> Result<GraphResult> {
        Ok(self.run(graph, ExecuteOptions::new().retry(policy.clone()))?.result)
    }
}

/// Does this error mean the session's server-side state was lost and the
/// loop should restart from scratch (replica death mid-session)?
///
/// Thin alias over [`crate::client::retry::is_retryable`] — the envelope
/// contract (and the backoff that should follow) lives in one place.
pub fn is_retryable_session_err(e: &anyhow::Error) -> bool {
    crate::client::retry::is_retryable(e)
}

/// Does this stream error mean the serving replica died mid-stream and the
/// client should restart the stream (rather than a graph/request fault)?
///
/// Thin alias over [`crate::client::retry::is_retryable`].
pub fn is_retryable_stream_err(e: &anyhow::Error) -> bool {
    crate::client::retry::is_retryable(e)
}

// ---------------------------------------------------------------------------
// Streaming
// ---------------------------------------------------------------------------

/// One event of a streaming generation.
#[derive(Debug, Clone)]
pub enum StreamEvent {
    /// A decode step completed: the chosen token, its logit, and the
    /// values collected by `step_hook`/`save` nodes during that step.
    Step {
        step: usize,
        token: usize,
        score: f32,
        values: GraphResult,
    },
    /// The stream finished; the full greedy trajectory.
    Done {
        tokens: Vec<usize>,
        scores: Vec<f32>,
    },
}

/// Blocking iterator over a live event stream. Yields `Step` events as
/// they arrive, then exactly one `Done` — or one `Err`:
/// * mid-stream replica death (via a coordinator) arrives as a tail error
///   with `"retryable":true` ([`is_retryable_stream_err`]);
/// * a direct transport cut (no coordinator to append the tail) surfaces
///   as the same retryable error — truncation is NEVER a silent clean end;
/// * a graph execution error arrives as a non-retryable error.
///
/// The iterator ends (returns `None`) after the terminal item either way.
pub struct StreamIter {
    stream: http::HttpStream,
    link: NetSim,
    /// First body frame already charged (latency paid once; later frames
    /// ride the open pipeline).
    opened: bool,
    finished: bool,
}

impl StreamIter {
    fn charge(&mut self, bytes: usize) {
        if self.opened {
            self.link.send_streamed(bytes);
        } else {
            self.link.send(bytes);
            self.opened = true;
        }
    }

    fn parse_event(&mut self, line: &str) -> Result<StreamEvent> {
        let j = parse(line)?;
        match j.get("event").as_str() {
            Some("step") => {
                let values = gserde::result_from_json(&j)?;
                Ok(StreamEvent::Step {
                    step: j.get("step").as_usize().unwrap_or(0),
                    token: j.get("token").as_usize().unwrap_or(0),
                    score: j.get("score").as_f64().unwrap_or(0.0) as f32,
                    values,
                })
            }
            Some("done") => Ok(StreamEvent::Done {
                tokens: j
                    .get("tokens")
                    .as_usize_vec()
                    .ok_or_else(|| anyhow!("done event missing tokens"))?,
                scores: j
                    .get("scores")
                    .as_f64_vec()
                    .unwrap_or_default()
                    .into_iter()
                    .map(|v| v as f32)
                    .collect(),
            }),
            Some("error") => {
                let msg = j.get("error").as_str().unwrap_or("unknown stream error");
                let retryable = j.get("retryable").as_bool().unwrap_or(false);
                Err(anyhow!(
                    "stream failed: {msg} {}",
                    if retryable { "{\"retryable\":true}" } else { "" }
                ))
            }
            other => Err(anyhow!("unknown stream event {other:?} in {line:?}")),
        }
    }
}

impl Iterator for StreamIter {
    type Item = Result<StreamEvent>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.finished {
            return None;
        }
        match self.stream.next_line() {
            Ok(Some(line)) => {
                self.charge(line.len() + 1);
                let item = self.parse_event(&line);
                if matches!(item, Ok(StreamEvent::Done { .. }) | Err(_)) {
                    self.finished = true;
                }
                Some(item)
            }
            Ok(None) => {
                // a clean chunked end without a terminal event: the server
                // side stopped early — report it, retryably, not silently
                self.finished = true;
                Some(Err(anyhow!(
                    "stream ended without a terminal event (server stopped mid-stream) \
                     {{\"retryable\":true}}"
                )))
            }
            Err(e) => {
                // transport death mid-stream (direct replica connection)
                self.finished = true;
                Some(Err(anyhow!(
                    "stream transport died mid-stream ({e}) {{\"retryable\":true}}"
                )))
            }
        }
    }
}
