//! Unified client resilience: one retry policy for every remote path.
//!
//! Before this module, each call site decided ad hoc whether an error was
//! worth retrying (`is_retryable_session_err`, `is_retryable_stream_err`,
//! hand-rolled loops in tests). The fabric's error contract is simple —
//! transient faults carry `"retryable":true` in the error envelope, and
//! backpressure (429 / load shed) additionally carries `retry_after_ms` —
//! so the retry decision belongs in exactly one place.
//!
//! [`RetryPolicy`] implements capped exponential backoff with
//! *decorrelated jitter* (each sleep is drawn uniformly from
//! `[base, 3 × previous]`, capped), the variant that best de-synchronizes
//! a thundering herd of retrying clients. A server-advertised
//! `Retry-After` (parsed from `retry_after_ms` in the envelope) acts as a
//! floor on the next sleep — the server knows its refill rate better than
//! the client's backoff curve does. A total deadline budget bounds the
//! worst case: a retry is only attempted if its sleep still fits in the
//! budget, so callers get an error in bounded time instead of a stall.
//!
//! Jitter draws come from the seeded [`Prng`], so a client's retry
//! schedule is reproducible in tests and chaos runs.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::util::prng::Prng;

/// How an error should be treated by a retry loop.
#[derive(Clone, Debug, PartialEq)]
pub enum ErrorClass {
    /// Transient: the operation may succeed if repeated (replica died and
    /// the coordinator will re-route; bucket refills; shed clears).
    /// `retry_after` is the server-advertised wait, when present.
    Retryable { retry_after: Option<Duration> },
    /// Permanent: a request fault (bad graph, auth failure) — repeating it
    /// reproduces it.
    Fatal,
}

/// Classify an error by the fabric's envelope contract: transient faults
/// are marked `"retryable":true`; backpressure adds `retry_after_ms`.
pub fn classify(e: &anyhow::Error) -> ErrorClass {
    let s = e.to_string();
    if !s.contains("\"retryable\":true") {
        return ErrorClass::Fatal;
    }
    ErrorClass::Retryable { retry_after: parse_retry_after_ms(&s) }
}

/// Is this error worth retrying at all? (The predicate behind the old
/// `is_retryable_session_err`/`is_retryable_stream_err` helpers.)
pub fn is_retryable(e: &anyhow::Error) -> bool {
    matches!(classify(e), ErrorClass::Retryable { .. })
}

/// Pull `"retry_after_ms":N` out of an error envelope, if present.
fn parse_retry_after_ms(s: &str) -> Option<Duration> {
    let key = "\"retry_after_ms\":";
    let at = s.find(key)? + key.len();
    let digits: String = s[at..]
        .chars()
        .skip_while(|c| c.is_whitespace())
        .take_while(|c| c.is_ascii_digit())
        .collect();
    digits.parse::<u64>().ok().map(Duration::from_millis)
}

/// Capped exponential backoff with decorrelated jitter, a deadline
/// budget, and `Retry-After` honoring.
#[derive(Debug)]
pub struct RetryPolicy {
    /// Attempt ceiling (1 = no retries).
    pub max_attempts: u32,
    /// First backoff sleep (and the jitter distribution's floor).
    pub base: Duration,
    /// Per-sleep ceiling.
    pub cap: Duration,
    /// Total wall-clock budget across all attempts and sleeps.
    pub budget: Duration,
    /// Jitter stream; seeded so retry schedules replay deterministically.
    prng: Mutex<Prng>,
}

impl Clone for RetryPolicy {
    fn clone(&self) -> RetryPolicy {
        RetryPolicy {
            max_attempts: self.max_attempts,
            base: self.base,
            cap: self.cap,
            budget: self.budget,
            prng: Mutex::new(self.prng.lock().unwrap().clone()),
        }
    }
}

impl Default for RetryPolicy {
    /// 6 attempts, 50 ms base, 2 s cap, 30 s budget — tuned so a replica
    /// death (coordinator re-routes on the next attempt) and a drained
    /// token bucket (sub-second refill at sane rates) both recover well
    /// inside the budget.
    fn default() -> RetryPolicy {
        RetryPolicy::new(6, Duration::from_millis(50), Duration::from_secs(2), Duration::from_secs(30), 0x7e7a)
    }
}

impl RetryPolicy {
    pub fn new(
        max_attempts: u32,
        base: Duration,
        cap: Duration,
        budget: Duration,
        seed: u64,
    ) -> RetryPolicy {
        RetryPolicy {
            max_attempts: max_attempts.max(1),
            base,
            cap,
            budget,
            prng: Mutex::new(Prng::new(seed)),
        }
    }

    /// A policy that never retries (for call sites that want the
    /// classification contract but handle scheduling themselves).
    pub fn none() -> RetryPolicy {
        RetryPolicy::new(1, Duration::ZERO, Duration::ZERO, Duration::from_secs(30), 0)
    }

    /// Next sleep: decorrelated jitter `uniform(base, 3 × prev)` capped at
    /// `cap`, floored by the server's `Retry-After` when present.
    fn next_sleep(&self, prev: Duration, retry_after: Option<Duration>) -> Duration {
        let lo = self.base.as_secs_f64();
        let hi = (prev.as_secs_f64() * 3.0).max(lo * 1.000_001);
        let drawn = {
            let mut p = self.prng.lock().unwrap();
            lo + p.uniform() * (hi - lo)
        };
        let jittered = Duration::from_secs_f64(drawn).min(self.cap);
        match retry_after {
            Some(ra) => jittered.max(ra),
            None => jittered,
        }
    }

    /// Run `op` under this policy. `op` receives the 0-based attempt
    /// index. Fatal errors and budget/attempt exhaustion return the last
    /// error unchanged.
    pub fn call<T>(&self, op: impl FnMut(u32) -> Result<T>) -> Result<T> {
        self.call_with_sleeper(op, |d| std::thread::sleep(d))
    }

    /// [`RetryPolicy::call`] with an injected sleeper (tests record the
    /// schedule instead of actually sleeping).
    pub fn call_with_sleeper<T>(
        &self,
        mut op: impl FnMut(u32) -> Result<T>,
        mut sleep: impl FnMut(Duration),
    ) -> Result<T> {
        let start = Instant::now();
        let mut prev_sleep = self.base;
        for attempt in 0..self.max_attempts {
            match op(attempt) {
                Ok(v) => return Ok(v),
                Err(e) => {
                    let retry_after = match classify(&e) {
                        ErrorClass::Fatal => return Err(e),
                        ErrorClass::Retryable { retry_after } => retry_after,
                    };
                    if attempt + 1 >= self.max_attempts {
                        return Err(e);
                    }
                    let pause = self.next_sleep(prev_sleep, retry_after);
                    if start.elapsed() + pause > self.budget {
                        return Err(e.context(format!(
                            "retry budget {:?} exhausted after {} attempts",
                            self.budget,
                            attempt + 1
                        )));
                    }
                    sleep(pause);
                    prev_sleep = pause;
                }
            }
        }
        unreachable!("loop returns on last attempt")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::anyhow;

    fn retryable_err() -> anyhow::Error {
        anyhow!("replica died {{\"retryable\":true}}")
    }

    fn throttled_err(ms: u64) -> anyhow::Error {
        anyhow!("{{\"error\":\"rate limited\",\"retryable\":true,\"retry_after_ms\":{ms}}}")
    }

    #[test]
    fn classifies_the_envelope_contract() {
        assert_eq!(
            classify(&retryable_err()),
            ErrorClass::Retryable { retry_after: None }
        );
        assert_eq!(
            classify(&throttled_err(250)),
            ErrorClass::Retryable { retry_after: Some(Duration::from_millis(250)) }
        );
        assert_eq!(classify(&anyhow!("validation: unknown module")), ErrorClass::Fatal);
        assert!(is_retryable(&retryable_err()));
        assert!(!is_retryable(&anyhow!("auth required")));
    }

    #[test]
    fn retries_transient_until_success() {
        let p = RetryPolicy::new(5, Duration::from_millis(1), Duration::from_millis(4), Duration::from_secs(5), 1);
        let mut calls = 0;
        let out: Result<u32> = p.call_with_sleeper(
            |_| {
                calls += 1;
                if calls < 3 { Err(retryable_err()) } else { Ok(7) }
            },
            |_| {},
        );
        assert_eq!(out.unwrap(), 7);
        assert_eq!(calls, 3);
    }

    #[test]
    fn fatal_errors_do_not_retry() {
        let p = RetryPolicy::default();
        let mut calls = 0;
        let out: Result<()> = p.call_with_sleeper(
            |_| {
                calls += 1;
                Err(anyhow!("bad graph"))
            },
            |_| panic!("must not sleep on fatal"),
        );
        assert!(out.is_err());
        assert_eq!(calls, 1);
    }

    #[test]
    fn attempts_are_capped() {
        let p = RetryPolicy::new(3, Duration::from_millis(1), Duration::from_millis(2), Duration::from_secs(5), 2);
        let mut calls = 0;
        let out: Result<()> = p.call_with_sleeper(
            |_| {
                calls += 1;
                Err(retryable_err())
            },
            |_| {},
        );
        assert!(out.is_err());
        assert_eq!(calls, 3);
    }

    #[test]
    fn honors_retry_after_as_floor() {
        let p = RetryPolicy::new(3, Duration::from_millis(1), Duration::from_secs(10), Duration::from_secs(30), 3);
        let mut sleeps = Vec::new();
        let mut calls = 0;
        let _: Result<()> = p.call_with_sleeper(
            |_| {
                calls += 1;
                Err(throttled_err(500))
            },
            |d| sleeps.push(d),
        );
        assert_eq!(sleeps.len(), 2);
        for s in &sleeps {
            assert!(*s >= Duration::from_millis(500), "Retry-After is a floor: {s:?}");
        }
    }

    #[test]
    fn sleeps_are_jittered_capped_and_deterministic() {
        let run = |seed| -> Vec<Duration> {
            let p = RetryPolicy::new(
                6,
                Duration::from_millis(10),
                Duration::from_millis(80),
                Duration::from_secs(30),
                seed,
            );
            let mut sleeps = Vec::new();
            let _: Result<()> =
                p.call_with_sleeper(|_| Err(retryable_err()), |d| sleeps.push(d));
            sleeps
        };
        let a = run(42);
        let b = run(42);
        assert_eq!(a, b, "same seed, same schedule");
        for s in &a {
            assert!(*s >= Duration::from_millis(10) && *s <= Duration::from_millis(80), "{s:?}");
        }
        // jitter: not all sleeps identical
        assert!(a.iter().any(|s| s != &a[0]), "{a:?}");
    }

    #[test]
    fn budget_bounds_total_wait() {
        // budget far smaller than what the advertised Retry-After demands:
        // the loop must give up rather than stall
        let p = RetryPolicy::new(10, Duration::from_millis(1), Duration::from_secs(60), Duration::from_millis(50), 4);
        let mut calls = 0;
        let out: Result<()> = p.call_with_sleeper(
            |_| {
                calls += 1;
                Err(throttled_err(10_000))
            },
            |_| panic!("sleep would blow the budget"),
        );
        let msg = format!("{:#}", out.unwrap_err());
        assert!(msg.contains("retry budget"), "{msg}");
        assert_eq!(calls, 1);
    }
}
