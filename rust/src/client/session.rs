//! Sessions: multiple tracing contexts executed in order (§B.1 "Remote
//! Execution and Session").
//!
//! A [`Session`] bundles several traces so that remote execution costs one
//! request instead of N round trips — the paper's mechanism for iterative
//! experiments (multi-pass probing, LoRA-style loops). Values cannot yet
//! flow *between* traces on the server (that requires remote parameter
//! state, paper Code Example 5); each trace's saved values return to the
//! client, which can feed them into the next trace as constants before
//! submission — the builder supports this via deferred construction.

use anyhow::Result;

use crate::graph::InterventionGraph;
use crate::models::ModelRunner;

use super::remote::NdifClient;
use super::{Trace, TraceResult};

/// An ordered bundle of traces executed together.
#[derive(Default)]
pub struct Session {
    graphs: Vec<InterventionGraph>,
}

impl Session {
    pub fn new() -> Session {
        Session::default()
    }

    /// Add a completed trace to the session; returns its index.
    pub fn add(&mut self, trace: Trace) -> usize {
        self.graphs.push(trace.into_graph());
        self.graphs.len() - 1
    }

    pub fn len(&self) -> usize {
        self.graphs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.graphs.is_empty()
    }

    /// Execute all traces locally, in order.
    pub fn run_local(self, runner: &ModelRunner) -> Result<Vec<TraceResult>> {
        self.graphs
            .iter()
            .map(|g| Ok(TraceResult::from_graph_result(crate::interp::execute(g, runner)?)))
            .collect()
    }

    /// Execute all traces remotely as one bundled request.
    pub fn run_remote(self, client: &NdifClient) -> Result<Vec<TraceResult>> {
        Ok(client
            .execute_session(&self.graphs)?
            .into_iter()
            .map(TraceResult::from_graph_result)
            .collect())
    }

    /// Total wire bytes if submitted remotely (for overhead accounting).
    pub fn wire_bytes(&self) -> usize {
        self.graphs.iter().map(|g| g.wire_bytes()).sum()
    }
}
