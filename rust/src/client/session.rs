//! Sessions: multiple tracing contexts executed in order (§B.1 "Remote
//! Execution and Session").
//!
//! A [`Session`] bundles several traces so that remote execution costs one
//! request instead of N round trips — the paper's mechanism for iterative
//! experiments (multi-pass probing, LoRA-style loops). Values flow
//! *between* traces on the server through named session-state variables
//! (paper Code Example 5): a trace stores a tensor with
//! [`Trace::save_to_state`] and any later trace of the same session reads
//! it back with [`Trace::from_state`], so parameters being trained never
//! leave the fabric. An entire optimizer loop therefore costs one upload
//! and one download — see `examples/probe_training.rs`.
//!
//! By default a session's server-side state is ephemeral: it is dropped
//! when the bundled response is sent. Naming the session with
//! [`Session::with_id`] makes the state persist across requests — follow-up
//! bundles submitted under the same id continue from the stored
//! parameters (the coordinator pins such sessions to the replica holding
//! the state) — until `DELETE /v1/session/<id>` or server-side TTL expiry.

use anyhow::Result;

use crate::graph::InterventionGraph;
use crate::interp::StateView;
use crate::models::ModelRunner;

use super::remote::NdifClient;
use super::{Trace, TraceResult};

/// An ordered bundle of traces executed together, with cross-trace state.
#[derive(Default)]
pub struct Session {
    graphs: Vec<InterventionGraph>,
    /// Persistent session-state id; `None` = ephemeral state.
    id: Option<String>,
}

impl Session {
    pub fn new() -> Session {
        Session::default()
    }

    /// Name the session: its server-side state survives this request and
    /// follow-up bundles under the same id continue from it.
    pub fn with_id(mut self, id: &str) -> Session {
        self.id = Some(id.to_string());
        self
    }

    /// The persistent session-state id, if any.
    pub fn id(&self) -> Option<&str> {
        self.id.as_deref()
    }

    /// Add a completed trace to the session; returns its index.
    pub fn add(&mut self, trace: Trace) -> usize {
        self.graphs.push(trace.into_graph());
        self.graphs.len() - 1
    }

    pub fn len(&self) -> usize {
        self.graphs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.graphs.is_empty()
    }

    /// Execute all traces locally, in order, threading session state
    /// between them (stores commit after each trace; loads observe the
    /// state as of trace start).
    pub fn run_local(self, runner: &ModelRunner) -> Result<Vec<TraceResult>> {
        let mut state = StateView::new();
        Ok(crate::engine::Engine::new(runner)
            .run_session(&self.graphs, &mut state, true)?
            .into_iter()
            .map(TraceResult::from_graph_result)
            .collect())
    }

    /// Execute all traces remotely as one bundled request; state lives on
    /// the server for the whole loop.
    pub fn run_remote(self, client: &NdifClient) -> Result<Vec<TraceResult>> {
        Ok(client
            .run_session(&self.graphs, self.id.as_deref(), crate::client::ExecuteOptions::new())?
            .into_iter()
            .map(TraceResult::from_graph_result)
            .collect())
    }

    /// Total wire bytes if submitted remotely (for overhead accounting).
    pub fn wire_bytes(&self) -> usize {
        self.graphs.iter().map(|g| g.wire_bytes()).sum()
    }
}
