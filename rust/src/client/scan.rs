//! Shape pre-flight ("scanning", §B.1): infer every node's shape from the
//! model manifest without executing anything — the FakeTensor analog.
//! Catches slice-out-of-bounds, broadcast mismatches, and contraction
//! errors before a forward pass (local or remote) is spent.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::graph::{InterventionGraph, Op, Port};
use crate::runtime::Manifest;
use crate::tensor::{Range1, Shape};

fn slice_dims(dims: &[usize], ranges: &[Range1]) -> Result<Vec<usize>> {
    if ranges.len() > dims.len() {
        return Err(anyhow!("slice rank {} > tensor rank {}", ranges.len(), dims.len()));
    }
    let mut out = dims.to_vec();
    for (i, r) in ranges.iter().enumerate() {
        let stop = if r.stop == usize::MAX { dims[i] } else { r.stop };
        if r.start > stop || stop > dims[i] {
            return Err(anyhow!(
                "slice [{}, {stop}) out of bounds for dim {i} (size {})",
                r.start,
                dims[i]
            ));
        }
        out[i] = stop - r.start;
    }
    Ok(out)
}

/// Infer all node shapes; errors mirror what execution would hit. A graph
/// that loads session state cannot be scanned without knowing the state's
/// shapes — use [`scan_with_state`].
pub fn scan(g: &InterventionGraph, manifest: &Manifest) -> Result<Vec<Vec<usize>>> {
    scan_with_state(g, manifest, &BTreeMap::new())
}

/// [`scan`] with `state_shapes` declaring the dims of every session-state
/// key that exists when the trace starts.
pub fn scan_with_state(
    g: &InterventionGraph,
    manifest: &Manifest,
    state_shapes: &BTreeMap<String, Vec<usize>>,
) -> Result<Vec<Vec<usize>>> {
    let fseq = manifest.forward_sequence();
    let keys = state_shapes.keys().cloned().collect();
    crate::graph::validate::validate_with_state(g, &fseq, &keys)?;
    let rows = g.batch_group.map(|(_, r)| r).unwrap_or(g.batch.max(1));
    let mut shapes: Vec<Vec<usize>> = Vec::with_capacity(g.nodes.len());

    let point_dims = |module: &str, port: Port| -> Result<Vec<usize>> {
        // input of module k = output of module k-1
        let point = match port {
            Port::Output => module.to_string(),
            Port::Input => {
                let idx = fseq
                    .iter()
                    .position(|m| m == module)
                    .ok_or_else(|| anyhow!("unknown module {module}"))?;
                if idx == 0 {
                    return Err(anyhow!("module {module} has no observable input"));
                }
                fseq[idx - 1].clone()
            }
        };
        Ok(manifest.output_dims(Manifest::module_kind(&point), rows))
    };

    for n in &g.nodes {
        let dims: Vec<usize> = match &n.op {
            Op::Getter { module, port } => point_dims(module, *port)?,
            Op::Setter { module, port, arg } => {
                let expect = point_dims(module, *port)?;
                let got = &shapes[*arg];
                if got != &expect {
                    return Err(anyhow!(
                        "setter at {module}: value shape {got:?} != activation shape {expect:?}"
                    ));
                }
                expect
            }
            Op::Grad { module } => point_dims(module, Port::Output)?,
            Op::Const { dims, .. } => dims.clone(),
            Op::Slice { arg, ranges } => slice_dims(&shapes[*arg], ranges)?,
            Op::Assign { dst, ranges, src } => {
                let want = slice_dims(&shapes[*dst], ranges)?;
                if shapes[*src] != want {
                    return Err(anyhow!(
                        "assign: src shape {:?} != slice shape {want:?}",
                        shapes[*src]
                    ));
                }
                shapes[*dst].clone()
            }
            Op::Fill { dst, ranges, .. } => {
                slice_dims(&shapes[*dst], ranges)?;
                shapes[*dst].clone()
            }
            Op::Add { a, b } | Op::Sub { a, b } | Op::Mul { a, b }
            | Op::FusedScaleAdd { a, b, .. } => {
                Shape::broadcast(&shapes[*a], &shapes[*b]).ok_or_else(|| {
                    anyhow!("broadcast {:?} vs {:?}", shapes[*a], shapes[*b])
                })?
            }
            Op::Matmul { a, b } | Op::FusedMatmulGelu { a, b } => {
                let (sa, sb) = (&shapes[*a], &shapes[*b]);
                if sb.len() != 2 {
                    return Err(anyhow!("matmul rhs must be 2-D, got {sb:?}"));
                }
                let k = *sa.last().ok_or_else(|| anyhow!("matmul lhs is scalar"))?;
                if k != sb[0] {
                    return Err(anyhow!("matmul contraction {k} vs {}", sb[0]));
                }
                let mut out = sa.clone();
                *out.last_mut().unwrap() = sb[1];
                out
            }
            Op::Scale { arg, .. } | Op::Gelu { arg } | Op::Softmax { arg } | Op::Save { arg }
            | Op::StepHook { arg } | Op::StoreState { arg, .. }
            | Op::FusedScaleSoftmax { arg, .. } => shapes[*arg].clone(),
            Op::LoadState { key } => state_shapes
                .get(key)
                .cloned()
                .ok_or_else(|| anyhow!("no declared shape for state key '{key}'"))?,
            Op::Transpose { arg } => {
                let s = &shapes[*arg];
                if s.len() != 2 {
                    return Err(anyhow!("transpose needs a 2-D tensor, got {s:?}"));
                }
                vec![s[1], s[0]]
            }
            Op::Reshape { arg, dims } => {
                let have: usize = shapes[*arg].iter().product();
                let want: usize = dims.iter().product();
                if have != want {
                    return Err(anyhow!(
                        "reshape {:?} -> {dims:?} changes element count",
                        shapes[*arg]
                    ));
                }
                dims.clone()
            }
            Op::MeanAxis { arg, axis } => {
                let s = &shapes[*arg];
                if *axis >= s.len() {
                    return Err(anyhow!("mean_axis axis {axis} out of rank {}", s.len()));
                }
                let mut out = s.clone();
                out.remove(*axis);
                out
            }
            Op::Argmax { arg } => {
                let s = &shapes[*arg];
                if s.is_empty() {
                    return Err(anyhow!("argmax of scalar"));
                }
                s[..s.len() - 1].to_vec()
            }
            Op::Mean { arg } | Op::Sum { arg } => {
                let _ = &shapes[*arg];
                vec![]
            }
            Op::LogitDiff { logits, target, foil } => {
                let s = &shapes[*logits];
                if s.len() < 2 {
                    return Err(anyhow!("logit_diff needs [.., seq, vocab], got {s:?}"));
                }
                let vocab = *s.last().unwrap();
                if *target >= vocab || *foil >= vocab {
                    return Err(anyhow!("logit_diff ids out of vocab {vocab}"));
                }
                let batch: usize = s[..s.len() - 2].iter().product::<usize>().max(1);
                vec![batch]
            }
        };
        shapes.push(dims);
    }
    Ok(shapes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::Trace;
    use crate::models::artifacts_dir;
    use crate::tensor::Tensor;

    fn manifest() -> Manifest {
        Manifest::load(&artifacts_dir(), "tiny-sim").unwrap()
    }

    #[test]
    fn scan_infers_activation_shapes() {
        let m = manifest();
        let mut tr = Trace::new("tiny-sim", &Tensor::zeros(&[1, 16]));
        let h = tr.output("layer.0");
        let logits = tr.output("lm_head");
        let ld = tr.logit_diff(logits, 3, 5);
        tr.save(h);
        tr.save(ld);
        let shapes = tr.scan(&m).unwrap();
        assert_eq!(shapes[h.0], vec![1, 16, 32]);
        assert_eq!(shapes[logits.0], vec![1, 16, 64]);
        assert_eq!(shapes[ld.0], vec![1]);
    }

    #[test]
    fn scan_rejects_out_of_bounds_slice() {
        let m = manifest();
        let mut tr = Trace::new("tiny-sim", &Tensor::zeros(&[1, 16]));
        let h = tr.output("layer.0");
        let bad = tr.slice(h, &[Range1::new(0, 99)]);
        tr.save(bad);
        assert!(tr.scan(&m).is_err());
    }

    #[test]
    fn scan_rejects_setter_shape_mismatch() {
        let m = manifest();
        let mut tr = Trace::new("tiny-sim", &Tensor::zeros(&[1, 16]));
        let c = tr.constant(&Tensor::zeros(&[1, 2, 3]));
        tr.set_output("layer.0", c);
        let err = tr.scan(&m).unwrap_err().to_string();
        assert!(err.contains("setter"), "{err}");
    }

    #[test]
    fn scan_rejects_bad_logit_diff_ids() {
        let m = manifest();
        let mut tr = Trace::new("tiny-sim", &Tensor::zeros(&[1, 16]));
        let logits = tr.output("lm_head");
        let ld = tr.logit_diff(logits, 9999, 0);
        tr.save(ld);
        assert!(tr.scan(&m).is_err());
    }

    #[test]
    fn scan_respects_batch_group_rows() {
        let m = manifest();
        let mut tr = Trace::new("tiny-sim", &Tensor::zeros(&[4, 16]));
        tr.batch_group(2, 2);
        let h = tr.output("layer.0");
        tr.save(h);
        let shapes = tr.scan(&m).unwrap();
        assert_eq!(shapes[h.0], vec![2, 16, 32]);
    }

    #[test]
    fn scan_state_and_shape_ops() {
        let m = manifest();
        let mut tr = Trace::new("tiny-sim", &Tensor::zeros(&[1, 16]));
        let h = tr.output("layer.0"); // [1,16,32]
        let x = tr.reshape(h, &[16, 32]);
        let w = tr.from_state("w"); // [32,32] via declared shape
        let pred = tr.matmul(x, w);
        let xt = tr.transpose(x); // [32,16]
        let dw = tr.matmul(xt, pred); // [32,32]
        let col = tr.mean_axis(dw, 0); // [32]
        tr.save_to_state("w", dw);
        tr.save(col);
        // without the declared state shape, scan fails validation
        assert!(tr.scan(&m).is_err());
        let mut shapes = BTreeMap::new();
        shapes.insert("w".to_string(), vec![32usize, 32]);
        let out = scan_with_state(tr.graph(), &m, &shapes).unwrap();
        assert_eq!(out[x.0], vec![16, 32]);
        assert_eq!(out[xt.0], vec![32, 16]);
        assert_eq!(out[dw.0], vec![32, 32]);
        assert_eq!(out[col.0], vec![32]);
    }

    #[test]
    fn scan_rejects_bad_reshape_and_transpose() {
        let m = manifest();
        let mut tr = Trace::new("tiny-sim", &Tensor::zeros(&[1, 16]));
        let h = tr.output("layer.0"); // [1,16,32] — rank 3
        let t = tr.transpose(h);
        tr.save(t);
        assert!(tr.scan(&m).is_err());

        let mut tr = Trace::new("tiny-sim", &Tensor::zeros(&[1, 16]));
        let h = tr.output("layer.0");
        let r = tr.reshape(h, &[3, 3]); // wrong numel
        tr.save(r);
        assert!(tr.scan(&m).is_err());
    }

    #[test]
    fn scan_rejects_matmul_mismatch() {
        let m = manifest();
        let mut tr = Trace::new("tiny-sim", &Tensor::zeros(&[1, 16]));
        let h = tr.output("layer.0"); // [1,16,32]
        let w = tr.constant(&Tensor::zeros(&[7, 5]));
        let bad = tr.matmul(h, w);
        tr.save(bad);
        assert!(tr.scan(&m).is_err());
    }
}
