//! Fixed-size worker thread pool.
//!
//! Powers the NDIF HTTP frontend (one job per accepted connection), the
//! load-test client fleet, the simulated tensor-parallel shard workers,
//! and — via [`compute_pool`] — the data-parallel tensor kernels in
//! [`crate::tensor::ops`]. `tokio` is unavailable offline; a plain pool
//! over `std::sync::mpsc` is sufficient because request handling is
//! dominated by model execution, not connection counts.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Thread-name prefix of the shared compute pool's workers; used to detect
/// (and serialize) accidental nested kernel dispatch, which would otherwise
/// deadlock a bounded pool.
const COMPUTE_PREFIX: &str = "nnscope-compute";

static COMPUTE: OnceLock<ThreadPool> = OnceLock::new();

/// The shared lazy compute pool used by the parallel tensor kernels.
///
/// Sized from `NNSCOPE_COMPUTE_THREADS` if set (a value of `1` disables
/// kernel parallelism), otherwise from `std::thread::available_parallelism`.
/// Created on first use so binaries that never touch a large tensor spawn
/// no extra threads.
pub fn compute_pool() -> &'static ThreadPool {
    COMPUTE.get_or_init(|| {
        let size = std::env::var("NNSCOPE_COMPUTE_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            });
        ThreadPool::with_name(size, COMPUTE_PREFIX)
    })
}

/// A fixed pool of worker threads consuming a shared job queue.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `size` workers (≥1 enforced).
    pub fn new(size: usize) -> ThreadPool {
        ThreadPool::with_name(size, "nnscope-worker")
    }

    /// Spawn `size` workers with a custom thread-name prefix.
    pub fn with_name(size: usize, prefix: &str) -> ThreadPool {
        let size = size.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let inf = Arc::clone(&in_flight);
                std::thread::Builder::new()
                    .name(format!("{prefix}-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                inf.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, in_flight }
    }

    /// Submit a job for execution.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("worker pool hung up");
    }

    /// Jobs submitted but not yet finished.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Busy-wait (with yields) until all submitted jobs finish.
    pub fn wait_idle(&self) {
        while self.in_flight() > 0 {
            std::thread::yield_now();
        }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Run a set of jobs that may borrow from the caller's stack, blocking
    /// until every job has finished (the fork-join primitive behind the
    /// parallel tensor kernels). Unlike [`ThreadPool::wait_idle`], waiting
    /// is scoped to exactly these jobs, so concurrent callers sharing the
    /// pool never wait on each other's work.
    ///
    /// The last job runs inline on the caller's thread (one fewer
    /// queue/wake round-trip, and single-job calls never leave the caller).
    /// If the caller is itself a compute-pool worker — nested kernel
    /// dispatch — all jobs run inline, which is slower but cannot deadlock
    /// the bounded pool.
    pub fn scoped<'scope>(&self, mut jobs: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        let Some(inline) = jobs.pop() else { return };
        let nested =
            std::thread::current().name().is_some_and(|n| n.starts_with(COMPUTE_PREFIX));
        if nested {
            for job in jobs {
                job();
            }
            inline();
            return;
        }

        /// Counts a job as finished even if it unwinds, so a panicking
        /// kernel cannot leave `scoped` blocked forever (the panic still
        /// kills its worker thread, as in `execute`).
        struct Done(Arc<(Mutex<usize>, Condvar)>);
        impl Drop for Done {
            fn drop(&mut self) {
                let (count, cv) = &*self.0;
                *count.lock().unwrap() += 1;
                cv.notify_all();
            }
        }

        /// Blocks until all queued jobs finish — on normal return *and* on
        /// unwind out of the inline job, so borrowed data can never be
        /// freed while a worker still touches it.
        struct WaitAll<'a> {
            sync: &'a (Mutex<usize>, Condvar),
            n: usize,
        }
        impl Drop for WaitAll<'_> {
            fn drop(&mut self) {
                let (count, cv) = self.sync;
                let mut finished = count.lock().unwrap();
                while *finished < self.n {
                    finished = cv.wait(finished).unwrap();
                }
            }
        }

        let n = jobs.len();
        let sync = Arc::new((Mutex::new(0usize), Condvar::new()));
        for job in jobs {
            // SAFETY: `scoped` does not return until the completion count
            // reaches `n`, and the count for each job is bumped (via the
            // `Done` drop guard) only after the job has run or unwound —
            // so every borrow captured in `job` strictly outlives its
            // execution. The transmute only erases the `'scope` lifetime;
            // the fat-pointer representation is identical.
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe {
                std::mem::transmute::<
                    Box<dyn FnOnce() + Send + 'scope>,
                    Box<dyn FnOnce() + Send + 'static>,
                >(job)
            };
            let done = Done(Arc::clone(&sync));
            self.execute(move || {
                let _done = done;
                job();
            });
        }
        let _wait = WaitAll { sync: &*sync, n };
        inline();
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel; workers exit on recv error
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run a set of closures in parallel on a transient pool and collect their
/// results in input order — the fork-join helper used by shard execution.
pub fn parallel_map<T, F>(jobs: Vec<F>, pool_size: usize) -> Vec<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let n = jobs.len();
    let results: Arc<Mutex<Vec<Option<T>>>> = Arc::new(Mutex::new((0..n).map(|_| None).collect()));
    {
        let pool = ThreadPool::new(pool_size);
        for (i, job) in jobs.into_iter().enumerate() {
            let results = Arc::clone(&results);
            pool.execute(move || {
                let r = job();
                results.lock().unwrap()[i] = Some(r);
            });
        }
        pool.wait_idle();
    }
    Arc::try_unwrap(results)
        .ok()
        .expect("all workers done")
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("job completed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn drop_joins_workers() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop waits for queue drain via join
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let jobs: Vec<_> = (0..32)
            .map(|i| {
                move || {
                    std::thread::sleep(std::time::Duration::from_micros((32 - i) as u64 * 10));
                    i * i
                }
            })
            .collect();
        let out = parallel_map(jobs, 8);
        assert_eq!(out, (0..32).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn pool_size_minimum_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
    }

    #[test]
    fn scoped_jobs_borrow_caller_data() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0u64; 1024];
        {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = data
                .chunks_mut(100)
                .enumerate()
                .map(|(c, chunk)| {
                    Box::new(move || {
                        for (i, v) in chunk.iter_mut().enumerate() {
                            *v = (c * 100 + i) as u64;
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.scoped(jobs);
        }
        assert!(data.iter().enumerate().all(|(i, &v)| v == i as u64));
    }

    #[test]
    fn scoped_empty_and_single_job() {
        let pool = ThreadPool::new(2);
        pool.scoped(Vec::new());
        let mut hit = false;
        pool.scoped(vec![Box::new(|| hit = true) as Box<dyn FnOnce() + Send + '_>]);
        assert!(hit);
    }

    #[test]
    fn scoped_concurrent_callers_do_not_cross_wait() {
        let pool = Arc::new(ThreadPool::new(4));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let pool = Arc::clone(&pool);
                std::thread::spawn(move || {
                    let mut acc = vec![0u64; 64];
                    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = acc
                        .chunks_mut(16)
                        .map(|chunk| {
                            Box::new(move || {
                                for v in chunk.iter_mut() {
                                    *v += 1;
                                }
                            }) as Box<dyn FnOnce() + Send + '_>
                        })
                        .collect();
                    pool.scoped(jobs);
                    acc.iter().sum::<u64>()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 64);
        }
    }

    #[test]
    fn compute_pool_is_shared_and_nonempty() {
        let a = compute_pool() as *const ThreadPool;
        let b = compute_pool() as *const ThreadPool;
        assert_eq!(a, b);
        assert!(compute_pool().size() >= 1);
    }

    #[test]
    fn nested_scoped_dispatch_runs_inline() {
        // scoped jobs that themselves call scoped must not deadlock, even
        // when they land on compute-pool workers (nested dispatch is
        // detected by thread name and serialized inline)
        let hits = Arc::new(AtomicU64::new(0));
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
            .map(|_| {
                let hits = Arc::clone(&hits);
                Box::new(move || {
                    let inner_jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                        .map(|_| {
                            let hits = Arc::clone(&hits);
                            Box::new(move || {
                                hits.fetch_add(1, Ordering::SeqCst);
                            })
                                as Box<dyn FnOnce() + Send + '_>
                        })
                        .collect();
                    compute_pool().scoped(inner_jobs);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        compute_pool().scoped(jobs);
        assert_eq!(hits.load(Ordering::SeqCst), 32);
    }
}
