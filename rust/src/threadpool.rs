//! Fixed-size worker thread pool.
//!
//! Powers the NDIF HTTP frontend (one job per accepted connection), the
//! load-test client fleet, and the simulated tensor-parallel shard workers.
//! `tokio` is unavailable offline; a plain pool over `std::sync::mpsc` is
//! sufficient because request handling is dominated by model execution,
//! not connection counts.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads consuming a shared job queue.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `size` workers (≥1 enforced).
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let inf = Arc::clone(&in_flight);
                std::thread::Builder::new()
                    .name(format!("nnscope-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                inf.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, in_flight }
    }

    /// Submit a job for execution.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("worker pool hung up");
    }

    /// Jobs submitted but not yet finished.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Busy-wait (with yields) until all submitted jobs finish.
    pub fn wait_idle(&self) {
        while self.in_flight() > 0 {
            std::thread::yield_now();
        }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel; workers exit on recv error
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run a set of closures in parallel on a transient pool and collect their
/// results in input order — the fork-join helper used by shard execution.
pub fn parallel_map<T, F>(jobs: Vec<F>, pool_size: usize) -> Vec<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let n = jobs.len();
    let results: Arc<Mutex<Vec<Option<T>>>> = Arc::new(Mutex::new((0..n).map(|_| None).collect()));
    {
        let pool = ThreadPool::new(pool_size);
        for (i, job) in jobs.into_iter().enumerate() {
            let results = Arc::clone(&results);
            pool.execute(move || {
                let r = job();
                results.lock().unwrap()[i] = Some(r);
            });
        }
        pool.wait_idle();
    }
    Arc::try_unwrap(results)
        .ok()
        .expect("all workers done")
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("job completed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn drop_joins_workers() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop waits for queue drain via join
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let jobs: Vec<_> = (0..32)
            .map(|i| {
                move || {
                    std::thread::sleep(std::time::Duration::from_micros((32 - i) as u64 * 10));
                    i * i
                }
            })
            .collect();
        let out = parallel_map(jobs, 8);
        assert_eq!(out, (0..32).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn pool_size_minimum_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
    }
}
