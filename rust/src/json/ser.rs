//! JSON serialization: compact (wire format) and pretty (manifests,
//! debugging dumps). Integers that fit in `i64` are printed without a
//! decimal point so node ids and shapes round-trip textually.

use super::Json;

/// Compact serialization.
pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

/// Pretty serialization with 2-space indents.
pub fn to_pretty(v: &Json) -> String {
    let mut s = String::new();
    write_pretty(v, 0, &mut s);
    s
}

impl Json {
    /// Pretty-printed form.
    pub fn pretty(&self) -> String {
        to_pretty(self)
    }
}

fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no NaN/Inf; clamp like most serializers' lossy modes.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        // shortest f64 repr via Rust's float formatting
        out.push_str(&format!("{n}"));
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{0008}' => out.push_str("\\b"),
            '\u{000C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(n) => write_num(*n, out),
        Json::Str(s) => write_escaped(s, out),
        Json::Array(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Json::Object(o) => {
            out.push('{');
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_escaped(k, out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

fn write_pretty(v: &Json, indent: usize, out: &mut String) {
    let pad = "  ".repeat(indent);
    let pad2 = "  ".repeat(indent + 1);
    match v {
        Json::Array(a) if !a.is_empty() => {
            out.push_str("[\n");
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                out.push_str(&pad2);
                write_pretty(item, indent + 1, out);
            }
            out.push('\n');
            out.push_str(&pad);
            out.push(']');
        }
        Json::Object(o) if !o.is_empty() => {
            out.push_str("{\n");
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                out.push_str(&pad2);
                write_escaped(k, out);
                out.push_str(": ");
                write_pretty(val, indent + 1, out);
            }
            out.push('\n');
            out.push_str(&pad);
            out.push('}');
        }
        other => write_value(other, out),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;

    #[test]
    fn integers_have_no_decimal_point() {
        assert_eq!(to_string(&Json::Num(42.0)), "42");
        assert_eq!(to_string(&Json::Num(-3.0)), "-3");
        assert_eq!(to_string(&Json::Num(2.5)), "2.5");
    }

    #[test]
    fn escapes_control_chars() {
        let s = to_string(&Json::Str("a\"b\\c\nd\u{0001}".into()));
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\\u0001\"");
        assert_eq!(parse(&s).unwrap(), Json::Str("a\"b\\c\nd\u{0001}".into()));
    }

    #[test]
    fn nonfinite_serializes_as_null() {
        assert_eq!(to_string(&Json::Num(f64::NAN)), "null");
        assert_eq!(to_string(&Json::Num(f64::INFINITY)), "null");
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![
            ("a", Json::arr(vec![Json::from(1i64)])),
            ("b", Json::obj(vec![("c", Json::Null)])),
            ("empty", Json::arr(vec![])),
        ]);
        assert_eq!(parse(&to_pretty(&v)).unwrap(), v);
        assert!(to_pretty(&v).contains('\n'));
    }
}
