//! Recursive-descent JSON parser (RFC 8259), with positions in errors.

use super::Json;
use std::collections::BTreeMap;

/// Parse failure with byte offset for debugging malformed requests.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub msg: String,
    pub offset: usize,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}
impl std::error::Error for ParseError {}

/// Parse a complete JSON document (trailing whitespace allowed, trailing
/// garbage rejected).
pub fn parse(src: &str) -> Result<Json, ParseError> {
    let mut p = Parser { b: src.as_bytes(), i: 0, depth: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { msg: msg.to_string(), offset: self.i }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'{') => {
                self.depth += 1;
                let v = self.object();
                self.depth -= 1;
                v
            }
            Some(b'[') => {
                self.depth += 1;
                let v = self.array();
                self.depth -= 1;
                v
            }
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Object(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Object(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Array(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Array(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            // surrogate pair handling
                            if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(self.err("invalid low surrogate"));
                                    }
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else if (0xDC00..0xE000).contains(&cp) {
                                return Err(self.err("lone low surrogate"));
                            } else {
                                s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                            }
                            continue; // hex4 advanced i past the digits
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.i += 1;
                }
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(_) => {
                    // consume a full UTF-8 sequence
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    if start + len > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let mut v = 0u32;
        for k in 0..4 {
            let c = self.b[self.i + k];
            let d = match c {
                b'0'..=b'9' => (c - b'0') as u32,
                b'a'..=b'f' => (c - b'a' + 10) as u32,
                b'A'..=b'F' => (c - b'A' + 10) as u32,
                _ => return Err(self.err("invalid hex digit")),
            };
            v = v * 16 + d;
        }
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        let digits_start = self.i;
        while self.peek().map(|c| c.is_ascii_digit()).unwrap_or(false) {
            self.i += 1;
        }
        if self.i == digits_start {
            return Err(self.err("expected digit"));
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            let fs = self.i;
            while self.peek().map(|c| c.is_ascii_digit()).unwrap_or(false) {
                self.i += 1;
            }
            if self.i == fs {
                return Err(self.err("expected fraction digit"));
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            let es = self.i;
            while self.peek().map(|c| c.is_ascii_digit()).unwrap_or(false) {
                self.i += 1;
            }
            if self.i == es {
                return Err(self.err("expected exponent digit"));
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-0.5e2").unwrap(), Json::Num(-50.0));
        assert_eq!(parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn escapes_and_unicode() {
        assert_eq!(parse(r#""A\n\t\"\\""#).unwrap(), Json::Str("A\n\t\"\\".into()));
        // surrogate pair for 𝄞 (U+1D11E)
        assert_eq!(parse(r#""𝄞""#).unwrap(), Json::Str("𝄞".into()));
        assert_eq!(parse("\"é𝄞\"").unwrap(), Json::Str("é𝄞".into()));
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "", "{", "[1,", "{\"a\"}", "tru", "01x", "\"\\u12\"", "\"abc", "1 2",
            "{\"a\":1,}", "[1,]", "\"\\q\"", "\"\\uD834\"",
        ] {
            assert!(parse(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn deep_nesting_guard() {
        let deep = "[".repeat(1000) + &"]".repeat(1000);
        assert!(parse(&deep).is_err());
        let ok = "[".repeat(100) + &"]".repeat(100);
        assert!(parse(&ok).is_ok());
    }

    #[test]
    fn object_and_array() {
        let v = parse(r#"{ "a" : [ 1 , 2 ] , "b" : { } }"#).unwrap();
        assert_eq!(v.get("a").as_usize_vec(), Some(vec![1, 2]));
        assert!(v.get("b").as_object().unwrap().is_empty());
    }
}
