//! JSON: the intervention-graph interchange format.
//!
//! The paper serializes intervention graphs "into a custom JSON format"
//! (§B.2); `serde_json` is unavailable in this offline build, so the crate
//! carries its own value model, recursive-descent parser, and serializer.
//! The implementation is complete for the JSON grammar (RFC 8259) with the
//! usual Rust conveniences: typed accessors, builder helpers, and both
//! compact and pretty output.

mod value;
mod parse;
mod ser;

pub use parse::{parse, ParseError};
pub use value::Json;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_nested() {
        let src = r#"{"a":[1,2.5,-3e2],"b":{"c":true,"d":null,"e":"hi\n\"there\""},"f":[]}"#;
        let v = parse(src).unwrap();
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn round_trip_pretty() {
        let v = Json::obj(vec![
            ("nodes", Json::arr(vec![Json::from(1i64), Json::from("x")])),
            ("ok", Json::from(true)),
        ]);
        let re = parse(&v.pretty()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn property_random_values_round_trip() {
        use crate::util::Prng;
        let mut p = Prng::new(0xBEEF);
        for case in 0..200 {
            let v = random_json(&mut p, 3);
            let s = v.to_string();
            let re = parse(&s).unwrap_or_else(|e| panic!("case {case}: {e:?} for {s}"));
            assert_eq!(v, re, "case {case}");
        }
    }

    fn random_json(p: &mut crate::util::Prng, depth: usize) -> Json {
        match if depth == 0 { p.range(0, 4) } else { p.range(0, 6) } {
            0 => Json::Null,
            1 => Json::Bool(p.below(2) == 0),
            2 => {
                // use exactly representable values so equality is exact
                Json::from((p.below(2_000_000) as i64) - 1_000_000)
            }
            3 => {
                let mut s = String::new();
                for _ in 0..p.range(0, 12) {
                    s.push(match p.range(0, 6) {
                        0 => '"',
                        1 => '\\',
                        2 => '\n',
                        3 => 'é',
                        4 => '𝄞',
                        _ => char::from(b'a' + p.below(26) as u8),
                    });
                }
                Json::from(s)
            }
            4 => Json::Array((0..p.range(0, 4)).map(|_| random_json(p, depth - 1)).collect()),
            _ => Json::Object(
                (0..p.range(0, 4))
                    .map(|i| (format!("k{i}"), random_json(p, depth - 1)))
                    .collect(),
            ),
        }
    }
}
