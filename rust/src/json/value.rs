//! The JSON value model with typed accessors and builder helpers.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are stored as `f64` with an exact-integer fast
/// path preserved at serialization time (i64-representable values print
/// without a decimal point, so node ids survive round-trips textually).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors -----------------------------------------------------

    pub fn obj<K: Into<String>>(pairs: Vec<(K, Json)>) -> Json {
        Json::Object(pairs.into_iter().map(|(k, v)| (k.into(), v)).collect())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Array(items)
    }

    // ---- accessors --------------------------------------------------------

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9.0e15 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Object(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` if missing or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Object(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index; `Json::Null` out of range.
    pub fn at(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Array(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Extract a `Vec<f64>` from a numeric array.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_array()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Extract a `Vec<usize>` from a numeric array.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_array()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Extract a `Vec<i64>` from a numeric array.
    pub fn as_i64_vec(&self) -> Option<Vec<i64>> {
        self.as_array()?.iter().map(|v| v.as_i64()).collect()
    }

    /// Insert into an object (panics if not an object) — builder-style.
    pub fn set(&mut self, key: &str, v: Json) {
        match self {
            Json::Object(o) => {
                o.insert(key.to_string(), v);
            }
            _ => panic!("Json::set on non-object"),
        }
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<f32> for Json {
    fn from(n: f32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<Vec<f32>> for Json {
    fn from(v: Vec<f32>) -> Json {
        Json::Array(v.into_iter().map(Json::from).collect())
    }
}
impl From<Vec<usize>> for Json {
    fn from(v: Vec<usize>) -> Json {
        Json::Array(v.into_iter().map(Json::from).collect())
    }
}
impl From<Vec<i64>> for Json {
    fn from(v: Vec<i64>) -> Json {
        Json::Array(v.into_iter().map(Json::from).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&super::ser::to_string(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        let v = Json::obj(vec![
            ("n", Json::from(3i64)),
            ("s", Json::from("x")),
            ("a", Json::arr(vec![Json::from(1i64), Json::from(2i64)])),
        ]);
        assert_eq!(v.get("n").as_i64(), Some(3));
        assert_eq!(v.get("s").as_str(), Some("x"));
        assert_eq!(v.get("a").as_usize_vec(), Some(vec![1, 2]));
        assert!(v.get("missing").is_null());
        assert_eq!(v.get("a").at(1).as_i64(), Some(2));
        assert!(v.get("a").at(9).is_null());
    }

    #[test]
    fn as_i64_rejects_fractions() {
        assert_eq!(Json::Num(2.5).as_i64(), None);
        assert_eq!(Json::Num(-7.0).as_i64(), Some(-7));
    }
}
