//! Deep execution profiler: opt-in per-request, per-op timing and memory
//! accounting.
//!
//! PR 6's [`super::trace::ReqTrace`] answers "where did this request's
//! time go?" at request granularity (validate/opt/queue/exec spans).
//! This module answers the next question down — "where inside `exec`?" —
//! by recording, for every executed graph node and model phase: the op
//! kind, the forward point it ran at, the decode step (for streams),
//! wall time, the executing thread, and the bytes the tensor layer
//! allocated while it ran. Value-lifecycle accounting in the interpreter
//! (`put` / `take_dep`) drives live-bytes and peak-bytes gauges.
//!
//! The collector is the same thread-local arm/record/take pattern as
//! [`super::phases`] — the scheduler worker arms it before executing a
//! profiled job and takes the finished [`Profile`] after — so the
//! **disarmed** path costs exactly one thread-local `bool` read per
//! recording site (the same discipline as `util/failpoint.rs`), which is
//! what keeps un-profiled traffic at pre-profiler throughput
//! (`benches/profile.rs` asserts the disarmed overhead stays ≤3%).
//!
//! A finished profile surfaces three ways:
//!
//! * a `"profile"` summary block in result metadata
//!   ([`Profile::summary_json`]: top-K ops by self-time, peak memory,
//!   per-phase totals);
//! * the full Chrome/Perfetto trace-event JSON at
//!   `GET /v1/debug/profile/<req-id>` ([`Profile::trace_events_json`]),
//!   held in a bounded [`ProfileRing`];
//! * cumulative per-op self-time in a replica-wide [`HotOps`] table,
//!   aggregated fleet-wide by the coordinator's `GET /v1/fleet/hotops`.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::Mutex;
use std::time::Instant;

use crate::json::Json;

/// Request header arming the profiler for one request (value `1`). The
/// body key `"profile": true` is equivalent and — because the
/// coordinator forwards request bodies verbatim — also fleet-transparent.
pub const PROFILE_HEADER: &str = "x-nnscope-profile";

/// Sentinel step index for ops outside any decode step.
pub const NO_STEP: i64 = -1;

// Stable small integer ids for trace-event `tid` fields:
// `std::thread::ThreadId` has no portable numeric form.
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Relaxed);
    static COLLECTOR: std::cell::RefCell<Option<Collector>> =
        const { std::cell::RefCell::new(None) };
}

/// One recorded op (a graph node execution) or model phase.
#[derive(Clone, Debug)]
pub struct OpRec {
    /// Op tag (`"matmul"`, `"getter"`, …) or phase name (`"forward"`).
    pub kind: &'static str,
    /// `"op"` for graph nodes, `"phase"` for model phases.
    pub cat: &'static str,
    /// Interned index into [`Profile::points`] (`u32::MAX` = none).
    pub point: u32,
    /// Decode step, [`NO_STEP`] outside a stream step.
    pub step: i64,
    /// Start relative to arming, microseconds.
    pub start_us: u64,
    /// Duration, nanoseconds (sub-µs ops still sum meaningfully).
    pub dur_ns: u64,
    /// Tensor bytes allocated while this op ran.
    pub alloc_bytes: u64,
}

/// The live thread-local collector while a profiled request executes.
struct Collector {
    t0: Instant,
    tid: u64,
    ops: Vec<OpRec>,
    /// Interned forward points; ops reference them by index.
    points: Vec<String>,
    cur_point: u32,
    cur_step: i64,
    /// Alloc bytes since the last op record (attributed to that op).
    pending_alloc: u64,
    alloc_bytes: u64,
    freed_bytes: u64,
    live_bytes: u64,
    peak_bytes: u64,
}

/// A finished, taken profile.
#[derive(Clone, Debug, Default)]
pub struct Profile {
    /// Every recorded op and phase, in execution order.
    pub ops: Vec<OpRec>,
    /// Interned forward-point names referenced by [`OpRec::point`].
    pub points: Vec<String>,
    /// Small stable id of the thread that executed the request.
    pub tid: u64,
    /// Total tensor bytes allocated while armed.
    pub alloc_bytes: u64,
    /// Bytes of graph values freed (moved out / dropped) while armed.
    pub freed_bytes: u64,
    /// High-water mark of live graph-value bytes.
    pub peak_bytes: u64,
    /// Live graph-value bytes at take time (normally ~0).
    pub live_bytes: u64,
}

/// Start collecting on this thread (clears any previous, un-taken
/// profile). The scheduler worker arms this alongside
/// [`super::phases::arm`] for profiled jobs only.
pub fn arm() {
    COLLECTOR.with(|c| {
        *c.borrow_mut() = Some(Collector {
            t0: Instant::now(),
            tid: TID.with(|t| *t),
            ops: Vec::new(),
            points: Vec::new(),
            cur_point: u32::MAX,
            cur_step: NO_STEP,
            pending_alloc: 0,
            alloc_bytes: 0,
            freed_bytes: 0,
            live_bytes: 0,
            peak_bytes: 0,
        });
    });
}

/// Is the profiler armed on this thread? The ONE branch every disarmed
/// recording site pays.
#[inline]
pub fn armed() -> bool {
    COLLECTOR.with(|c| c.borrow().is_some())
}

/// Mark the forward point subsequent ops execute at (interned; no-op
/// when disarmed). Pass `""` to clear (pre/post phases).
pub fn set_point(point: &str) {
    COLLECTOR.with(|c| {
        if let Some(col) = c.borrow_mut().as_mut() {
            if point.is_empty() {
                col.cur_point = u32::MAX;
                return;
            }
            col.cur_point = match col.points.iter().position(|p| p == point) {
                Some(i) => i as u32,
                None => {
                    col.points.push(point.to_string());
                    (col.points.len() - 1) as u32
                }
            };
        }
    });
}

/// Mark the decode step subsequent ops belong to ([`NO_STEP`] = none).
pub fn set_step(step: i64) {
    COLLECTOR.with(|c| {
        if let Some(col) = c.borrow_mut().as_mut() {
            col.cur_step = step;
        }
    });
}

/// Record one executed graph node: `start` was taken just before the op
/// ran (armed-gated by the caller), duration is measured here. Pending
/// tensor allocations since the previous record are attributed to it.
pub fn record_op(kind: &'static str, start: Instant) {
    record(kind, "op", start);
}

/// Record one model phase (`forward` / `backward`) the same way.
pub fn record_phase(kind: &'static str, start: Instant) {
    record(kind, "phase", start);
}

fn record(kind: &'static str, cat: &'static str, start: Instant) {
    let dur_ns = start.elapsed().as_nanos() as u64;
    COLLECTOR.with(|c| {
        if let Some(col) = c.borrow_mut().as_mut() {
            let start_us = start.saturating_duration_since(col.t0).as_micros() as u64;
            let alloc = std::mem::take(&mut col.pending_alloc);
            col.ops.push(OpRec {
                kind,
                cat,
                point: if cat == "op" { col.cur_point } else { u32::MAX },
                step: col.cur_step,
                start_us,
                dur_ns,
                alloc_bytes: alloc,
            });
        }
    });
}

/// Account a tensor-layer allocation of `bytes` (constructor sites in
/// `tensor/`). One thread-local read when disarmed.
#[inline]
pub fn note_alloc(bytes: usize) {
    COLLECTOR.with(|c| {
        if let Some(col) = c.borrow_mut().as_mut() {
            col.alloc_bytes += bytes as u64;
            col.pending_alloc += bytes as u64;
        }
    });
}

/// A graph value of `bytes` became live in the executor (`put`).
#[inline]
pub fn value_live(bytes: usize) {
    COLLECTOR.with(|c| {
        if let Some(col) = c.borrow_mut().as_mut() {
            col.live_bytes += bytes as u64;
            col.peak_bytes = col.peak_bytes.max(col.live_bytes);
        }
    });
}

/// A graph value of `bytes` died in the executor (moved out of
/// `take_dep` by its last listener, or dropped).
#[inline]
pub fn value_dead(bytes: usize) {
    COLLECTOR.with(|c| {
        if let Some(col) = c.borrow_mut().as_mut() {
            col.freed_bytes += bytes as u64;
            col.live_bytes = col.live_bytes.saturating_sub(bytes as u64);
        }
    });
}

/// Take the finished profile and disarm; `None` when not armed.
pub fn take() -> Option<Profile> {
    COLLECTOR.with(|c| {
        c.borrow_mut().take().map(|col| Profile {
            ops: col.ops,
            points: col.points,
            tid: col.tid,
            alloc_bytes: col.alloc_bytes,
            freed_bytes: col.freed_bytes,
            peak_bytes: col.peak_bytes,
            live_bytes: col.live_bytes,
        })
    })
}

impl Profile {
    /// Sum of op self-times (category `"op"` only — phases overlap ops),
    /// nanoseconds.
    pub fn total_op_ns(&self) -> u64 {
        self.ops.iter().filter(|o| o.cat == "op").map(|o| o.dur_ns).sum()
    }

    /// The `"profile"` result-metadata block: top-`k` ops by cumulative
    /// self-time, per-phase totals, memory gauges.
    pub fn summary_json(&self, k: usize) -> Json {
        let mut by_op: BTreeMap<&'static str, (u64, u64, u64)> = BTreeMap::new();
        let mut phases: Vec<(&'static str, u64)> = Vec::new();
        for o in &self.ops {
            if o.cat == "phase" {
                match phases.iter_mut().find(|(n, _)| *n == o.kind) {
                    Some((_, ns)) => *ns += o.dur_ns,
                    None => phases.push((o.kind, o.dur_ns)),
                }
                continue;
            }
            let e = by_op.entry(o.kind).or_insert((0, 0, 0));
            e.0 += 1;
            e.1 += o.dur_ns;
            e.2 += o.alloc_bytes;
        }
        let mut ranked: Vec<_> = by_op.into_iter().collect();
        ranked.sort_by(|a, b| b.1 .1.cmp(&a.1 .1).then(a.0.cmp(b.0)));
        let dropped = ranked.len().saturating_sub(k);
        ranked.truncate(k);
        let top: Vec<Json> = ranked
            .into_iter()
            .map(|(op, (count, ns, bytes))| {
                Json::obj(vec![
                    ("op", Json::from(op)),
                    ("count", Json::from(count as i64)),
                    ("self_us", Json::from((ns / 1_000) as i64)),
                    ("self_ns", Json::from(ns as i64)),
                    ("alloc_bytes", Json::from(bytes as i64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("ops", Json::from(self.ops.iter().filter(|o| o.cat == "op").count() as i64)),
            ("total_self_us", Json::from((self.total_op_ns() / 1_000) as i64)),
            ("top_ops", Json::Array(top)),
            ("dropped_ops", Json::from(dropped as i64)),
            (
                "phases",
                Json::Array(
                    phases
                        .into_iter()
                        .map(|(n, ns)| {
                            Json::obj(vec![
                                ("name", Json::from(n)),
                                ("total_us", Json::from((ns / 1_000) as i64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("alloc_bytes", Json::from(self.alloc_bytes as i64)),
            ("freed_bytes", Json::from(self.freed_bytes as i64)),
            ("peak_bytes", Json::from(self.peak_bytes as i64)),
        ])
    }

    /// The full profile as Chrome/Perfetto trace-event JSON: an object
    /// with a `"traceEvents"` array of complete (`"ph": "X"`) events,
    /// timestamps/durations in microseconds — loadable as-is in
    /// `chrome://tracing` or ui.perfetto.dev.
    pub fn trace_events_json(&self, req_id: &str) -> Json {
        let events: Vec<Json> = self
            .ops
            .iter()
            .map(|o| {
                let mut args = vec![("alloc_bytes", Json::from(o.alloc_bytes as i64))];
                if o.step != NO_STEP {
                    args.push(("step", Json::from(o.step)));
                }
                if let Some(p) = self.points.get(o.point as usize) {
                    args.push(("point", Json::from(p.as_str())));
                }
                Json::obj(vec![
                    ("name", Json::from(o.kind)),
                    ("cat", Json::from(o.cat)),
                    ("ph", Json::from("X")),
                    ("ts", Json::from(o.start_us as i64)),
                    // trace-event durations are µs; keep sub-µs ops visible
                    ("dur", Json::from((o.dur_ns as f64 / 1e3).max(0.001))),
                    ("pid", Json::from(1i64)),
                    ("tid", Json::from(self.tid as i64)),
                    ("args", Json::obj(args)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("traceEvents", Json::Array(events)),
            ("displayTimeUnit", Json::from("ms")),
            (
                "otherData",
                Json::obj(vec![
                    ("request", Json::from(req_id)),
                    ("peak_bytes", Json::from(self.peak_bytes as i64)),
                    ("alloc_bytes", Json::from(self.alloc_bytes as i64)),
                ]),
            ),
        ])
    }
}

/// Bounded, id-keyed ring of finished request profiles (trace-event
/// JSON), same lifecycle as [`super::trace::TraceRing`]: push evicts the
/// oldest beyond capacity, never blocks beyond the push itself.
pub struct ProfileRing {
    cap: usize,
    entries: Mutex<VecDeque<(String, Json)>>,
}

impl ProfileRing {
    /// Ring of at most `cap` profiles (minimum 1).
    pub fn new(cap: usize) -> ProfileRing {
        ProfileRing { cap: cap.max(1), entries: Mutex::new(VecDeque::new()) }
    }

    /// Insert a finished profile under its request/trace id.
    pub fn push(&self, id: &str, profile: Json) {
        let mut e = self.entries.lock().unwrap();
        if e.len() == self.cap {
            e.pop_front();
        }
        e.push_back((id.to_string(), profile));
    }

    /// Look a profile up by id (most recent entry wins on duplicates).
    pub fn get(&self, id: &str) -> Option<Json> {
        let e = self.entries.lock().unwrap();
        e.iter().rev().find(|(k, _)| k == id).map(|(_, v)| v.clone())
    }

    /// Retained ids, oldest first.
    pub fn ids(&self) -> Vec<String> {
        self.entries.lock().unwrap().iter().map(|(k, _)| k.clone()).collect()
    }

    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }
}

/// Replica-wide cumulative per-op self-time, fed by every profiled
/// request; the coordinator merges these across replicas for
/// `GET /v1/fleet/hotops`. Written once per *profiled* request (bounded
/// map: op kinds are a closed set), never touched by disarmed traffic.
#[derive(Default)]
pub struct HotOps {
    ops: Mutex<BTreeMap<&'static str, (u64, u64)>>,
}

impl HotOps {
    pub fn new() -> HotOps {
        HotOps::default()
    }

    /// Fold one finished profile's op self-times in.
    pub fn fold(&self, p: &Profile) {
        let mut m = self.ops.lock().unwrap();
        for o in p.ops.iter().filter(|o| o.cat == "op") {
            let e = m.entry(o.kind).or_insert((0, 0));
            e.0 += 1;
            e.1 += o.dur_ns;
        }
    }

    /// `{"hotops": [{"op", "count", "self_ns", "self_us"}...]}` ranked by
    /// cumulative self-time, top `k`.
    pub fn to_json(&self, k: usize) -> Json {
        let m = self.ops.lock().unwrap();
        let acc: BTreeMap<String, (u64, u64)> =
            m.iter().map(|(op, &v)| (op.to_string(), v)).collect();
        hotops_json(&acc, k)
    }
}

/// Render a `(count, self_ns)` per-op table as the wire `hotops` shape —
/// shared by the replica ([`HotOps::to_json`]) and the coordinator's
/// fleet merge so both tiers emit identical JSON.
pub fn hotops_json(acc: &BTreeMap<String, (u64, u64)>, k: usize) -> Json {
    let mut ranked: Vec<_> = acc.iter().collect();
    ranked.sort_by(|a, b| b.1 .1.cmp(&a.1 .1).then(a.0.cmp(b.0)));
    let total_ns: u64 = acc.values().map(|v| v.1).sum();
    ranked.truncate(k);
    Json::obj(vec![
        ("total_self_ns", Json::from(total_ns as i64)),
        (
            "hotops",
            Json::Array(
                ranked
                    .into_iter()
                    .map(|(op, &(count, ns))| {
                        Json::obj(vec![
                            ("op", Json::from(op.as_str())),
                            ("count", Json::from(count as i64)),
                            ("self_ns", Json::from(ns as i64)),
                            ("self_us", Json::from((ns / 1_000) as i64)),
                            (
                                "share",
                                Json::from(if total_ns == 0 {
                                    0.0
                                } else {
                                    ns as f64 / total_ns as f64
                                }),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Merge one replica's `hotops` JSON into a fleet accumulator (the
/// coordinator's half of the exchange; inverse of [`hotops_json`]).
pub fn merge_hotops(acc: &mut BTreeMap<String, (u64, u64)>, j: &Json) {
    for h in j.get("hotops").as_array().unwrap_or(&[]) {
        let Some(op) = h.get("op").as_str() else { continue };
        let count = h.get("count").as_i64().unwrap_or(0).max(0) as u64;
        let ns = h.get("self_ns").as_i64().unwrap_or(0).max(0) as u64;
        let e = acc.entry(op.to_string()).or_insert((0, 0));
        e.0 += count;
        e.1 += ns;
    }
}

/// The per-replica profiler surface a scheduler worker records into:
/// the bounded trace-event ring plus the cumulative hot-op table.
pub struct ProfileHub {
    /// Finished profiles for `GET /v1/debug/profile/<id>`.
    pub ring: ProfileRing,
    /// Cumulative per-op self-time for `GET /v1/debug/hotops`.
    pub hotops: HotOps,
}

impl ProfileHub {
    pub fn new(ring_cap: usize) -> ProfileHub {
        ProfileHub { ring: ProfileRing::new(ring_cap), hotops: HotOps::new() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_profile() -> Profile {
        arm();
        set_point("layer.0");
        let t = Instant::now();
        note_alloc(1024);
        value_live(1024);
        record_op("getter", t);
        let t = Instant::now();
        note_alloc(2048);
        value_live(2048);
        record_op("matmul", t);
        value_dead(1024);
        set_step(2);
        let t = Instant::now();
        record_op("matmul", t);
        let t = Instant::now();
        record_phase("forward", t);
        take().unwrap()
    }

    #[test]
    fn disarmed_by_default_and_take_disarms() {
        assert!(!armed());
        note_alloc(64); // no-op
        record_op("matmul", Instant::now()); // no-op
        assert!(take().is_none());
        arm();
        assert!(armed());
        assert!(take().is_some());
        assert!(!armed());
    }

    #[test]
    fn collector_attributes_allocs_and_tracks_peak() {
        let p = small_profile();
        assert_eq!(p.ops.len(), 4);
        assert_eq!(p.ops[0].kind, "getter");
        assert_eq!(p.ops[0].alloc_bytes, 1024);
        assert_eq!(p.ops[1].alloc_bytes, 2048);
        assert_eq!(p.ops[2].step, 2);
        assert_eq!(p.ops[0].step, NO_STEP);
        assert_eq!(p.points, vec!["layer.0".to_string()]);
        assert_eq!(p.alloc_bytes, 3072);
        assert_eq!(p.peak_bytes, 3072);
        assert_eq!(p.freed_bytes, 1024);
        assert_eq!(p.live_bytes, 2048);
    }

    #[test]
    fn summary_ranks_ops_by_self_time_and_totals_phases() {
        let p = small_profile();
        let s = p.summary_json(8);
        assert_eq!(s.get("ops").as_i64(), Some(3));
        let top = s.get("top_ops").as_array().unwrap();
        assert_eq!(top.len(), 2); // matmul + getter
        let ops: Vec<&str> = top.iter().filter_map(|t| t.get("op").as_str()).collect();
        assert!(ops.contains(&"matmul") && ops.contains(&"getter"));
        let matmul = top.iter().find(|t| t.get("op").as_str() == Some("matmul")).unwrap();
        assert_eq!(matmul.get("count").as_i64(), Some(2));
        let phases = s.get("phases").as_array().unwrap();
        assert_eq!(phases[0].get("name").as_str(), Some("forward"));
        assert_eq!(s.get("peak_bytes").as_i64(), Some(3072));
        // top-K truncation reports what it dropped
        let s1 = p.summary_json(1);
        assert_eq!(s1.get("top_ops").as_array().unwrap().len(), 1);
        assert_eq!(s1.get("dropped_ops").as_i64(), Some(1));
    }

    #[test]
    fn trace_events_are_structurally_valid() {
        let p = small_profile();
        let j = p.trace_events_json("r-1");
        let events = j.get("traceEvents").as_array().unwrap();
        assert_eq!(events.len(), 4);
        for e in events {
            assert!(e.get("name").as_str().is_some());
            assert_eq!(e.get("ph").as_str(), Some("X"));
            assert!(e.get("ts").as_i64().is_some());
            assert!(e.get("dur").as_f64().unwrap() > 0.0);
            assert!(e.get("pid").as_i64().is_some());
            assert!(e.get("tid").as_i64().is_some());
        }
        // round-trips through the wire form
        let text = j.to_string();
        let back = crate::json::parse(&text).unwrap();
        assert_eq!(back.get("traceEvents").as_array().unwrap().len(), 4);
        assert_eq!(back.get("otherData").get("request").as_str(), Some("r-1"));
    }

    #[test]
    fn ring_is_bounded_and_keyed() {
        let r = ProfileRing::new(2);
        assert_eq!(r.capacity(), 2);
        r.push("a", Json::from(1i64));
        r.push("b", Json::from(2i64));
        r.push("c", Json::from(3i64));
        assert_eq!(r.len(), 2);
        assert!(r.get("a").is_none(), "oldest evicted");
        assert_eq!(r.get("c").as_ref().and_then(Json::as_i64), Some(3));
        assert_eq!(r.ids(), vec!["b".to_string(), "c".to_string()]);
        assert_eq!(ProfileRing::new(0).capacity(), 1, "cap floor");
    }

    #[test]
    fn hotops_fold_rank_and_fleet_merge() {
        let hub = HotOps::new();
        hub.fold(&small_profile());
        hub.fold(&small_profile());
        let j = hub.to_json(10);
        let ops = j.get("hotops").as_array().unwrap();
        assert_eq!(ops.len(), 2);
        let matmul = ops.iter().find(|o| o.get("op").as_str() == Some("matmul")).unwrap();
        assert_eq!(matmul.get("count").as_i64(), Some(4));
        // shares sum to ~1 over the full table
        let total: f64 = ops.iter().map(|o| o.get("share").as_f64().unwrap()).sum();
        assert!((total - 1.0).abs() < 1e-9);
        // coordinator-side merge of two replicas doubles the counts
        let mut acc = BTreeMap::new();
        merge_hotops(&mut acc, &j);
        merge_hotops(&mut acc, &j);
        let merged = hotops_json(&acc, 10);
        let m = merged
            .get("hotops")
            .as_array()
            .unwrap()
            .iter()
            .find(|o| o.get("op").as_str() == Some("matmul"))
            .unwrap()
            .clone();
        assert_eq!(m.get("count").as_i64(), Some(8));
    }
}
