//! Request tracing: trace ids, per-stage spans, and the debug ring.
//!
//! A trace id is minted at the client (or by the coordinator for bare
//! requests) and rides the `x-nnscope-trace` header through coordinator
//! routing and retries, replica admission, scheduler queueing, co-tenant
//! merge, and interpreter execution. Each tier stamps spans
//! (validate/opt/queue/exec/serialize plus interpreter phases) onto the
//! [`ReqTrace`] that travels *with the job* — by value, so no locks are
//! held while a request is in flight. The finished trace is returned to
//! the caller as `"timing"` metadata in `/v1/result` and retained in a
//! bounded [`TraceRing`] served at `GET /v1/debug/requests`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::Mutex;
use std::time::Instant;

use crate::json::Json;

/// The header that carries a request's trace id across tiers.
pub const TRACE_HEADER: &str = "x-nnscope-trace";

static MINT_SEQ: AtomicU64 = AtomicU64::new(0);

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Mint a fresh 16-hex-char trace id (wall-clock nanos mixed with a
/// process-wide counter, so concurrent mints never collide).
pub fn mint_trace_id() -> String {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let seq = MINT_SEQ.fetch_add(1, Relaxed);
    format!("{:016x}", splitmix64(nanos ^ seq.rotate_left(32)))
}

/// One recorded span: a named stage with its offset from request start
/// and duration, both in microseconds.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpanRec {
    pub name: String,
    pub start_us: u64,
    pub dur_us: u64,
}

/// A request trace, moved along with the job through the pipeline.
#[derive(Debug)]
pub struct ReqTrace {
    pub trace_id: String,
    pub endpoint: &'static str,
    pub model: String,
    /// Admission time — the zero point all span offsets are relative to.
    pub t0: Instant,
    /// Set when the job is enqueued; the worker turns it into the
    /// `queue` span at dequeue.
    pub enqueued_at: Option<Instant>,
    pub spans: Vec<SpanRec>,
}

impl ReqTrace {
    pub fn new(trace_id: String, endpoint: &'static str, model: &str) -> ReqTrace {
        ReqTrace {
            trace_id,
            endpoint,
            model: model.to_string(),
            t0: Instant::now(),
            enqueued_at: None,
            spans: Vec::new(),
        }
    }

    /// Record a span that ran from `start` until now.
    pub fn span_since(&mut self, name: &str, start: Instant) {
        let start_us = start.saturating_duration_since(self.t0).as_micros() as u64;
        let dur_us = start.elapsed().as_micros() as u64;
        self.spans.push(SpanRec { name: name.to_string(), start_us, dur_us });
    }

    /// Record a span by explicit offset and duration (used for
    /// interpreter phases reported in nanoseconds).
    pub fn span_at(&mut self, name: &str, start_us: u64, dur_us: u64) {
        self.spans.push(SpanRec { name: name.to_string(), start_us, dur_us });
    }

    /// Time a closure as a span.
    pub fn time<R>(&mut self, name: &str, f: impl FnOnce() -> R) -> R {
        let start = Instant::now();
        let r = f();
        self.span_since(name, start);
        r
    }

    /// Stamp the enqueue instant (the worker closes the `queue` span at
    /// dequeue via [`ReqTrace::close_queue_span`]).
    pub fn mark_enqueued(&mut self) {
        self.enqueued_at = Some(Instant::now());
    }

    /// Close the `queue` span and return the queue wait, if
    /// [`ReqTrace::mark_enqueued`] was called.
    pub fn close_queue_span(&mut self) -> Option<std::time::Duration> {
        let start = self.enqueued_at.take()?;
        let wait = start.elapsed();
        self.span_since("queue", start);
        Some(wait)
    }

    /// The `"timing"` metadata object returned in `/v1/result` and kept
    /// in the debug ring: trace id, endpoint, model, total latency so
    /// far, and all recorded spans in order.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("trace", Json::from(self.trace_id.as_str())),
            ("endpoint", Json::from(self.endpoint)),
            ("model", Json::from(self.model.as_str())),
            ("total_us", Json::from(self.t0.elapsed().as_micros() as i64)),
            (
                "spans",
                Json::arr(
                    self.spans
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("name", Json::from(s.name.as_str())),
                                ("start_us", Json::from(s.start_us as i64)),
                                ("dur_us", Json::from(s.dur_us as i64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Time a closure as a span on an optional trace — the admission-path
/// idiom (`timed(&mut trace, "validate", || …)`), a plain call when
/// observability is off.
pub fn timed<R>(trace: &mut Option<ReqTrace>, name: &str, f: impl FnOnce() -> R) -> R {
    match trace.as_mut() {
        Some(t) => t.time(name, f),
        None => f(),
    }
}

/// Bounded ring buffer of finished request traces (most recent last).
/// One short lock per *finished* request — nothing on the in-flight
/// path touches it.
pub struct TraceRing {
    cap: usize,
    buf: Mutex<VecDeque<Json>>,
}

impl TraceRing {
    pub fn new(cap: usize) -> TraceRing {
        TraceRing { cap: cap.max(1), buf: Mutex::new(VecDeque::new()) }
    }

    /// Append a finished trace, evicting the oldest past capacity.
    pub fn push(&self, trace: Json) {
        let mut g = self.buf.lock().unwrap();
        if g.len() == self.cap {
            g.pop_front();
        }
        g.push_back(trace);
    }

    /// Copy of the retained traces, oldest first.
    pub fn snapshot(&self) -> Vec<Json> {
        self.buf.lock().unwrap().iter().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.buf.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mint_ids_are_unique_and_well_formed() {
        let mut seen = std::collections::HashSet::new();
        for _ in 0..1000 {
            let id = mint_trace_id();
            assert_eq!(id.len(), 16);
            assert!(id.chars().all(|c| c.is_ascii_hexdigit()));
            assert!(seen.insert(id), "trace id collision");
        }
    }

    #[test]
    fn spans_accumulate_and_serialize() {
        let mut t = ReqTrace::new("abc".into(), "trace", "tiny-sim");
        t.time("validate", || std::thread::sleep(std::time::Duration::from_millis(2)));
        t.mark_enqueued();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let wait = t.close_queue_span().unwrap();
        assert!(wait.as_micros() >= 1000);
        t.span_at("exec:forward", 0, 42);
        let j = t.to_json();
        assert_eq!(j.get("trace").as_str(), Some("abc"));
        assert_eq!(j.get("model").as_str(), Some("tiny-sim"));
        let spans = j.get("spans").as_array().unwrap();
        assert_eq!(spans.len(), 3);
        assert_eq!(spans[0].get("name").as_str(), Some("validate"));
        assert_eq!(spans[1].get("name").as_str(), Some("queue"));
        assert!(spans[1].get("dur_us").as_i64().unwrap() >= 1000);
        assert_eq!(spans[2].get("dur_us").as_i64(), Some(42));
    }

    #[test]
    fn queue_span_absent_without_enqueue_mark() {
        let mut t = ReqTrace::new("abc".into(), "trace", "m");
        assert!(t.close_queue_span().is_none());
        assert!(t.spans.is_empty());
    }

    #[test]
    fn ring_is_bounded_and_fifo() {
        let r = TraceRing::new(3);
        for i in 0..10i64 {
            r.push(Json::from(i));
        }
        assert_eq!(r.len(), 3);
        let got = r.snapshot();
        assert_eq!(got, vec![Json::from(7i64), Json::from(8i64), Json::from(9i64)]);
        assert_eq!(r.capacity(), 3);
    }

    #[test]
    fn ring_capacity_floor_is_one() {
        let r = TraceRing::new(0);
        r.push(Json::from(1i64));
        r.push(Json::from(2i64));
        assert_eq!(r.snapshot(), vec![Json::from(2i64)]);
    }
}
