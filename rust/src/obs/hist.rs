//! Fixed log-bucketed latency histograms.
//!
//! Bucket boundaries are static: bucket 0 holds everything under
//! [`LO`] (1 µs), buckets `1..=62` are log-spaced between [`LO`] and
//! [`HI`] (100 s) with a constant growth factor, and bucket 63 is the
//! overflow for anything at or above [`HI`]. Because every histogram in
//! the fleet shares these boundaries, merging is per-bucket `u64`
//! addition — and a percentile computed from merged counts is
//! *bit-identical* to one computed from the concatenation of the
//! per-replica bucket arrays, since both reduce to
//! [`percentile_from_counts`] over the same summed counts.
//!
//! Recording is a pair of relaxed atomic adds; there is no lock and no
//! allocation on the hot path.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

use crate::json::Json;

/// Number of buckets (including the under- and overflow buckets).
pub const BUCKETS: usize = 64;
/// Lower edge of the log range, seconds (everything below lands in
/// bucket 0).
pub const LO: f64 = 1e-6;
/// Upper edge of the log range, seconds (everything at or above lands
/// in the overflow bucket).
pub const HI: f64 = 1e2;
/// Log-spaced buckets strictly inside `[LO, HI)`.
const LOG_BUCKETS: usize = BUCKETS - 2;

/// `ln` of the per-bucket growth factor: `(HI/LO)^(1/62)`.
fn ln_growth() -> f64 {
    (HI / LO).ln() / LOG_BUCKETS as f64
}

/// Bucket index for a latency of `v` seconds.
pub fn bucket_of(v: f64) -> usize {
    if v.is_nan() || v < LO {
        // negative, NaN, or sub-LO: the underflow bucket
        return 0;
    }
    if v >= HI {
        return BUCKETS - 1;
    }
    let idx = 1 + ((v / LO).ln() / ln_growth()).floor() as usize;
    idx.min(BUCKETS - 2)
}

/// Lower edge of bucket `i` for `i` in `1..BUCKETS`. Every caller goes
/// through this one expression, so adjacent buckets share the exact same
/// `f64` edge value (no one-ULP seams between `upper(i)` and
/// `lower(i+1)`).
fn edge(i: usize) -> f64 {
    (LO.ln() + ln_growth() * (i - 1) as f64).exp()
}

/// `[lower, upper)` bounds of bucket `i`, seconds (`upper` of the
/// overflow bucket is `f64::INFINITY`).
pub fn bucket_bounds(i: usize) -> (f64, f64) {
    assert!(i < BUCKETS);
    if i == 0 {
        return (0.0, edge(1));
    }
    if i == BUCKETS - 1 {
        return (edge(BUCKETS - 1), f64::INFINITY);
    }
    (edge(i), edge(i + 1))
}

/// Deterministic representative latency for bucket `i`: the geometric
/// midpoint of its bounds (half of `LO` for the underflow bucket, `HI`
/// for the overflow bucket). Percentile queries return these values, so
/// two parties that agree on bucket counts agree on percentiles to the
/// last bit.
pub fn bucket_mid(i: usize) -> f64 {
    assert!(i < BUCKETS);
    if i == 0 {
        return LO * 0.5;
    }
    if i == BUCKETS - 1 {
        return HI;
    }
    let (lo, hi) = bucket_bounds(i);
    (lo * hi).sqrt()
}

/// Percentile (`q` in `[0, 1]`) over a bucket-count array using the
/// nearest-rank rule: the representative of the first bucket whose
/// cumulative count reaches `ceil(q · total)`. Returns `0.0` for an
/// empty histogram. This is the **single** percentile definition used by
/// replicas, the coordinator's fleet merge, and the integration tests —
/// determinism of this one pure function over summed counts is what
/// makes fleet percentiles bit-identical to concatenated-array
/// percentiles.
pub fn percentile_from_counts(counts: &[u64], q: f64) -> f64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
    let mut cum = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        cum += c;
        if cum >= rank {
            return bucket_mid(i);
        }
    }
    bucket_mid(BUCKETS - 1)
}

/// A lock-free latency histogram with static log buckets.
#[derive(Default)]
pub struct Histogram {
    counts: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_nanos: AtomicU64,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Record a latency in seconds (two relaxed atomic adds plus the
    /// bucket add).
    pub fn record(&self, seconds: f64) {
        self.counts[bucket_of(seconds)].fetch_add(1, Relaxed);
        self.count.fetch_add(1, Relaxed);
        let nanos = if seconds.is_finite() && seconds > 0.0 {
            (seconds * 1e9) as u64
        } else {
            0
        };
        self.sum_nanos.fetch_add(nanos, Relaxed);
    }

    /// Record an elapsed [`std::time::Duration`].
    pub fn record_duration(&self, d: std::time::Duration) {
        self.record(d.as_secs_f64());
    }

    /// Point-in-time copy of the counts.
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            counts: std::array::from_fn(|i| self.counts[i].load(Relaxed)),
            count: self.count.load(Relaxed),
            sum_nanos: self.sum_nanos.load(Relaxed),
        }
    }

    /// Total recorded observations.
    pub fn count(&self) -> u64 {
        self.count.load(Relaxed)
    }
}

/// An owned, mergeable copy of a histogram's counts.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    pub counts: [u64; BUCKETS],
    pub count: u64,
    pub sum_nanos: u64,
}

impl Default for HistSnapshot {
    fn default() -> HistSnapshot {
        HistSnapshot { counts: [0; BUCKETS], count: 0, sum_nanos: 0 }
    }
}

impl HistSnapshot {
    /// Fold another snapshot in (per-bucket addition — valid because
    /// bucket boundaries are static fleet-wide).
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_nanos += other.sum_nanos;
    }

    /// Percentile of the recorded latencies, seconds.
    pub fn percentile(&self, q: f64) -> f64 {
        percentile_from_counts(&self.counts, q)
    }

    /// Mean latency, seconds (`0.0` when empty).
    pub fn mean_s(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_nanos as f64 / 1e9 / self.count as f64
        }
    }

    /// Wire form: `{"buckets": [u64; 64], "count": n, "sum_ns": n}`.
    /// Counts are integers, so the JSON round-trip is exact and a
    /// receiver can merge and re-derive percentiles bit-identically.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "buckets",
                Json::arr(self.counts.iter().map(|&c| Json::from(c as i64)).collect()),
            ),
            ("count", Json::from(self.count as i64)),
            ("sum_ns", Json::from(self.sum_nanos as i64)),
            ("p50", Json::from(self.percentile(0.50))),
            ("p95", Json::from(self.percentile(0.95))),
            ("p99", Json::from(self.percentile(0.99))),
            ("mean_s", Json::from(self.mean_s())),
        ])
    }

    /// Parse the wire form; `None` when the shape is wrong.
    pub fn from_json(j: &Json) -> Option<HistSnapshot> {
        let arr = j.get("buckets").as_array()?;
        if arr.len() != BUCKETS {
            return None;
        }
        let mut counts = [0u64; BUCKETS];
        for (slot, v) in counts.iter_mut().zip(arr.iter()) {
            *slot = v.as_i64()? as u64;
        }
        Some(HistSnapshot {
            counts,
            count: j.get("count").as_i64()? as u64,
            sum_nanos: j.get("sum_ns").as_i64().unwrap_or(0) as u64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_monotone_and_cover() {
        let mut prev_hi = 0.0;
        for i in 0..BUCKETS {
            let (lo, hi) = bucket_bounds(i);
            assert_eq!(lo, prev_hi, "bucket {i} lower edge");
            assert!(hi > lo);
            prev_hi = hi;
        }
        assert_eq!(bucket_bounds(BUCKETS - 1).1, f64::INFINITY);
    }

    #[test]
    fn bucket_of_respects_bounds() {
        for i in 0..BUCKETS - 1 {
            let (lo, hi) = bucket_bounds(i);
            let probe = if i == 0 { lo } else { (lo * hi).sqrt() };
            assert_eq!(bucket_of(probe), i, "midpoint of bucket {i}");
        }
        assert_eq!(bucket_of(0.0), 0);
        assert_eq!(bucket_of(-1.0), 0);
        assert_eq!(bucket_of(f64::NAN), 0);
        assert_eq!(bucket_of(HI), BUCKETS - 1);
        assert_eq!(bucket_of(1e9), BUCKETS - 1);
    }

    #[test]
    fn empty_histogram_percentiles_are_zero() {
        let h = Histogram::new();
        let s = h.snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.percentile(0.5), 0.0);
        assert_eq!(s.percentile(0.99), 0.0);
        assert_eq!(s.mean_s(), 0.0);
    }

    #[test]
    fn single_bucket_percentiles_return_its_representative() {
        let h = Histogram::new();
        for _ in 0..10 {
            h.record(0.005); // 5 ms — all land in one bucket
        }
        let s = h.snapshot();
        let b = bucket_of(0.005);
        assert_eq!(s.counts[b], 10);
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(s.percentile(q), bucket_mid(b), "q={q}");
        }
    }

    #[test]
    fn percentiles_walk_ranked_buckets() {
        let h = Histogram::new();
        // 90 fast (≈1 ms), 10 slow (≈1 s): p50 must be fast, p95+ slow.
        for _ in 0..90 {
            h.record(0.001);
        }
        for _ in 0..10 {
            h.record(1.0);
        }
        let s = h.snapshot();
        assert_eq!(s.percentile(0.50), bucket_mid(bucket_of(0.001)));
        assert_eq!(s.percentile(0.90), bucket_mid(bucket_of(0.001)));
        assert_eq!(s.percentile(0.95), bucket_mid(bucket_of(1.0)));
        assert_eq!(s.percentile(0.99), bucket_mid(bucket_of(1.0)));
    }

    #[test]
    fn merge_equals_concatenation() {
        let a = Histogram::new();
        let b = Histogram::new();
        let both = Histogram::new();
        let latencies_a = [1e-5, 3e-4, 0.002, 0.002, 0.7];
        let latencies_b = [2e-6, 0.05, 0.05, 4.0, 250.0];
        for &v in &latencies_a {
            a.record(v);
            both.record(v);
        }
        for &v in &latencies_b {
            b.record(v);
            both.record(v);
        }
        let mut merged = a.snapshot();
        merged.merge(&b.snapshot());
        assert_eq!(merged, both.snapshot());
        for q in [0.1, 0.5, 0.9, 0.95, 0.99] {
            // bit-identical, not approximately equal
            assert_eq!(
                merged.percentile(q).to_bits(),
                both.snapshot().percentile(q).to_bits()
            );
        }
    }

    #[test]
    fn json_round_trip_is_exact() {
        let h = Histogram::new();
        for v in [1e-7, 0.001, 0.02, 0.02, 3.0, 500.0] {
            h.record(v);
        }
        let s = h.snapshot();
        let text = s.to_json().to_string();
        let back = HistSnapshot::from_json(&crate::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, s);
        assert_eq!(back.percentile(0.95).to_bits(), s.percentile(0.95).to_bits());
    }

    #[test]
    fn from_json_rejects_wrong_shapes() {
        assert!(HistSnapshot::from_json(&Json::Null).is_none());
        let short = Json::obj(vec![
            ("buckets", Json::arr(vec![Json::from(1i64)])),
            ("count", Json::from(1i64)),
        ]);
        assert!(HistSnapshot::from_json(&short).is_none());
    }
}
