//! Fleet-wide observability: mergeable histograms, request tracing, and
//! the per-process metrics registry.
//!
//! The paper frames NDIF as a shared fabric serving many concurrent
//! researchers; operating such a fabric needs more than flat counters.
//! This subsystem provides the three measurement primitives every tier
//! (coordinator → replica → scheduler worker → interpreter) records into:
//!
//! * [`hist`] — fixed log-bucketed latency histograms. Bucket boundaries
//!   are **static** (compile-time constants), so merging replica
//!   histograms is per-bucket count addition and fleet-wide percentiles
//!   computed from merged counts are *bit-identical* to percentiles
//!   computed from the concatenated per-replica bucket arrays.
//! * [`trace`] — request traces: a trace id minted at the client or
//!   coordinator, propagated via the `x-nnscope-trace` header, with
//!   per-stage spans (validate/opt/queue/exec/serialize) stamped as the
//!   request moves through the pipeline. Finished traces land in a
//!   bounded ring buffer served at `GET /v1/debug/requests`.
//! * [`registry`] — the per-process hub: per-model and per-endpoint
//!   histograms plus optimizer-pass counters, with JSON and Prometheus
//!   text exposition for `GET /v1/metrics`.
//! * [`profile`] — the opt-in deep execution profiler: per-op timing and
//!   memory accounting for individual requests (armed by the
//!   `x-nnscope-profile` header), exported as result metadata, Chrome
//!   trace-event JSON, and a fleet-aggregable hot-op table.
//!
//! Everything on the hot path is an atomic fetch-add with relaxed
//! ordering — no locks are taken while a request is being recorded
//! (the trace ring, written once per *finished* request, is the only
//! mutex, and it is bounded).
//!
//! Instrumentation can be disabled fleet-wide with `NNSCOPE_OBS=off`
//! (or per server via `NdifConfig::obs`); the `benches/obs.rs` gate
//! holds the instrumented-vs-disabled overhead under 5%.

pub mod hist;
pub mod profile;
pub mod registry;
pub mod trace;

pub use hist::{percentile_from_counts, HistSnapshot, Histogram, BUCKETS};
pub use profile::{HotOps, Profile, ProfileHub, ProfileRing, PROFILE_HEADER};
pub use registry::{EndpointObs, ModelObs, Obs, ServiceObs};
pub use trace::{mint_trace_id, timed, ReqTrace, SpanRec, TraceRing, TRACE_HEADER};

/// Does the environment allow instrumentation? `NNSCOPE_OBS=off|0|false`
/// forces observability off regardless of server config; anything else
/// (including unset) defers to the config flag.
pub fn env_allows() -> bool {
    match std::env::var("NNSCOPE_OBS") {
        Ok(v) => !matches!(v.as_str(), "off" | "0" | "false"),
        Err(_) => true,
    }
}

/// Per-thread interpreter phase timings (forward/backward), recorded by
/// the interpreter without it needing a handle to any registry: the
/// scheduler worker arms collection before executing a job and takes the
/// accumulated phases after, folding them into the request's trace as
/// `exec:<phase>` spans.
///
/// Collection is disarmed by default, so un-instrumented callers of the
/// interpreter (tests, benches, `NNSCOPE_OBS=off`) pay only a
/// thread-local bool read per phase.
pub mod phases {
    use std::cell::RefCell;

    thread_local! {
        static PHASES: RefCell<Option<Vec<(&'static str, u64)>>> = const { RefCell::new(None) };
    }

    /// Start collecting phase timings on this thread (clears any
    /// previous, un-taken collection).
    pub fn arm() {
        PHASES.with(|p| *p.borrow_mut() = Some(Vec::new()));
    }

    /// Is collection armed on this thread? Cheap guard so the
    /// interpreter can skip the clock reads entirely when not observed.
    pub fn armed() -> bool {
        PHASES.with(|p| p.borrow().is_some())
    }

    /// Record `nanos` spent in `name` (no-op when disarmed).
    pub fn record(name: &'static str, nanos: u64) {
        PHASES.with(|p| {
            if let Some(v) = p.borrow_mut().as_mut() {
                v.push((name, nanos));
            }
        });
    }

    /// Take the collected phases and disarm.
    pub fn take() -> Vec<(&'static str, u64)> {
        PHASES.with(|p| p.borrow_mut().take().unwrap_or_default())
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn phases_disarmed_by_default_and_take_disarms() {
        assert!(!super::phases::armed());
        super::phases::record("forward", 10); // no-op
        super::phases::arm();
        assert!(super::phases::armed());
        super::phases::record("forward", 10);
        super::phases::record("backward", 20);
        let got = super::phases::take();
        assert_eq!(got, vec![("forward", 10), ("backward", 20)]);
        assert!(!super::phases::armed());
        assert!(super::phases::take().is_empty());
    }
}
