//! The per-process observability hub: per-model and per-endpoint
//! histograms, optimizer-pass counters, and the debug trace ring, with
//! JSON and Prometheus text exposition.
//!
//! The model and endpoint maps are built once at server startup and
//! never mutated, so the hot path is a `BTreeMap` lookup plus relaxed
//! atomic adds — no locks.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::Arc;
use std::time::Duration;

use crate::json::Json;

use super::hist::{HistSnapshot, Histogram};
use super::trace::TraceRing;

/// Endpoints with their own latency histograms. Fixed at compile time so
/// the map never grows under load.
pub const ENDPOINTS: [&str; 4] = ["trace", "session", "stream", "result"];

/// Per-model latency histograms and optimizer-pass counters.
#[derive(Default)]
pub struct ModelObs {
    /// Admission → result published (or stream done).
    pub e2e: Histogram,
    /// Enqueue → dequeue by a worker.
    pub queue_wait: Histogram,
    /// Worker execution (interpreter) time.
    pub exec: Histogram,
    /// Streaming time-to-first-token: admission → first event sent.
    pub ttft: Histogram,
    /// Requests that went through the admission graph compiler.
    pub opt_requests: AtomicU64,
    pub opt_dce: AtomicU64,
    pub opt_folded: AtomicU64,
    pub opt_cse: AtomicU64,
    pub opt_fused: AtomicU64,
    /// Admissions served by a cached AOT plan (validate + opt skipped).
    pub plan_hits: AtomicU64,
    /// Admissions that compiled (and cached) a fresh AOT plan.
    pub plan_misses: AtomicU64,
}

impl ModelObs {
    /// Count an admission-compiler report into the pass counters.
    pub fn record_opt(&self, r: &crate::graph::opt::OptReport) {
        self.opt_requests.fetch_add(1, Relaxed);
        self.opt_dce.fetch_add(r.dce_removed as u64, Relaxed);
        self.opt_folded.fetch_add(r.folded as u64, Relaxed);
        self.opt_cse.fetch_add(r.cse_merged as u64, Relaxed);
        self.opt_fused.fetch_add(r.fused as u64, Relaxed);
    }

    /// Count one plan-cache admission outcome. On a hit the request skips
    /// validation and the optimizer entirely, so `opt_requests` stays flat
    /// — the pair of counters is the observable proof that cached
    /// admission does less work.
    pub fn record_plan(&self, hit: bool) {
        if hit {
            self.plan_hits.fetch_add(1, Relaxed);
        } else {
            self.plan_misses.fetch_add(1, Relaxed);
        }
    }

    /// The `"plan"` per-model metrics object (admission plan-cache
    /// outcomes as seen by this model's endpoints).
    pub fn plan_json(&self) -> Json {
        Json::obj(vec![
            ("hits", Json::from(self.plan_hits.load(Relaxed) as i64)),
            ("misses", Json::from(self.plan_misses.load(Relaxed) as i64)),
        ])
    }

    /// The `"latency"` + `"opt"` halves of one model's metrics entry.
    pub fn to_json(&self) -> (Json, Json) {
        let latency = Json::obj(vec![
            ("e2e", self.e2e.snapshot().to_json()),
            ("queue_wait", self.queue_wait.snapshot().to_json()),
            ("exec", self.exec.snapshot().to_json()),
            ("ttft", self.ttft.snapshot().to_json()),
        ]);
        let opt = Json::obj(vec![
            ("requests", Json::from(self.opt_requests.load(Relaxed) as i64)),
            ("dce_removed", Json::from(self.opt_dce.load(Relaxed) as i64)),
            ("folded", Json::from(self.opt_folded.load(Relaxed) as i64)),
            ("cse_merged", Json::from(self.opt_cse.load(Relaxed) as i64)),
            ("fused", Json::from(self.opt_fused.load(Relaxed) as i64)),
        ]);
        (latency, opt)
    }
}

/// Per-endpoint request/error counters and latency histogram.
#[derive(Default)]
pub struct EndpointObs {
    pub requests: AtomicU64,
    pub errors: AtomicU64,
    pub latency: Histogram,
}

/// Everything one scheduler worker needs to record into: its model's
/// histograms, the shared debug ring, and the profiler surface (bounded
/// profile ring + hot-op table). Threaded into `ModelService::start` so
/// the queue layer has no dependency on the full [`Obs`] hub.
#[derive(Clone)]
pub struct ServiceObs {
    pub model: Arc<ModelObs>,
    pub ring: Arc<TraceRing>,
    pub profile: Arc<super::profile::ProfileHub>,
}

/// The per-process observability registry.
pub struct Obs {
    enabled: bool,
    models: BTreeMap<String, Arc<ModelObs>>,
    endpoints: BTreeMap<&'static str, EndpointObs>,
    ring: Arc<TraceRing>,
    profile: Arc<super::profile::ProfileHub>,
}

impl Obs {
    /// Build the hub for a fixed model set. `enabled` combines the
    /// server config flag with the `NNSCOPE_OBS` environment override;
    /// `profile_ring` bounds the retained request profiles.
    pub fn new(enabled: bool, models: &[String], ring_cap: usize, profile_ring: usize) -> Obs {
        let enabled = enabled && super::env_allows();
        Obs {
            enabled,
            models: models
                .iter()
                .map(|m| (m.clone(), Arc::new(ModelObs::default())))
                .collect(),
            endpoints: ENDPOINTS.iter().map(|&e| (e, EndpointObs::default())).collect(),
            ring: Arc::new(TraceRing::new(ring_cap)),
            profile: Arc::new(super::profile::ProfileHub::new(profile_ring)),
        }
    }

    /// Disabled hub (`NNSCOPE_OBS=off` / `obs: false`): recording calls
    /// are skipped by callers checking [`Obs::enabled`].
    pub fn disabled() -> Obs {
        Obs::new(false, &[], 1, 1)
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// The per-model recorder, `None` when disabled or unknown model.
    pub fn model(&self, name: &str) -> Option<&Arc<ModelObs>> {
        if !self.enabled {
            return None;
        }
        self.models.get(name)
    }

    /// The bundle a `ModelService` worker records into.
    pub fn service_obs(&self, model: &str) -> Option<ServiceObs> {
        Some(ServiceObs {
            model: self.model(model)?.clone(),
            ring: self.ring.clone(),
            profile: self.profile.clone(),
        })
    }

    pub fn ring(&self) -> &Arc<TraceRing> {
        &self.ring
    }

    /// The profiler surface (`GET /v1/debug/profile/<id>`, hot-op table).
    pub fn profile(&self) -> &Arc<super::profile::ProfileHub> {
        &self.profile
    }

    /// Record one HTTP request against a named endpoint.
    pub fn record_endpoint(&self, endpoint: &str, latency: Duration, ok: bool) {
        if !self.enabled {
            return;
        }
        if let Some(e) = self.endpoints.get(endpoint) {
            e.requests.fetch_add(1, Relaxed);
            if !ok {
                e.errors.fetch_add(1, Relaxed);
            }
            e.latency.record_duration(latency);
        }
    }

    /// Merged end-to-end snapshot across all models (what heartbeats
    /// report p95 from).
    pub fn merged_e2e(&self) -> HistSnapshot {
        let mut out = HistSnapshot::default();
        for m in self.models.values() {
            out.merge(&m.e2e.snapshot());
        }
        out
    }

    /// The `"_endpoints"` metrics object.
    pub fn endpoints_json(&self) -> Json {
        Json::obj(
            self.endpoints
                .iter()
                .map(|(name, e)| {
                    (
                        *name,
                        Json::obj(vec![
                            ("requests", Json::from(e.requests.load(Relaxed) as i64)),
                            ("errors", Json::from(e.errors.load(Relaxed) as i64)),
                            ("latency", e.latency.snapshot().to_json()),
                        ]),
                    )
                })
                .collect(),
        )
    }

    /// Prometheus text exposition (`GET /v1/metrics?format=prometheus`).
    /// Histograms are emitted as cumulative `_bucket{le=...}` series in
    /// the standard exposition format, with counters and gauges the
    /// caller supplies appended as-is.
    pub fn prometheus(&self, extra: &[(String, f64)]) -> String {
        let mut out = String::new();
        out.push_str("# TYPE nnscope_latency_seconds histogram\n");
        for (model, m) in &self.models {
            for (stage, h) in [
                ("e2e", &m.e2e),
                ("queue_wait", &m.queue_wait),
                ("exec", &m.exec),
                ("ttft", &m.ttft),
            ] {
                prometheus_histogram(&mut out, model, stage, &h.snapshot());
            }
        }
        out.push_str("# TYPE nnscope_endpoint_requests_total counter\n");
        for (name, e) in &self.endpoints {
            out.push_str(&format!(
                "nnscope_endpoint_requests_total{{endpoint=\"{name}\"}} {}\n",
                e.requests.load(Relaxed)
            ));
            out.push_str(&format!(
                "nnscope_endpoint_errors_total{{endpoint=\"{name}\"}} {}\n",
                e.errors.load(Relaxed)
            ));
        }
        for (name, v) in extra {
            out.push_str(&format!("{name} {v}\n"));
        }
        out
    }
}

/// Render one latency histogram snapshot as cumulative Prometheus
/// `_bucket{le=...}` / `_sum` / `_count` series. Shared by
/// [`Obs::prometheus`] (replica, live histograms) and the coordinator's
/// `GET /v1/fleet/metrics?format=prometheus` (bucket-merged snapshots),
/// so the two expositions are line-identical for identical counts.
pub fn prometheus_histogram(out: &mut String, model: &str, stage: &str, s: &HistSnapshot) {
    let mut cum = 0u64;
    for (i, &c) in s.counts.iter().enumerate() {
        cum += c;
        let (_, hi) = super::hist::bucket_bounds(i);
        let le = if hi.is_infinite() { "+Inf".to_string() } else { format!("{hi:e}") };
        out.push_str(&format!(
            "nnscope_latency_seconds_bucket{{model=\"{model}\",stage=\"{stage}\",le=\"{le}\"}} {cum}\n"
        ));
    }
    out.push_str(&format!(
        "nnscope_latency_seconds_sum{{model=\"{model}\",stage=\"{stage}\"}} {}\n",
        s.sum_nanos as f64 / 1e9
    ));
    out.push_str(&format!(
        "nnscope_latency_seconds_count{{model=\"{model}\",stage=\"{stage}\"}} {}\n",
        s.count
    ));
}

#[cfg(test)]
mod tests {
    use super::*;

    fn models() -> Vec<String> {
        vec!["tiny-sim".to_string()]
    }

    #[test]
    fn disabled_hub_records_nothing() {
        let o = Obs::new(false, &models(), 8, 8);
        assert!(!o.enabled());
        assert!(o.model("tiny-sim").is_none());
        o.record_endpoint("trace", Duration::from_millis(5), true);
        let j = o.endpoints_json();
        assert_eq!(j.get("trace").get("requests").as_i64(), Some(0));
    }

    #[test]
    fn endpoint_recording_counts_errors() {
        let o = Obs::new(true, &models(), 8, 8);
        o.record_endpoint("trace", Duration::from_millis(5), true);
        o.record_endpoint("trace", Duration::from_millis(5), false);
        o.record_endpoint("bogus-endpoint", Duration::from_millis(5), true);
        let j = o.endpoints_json();
        assert_eq!(j.get("trace").get("requests").as_i64(), Some(2));
        assert_eq!(j.get("trace").get("errors").as_i64(), Some(1));
        assert_eq!(j.get("trace").get("latency").get("count").as_i64(), Some(2));
    }

    #[test]
    fn merged_e2e_sums_across_models() {
        let ms = vec!["a".to_string(), "b".to_string()];
        let o = Obs::new(true, &ms, 8, 8);
        o.model("a").unwrap().e2e.record(0.01);
        o.model("b").unwrap().e2e.record(0.02);
        o.model("b").unwrap().e2e.record(0.03);
        assert_eq!(o.merged_e2e().count, 3);
    }

    #[test]
    fn opt_counters_accumulate() {
        let o = Obs::new(true, &models(), 8, 8);
        let m = o.model("tiny-sim").unwrap();
        m.record_opt(&crate::graph::opt::OptReport {
            nodes_before: 10,
            nodes_after: 7,
            dce_removed: 2,
            folded: 1,
            cse_merged: 0,
            fused: 0,
        });
        m.record_opt(&crate::graph::opt::OptReport {
            nodes_before: 5,
            nodes_after: 5,
            ..Default::default()
        });
        let (_, opt) = m.to_json();
        assert_eq!(opt.get("requests").as_i64(), Some(2));
        assert_eq!(opt.get("dce_removed").as_i64(), Some(2));
        assert_eq!(opt.get("folded").as_i64(), Some(1));
    }

    #[test]
    fn prometheus_exposition_has_cumulative_buckets() {
        let o = Obs::new(true, &models(), 8, 8);
        let m = o.model("tiny-sim").unwrap();
        m.e2e.record(0.001);
        m.e2e.record(0.5);
        let text = o.prometheus(&[("nnscope_store_objects".to_string(), 3.0)]);
        assert!(text.contains("# TYPE nnscope_latency_seconds histogram"));
        assert!(text.contains("nnscope_latency_seconds_count{model=\"tiny-sim\",stage=\"e2e\"} 2"));
        assert!(text.contains("nnscope_store_objects 3"));
        // cumulative: the +Inf bucket of e2e equals the total count
        let inf_line = text
            .lines()
            .find(|l| l.contains("stage=\"e2e\"") && l.contains("le=\"+Inf\""))
            .unwrap();
        assert!(inf_line.ends_with(" 2"));
    }
}
