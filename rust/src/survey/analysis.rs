//! The Fig. 2 / Fig. 7 computations.

use crate::util::stats::quantile;

use super::data::{PaperRecord, ReleasedModel, BUCKETS, FEB_2023};

/// Fig. 2 headline statistics.
#[derive(Debug, Clone)]
pub struct Fig2Stats {
    pub total_papers: usize,
    /// papers published after Feb 2023
    pub post_feb_2023: usize,
    /// of those, the fraction studying <40% MMLU models (paper: 60.6%)
    pub frac_sub40_post_2023: f64,
    /// papers studying ≥70% MMLU models (the small group, Fig. 2a)
    pub count_ge70: usize,
    /// mean capability gap: frontier(=85) − studied MMLU, post-2023
    pub mean_gap_post_2023: f64,
}

/// Compute the Fig. 2 statistics over the survey dataset.
pub fn fig2_stats(papers: &[PaperRecord]) -> Fig2Stats {
    let post: Vec<&PaperRecord> = papers.iter().filter(|p| p.date >= FEB_2023).collect();
    let sub40 = post.iter().filter(|p| p.mmlu < 40.0).count();
    let ge70 = papers.iter().filter(|p| p.mmlu >= 70.0).count();
    let frontier = 85.0; // leading closed-weight MMLU in the survey window
    let mean_gap = if post.is_empty() {
        0.0
    } else {
        post.iter().map(|p| frontier - p.mmlu).sum::<f64>() / post.len() as f64
    };
    Fig2Stats {
        total_papers: papers.len(),
        post_feb_2023: post.len(),
        frac_sub40_post_2023: if post.is_empty() { 0.0 } else { sub40 as f64 / post.len() as f64 },
        count_ge70: ge70,
        mean_gap_post_2023: mean_gap,
    }
}

/// One Fig. 7 year bucket: research-vs-released size distributions.
#[derive(Debug, Clone)]
pub struct Fig7Bucket {
    pub label: &'static str,
    pub research_median_b: f64,
    pub research_q25: f64,
    pub research_q75: f64,
    pub released_median_b: f64,
    pub released_q25: f64,
    pub released_q75: f64,
    /// released median / research median — the paper's dashed-gold ratio
    pub ratio: f64,
}

/// Compute Fig. 7's per-bucket box statistics and median ratios.
pub fn fig7_buckets(papers: &[PaperRecord], released: &[ReleasedModel]) -> Vec<Fig7Bucket> {
    BUCKETS
        .iter()
        .map(|&(label, start, end, _)| {
            let r: Vec<f64> = papers
                .iter()
                .filter(|p| p.date >= start && p.date < end)
                .map(|p| p.params_b)
                .collect();
            let m: Vec<f64> = released
                .iter()
                .filter(|p| p.date >= start && p.date < end)
                .map(|p| p.params_b)
                .collect();
            let rq = |q| if r.is_empty() { 0.0 } else { quantile(&r, q) };
            let mq = |q| if m.is_empty() { 0.0 } else { quantile(&m, q) };
            Fig7Bucket {
                label,
                research_median_b: rq(0.5),
                research_q25: rq(0.25),
                research_q75: rq(0.75),
                released_median_b: mq(0.5),
                released_q25: mq(0.25),
                released_q75: mq(0.75),
                ratio: if rq(0.5) > 0.0 { mq(0.5) / rq(0.5) } else { 0.0 },
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::survey::data::{survey_dataset, DEFAULT_SEED};

    #[test]
    fn fig2_reproduces_headline_stats() {
        let (papers, _) = survey_dataset(DEFAULT_SEED);
        let s = fig2_stats(&papers);
        assert_eq!(s.total_papers, 184);
        // paper: 60.6% of post-Feb-2023 papers study <40% MMLU models
        assert!(
            (s.frac_sub40_post_2023 - 0.606).abs() < 0.03,
            "frac = {}",
            s.frac_sub40_post_2023
        );
        // a small but nonzero ≥70% group
        assert!(s.count_ge70 >= 2 && s.count_ge70 <= 20, "{}", s.count_ge70);
        assert!(s.mean_gap_post_2023 > 30.0);
    }

    #[test]
    fn fig7_ratio_grows_from_about_2_7_to_about_10_3() {
        let (papers, released) = survey_dataset(DEFAULT_SEED);
        let buckets = fig7_buckets(&papers, &released);
        assert_eq!(buckets.len(), 5);
        let first = buckets.first().unwrap().ratio;
        let last = buckets.last().unwrap().ratio;
        assert!((first - 2.7).abs() / 2.7 < 0.5, "first ratio {first}");
        assert!((last - 10.3).abs() / 10.3 < 0.5, "last ratio {last}");
        // monotone growth (allowing small wobble)
        for w in buckets.windows(2) {
            assert!(w[1].ratio > w[0].ratio * 0.8, "{:?}", w.iter().map(|b| b.ratio).collect::<Vec<_>>());
        }
    }

    #[test]
    fn fig7_boxes_are_ordered() {
        let (papers, released) = survey_dataset(DEFAULT_SEED);
        for b in fig7_buckets(&papers, &released) {
            assert!(b.research_q25 <= b.research_median_b);
            assert!(b.research_median_b <= b.research_q75);
            assert!(b.released_q25 <= b.released_median_b);
        }
    }
}
