//! Synthetic survey dataset constructed to the paper's published
//! statistics (see module docs in [`super`]).
//!
//! Construction constraints (all from the paper):
//! * 184 papers, 2019 – late 2024, counts ramping with the field's growth;
//! * 60.6% of papers dated after Feb 2023 study models with <40% MMLU
//!   (enforced with a running quota so the fraction is exact up to
//!   rounding, independent of sampling noise);
//! * earlier eras are ~95% sub-40 (capable open models did not exist);
//! * a small ≥70%-MMLU group exists (Fig. 2a);
//! * Fig. 7's released-median / research-median ratio grows 2.7× → 10.3×
//!   across year buckets — enforced by generating the released series
//!   around `ratio × (empirical research median)` per bucket.

use crate::util::stats::quantile;
use crate::util::Prng;

/// One surveyed paper: publication date and the largest open-weight model
/// it studies.
#[derive(Clone, Debug)]
pub struct PaperRecord {
    /// decimal year, e.g. 2023.5
    pub date: f64,
    /// parameter count of the largest model studied, in billions
    pub params_b: f64,
    /// MMLU score (0–100) of that model (interpolated where the paper's
    /// sources lacked one, as in Appendix A)
    pub mmlu: f64,
}

/// A publicly released open-weight model (Epoch AI reference series).
#[derive(Clone, Debug)]
pub struct ReleasedModel {
    pub date: f64,
    pub params_b: f64,
    pub mmlu: f64,
}

/// Fig. 7 year buckets with target released/research median ratios.
/// The paper reports the endpoints (2.7× in 2019–20, 10.3× in 2024) with
/// monotone growth between.
pub const BUCKETS: [(&str, f64, f64, f64); 5] = [
    // (label, start, end, target ratio)
    ("2019-2020", 2019.0, 2021.0, 2.7),
    ("2021", 2021.0, 2022.0, 4.1),
    ("2022", 2022.0, 2023.0, 6.0),
    ("2023", 2023.0, 2024.0, 8.2),
    ("2024", 2024.0, 2024.8, 10.3),
];

/// Papers per bucket (sums to 184).
pub const PAPER_COUNTS: [usize; 5] = [14, 22, 36, 64, 48];

/// Feb 2023 as a decimal year — the paper's "since February 2023" cut.
pub const FEB_2023: f64 = 2023.0 + 1.0 / 12.0;

/// MMLU as a rough logistic in log-params, calibrated so ~1B → ~30,
/// 7B → ~50, 70B → ~70, 405B → ~85 (the era's leaderboard shape).
/// Random baseline is 25; crosses 40 at ≈1.7B.
pub fn mmlu_of_params(params_b: f64, noise: f64) -> f64 {
    let x = params_b.max(0.01).ln();
    let v = 25.0 + 62.0 / (1.0 + (-(x - 2.2) / 1.45).exp());
    (v + noise).clamp(24.0, 90.0)
}

fn lognormal_around(rng: &mut Prng, median: f64, sigma: f64) -> f64 {
    median * (sigma * rng.normal()).exp()
}

/// The default dataset seed used everywhere.
pub const DEFAULT_SEED: u64 = 184;

/// Generate the 184-paper dataset plus the released-model reference
/// series. Deterministic per seed.
pub fn survey_dataset(seed: u64) -> (Vec<PaperRecord>, Vec<ReleasedModel>) {
    let mut rng = Prng::new(seed);
    let mut papers: Vec<PaperRecord> = Vec::with_capacity(184);

    // quota accumulators: (small so far, total so far) per era
    let mut post = (0usize, 0usize);
    let mut pre = (0usize, 0usize);

    for (bi, &(_, start, end, _)) in BUCKETS.iter().enumerate() {
        let n = PAPER_COUNTS[bi];
        for k in 0..n {
            let date = start + (end - start) * ((k as f64 + 0.5) / n as f64);
            let (quota, era) = if date >= FEB_2023 {
                (0.606, &mut post)
            } else {
                (0.95, &mut pre)
            };
            era.1 += 1;
            // running-quota decision keeps the era fraction exact
            let want_small = (era.0 as f64) < quota * era.1 as f64 - 1e-9;
            if want_small {
                era.0 += 1;
            }
            let params_b = if want_small {
                // sub-40-MMLU regime: < ~1.7B (GPT-2/Pythia class)
                lognormal_around(&mut rng, 0.4, 0.8).clamp(0.05, 1.55)
            } else if rng.uniform() < 0.22 {
                // the small ≥70%-MMLU group (Fig. 2a): Qwen-72B/Yi-34B class
                lognormal_around(&mut rng, 62.0, 0.25).clamp(34.0, 110.0)
            } else {
                // mid-capability open models (7B–34B class)
                lognormal_around(&mut rng, 9.0, 0.55).clamp(2.4, 40.0)
            };
            let mmlu = if want_small {
                mmlu_of_params(params_b, 1.5 * rng.normal()).min(39.5)
            } else {
                mmlu_of_params(params_b, 1.5 * rng.normal()).max(40.5)
            };
            papers.push(PaperRecord { date, params_b, mmlu });
        }
    }

    // Released-model series: generated around ratio × empirical research
    // median per bucket, so Fig. 7's ratios land on target by design.
    let mut released = Vec::new();
    for &(_, start, end, ratio) in BUCKETS.iter() {
        let research: Vec<f64> = papers
            .iter()
            .filter(|p| p.date >= start && p.date < end)
            .map(|p| p.params_b)
            .collect();
        let research_median = quantile(&research, 0.5);
        let target = ratio * research_median;
        // symmetric multiplicative spread preserves the median
        for k in 0..12 {
            let date = start + (end - start) * ((k as f64 + 0.5) / 12.0);
            let spread: f64 = 0.9 * rng.normal();
            // pair up symmetric factors: even k up, odd k mirrors previous
            let params_b = if k % 2 == 0 {
                target * spread.abs().exp()
            } else {
                target * (-spread.abs()).exp()
            };
            released.push(ReleasedModel {
                date,
                params_b,
                mmlu: mmlu_of_params(params_b, rng.normal()),
            });
        }
    }
    (papers, released)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_size_is_184() {
        let (papers, released) = survey_dataset(DEFAULT_SEED);
        assert_eq!(papers.len(), 184);
        assert_eq!(released.len(), 60);
    }

    #[test]
    fn deterministic() {
        let (a, _) = survey_dataset(DEFAULT_SEED);
        let (b, _) = survey_dataset(DEFAULT_SEED);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.params_b, y.params_b);
        }
    }

    #[test]
    fn mmlu_curve_is_monotone_and_calibrated() {
        assert!(mmlu_of_params(0.1, 0.0) < 35.0);
        assert!(mmlu_of_params(70.0, 0.0) > 60.0);
        assert!(mmlu_of_params(405.0, 0.0) > 75.0);
        let mut prev = 0.0;
        for p in [0.1, 1.0, 7.0, 70.0, 405.0] {
            let v = mmlu_of_params(p, 0.0);
            assert!(v > prev);
            prev = v;
        }
        // the 40-MMLU crossover sits near 1.7B, below the small-model cap
        assert!(mmlu_of_params(1.55, 0.0) < 40.0);
        assert!(mmlu_of_params(2.4, 0.0) > 40.0);
    }

    #[test]
    fn small_quota_is_exact_per_era() {
        let (papers, _) = survey_dataset(DEFAULT_SEED);
        let post: Vec<_> = papers.iter().filter(|p| p.date >= FEB_2023).collect();
        let small = post.iter().filter(|p| p.mmlu < 40.0).count();
        let frac = small as f64 / post.len() as f64;
        assert!((frac - 0.606).abs() < 0.01, "{frac}");
    }
}
