//! The §2 research survey: Figures 2 and 7.
//!
//! The paper curates 184 interpretability papers (from Ferrando et al.
//! 2024's citations) and shows (Fig. 2) that most study models far below
//! frontier MMLU capability, and (Fig. 7) that the gap between the median
//! model size used in research and the median publicly-released model size
//! grew from 2.7× (2019–20) to 10.3× (2024).
//!
//! The curated dataset itself is in the paper's supplementary materials,
//! which we do not have; [`data`] synthesizes a dataset *to the paper's
//! published statistics* (documented substitution, DESIGN.md §3):
//! 184 papers, 60.6% of post-Feb-2023 papers studying <40% MMLU models, a
//! small ≥70% group, and per-bucket size medians that reproduce the
//! 2.7×→10.3× trajectory. [`analysis`] then implements the actual Fig. 2 /
//! Fig. 7 computations over it — the analysis code is the reproduction
//! target; the data generator is the stand-in for the supplementary CSV.

pub mod analysis;
pub mod data;

pub use analysis::{fig2_stats, fig7_buckets, Fig2Stats, Fig7Bucket};
pub use data::{survey_dataset, PaperRecord, ReleasedModel};
