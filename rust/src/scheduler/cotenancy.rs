//! Batch-grouped parallel co-tenancy (§B.2).
//!
//! "During tracing, intervention nodes record batch groups that specify
//! tensor slices. During execution, the system extracts appropriate
//! slices …, enabling multiple users to share execution within a single
//! forward pass." — the paper describes this as future work; we implement
//! it: [`execute_merged`] runs k compatible intervention graphs in ONE
//! forward pass, each graph seeing and touching only its own rows.

use anyhow::{anyhow, Result};

use crate::graph::opt::Prepared;
use crate::graph::{GraphResult, InterventionGraph};
use crate::interp::{Executor, StateView};
use crate::models::{Hooks, ModelRunner};
use crate::tensor::Tensor;

/// Co-tenancy policy for a model service.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoTenancy {
    /// One request per forward pass (arrival order).
    Sequential,
    /// Merge up to `max_merge` compatible requests per forward pass.
    Parallel { max_merge: usize },
}

/// Plan merge chunks: split a burst of jobs (by their row counts) into
/// groups whose total rows land on an exported batch size with minimal
/// padding — merging 16 single-row requests into one 32-row forward wastes
/// half the compute when 8-row executables exist. Greedy: each chunk
/// targets the largest exported batch ≤ remaining rows (min the largest
/// exported batch overall).
pub fn plan_merge_chunks(rows: &[usize], exported: &[usize]) -> Vec<usize> {
    let max_b = exported.iter().copied().max().unwrap_or(1);
    let mut chunks = Vec::new();
    let mut i = 0;
    while i < rows.len() {
        let remaining: usize = rows[i..].iter().sum();
        // largest exported batch not exceeding the remaining rows (fall
        // back to max_b so oversized tails still split sensibly)
        let target = exported
            .iter()
            .copied()
            .filter(|&b| b <= remaining)
            .max()
            .unwrap_or(max_b);
        let mut take = 0usize;
        let mut acc = 0usize;
        while i + take < rows.len() && acc + rows[i + take] <= target {
            acc += rows[i + take];
            take += 1;
        }
        let take = take.max(1); // a single over-sized job forms its own chunk
        chunks.push(take);
        i += take;
    }
    chunks
}

/// Can these graphs share one forward pass on this runner?
///
/// Requirements: same model, no gradient work (the backward pass is
/// per-request), no session-state dataflow (state threading is strictly
/// ordered), unsharded, and the combined rows fit an exported batch.
pub fn mergeable(graphs: &[&InterventionGraph], runner: &ModelRunner) -> bool {
    if graphs.len() < 2 {
        return true;
    }
    let total_rows: usize = graphs.iter().map(|g| g.batch).sum();
    graphs.iter().all(|g| {
        g.model == runner.manifest.name
            && g.grad_points().is_empty()
            && !g.uses_state()
            && g.shards <= 1
            && g.batch > 0
    }) && runner.batch_for(total_rows).is_ok()
}

/// Dispatches hooks to every co-tenant executor; any setter marks the
/// activation modified.
struct MultiHooks<'a, 'g> {
    executors: &'a mut [Executor<'g>],
}

impl Hooks for MultiHooks<'_, '_> {
    fn wants(&self, point: &str) -> bool {
        self.executors.iter().any(|e| e.wants(point))
    }

    fn on_output(&mut self, point: &str, t: &mut Tensor) -> bool {
        let mut modified = false;
        for e in self.executors.iter_mut() {
            if e.wants(point) {
                modified |= e.on_output(point, t);
            }
        }
        modified
    }
}

/// Execute k graphs in one forward pass. Returns per-graph results in
/// input order. All-or-nothing on infrastructure errors; per-graph errors
/// are returned individually.
pub fn execute_merged(
    graphs: &[InterventionGraph],
    runner: &ModelRunner,
) -> Result<Vec<Result<GraphResult>>> {
    let preps: Vec<Prepared> = graphs.iter().cloned().map(Prepared::raw).collect();
    let refs: Vec<&Prepared> = preps.iter().collect();
    execute_merged_prepared(&refs, runner)
}

/// Plan-aware merge: like [`execute_merged`] but each co-tenant runs its
/// own [`Prepared`] admission output — graphs that came through the plan
/// cache get arena-planned executors ([`Executor::planned`]); raw graphs
/// fall back to per-node allocation. Results are keyed by *template* ids;
/// the caller re-keys with [`Prepared::remap_values`]. Batch-group
/// patching happens here, after plan bind: the plan's schedule and arena
/// are row-count independent, so a standalone-compiled plan stays valid
/// when its graph is pinned to a slice of a merged forward pass.
pub fn execute_merged_prepared(
    jobs: &[&Prepared],
    runner: &ModelRunner,
) -> Result<Vec<Result<GraphResult>>> {
    let refs: Vec<&InterventionGraph> = jobs.iter().map(|p| &p.graph).collect();
    if !mergeable(&refs, runner) {
        return Err(anyhow!("graphs are not mergeable into one forward pass"));
    }
    let seq = runner.manifest.seq;

    // combined tokens + per-graph row offsets
    let total_rows: usize = refs.iter().map(|g| g.batch).sum();
    let mut tokens = Vec::with_capacity(total_rows * seq);
    let mut offsets = Vec::with_capacity(jobs.len());
    let mut off = 0usize;
    for g in &refs {
        if g.tokens.len() != g.batch * seq {
            return Err(anyhow!("graph token length mismatch"));
        }
        offsets.push(off);
        tokens.extend_from_slice(&g.tokens);
        off += g.batch;
    }
    let tokens = Tensor::new(&[total_rows, seq], tokens);
    let (padded, _) = runner.pad_tokens(&tokens)?;

    // per-graph executors pinned to their row slices
    let fseq = runner.manifest.forward_sequence();
    let mut patched: Vec<InterventionGraph> = refs.iter().map(|&g| g.clone()).collect();
    for (g, &off) in patched.iter_mut().zip(&offsets) {
        g.batch_group = Some((off, g.batch));
    }
    let mut executors: Vec<Executor> = Vec::with_capacity(patched.len());
    for (g, p) in patched.iter().zip(jobs) {
        let mut ex = match &p.plan {
            Some(plan) => Executor::planned(g, &fseq, StateView::new(), plan),
            None => Executor::new(g, &fseq)?,
        };
        ex.run_pre()?;
        executors.push(ex);
    }

    {
        let tf = crate::obs::phases::armed().then(std::time::Instant::now);
        let mut hooks = MultiHooks { executors: &mut executors };
        runner.forward(&padded, &mut hooks)?;
        if let Some(t) = tf {
            crate::obs::phases::record("forward", t.elapsed().as_nanos() as u64);
        }
    }

    Ok(executors.into_iter().map(|e| e.into_result()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::Trace;
    use crate::models::artifacts_dir;

    fn runner() -> ModelRunner {
        ModelRunner::load(&artifacts_dir(), "tiny-sim").unwrap()
    }

    fn save_layer_graph(row_vals: f32, layer: &str) -> InterventionGraph {
        let tokens = Tensor::full(&[1, 16], row_vals);
        let mut tr = Trace::new("tiny-sim", &tokens);
        let h = tr.output(layer);
        tr.save(h);
        tr.into_graph()
    }

    #[test]
    fn merged_results_equal_standalone() {
        let r = runner();
        let g1 = save_layer_graph(1.0, "layer.0");
        let g2 = save_layer_graph(2.0, "layer.1");

        let solo1 = crate::interp::execute(&g1, &r).unwrap();
        let solo2 = crate::interp::execute(&g2, &r).unwrap();

        let merged = execute_merged(&[g1.clone(), g2.clone()], &r).unwrap();
        let m1 = merged[0].as_ref().unwrap();
        let m2 = merged[1].as_ref().unwrap();

        for (id, t) in &solo1.values {
            assert!(m1.values[id].allclose(t, 1e-5), "g1 node {id}");
        }
        for (id, t) in &solo2.values {
            assert!(m2.values[id].allclose(t, 1e-5), "g2 node {id}");
        }
    }

    #[test]
    fn cotenant_setter_isolation() {
        // user 1 ablates their row at layer.0; user 2 just saves logits.
        // user 2's logits must equal a standalone run (no cross-tenant
        // interference) — the paper's safe co-tenancy property.
        let r = runner();
        let mut tr1 = Trace::new("tiny-sim", &Tensor::full(&[1, 16], 3.0));
        let h = tr1.output("layer.0");
        let z = tr1.scale(h, 0.0);
        tr1.set_output("layer.0", z);
        let s1 = tr1.save(z);
        let g1 = tr1.into_graph();

        let mut tr2 = Trace::new("tiny-sim", &Tensor::full(&[1, 16], 5.0));
        let logits = tr2.output("lm_head");
        let s2 = tr2.save(logits);
        let g2 = tr2.into_graph();

        let solo2 = crate::interp::execute(&g2, &r).unwrap();
        let merged = execute_merged(&[g1, g2], &r).unwrap();
        let m1 = merged[0].as_ref().unwrap();
        let m2 = merged[1].as_ref().unwrap();

        assert!(m1.values[&s1.0].data().iter().all(|&v| v == 0.0));
        assert!(
            m2.values[&s2.0].allclose(&solo2.values[&s2.0], 1e-4),
            "user 2 affected by user 1's intervention: diff {}",
            m2.values[&s2.0].max_abs_diff(&solo2.values[&s2.0])
        );
    }

    #[test]
    fn mergeable_rejects_grads_and_overflow() {
        let r = runner();
        let g1 = save_layer_graph(1.0, "layer.0");
        let mut g2 = save_layer_graph(1.0, "layer.0");
        g2.targets = Some(vec![1.0]);
        g2.nodes.clear();
        let gid = g2.push(crate::graph::Op::Grad { module: "layer.0".into() });
        g2.push(crate::graph::Op::Save { arg: gid });
        assert!(!mergeable(&[&g1, &g2], &r));

        // 5 single-row graphs exceed tiny-sim's max exported batch of 4
        let many: Vec<InterventionGraph> =
            (0..5).map(|_| save_layer_graph(1.0, "layer.0")).collect();
        let refs: Vec<&InterventionGraph> = many.iter().collect();
        assert!(!mergeable(&refs, &r));
        let refs4: Vec<&InterventionGraph> = many[..4].iter().collect();
        assert!(mergeable(&refs4, &r));
    }
}

#[cfg(test)]
mod chunk_tests {
    use super::plan_merge_chunks;

    #[test]
    fn sixteen_singles_split_into_two_eights() {
        assert_eq!(plan_merge_chunks(&[1; 16], &[1, 4, 8, 32]), vec![8, 8]);
    }

    #[test]
    fn thirty_two_singles_fill_one_batch() {
        assert_eq!(plan_merge_chunks(&[1; 32], &[1, 4, 8, 32]), vec![32]);
    }

    #[test]
    fn odd_tail_gets_smaller_chunk() {
        assert_eq!(plan_merge_chunks(&[1; 13], &[1, 4, 8, 32]), vec![8, 4, 1]);
    }

    #[test]
    fn multi_row_jobs_pack_without_overflow() {
        // jobs of 3+3+3 rows with batches {1,4,8}: 3+3=6 ≤ 8, next 3 would
        // exceed → chunk [2 jobs], then [1 job]
        assert_eq!(plan_merge_chunks(&[3, 3, 3], &[1, 4, 8]), vec![2, 1]);
    }

    #[test]
    fn oversized_job_is_its_own_chunk() {
        assert_eq!(plan_merge_chunks(&[64, 1], &[1, 4, 8, 32]), vec![1, 1]);
    }

    #[test]
    fn empty_burst_plans_no_chunks() {
        assert_eq!(plan_merge_chunks(&[], &[1, 4, 8]), Vec::<usize>::new());
        assert_eq!(plan_merge_chunks(&[], &[]), Vec::<usize>::new());
    }

    #[test]
    fn empty_exported_batches_degrade_to_per_job_chunks() {
        // a manifest with no exported batch sizes must not panic or merge:
        // every job becomes its own chunk
        assert_eq!(plan_merge_chunks(&[1, 1, 1], &[]), vec![1, 1, 1]);
        assert_eq!(plan_merge_chunks(&[3, 3], &[]), vec![1, 1]);
    }

    #[test]
    fn single_oversized_job_alone_forms_one_chunk() {
        // larger than every exported batch, no companions: exactly one
        // chunk of one job (the runner pads/fails downstream, the planner
        // must not loop or drop it)
        assert_eq!(plan_merge_chunks(&[64], &[1, 4, 8, 32]), vec![1]);
        assert_eq!(plan_merge_chunks(&[64], &[]), vec![1]);
    }

    #[test]
    fn tail_underfilling_smallest_exported_batch_still_ships() {
        // 5 single-row jobs with batches {4, 8}: the first chunk fills the
        // 4-batch, the 1-row tail underfills even the smallest exported
        // batch but must still be planned (padded at execution)
        assert_eq!(plan_merge_chunks(&[1; 5], &[4, 8]), vec![4, 1]);
        // same with a multi-row tail: 4+4 fills 8, the 3-row tail rides
        // alone under the 4-batch
        assert_eq!(plan_merge_chunks(&[4, 4, 3], &[4, 8]), vec![2, 1]);
    }

    #[test]
    fn chunks_always_cover_every_job() {
        // planner invariant: chunk sizes sum to the burst length for
        // arbitrary row/batch mixes
        let cases: &[(&[usize], &[usize])] = &[
            (&[1; 13], &[1, 4, 8, 32]),
            (&[2, 5, 1, 7, 3], &[4, 8]),
            (&[9, 9, 9], &[8]),
            (&[1, 1], &[]),
        ];
        for (rows, exported) in cases {
            let chunks = plan_merge_chunks(rows, exported);
            assert_eq!(
                chunks.iter().sum::<usize>(),
                rows.len(),
                "rows {rows:?} exported {exported:?} -> {chunks:?}"
            );
            assert!(chunks.iter().all(|&c| c > 0), "{chunks:?}");
        }
    }
}
