//! Request scheduling: per-model FIFO queues and co-tenant execution.
//!
//! NDIF's compute efficiency comes from *co-tenancy* (§3.3, §B.2): many
//! users share one preloaded model instance. Two modes are implemented:
//!
//! * **sequential** — one queue per model service; requests run one
//!   forward pass each, in arrival order (the mode the paper's Fig. 9
//!   load test used);
//! * **parallel (batch-grouped)** — the §B.2 "future implementation":
//!   compatible queued requests are merged into a single forward pass,
//!   each intervention graph operating on its own batch-group row slice
//!   with isolation guaranteed by the executor (and verified by tests).
//!
//! Streaming decodes are *continuously batched* (vLLM-style): the worker
//! advances every in-flight stream by one token per tick, admits new
//! work between ticks, and retires finished streams without draining
//! the rest. All submissions go through three unified entry points
//! ([`ModelService::submit_trace`] / [`ModelService::submit_session`] /
//! [`ModelService::submit_stream`]) taking one [`SubmitOpts`].

pub mod cotenancy;
pub mod queue;

pub use cotenancy::{execute_merged, execute_merged_prepared, CoTenancy};
pub use queue::{
    LoadSnapshot, ModelService, ServiceMetrics, StreamChunk, SubmitOpts, TenantCapExceeded,
    TenantDepths,
};
