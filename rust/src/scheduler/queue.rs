//! Per-model request queues ("Model Service deployments", §B.2).
//!
//! Each preloaded model gets one service: a FIFO queue consumed by a
//! dedicated worker thread that executes intervention graphs against the
//! shared [`ModelRunner`]. In [`CoTenancy::Parallel`] mode the worker
//! drains up to `max_merge` compatible requests and runs them as one
//! batch-grouped forward pass; anything unmergeable falls back to
//! sequential execution. Results land in the object store.
//!
//! **Stateful sessions** ride the same FIFO: a session job carries an
//! ordered trace bundle plus a session-state id; the worker executes the
//! traces strictly in order, threading loads/stores through the shared
//! [`SessionStateStore`], and publishes one bundled result. Running on the
//! model's single worker thread gives the ordering guarantee state
//! dataflow needs for free.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, SyncSender, TryRecvError, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::graph::{opt::Prepared, serde as gserde, InterventionGraph};
use crate::interp::{self, StateView};
use crate::json::Json;
use crate::models::ModelRunner;
use crate::obs::{phases, ReqTrace, ServiceObs};
use crate::server::state::SessionStateStore;
use crate::server::store::ObjectStore;
use crate::util::failpoint::{self, FailAction};

use super::cotenancy::{execute_merged_prepared, mergeable, plan_merge_chunks, CoTenancy};

/// Submission rejected because the tenant is at its queue-depth cap.
/// Surfaced to the HTTP front as a 429 (the tenant's backpressure, not the
/// replica's — a coordinator must NOT fail over on it).
#[derive(Debug)]
pub struct TenantCapExceeded {
    pub tenant: String,
    pub depth: usize,
    pub cap: usize,
}

impl std::fmt::Display for TenantCapExceeded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "tenant '{}' at queue-depth cap ({}/{})",
            self.tenant, self.depth, self.cap
        )
    }
}

impl std::error::Error for TenantCapExceeded {}

/// Per-tenant in-flight accounting, shared across all model services of a
/// replica so one tenant cannot monopolize the queues even by spreading
/// requests over models. Anonymous traffic pools under one key (it is
/// collectively lowest-priority).
pub struct TenantDepths {
    cap: AtomicUsize,
    map: Mutex<HashMap<String, usize>>,
}

const ANON_TENANT: &str = "anon";

impl Default for TenantDepths {
    fn default() -> Self {
        TenantDepths::new(usize::MAX)
    }
}

impl TenantDepths {
    pub fn new(cap: usize) -> TenantDepths {
        TenantDepths { cap: AtomicUsize::new(cap.max(1)), map: Mutex::new(HashMap::new()) }
    }

    pub fn set_cap(&self, cap: usize) {
        self.cap.store(cap.max(1), Ordering::Relaxed);
    }

    /// Current in-flight units for a tenant (tests, metrics).
    pub fn depth(&self, tenant: Option<&str>) -> usize {
        let key = tenant.unwrap_or(ANON_TENANT);
        *self.map.lock().unwrap().get(key).unwrap_or(&0)
    }

    fn try_acquire(&self, tenant: Option<&str>, n: usize) -> Result<(), TenantCapExceeded> {
        let cap = self.cap.load(Ordering::Relaxed);
        let key = tenant.unwrap_or(ANON_TENANT);
        let mut g = self.map.lock().unwrap();
        let depth = g.entry(key.to_string()).or_insert(0);
        if *depth + n > cap {
            return Err(TenantCapExceeded { tenant: key.to_string(), depth: *depth, cap });
        }
        *depth += n;
        Ok(())
    }

    fn release(&self, tenant: Option<&str>, n: usize) {
        let key = tenant.unwrap_or(ANON_TENANT);
        let mut g = self.map.lock().unwrap();
        if let Some(depth) = g.get_mut(key) {
            *depth = depth.saturating_sub(n);
            if *depth == 0 {
                g.remove(key);
            }
        }
    }
}

/// Counters exposed at `/v1/metrics`.
#[derive(Default)]
pub struct ServiceMetrics {
    pub enqueued: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub merged_batches: AtomicU64,
    pub queue_depth: AtomicUsize,
    /// total execution nanoseconds (per-request, summed)
    pub exec_nanos: AtomicU64,
}

/// Point-in-time copy of [`ServiceMetrics`] — the load snapshot carried by
/// `/v1/metrics`, coordinator heartbeats, and the least-loaded router.
#[derive(Clone, Copy, Debug, Default)]
pub struct LoadSnapshot {
    pub enqueued: u64,
    pub completed: u64,
    pub failed: u64,
    pub merged_batches: u64,
    pub queue_depth: usize,
    pub exec_seconds: f64,
}

impl ServiceMetrics {
    /// Snapshot the counters. Loads are individually `Relaxed`, so the copy
    /// is not a single atomic cut, but each counter is exact and the
    /// invariant `completed + failed <= enqueued` holds at any observation
    /// point (counters bump before results publish).
    pub fn snapshot(&self) -> LoadSnapshot {
        LoadSnapshot {
            enqueued: self.enqueued.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            merged_batches: self.merged_batches.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            exec_seconds: self.exec_nanos.load(Ordering::Relaxed) as f64 / 1e9,
        }
    }
}

/// Everything optional about a submission, shared by every entry point
/// ([`ModelService::submit_trace`] / [`ModelService::submit_session`] /
/// [`ModelService::submit_stream`]). Build with the fluent setters:
///
/// ```ignore
/// svc.submit_trace(id, prepared, SubmitOpts::new().tenant(Some("alice")).profiled(true))?;
/// ```
#[derive(Default)]
pub struct SubmitOpts {
    trace: Option<ReqTrace>,
    tenant: Option<String>,
    profile: bool,
}

impl SubmitOpts {
    pub fn new() -> SubmitOpts {
        SubmitOpts::default()
    }

    /// Carry a request trace: the worker stamps queue/exec/serialize
    /// spans onto it, attaches it as `"timing"` result metadata, and
    /// retains it in the debug ring.
    pub fn traced(mut self, trace: Option<ReqTrace>) -> SubmitOpts {
        self.trace = trace;
        self
    }

    /// Attribute the submission to a tenant: it counts against the
    /// tenant's in-flight cap and is rejected with [`TenantCapExceeded`]
    /// when the tenant is at it. `None` charges the anonymous pool.
    pub fn tenant(mut self, tenant: Option<&str>) -> SubmitOpts {
        self.tenant = tenant.map(str::to_string);
        self
    }

    /// Arm the deep per-op profiler (see `obs/profile.rs`): the worker
    /// records per-op timings and memory, attaches the `"profile"`
    /// summary to the result, retains the full trace-event stream in the
    /// profile ring, and folds the replica hot-op table.
    pub fn profiled(mut self, profile: bool) -> SubmitOpts {
        self.profile = profile;
        self
    }
}

struct TraceJob {
    id: String,
    /// The graph to run — compiled at admission by the server (carrying
    /// the saved-id remap and opt report), or raw for direct submits.
    prepared: Prepared,
    /// Request trace, moved along with the job (None when observability
    /// is off or the submit bypassed the server front).
    trace: Option<ReqTrace>,
    /// Tenant the job's in-flight unit is charged to (None = anonymous
    /// pool); released when the job completes.
    tenant: Option<String>,
    /// Arm the deep per-op profiler for this job (see `obs/profile.rs`).
    profile: bool,
}

struct SessionJob {
    id: String,
    /// Session-state id the traces thread their loads/stores through.
    session: String,
    graphs: Vec<Prepared>,
    /// Keep the session's state alive after this bundle (multi-request
    /// sessions); ephemeral sessions drop it at the end.
    persist: bool,
    trace: Option<ReqTrace>,
    tenant: Option<String>,
    profile: bool,
}

/// One frame of a streaming response, already serialized for the wire.
/// `Event` frames flow while the decode runs; exactly one `Done` or
/// `Failed` frame terminates a well-behaved stream.
#[derive(Debug)]
pub enum StreamChunk {
    /// One per-step event line.
    Event(String),
    /// Terminal success line (the full trajectory summary).
    Done(String),
    /// Terminal failure line (graph execution error).
    Failed(String),
}

struct StreamJob {
    prepared: Prepared,
    steps: usize,
    /// Bounded per-request channel: the HTTP handler drains it into the
    /// chunked response. The bound is the backpressure contract — see
    /// [`ModelService::submit_stream`].
    tx: SyncSender<StreamChunk>,
    /// How long the worker will wait on a full channel before declaring
    /// the consumer gone and aborting the decode.
    send_timeout: Duration,
    trace: Option<ReqTrace>,
    tenant: Option<String>,
    profile: bool,
}

/// Top-K cap for the `"profile"` result-metadata block; the full per-op
/// stream is available from the debug ring.
const PROFILE_TOP_K: usize = 10;

enum Job {
    Trace(TraceJob),
    Session(SessionJob),
    Stream(StreamJob),
}

/// A streaming decode being continuously batched by the worker: its
/// admitted per-sequence decode state plus everything needed to emit
/// frames and publish terminal state when it retires.
struct ActiveStream {
    stream: crate::engine::RunnerStream,
    /// Admission-compiled graph, retained for the saved-id remap and the
    /// opt report (the stream owns its own copy of the graph).
    prepared: Prepared,
    tx: SyncSender<StreamChunk>,
    send_timeout: Duration,
    trace: Option<ReqTrace>,
    tenant: Option<String>,
    /// Admission instant (the trace's t0 when traced) — TTFT base.
    admitted: Instant,
    /// Instant of the first possible step — the terminal `exec` span base.
    t0: Instant,
    /// Event frames successfully delivered so far.
    emitted: usize,
    ttft_recorded: bool,
    consumer_gone: bool,
    /// Sum of this stream's own step slices (compute + emit), in nanos —
    /// NOT wall time across the interleave.
    exec_nanos: u64,
    /// Per-step interpreter phase timings, folded at retirement.
    phase_acc: Vec<(&'static str, u64)>,
}

/// What one scheduler tick did to one active stream.
enum StepOutcomeKind {
    /// The stream emitted an event and wants more ticks.
    Live,
    /// The stream is finished: all steps emitted, or its consumer is gone.
    Done,
    /// The decode failed; a terminal `Failed` frame is owed.
    Failed(String),
}

/// One model's request service: queue + worker thread + shared runner.
pub struct ModelService {
    pub runner: Arc<ModelRunner>,
    pub metrics: Arc<ServiceMetrics>,
    store: Arc<ObjectStore>,
    session_state: Arc<SessionStateStore>,
    tenants: Arc<TenantDepths>,
    tx: Option<Sender<Job>>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl ModelService {
    /// Spawn the service worker. `obs` is the model's observability
    /// bundle (latency histograms + debug trace ring); `None` turns all
    /// recording off. The service gets a private (uncapped) tenant-depth
    /// tracker; use [`Self::start_with_tenants`] to share one across a
    /// replica's model services.
    pub fn start(
        runner: Arc<ModelRunner>,
        store: Arc<ObjectStore>,
        session_state: Arc<SessionStateStore>,
        mode: CoTenancy,
        obs: Option<ServiceObs>,
    ) -> ModelService {
        Self::start_with_tenants(
            runner,
            store,
            session_state,
            mode,
            obs,
            Arc::new(TenantDepths::default()),
        )
    }

    /// [`Self::start`] with a shared tenant-depth tracker, so one tenant's
    /// in-flight cap spans every model service of the replica.
    pub fn start_with_tenants(
        runner: Arc<ModelRunner>,
        store: Arc<ObjectStore>,
        session_state: Arc<SessionStateStore>,
        mode: CoTenancy,
        obs: Option<ServiceObs>,
        tenants: Arc<TenantDepths>,
    ) -> ModelService {
        let (tx, rx) = channel::<Job>();
        let metrics = Arc::new(ServiceMetrics::default());
        let m2 = Arc::clone(&metrics);
        let r2 = Arc::clone(&runner);
        let store2 = Arc::clone(&store);
        let state2 = Arc::clone(&session_state);
        let t2 = Arc::clone(&tenants);
        let worker = std::thread::Builder::new()
            .name(format!("ndif-service-{}", runner.manifest.name))
            .spawn(move || Self::worker_loop(rx, r2, store2, state2, mode, m2, obs, t2))
            .expect("spawn service worker");
        ModelService {
            runner,
            metrics,
            store,
            session_state,
            tenants,
            tx: Some(tx),
            worker: Some(worker),
        }
    }

    /// The per-tenant depth tracker (shared or private — see
    /// [`Self::start_with_tenants`]).
    pub fn tenant_depths(&self) -> &Arc<TenantDepths> {
        &self.tenants
    }

    /// Set the per-tenant in-flight cap (units match `queue_depth`:
    /// 1 per trace/stream, bundle size per session).
    pub fn set_tenant_cap(&self, cap: usize) {
        self.tenants.set_cap(cap);
    }

    /// Load snapshot for `/v1/metrics`, coordinator heartbeats, and fleet
    /// status.
    pub fn load(&self) -> LoadSnapshot {
        self.metrics.snapshot()
    }

    /// The session-state store stateful bundles thread through.
    pub fn session_state(&self) -> &Arc<SessionStateStore> {
        &self.session_state
    }

    /// Enqueue a one-shot trace (non-blocking). The result will appear in
    /// the object store under `id`. The graph runs exactly as prepared —
    /// the server front compiles at admission ([`Prepared`]); direct
    /// submits wrap with [`Prepared::raw`]. Everything optional about the
    /// submission (request trace, tenant attribution, deep profiling)
    /// rides in `opts`.
    pub fn submit_trace(&self, id: String, prepared: Prepared, opts: SubmitOpts) -> Result<()> {
        let SubmitOpts { mut trace, tenant, profile } = opts;
        self.tenants.try_acquire(tenant.as_deref(), 1).map_err(anyhow::Error::new)?;
        self.store.put_pending(&id);
        if let Some(t) = trace.as_mut() {
            t.mark_enqueued();
        }
        // counters bump before the send so a reader that wakes on the
        // result never sees completed > enqueued; a failed send rolls
        // them back (the job never reached the worker)
        self.metrics.enqueued.fetch_add(1, Ordering::Relaxed);
        self.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
        let sent = self.tx.as_ref().expect("service stopped").send(Job::Trace(TraceJob {
            id: id.clone(),
            prepared,
            trace,
            tenant: tenant.clone(),
            profile,
        }));
        if sent.is_err() {
            self.metrics.enqueued.fetch_sub(1, Ordering::Relaxed);
            self.metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
            self.tenants.release(tenant.as_deref(), 1);
            self.store.put_failed(&id, "service worker exited");
            return Err(anyhow::anyhow!("service worker exited"));
        }
        Ok(())
    }

    #[deprecated(note = "use submit_trace(id, Prepared::raw(graph), SubmitOpts::new())")]
    #[doc(hidden)]
    pub fn submit(&self, id: String, graph: InterventionGraph) -> Result<()> {
        self.submit_trace(id, Prepared::raw(graph), SubmitOpts::new())
    }

    #[deprecated(note = "use submit_trace(id, prepared, SubmitOpts::new())")]
    #[doc(hidden)]
    pub fn submit_prepared(&self, id: String, prepared: Prepared) -> Result<()> {
        self.submit_trace(id, prepared, SubmitOpts::new())
    }

    /// Enqueue an ordered stateful trace bundle. One bundled result (the
    /// full `{"results": [...]}` payload) will appear under `id`; loads
    /// and stores thread through session-state `session`, which is dropped
    /// afterwards unless `persist`.
    /// The bundle counts `graphs.len()` units against the submitting
    /// tenant's in-flight cap; with the profiler armed the ops of all
    /// traces accumulate into one profile. Direct (uncompiled) submits
    /// wrap each graph with [`Prepared::raw`].
    pub fn submit_session(
        &self,
        id: String,
        session: String,
        persist: bool,
        graphs: Vec<Prepared>,
        opts: SubmitOpts,
    ) -> Result<()> {
        let SubmitOpts { mut trace, tenant, profile } = opts;
        let n = graphs.len();
        self.tenants.try_acquire(tenant.as_deref(), n).map_err(anyhow::Error::new)?;
        self.store.put_pending(&id);
        if let Some(t) = trace.as_mut() {
            t.mark_enqueued();
        }
        self.metrics.enqueued.fetch_add(n as u64, Ordering::Relaxed);
        self.metrics.queue_depth.fetch_add(n, Ordering::Relaxed);
        let sent = self.tx.as_ref().expect("service stopped").send(Job::Session(SessionJob {
            id: id.clone(),
            session,
            graphs,
            persist,
            trace,
            tenant: tenant.clone(),
            profile,
        }));
        if sent.is_err() {
            self.metrics.enqueued.fetch_sub(n as u64, Ordering::Relaxed);
            self.metrics.queue_depth.fetch_sub(n, Ordering::Relaxed);
            self.tenants.release(tenant.as_deref(), n);
            self.store.put_failed(&id, "service worker exited");
            return Err(anyhow::anyhow!("service worker exited"));
        }
        Ok(())
    }

    /// Enqueue a streaming decode. Per-step events (and the terminal
    /// `Done`/`Failed` frame) are pushed into `tx` as they are produced; a
    /// consumer that stops draining for longer than `send_timeout` while
    /// the channel is full is treated as gone and the decode is aborted,
    /// so a slow reader can never pin the model worker.
    /// The stream holds one unit of the submitting tenant's in-flight cap
    /// until its terminal frame. Streams compiled at admission re-key
    /// per-step values through the remap and the terminal `done` event
    /// carries the opt report; direct submits wrap with [`Prepared::raw`].
    /// With a request trace attached, the worker records TTFT at the
    /// first event sent and attaches `"timing"` to the `done` event. A
    /// profiled stream runs exclusively (never interleaved with other
    /// decodes — the per-op collector is per-thread) and its `done` event
    /// carries the `"profile"` summary keyed by step index.
    pub fn submit_stream(
        &self,
        prepared: Prepared,
        steps: usize,
        tx: SyncSender<StreamChunk>,
        send_timeout: Duration,
        opts: SubmitOpts,
    ) -> Result<()> {
        let SubmitOpts { mut trace, tenant, profile } = opts;
        self.tenants.try_acquire(tenant.as_deref(), 1).map_err(anyhow::Error::new)?;
        if let Some(t) = trace.as_mut() {
            t.mark_enqueued();
        }
        self.metrics.enqueued.fetch_add(1, Ordering::Relaxed);
        self.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
        let sent = self.tx.as_ref().expect("service stopped").send(Job::Stream(StreamJob {
            prepared,
            steps,
            tx,
            send_timeout,
            trace,
            tenant: tenant.clone(),
            profile,
        }));
        if sent.is_err() {
            self.metrics.enqueued.fetch_sub(1, Ordering::Relaxed);
            self.metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
            self.tenants.release(tenant.as_deref(), 1);
            return Err(anyhow::anyhow!("service worker exited"));
        }
        Ok(())
    }

    /// The continuous-batching service loop. Streaming decodes become
    /// [`ActiveStream`]s that advance one token per scheduler tick,
    /// interleaved round-robin; new work is admitted between ticks and
    /// finished streams retire without draining the rest. One-shot traces
    /// drain into co-tenant bursts (merged in Parallel mode) that run
    /// between decode ticks; sessions run inline (their state ordering is
    /// this single worker's arrival order); profiled streams run
    /// exclusively to completion — the per-op collector is per-thread, so
    /// interleaving two profiled decodes would mix their attribution.
    #[allow(clippy::too_many_arguments)]
    fn worker_loop(
        rx: Receiver<Job>,
        runner: Arc<ModelRunner>,
        store: Arc<ObjectStore>,
        session_state: Arc<SessionStateStore>,
        mode: CoTenancy,
        metrics: Arc<ServiceMetrics>,
        obs: Option<ServiceObs>,
        tenants: Arc<TenantDepths>,
    ) {
        let obs = obs.as_ref();
        let tenants = &*tenants;
        let mut streams: Vec<ActiveStream> = Vec::new();
        let mut open = true;
        while open || !streams.is_empty() {
            // admit new work: block only when no decode is in flight,
            // otherwise take whatever has arrived and get back to stepping
            let mut traces: Vec<TraceJob> = Vec::new();
            if open && streams.is_empty() {
                match rx.recv() {
                    Ok(job) => Self::dispatch_job(
                        job,
                        &mut traces,
                        &mut streams,
                        &runner,
                        &store,
                        &session_state,
                        mode,
                        &metrics,
                        obs,
                        tenants,
                    ),
                    Err(_) => open = false,
                }
            }
            while open {
                match rx.try_recv() {
                    Ok(job) => Self::dispatch_job(
                        job,
                        &mut traces,
                        &mut streams,
                        &runner,
                        &store,
                        &session_state,
                        mode,
                        &metrics,
                        obs,
                        tenants,
                    ),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => open = false,
                }
            }
            if !traces.is_empty() {
                Self::run_trace_burst(&runner, &store, &metrics, obs, tenants, traces, mode);
            }
            // one decode tick: a single token step per active stream;
            // completion/failure/consumer-gone retires just that stream
            let mut i = 0;
            while i < streams.len() {
                match Self::step_stream(&runner, obs, &mut streams[i]) {
                    StepOutcomeKind::Live => i += 1,
                    StepOutcomeKind::Done => {
                        let s = streams.remove(i);
                        Self::finish_stream(&metrics, obs, tenants, s, None);
                    }
                    StepOutcomeKind::Failed(e) => {
                        let s = streams.remove(i);
                        Self::finish_stream(&metrics, obs, tenants, s, Some(e));
                    }
                }
            }
        }
    }

    /// Route one received job: traces accumulate into the caller's burst,
    /// sessions flush the burst and run inline, streams are admitted as
    /// [`ActiveStream`]s (or run exclusively when profiled).
    #[allow(clippy::too_many_arguments)]
    fn dispatch_job(
        job: Job,
        traces: &mut Vec<TraceJob>,
        streams: &mut Vec<ActiveStream>,
        runner: &ModelRunner,
        store: &ObjectStore,
        session_state: &SessionStateStore,
        mode: CoTenancy,
        metrics: &ServiceMetrics,
        obs: Option<&ServiceObs>,
        tenants: &TenantDepths,
    ) {
        match job {
            Job::Trace(t) => traces.push(t),
            Job::Session(s) => {
                // traces drained before this session arrived first: run
                // them first so result publication follows arrival order
                if !traces.is_empty() {
                    let burst = std::mem::take(traces);
                    Self::run_trace_burst(runner, store, metrics, obs, tenants, burst, mode);
                }
                Self::run_session(runner, store, session_state, metrics, obs, tenants, s);
            }
            Job::Stream(s) if s.profile => {
                if !traces.is_empty() {
                    let burst = std::mem::take(traces);
                    Self::run_trace_burst(runner, store, metrics, obs, tenants, burst, mode);
                }
                Self::run_stream(runner, metrics, obs, tenants, s);
            }
            Job::Stream(s) => {
                if let Some(a) = Self::admit_stream(runner, metrics, obs, tenants, s) {
                    streams.push(a);
                }
            }
        }
    }

    /// Run a drained burst of one-shot traces between decode ticks,
    /// merging co-tenants in Parallel mode exactly as the dedicated batch
    /// path does: up to `max_merge` per batch, split into exported-batch-
    /// aligned chunks so merging never pads past the next exported size.
    fn run_trace_burst(
        runner: &ModelRunner,
        store: &ObjectStore,
        metrics: &ServiceMetrics,
        obs: Option<&ServiceObs>,
        tenants: &TenantDepths,
        mut jobs: Vec<TraceJob>,
        mode: CoTenancy,
    ) {
        let max = match mode {
            CoTenancy::Parallel { max_merge } => max_merge.max(1),
            CoTenancy::Sequential => 1,
        };
        while !jobs.is_empty() {
            let tail = jobs.split_off(max.min(jobs.len()));
            let batch = std::mem::replace(&mut jobs, tail);
            if matches!(mode, CoTenancy::Parallel { .. }) && batch.len() > 1 {
                let rows: Vec<usize> =
                    batch.iter().map(|j| j.prepared.graph.batch.max(1)).collect();
                let chunks = plan_merge_chunks(&rows, &runner.manifest.batches);
                let mut rest = batch;
                for take in chunks {
                    let tail = rest.split_off(take.min(rest.len()));
                    Self::run_batch(runner, store, metrics, obs, tenants, rest, mode);
                    rest = tail;
                    if rest.is_empty() {
                        break;
                    }
                }
                // a chunk plan that under-covers the burst must not drop
                // jobs: every drained request is owed a result and a
                // completed/failed counter bump
                if !rest.is_empty() {
                    Self::run_batch(runner, store, metrics, obs, tenants, rest, mode);
                }
            } else {
                Self::run_batch(runner, store, metrics, obs, tenants, batch, mode);
            }
        }
    }

    /// Validate a stream job and stand up its per-sequence decode state.
    /// Admission failure (bad graph, context overrun, shard/batch-group
    /// constraints) terminates the stream immediately with a `Failed`
    /// frame; the job never joins the batch.
    fn admit_stream(
        runner: &ModelRunner,
        metrics: &ServiceMetrics,
        obs: Option<&ServiceObs>,
        tenants: &TenantDepths,
        mut job: StreamJob,
    ) -> Option<ActiveStream> {
        Self::note_dequeue(&mut job.trace, obs);
        let t0 = Instant::now();
        let admitted = job.trace.as_ref().map(|t| t.t0).unwrap_or(t0);
        match crate::engine::RunnerStream::with_plan(
            job.prepared.graph.clone(),
            runner,
            job.steps,
            job.prepared.plan.clone(),
        ) {
            Ok(stream) => Some(ActiveStream {
                stream,
                prepared: job.prepared,
                tx: job.tx,
                send_timeout: job.send_timeout,
                trace: job.trace,
                tenant: job.tenant,
                admitted,
                t0,
                emitted: 0,
                ttft_recorded: false,
                consumer_gone: false,
                exec_nanos: 0,
                phase_acc: Vec::new(),
            }),
            Err(e) => {
                let _ = Self::send_chunk(
                    &job.tx,
                    StreamChunk::Failed(e.to_string()),
                    job.send_timeout,
                );
                metrics.failed.fetch_add(1, Ordering::Relaxed);
                metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
                tenants.release(job.tenant.as_deref(), 1);
                None
            }
        }
    }

    /// Advance one interleaved stream by one decode step and push its
    /// event frame. Interpreter phase timings accumulate per stream so
    /// the terminal trace spans cover only this stream's compute.
    fn step_stream(
        runner: &ModelRunner,
        obs: Option<&ServiceObs>,
        s: &mut ActiveStream,
    ) -> StepOutcomeKind {
        let ts = Instant::now();
        if obs.is_some() {
            phases::arm();
        }
        let res = s.stream.step(runner);
        if obs.is_some() {
            s.phase_acc.extend(phases::take());
        }
        match res {
            Ok(Some(mut out)) => {
                out.values = s.prepared.remap_values(out.values);
                let ev = Json::obj(vec![
                    ("event", Json::from("step")),
                    ("step", Json::from(s.emitted)),
                    ("token", Json::from(out.token)),
                    ("score", Json::from(out.score)),
                    ("values", gserde::values_to_json(&out.values.values)),
                ])
                .to_string();
                let sent = Self::send_chunk(&s.tx, StreamChunk::Event(ev), s.send_timeout);
                s.exec_nanos += ts.elapsed().as_nanos() as u64;
                if !sent {
                    s.consumer_gone = true;
                    return StepOutcomeKind::Done;
                }
                s.emitted += 1;
                if !s.ttft_recorded {
                    s.ttft_recorded = true;
                    if let Some(o) = obs {
                        o.model.ttft.record_duration(s.admitted.elapsed());
                    }
                }
                if s.stream.finished() {
                    StepOutcomeKind::Done
                } else {
                    StepOutcomeKind::Live
                }
            }
            Ok(None) => {
                s.exec_nanos += ts.elapsed().as_nanos() as u64;
                StepOutcomeKind::Done
            }
            Err(e) => {
                s.exec_nanos += ts.elapsed().as_nanos() as u64;
                StepOutcomeKind::Failed(e.to_string())
            }
        }
    }

    /// Retire a stream from the batch: terminal frame, counters, trace
    /// spans, histograms, tenant release. Mirrors the exclusive
    /// [`Self::run_stream`] epilogue, with exec time being the sum of this
    /// stream's own step slices rather than wall time across the
    /// interleave.
    fn finish_stream(
        metrics: &ServiceMetrics,
        obs: Option<&ServiceObs>,
        tenants: &TenantDepths,
        mut s: ActiveStream,
        failure: Option<String>,
    ) {
        let ph = Self::fold_phases(&s.phase_acc);
        let exec_d = Duration::from_nanos(s.exec_nanos);
        if let Some(tr) = s.trace.as_mut() {
            tr.span_since("exec", s.t0);
            let off = s.t0.saturating_duration_since(tr.t0).as_micros() as u64;
            for (name, nanos) in &ph {
                tr.span_at(&format!("exec:{name}"), off, nanos / 1_000);
            }
        }
        let ok = if let Some(e) = failure {
            let _ = Self::send_chunk(&s.tx, StreamChunk::Failed(e), s.send_timeout);
            metrics.failed.fetch_add(1, Ordering::Relaxed);
            false
        } else if s.consumer_gone {
            // the consumer vanished mid-stream; nothing to deliver to
            metrics.failed.fetch_add(1, Ordering::Relaxed);
            false
        } else {
            let gen = s.stream.generation();
            let tokens = Json::Array(gen.tokens.iter().map(|&t| Json::from(t)).collect());
            let scores = Json::Array(gen.scores.iter().map(|&v| Json::from(v)).collect());
            let mut done_obj = Json::obj(vec![
                ("event", Json::from("done")),
                ("steps", Json::from(gen.tokens.len())),
                ("tokens", tokens),
                ("scores", scores),
            ]);
            if let Some(report) = &s.prepared.report {
                done_obj.set("opt", report.to_json());
            }
            if let Some(tr) = &s.trace {
                done_obj.set("timing", tr.to_json());
            }
            let done = done_obj.to_string();
            if Self::send_chunk(&s.tx, StreamChunk::Done(done), s.send_timeout) {
                metrics.completed.fetch_add(1, Ordering::Relaxed);
                true
            } else {
                metrics.failed.fetch_add(1, Ordering::Relaxed);
                false
            }
        };
        if let Some(o) = obs {
            o.model.exec.record_duration(exec_d);
            if let Some(tr) = &s.trace {
                if ok {
                    o.model.e2e.record_duration(tr.t0.elapsed());
                }
                o.ring.push(tr.to_json());
            }
        }
        metrics.exec_nanos.fetch_add(s.exec_nanos, Ordering::Relaxed);
        metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
        tenants.release(s.tenant.as_deref(), 1);
    }

    /// Sum interpreter phase timings by name (one entry per phase even
    /// for multi-step streams), preserving first-seen order.
    fn fold_phases(ph: &[(&'static str, u64)]) -> Vec<(&'static str, u64)> {
        let mut out: Vec<(&'static str, u64)> = Vec::new();
        for &(name, nanos) in ph {
            match out.iter_mut().find(|(n, _)| *n == name) {
                Some(slot) => slot.1 += nanos,
                None => out.push((name, nanos)),
            }
        }
        out
    }

    /// Stamp the queue span onto a job's trace and record the wait in
    /// the model's queue-wait histogram.
    fn note_dequeue(trace: &mut Option<ReqTrace>, obs: Option<&ServiceObs>) {
        if let Some(tr) = trace.as_mut() {
            if let Some(wait) = tr.close_queue_span() {
                if let Some(o) = obs {
                    o.model.queue_wait.record_duration(wait);
                }
            }
        }
    }

    /// Push one frame into the bounded stream channel, waiting at most
    /// `timeout` for a slow consumer to make room. Returns false when the
    /// consumer is gone (disconnected) or too slow (timeout) — the decode
    /// must stop rather than pin this worker.
    fn send_chunk(tx: &SyncSender<StreamChunk>, mut chunk: StreamChunk, timeout: Duration) -> bool {
        // chaos hooks: Skip drops the frame on the floor (lossy consumer
        // path), Error declares the consumer gone, Delay stalls the
        // producer as a slow consumer would
        match failpoint::hit("stream.frame") {
            Some(FailAction::Skip) => return true,
            Some(FailAction::Error(_)) => return false,
            Some(FailAction::Delay(d)) => std::thread::sleep(d),
            _ => {}
        }
        let deadline = Instant::now() + timeout;
        loop {
            match tx.try_send(chunk) {
                Ok(()) => return true,
                Err(TrySendError::Disconnected(_)) => return false,
                Err(TrySendError::Full(c)) => {
                    if Instant::now() >= deadline {
                        return false;
                    }
                    chunk = c;
                    std::thread::sleep(Duration::from_millis(2));
                }
            }
        }
    }

    /// Execute a streaming decode on this worker thread, pushing one
    /// event frame per step and a terminal frame at the end. The graph
    /// runs as prepared at admission; per-step values are re-keyed into
    /// the submitted graph's ids before they hit the wire.
    fn run_stream(
        runner: &ModelRunner,
        metrics: &ServiceMetrics,
        obs: Option<&ServiceObs>,
        tenants: &TenantDepths,
        mut job: StreamJob,
    ) {
        Self::note_dequeue(&mut job.trace, obs);
        let t0 = Instant::now();
        // TTFT is admission → first event on the wire; fall back to
        // dequeue time for untraced jobs
        let admitted = job.trace.as_ref().map(|t| t.t0).unwrap_or(t0);
        let mut ttft_recorded = false;
        let mut consumer_gone = false;
        let prepared = &job.prepared;
        if obs.is_some() {
            phases::arm();
            if job.profile {
                crate::obs::profile::arm();
            }
        }
        let mut on_step = |step: usize, mut out: crate::interp::StepOutcome| {
            // per-step serialization + delivery is real exec-span time; a
            // profiled stream records it as an "emit" phase so the profile
            // accounts for the whole span, not just compute
            let te = crate::obs::profile::armed().then(Instant::now);
            out.values = prepared.remap_values(out.values);
            let ev = Json::obj(vec![
                ("event", Json::from("step")),
                ("step", Json::from(step)),
                ("token", Json::from(out.token)),
                ("score", Json::from(out.score)),
                ("values", gserde::values_to_json(&out.values.values)),
            ])
            .to_string();
            let sent = Self::send_chunk(&job.tx, StreamChunk::Event(ev), job.send_timeout);
            if let Some(t) = te {
                crate::obs::profile::record_phase("emit", t);
            }
            if sent {
                if !ttft_recorded {
                    ttft_recorded = true;
                    if let Some(o) = obs {
                        o.model.ttft.record_duration(admitted.elapsed());
                    }
                }
                true
            } else {
                consumer_gone = true;
                false
            }
        };
        let res =
            interp::execute_stream_prepared(prepared, runner, job.steps, &mut on_step);
        let ph = if obs.is_some() { Self::fold_phases(&phases::take()) } else { Vec::new() };
        let prof = crate::obs::profile::take();
        let exec_d = t0.elapsed();
        if let Some(tr) = job.trace.as_mut() {
            tr.span_since("exec", t0);
            let off = t0.saturating_duration_since(tr.t0).as_micros() as u64;
            for (name, nanos) in &ph {
                tr.span_at(&format!("exec:{name}"), off, nanos / 1_000);
            }
        }
        let ok = match res {
            Ok(_) if consumer_gone => {
                // the consumer vanished mid-stream; nothing to deliver to
                metrics.failed.fetch_add(1, Ordering::Relaxed);
                false
            }
            Ok(gen) => {
                let tokens = Json::Array(gen.tokens.iter().map(|&t| Json::from(t)).collect());
                let scores = Json::Array(gen.scores.iter().map(|&s| Json::from(s)).collect());
                let mut done_obj = Json::obj(vec![
                    ("event", Json::from("done")),
                    ("steps", Json::from(gen.tokens.len())),
                    ("tokens", tokens),
                    ("scores", scores),
                ]);
                if let Some(report) = &job.prepared.report {
                    done_obj.set("opt", report.to_json());
                }
                if let Some(tr) = &job.trace {
                    done_obj.set("timing", tr.to_json());
                }
                if let Some(p) = &prof {
                    done_obj.set("profile", p.summary_json(PROFILE_TOP_K));
                }
                let done = done_obj.to_string();
                if Self::send_chunk(&job.tx, StreamChunk::Done(done), job.send_timeout) {
                    metrics.completed.fetch_add(1, Ordering::Relaxed);
                    true
                } else {
                    metrics.failed.fetch_add(1, Ordering::Relaxed);
                    false
                }
            }
            Err(e) => {
                let _ = Self::send_chunk(
                    &job.tx,
                    StreamChunk::Failed(e.to_string()),
                    job.send_timeout,
                );
                metrics.failed.fetch_add(1, Ordering::Relaxed);
                false
            }
        };
        if let Some(o) = obs {
            o.model.exec.record_duration(exec_d);
            if let Some(tr) = &job.trace {
                if ok {
                    o.model.e2e.record_duration(tr.t0.elapsed());
                }
                o.ring.push(tr.to_json());
            }
            if let Some(p) = &prof {
                // streams have no store id; the ring entry is keyed by
                // the request's trace id (untraced streams keep only the
                // inline summary and the hot-op fold)
                if let Some(tr) = &job.trace {
                    o.profile.ring.push(&tr.trace_id, p.trace_events_json(&tr.trace_id));
                }
                o.profile.hotops.fold(p);
            }
        }
        metrics
            .exec_nanos
            .fetch_add(exec_d.as_nanos() as u64, Ordering::Relaxed);
        metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
        tenants.release(job.tenant.as_deref(), 1);
    }

    /// Execute a stateful session bundle in order on this worker thread.
    /// Each trace runs against a snapshot of the session state and commits
    /// its store updates on success; the first failure fails the whole
    /// bundle (updates from earlier traces stay committed — they already
    /// happened, exactly like earlier requests of a multi-request session).
    fn run_session(
        runner: &ModelRunner,
        store: &ObjectStore,
        session_state: &SessionStateStore,
        metrics: &ServiceMetrics,
        obs: Option<&ServiceObs>,
        tenants: &TenantDepths,
        mut job: SessionJob,
    ) {
        Self::note_dequeue(&mut job.trace, obs);
        let t0 = std::time::Instant::now();
        let n = job.graphs.len();
        if obs.is_some() {
            phases::arm();
            if job.profile {
                crate::obs::profile::arm();
            }
        }
        let outcome = (|| -> Result<Json, String> {
            session_state
                .open(&job.session, &runner.manifest.name)
                .map_err(|e| e.to_string())?;
            let mut results = Vec::with_capacity(n);
            for (i, g) in job.graphs.iter().enumerate() {
                let view = session_state
                    .snapshot(&job.session)
                    .ok_or_else(|| format!("session '{}' expired mid-run", job.session))?;
                let (res, updates) = interp::execute_view_prepared(g, runner, view)
                    .map_err(|e| format!("session trace {i}: {e}"))?;
                let res = g.remap_values(res);
                session_state
                    .commit(&job.session, updates)
                    .map_err(|e| format!("session trace {i}: {e}"))?;
                results.push(gserde::result_to_json_with_opt(&res, g.report.as_ref()));
            }
            Ok(Json::obj(vec![
                ("session", Json::from(job.session.as_str())),
                ("results", Json::Array(results)),
            ]))
        })();
        if !job.persist {
            session_state.drop_session(&job.session);
        }
        let ph = if obs.is_some() { Self::fold_phases(&phases::take()) } else { Vec::new() };
        let prof = crate::obs::profile::take();
        let exec_d = t0.elapsed();
        if let Some(tr) = job.trace.as_mut() {
            tr.span_since("exec", t0);
            let off = t0.saturating_duration_since(tr.t0).as_micros() as u64;
            for (name, nanos) in &ph {
                tr.span_at(&format!("exec:{name}"), off, nanos / 1_000);
            }
        }
        let ok = outcome.is_ok();
        match outcome {
            Ok(mut json) => {
                if let Some(tr) = &job.trace {
                    json.set("timing", tr.to_json());
                }
                if let Some(p) = &prof {
                    json.set("profile", p.summary_json(PROFILE_TOP_K));
                }
                metrics.completed.fetch_add(n as u64, Ordering::Relaxed);
                store.put_ready(&job.id, json.to_string());
            }
            Err(e) => {
                metrics.failed.fetch_add(n as u64, Ordering::Relaxed);
                store.put_failed(&job.id, &e);
            }
        }
        if let Some(o) = obs {
            o.model.exec.record_duration(exec_d);
            if let Some(tr) = &job.trace {
                if ok {
                    o.model.e2e.record_duration(tr.t0.elapsed());
                }
                o.ring.push(tr.to_json());
            }
            if let Some(p) = &prof {
                o.profile.ring.push(&job.id, p.trace_events_json(&job.id));
                o.profile.hotops.fold(p);
            }
        }
        metrics
            .exec_nanos
            .fetch_add(exec_d.as_nanos() as u64, Ordering::Relaxed);
        metrics.queue_depth.fetch_sub(n, Ordering::Relaxed);
        tenants.release(job.tenant.as_deref(), n);
    }

    fn run_batch(
        runner: &ModelRunner,
        store: &ObjectStore,
        metrics: &ServiceMetrics,
        obs: Option<&ServiceObs>,
        tenants: &TenantDepths,
        mut batch: Vec<TraceJob>,
        mode: CoTenancy,
    ) {
        let n = batch.len();
        for job in &mut batch {
            Self::note_dequeue(&mut job.trace, obs);
        }
        let t0 = std::time::Instant::now();
        let graphs: Vec<&InterventionGraph> = batch.iter().map(|j| &j.prepared.graph).collect();
        // profiled jobs never merge: their per-op timings must measure
        // only their own graph, not a co-tenant forward pass
        let can_merge = matches!(mode, CoTenancy::Parallel { .. })
            && batch.len() > 1
            && batch.iter().all(|j| !j.profile)
            && mergeable(&graphs, runner);

        if can_merge {
            // graphs were individually compiled at admission, so duplicate
            // work WITHIN each co-tenant graph is already hash-consed; the
            // merge shares the forward pass across them (plan-carrying
            // jobs keep their arena-planned executors inside the merge)
            let preps: Vec<&Prepared> = batch.iter().map(|j| &j.prepared).collect();
            if obs.is_some() {
                phases::arm();
            }
            match execute_merged_prepared(&preps, runner) {
                Ok(results) => {
                    metrics.merged_batches.fetch_add(1, Ordering::Relaxed);
                    let ph = if obs.is_some() {
                        Self::fold_phases(&phases::take())
                    } else {
                        Vec::new()
                    };
                    for (job, res) in batch.iter_mut().zip(results) {
                        let res = res.map(|r| job.prepared.remap_values(r));
                        Self::finish(store, metrics, obs, t0, &ph, n, job, res, None);
                    }
                }
                Err(e) => {
                    // infrastructure failure: fail the whole merge
                    let _ = phases::take();
                    let msg = e.to_string();
                    for job in batch.iter_mut() {
                        Self::finish(
                            store,
                            metrics,
                            obs,
                            t0,
                            &[],
                            n,
                            job,
                            Err::<crate::graph::GraphResult, &str>(&msg),
                            None,
                        );
                    }
                }
            }
        } else {
            for job in batch.iter_mut() {
                if obs.is_some() {
                    phases::arm();
                    if job.profile {
                        crate::obs::profile::arm();
                    }
                }
                let te = std::time::Instant::now();
                let res =
                    interp::execute_view_prepared(&job.prepared, runner, StateView::new())
                        .map(|(r, _)| job.prepared.remap_values(r));
                let ph = if obs.is_some() {
                    Self::fold_phases(&phases::take())
                } else {
                    Vec::new()
                };
                let prof = crate::obs::profile::take();
                Self::finish(store, metrics, obs, te, &ph, 1, job, res, prof);
            }
        }
        metrics
            .exec_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        metrics.queue_depth.fetch_sub(n, Ordering::Relaxed);
        for job in &batch {
            tenants.release(job.tenant.as_deref(), 1);
        }
    }

    /// Publish one trace result: bump counters, stamp exec/serialize
    /// spans and interpreter phases onto the trace, attach `"timing"`
    /// (and, for profiled jobs, `"profile"`) to the result payload,
    /// record histograms, and retain the trace in the debug ring and the
    /// profile in the profile ring.
    #[allow(clippy::too_many_arguments)]
    fn finish(
        store: &ObjectStore,
        metrics: &ServiceMetrics,
        obs: Option<&ServiceObs>,
        exec_start: Instant,
        ph: &[(&'static str, u64)],
        merged: usize,
        job: &mut TraceJob,
        res: Result<crate::graph::GraphResult, impl std::fmt::Display>,
        prof: Option<crate::obs::Profile>,
    ) {
        let exec_d = exec_start.elapsed();
        if let Some(tr) = job.trace.as_mut() {
            tr.span_since("exec", exec_start);
            let off = exec_start.saturating_duration_since(tr.t0).as_micros() as u64;
            for &(name, nanos) in ph {
                tr.span_at(&format!("exec:{name}"), off, nanos / 1_000);
            }
            if merged > 1 {
                // zero-width marker: this request ran in a co-tenant
                // merge of `merged` requests
                tr.span_at(&format!("cotenant_merge:{merged}"), off, 0);
            }
        }
        // bump counters BEFORE publishing: clients wake on the store write
        // and may read metrics immediately.
        let ok = res.is_ok();
        match res {
            Ok(r) => {
                metrics.completed.fetch_add(1, Ordering::Relaxed);
                let ser_start = Instant::now();
                let mut json = gserde::result_to_json_with_opt(&r, job.prepared.report.as_ref());
                if let Some(tr) = job.trace.as_mut() {
                    tr.span_since("serialize", ser_start);
                    json.set("timing", tr.to_json());
                }
                if let Some(p) = &prof {
                    json.set("profile", p.summary_json(PROFILE_TOP_K));
                }
                store.put_ready(&job.id, json.to_string());
            }
            Err(e) => {
                metrics.failed.fetch_add(1, Ordering::Relaxed);
                store.put_failed(&job.id, &e.to_string());
            }
        }
        if let Some(o) = obs {
            o.model.exec.record_duration(exec_d);
            if let Some(tr) = &job.trace {
                if ok {
                    o.model.e2e.record_duration(tr.t0.elapsed());
                }
                o.ring.push(tr.to_json());
            }
            if let Some(p) = &prof {
                o.profile.ring.push(&job.id, p.trace_events_json(&job.id));
                o.profile.hotops.fold(p);
            }
        }
    }
}

impl Drop for ModelService {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::Trace;
    use crate::models::artifacts_dir;
    use crate::tensor::Tensor;

    fn service(mode: CoTenancy) -> (ModelService, Arc<ObjectStore>) {
        let runner = Arc::new(ModelRunner::load(&artifacts_dir(), "tiny-sim").unwrap());
        let store = Arc::new(ObjectStore::new());
        let state = Arc::new(SessionStateStore::default());
        (ModelService::start(runner, Arc::clone(&store), state, mode, None), store)
    }

    /// `service` for tests that skip (rather than fail) when the model
    /// artifacts are absent.
    fn try_service(mode: CoTenancy) -> Option<(ModelService, Arc<ObjectStore>)> {
        let runner = Arc::new(ModelRunner::load(&artifacts_dir(), "tiny-sim").ok()?);
        let store = Arc::new(ObjectStore::new());
        let state = Arc::new(SessionStateStore::default());
        Some((ModelService::start(runner, Arc::clone(&store), state, mode, None), store))
    }

    fn submit_raw(svc: &ModelService, id: &str, g: InterventionGraph) {
        svc.submit_trace(id.to_string(), Prepared::raw(g), SubmitOpts::new()).unwrap();
    }

    fn simple_graph(v: f32) -> InterventionGraph {
        let mut tr = Trace::new("tiny-sim", &Tensor::full(&[1, 16], v));
        let h = tr.output("layer.0");
        tr.save(h);
        tr.into_graph()
    }

    #[test]
    fn sequential_service_completes_requests() {
        let (svc, store) = service(CoTenancy::Sequential);
        for i in 0..4 {
            submit_raw(&svc, &format!("r{i}"), simple_graph(i as f32));
        }
        for i in 0..4 {
            let json = store
                .wait_ready(&format!("r{i}"), std::time::Duration::from_secs(30))
                .unwrap();
            assert!(json.contains("values"));
        }
        assert_eq!(svc.metrics.completed.load(Ordering::Relaxed), 4);
        assert_eq!(svc.metrics.failed.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn parallel_service_merges_when_possible() {
        let (svc, store) = service(CoTenancy::Parallel { max_merge: 4 });
        // submit a burst; the worker should merge at least once
        for i in 0..8 {
            submit_raw(&svc, &format!("r{i}"), simple_graph(i as f32));
        }
        for i in 0..8 {
            store
                .wait_ready(&format!("r{i}"), std::time::Duration::from_secs(30))
                .unwrap();
        }
        assert_eq!(svc.metrics.completed.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn metrics_consistent_under_parallel_producers() {
        let (svc, store) = service(CoTenancy::Sequential);
        let svc = Arc::new(svc);
        let (n_threads, per) = (4usize, 8usize);
        let handles: Vec<_> = (0..n_threads)
            .map(|t| {
                let svc = Arc::clone(&svc);
                std::thread::spawn(move || {
                    for i in 0..per {
                        submit_raw(&svc, &format!("p{t}-{i}"), simple_graph((t * per + i) as f32));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        for t in 0..n_threads {
            for i in 0..per {
                store
                    .wait_ready(&format!("p{t}-{i}"), std::time::Duration::from_secs(60))
                    .unwrap();
            }
        }
        let total = (n_threads * per) as u64;
        let snap = svc.load();
        assert_eq!(snap.enqueued, total);
        assert_eq!(snap.completed, total);
        assert_eq!(snap.failed, 0);
        assert!(snap.exec_seconds > 0.0);
        // queue depth drains to zero shortly after the last result lands
        // (the worker decrements after publishing)
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while svc.load().queue_depth > 0 {
            assert!(
                std::time::Instant::now() < deadline,
                "queue depth stuck at {}",
                svc.load().queue_depth
            );
            std::thread::yield_now();
        }
    }

    #[test]
    fn stateful_session_threads_values_across_traces() {
        let (svc, store) = service(CoTenancy::Sequential);
        let tokens = Tensor::zeros(&[1, 16]);
        // t0: store 2.0 → "acc"; t1: acc*3 → store+save; t2: acc+1 → save
        let mut t0 = Trace::new("tiny-sim", &tokens);
        let c = t0.constant(&Tensor::scalar(2.0));
        t0.save_to_state("acc", c);
        let mut t1 = Trace::new("tiny-sim", &tokens);
        let a = t1.from_state("acc");
        let a3 = t1.scale(a, 3.0);
        t1.save_to_state("acc", a3);
        t1.save(a3);
        let mut t2 = Trace::new("tiny-sim", &tokens);
        let a = t2.from_state("acc");
        let one = t2.constant(&Tensor::scalar(1.0));
        let sum = t2.add(a, one);
        t2.save(sum);
        svc.submit_session(
            "s".into(),
            "sess-1".into(),
            false,
            vec![t0.into_graph(), t1.into_graph(), t2.into_graph()]
                .into_iter()
                .map(Prepared::raw)
                .collect(),
            SubmitOpts::new(),
        )
        .unwrap();
        let json = store
            .wait_ready("s", std::time::Duration::from_secs(30))
            .unwrap();
        let j = crate::json::parse(&json).unwrap();
        let results = j.get("results").as_array().unwrap();
        assert_eq!(results.len(), 3);
        let r1 = gserde::result_from_json(&results[1]).unwrap();
        let r2 = gserde::result_from_json(&results[2]).unwrap();
        assert_eq!(r1.values.values().next().unwrap().item(), 6.0);
        assert_eq!(r2.values.values().next().unwrap().item(), 7.0);
        // ephemeral session: state dropped at the end
        assert!(svc.session_state().is_empty());
    }

    #[test]
    fn failed_session_trace_fails_bundle_with_index() {
        let (svc, store) = service(CoTenancy::Sequential);
        let tokens = Tensor::zeros(&[1, 16]);
        let mut t0 = Trace::new("tiny-sim", &tokens);
        let c = t0.constant(&Tensor::new(&[1, 2, 2], vec![0.0; 4]));
        let t = t0.transpose(c); // rank-3 transpose fails at exec
        t0.save(t);
        svc.submit_session(
            "s".into(),
            "sess-err".into(),
            false,
            vec![Prepared::raw(t0.into_graph())],
            SubmitOpts::new(),
        )
        .unwrap();
        let err = store
            .wait_outcome("s", std::time::Duration::from_secs(30))
            .unwrap()
            .unwrap_err();
        assert!(err.contains("session trace 0"), "{err}");
        assert_eq!(svc.metrics.failed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn stream_job_emits_step_events_then_done() {
        let (svc, _store) = service(CoTenancy::Sequential);
        let mut tr = Trace::new("tiny-sim", &Tensor::zeros(&[1, 16]));
        let h = tr.output("layer.0");
        let m = tr.mean(h);
        tr.step_hook(m);
        let (tx, rx) = std::sync::mpsc::sync_channel(32);
        svc.submit_stream(
            Prepared::raw(tr.into_graph()),
            3,
            tx,
            std::time::Duration::from_secs(5),
            SubmitOpts::new(),
        )
        .unwrap();
        let mut steps = 0;
        loop {
            match rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap() {
                StreamChunk::Event(e) => {
                    assert!(e.contains("\"event\":\"step\""), "{e}");
                    steps += 1;
                }
                StreamChunk::Done(d) => {
                    assert!(d.contains("\"event\":\"done\""), "{d}");
                    break;
                }
                StreamChunk::Failed(e) => panic!("stream failed: {e}"),
            }
        }
        assert_eq!(steps, 3);
        assert_eq!(svc.metrics.completed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn slow_stream_consumer_cannot_pin_the_worker() {
        let (svc, store) = service(CoTenancy::Sequential);
        let mut tr = Trace::new("tiny-sim", &Tensor::zeros(&[1, 16]));
        let h = tr.output("layer.0");
        tr.step_hook(h);
        // capacity-1 channel that nobody drains, with a short send
        // timeout: the worker must abort the decode, count a failure, and
        // go on to serve the next (normal) request
        let (tx, rx) = std::sync::mpsc::sync_channel(1);
        svc.submit_stream(
            Prepared::raw(tr.into_graph()),
            1000,
            tx,
            std::time::Duration::from_millis(50),
            SubmitOpts::new(),
        )
        .unwrap();
        submit_raw(&svc, "after", simple_graph(1.0));
        let json = store
            .wait_ready("after", std::time::Duration::from_secs(30))
            .unwrap();
        assert!(json.contains("values"));
        // under continuous batching the trace runs between decode ticks,
        // so it can finish before the stream's send timeout expires; poll
        // for the abort rather than asserting it already happened
        let deadline = Instant::now() + Duration::from_secs(30);
        while svc.metrics.failed.load(Ordering::Relaxed) < 1 {
            assert!(Instant::now() < deadline, "aborted stream never counted");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(svc.metrics.failed.load(Ordering::Relaxed), 1);
        drop(rx);
    }

    /// Satellite audit: the documented invariant
    /// `completed + failed <= enqueued` must converge to equality once
    /// the queue drains — across plain traces, co-tenant merges, session
    /// bundles, healthy streams, an aborted stream, and a failing trace.
    #[test]
    fn counters_balance_after_mixed_load() {
        let (svc, store) = service(CoTenancy::Parallel { max_merge: 4 });
        // burst of plain traces (some will merge)
        for i in 0..6 {
            submit_raw(&svc, &format!("t{i}"), simple_graph(i as f32));
        }
        // a stateful session bundle (2 traces → 2 enqueued)
        let tokens = Tensor::zeros(&[1, 16]);
        let mut s0 = Trace::new("tiny-sim", &tokens);
        let c = s0.constant(&Tensor::scalar(2.0));
        s0.save_to_state("acc", c);
        let mut s1 = Trace::new("tiny-sim", &tokens);
        let a = s1.from_state("acc");
        s1.save(a);
        svc.submit_session(
            "sess".into(),
            "bal-1".into(),
            false,
            vec![Prepared::raw(s0.into_graph()), Prepared::raw(s1.into_graph())],
            SubmitOpts::new(),
        )
        .unwrap();
        // a healthy stream
        let mut st = Trace::new("tiny-sim", &tokens);
        let h = st.output("layer.0");
        let m = st.mean(h);
        st.step_hook(m);
        let (tx, rx) = std::sync::mpsc::sync_channel(32);
        svc.submit_stream(
            Prepared::raw(st.into_graph()),
            2,
            tx,
            Duration::from_secs(5),
            SubmitOpts::new(),
        )
        .unwrap();
        // an aborted stream: capacity-1 channel that nobody drains
        let mut ab = Trace::new("tiny-sim", &tokens);
        let h2 = ab.output("layer.0");
        ab.step_hook(h2);
        let (tx2, _undrained_rx) = std::sync::mpsc::sync_channel(1);
        svc.submit_stream(
            Prepared::raw(ab.into_graph()),
            1000,
            tx2,
            Duration::from_millis(50),
            SubmitOpts::new(),
        )
        .unwrap();
        // a failing trace
        let mut bad = simple_graph(0.0);
        bad.nodes.clear();
        let b = bad.push(crate::graph::Op::Getter {
            module: "layer.99".into(),
            port: crate::graph::Port::Output,
        });
        bad.push(crate::graph::Op::Save { arg: b });
        submit_raw(&svc, "bad", bad);

        for i in 0..6 {
            store
                .wait_ready(&format!("t{i}"), Duration::from_secs(30))
                .unwrap();
        }
        store.wait_ready("sess", Duration::from_secs(30)).unwrap();
        assert!(store
            .wait_outcome("bad", Duration::from_secs(30))
            .unwrap()
            .is_err());
        loop {
            match rx.recv_timeout(Duration::from_secs(30)).unwrap() {
                StreamChunk::Done(_) => break,
                StreamChunk::Failed(e) => panic!("healthy stream failed: {e}"),
                StreamChunk::Event(_) => {}
            }
        }
        // the aborted stream needs its send timeout to expire; poll
        // until the queue drains and the counters balance exactly
        let deadline = Instant::now() + Duration::from_secs(30);
        let expect_enqueued = 6 + 2 + 1 + 1 + 1;
        loop {
            let snap = svc.load();
            assert!(
                snap.completed + snap.failed <= snap.enqueued,
                "invariant violated mid-drain: {snap:?}"
            );
            if snap.queue_depth == 0 && snap.completed + snap.failed == snap.enqueued {
                break;
            }
            assert!(Instant::now() < deadline, "counters stuck: {snap:?}");
            std::thread::sleep(Duration::from_millis(10));
        }
        let snap = svc.load();
        assert_eq!(snap.enqueued, expect_enqueued);
        assert_eq!(snap.failed, 2, "aborted stream + failing trace: {snap:?}");
        assert_eq!(snap.completed, expect_enqueued - 2);
    }

    /// Worker-side observability: a traced job comes back with `"timing"`
    /// metadata (queue/exec/serialize spans + interpreter phases), the
    /// model histograms record it, and the debug ring retains it.
    #[test]
    fn traced_jobs_record_histograms_ring_and_timing() {
        let runner = Arc::new(ModelRunner::load(&artifacts_dir(), "tiny-sim").unwrap());
        let store = Arc::new(ObjectStore::new());
        let state = Arc::new(SessionStateStore::default());
        let obs = ServiceObs {
            model: Arc::new(crate::obs::ModelObs::default()),
            ring: Arc::new(crate::obs::TraceRing::new(8)),
            profile: Arc::new(crate::obs::ProfileHub::new(8)),
        };
        let svc = ModelService::start(
            runner,
            Arc::clone(&store),
            state,
            CoTenancy::Sequential,
            Some(obs.clone()),
        );
        let tr = ReqTrace::new("deadbeefdeadbeef".into(), "trace", "tiny-sim");
        svc.submit_trace(
            "r0".into(),
            Prepared::raw(simple_graph(1.0)),
            SubmitOpts::new().traced(Some(tr)),
        )
        .unwrap();
        let json = store.wait_ready("r0", Duration::from_secs(30)).unwrap();
        let j = crate::json::parse(&json).unwrap();
        assert_eq!(j.get("timing").get("trace").as_str(), Some("deadbeefdeadbeef"));
        let spans: Vec<String> = j
            .get("timing")
            .get("spans")
            .as_array()
            .unwrap()
            .iter()
            .map(|s| s.get("name").as_str().unwrap().to_string())
            .collect();
        for expected in ["queue", "exec", "exec:forward", "serialize"] {
            assert!(spans.iter().any(|s| s == expected), "missing {expected}: {spans:?}");
        }
        assert_eq!(obs.model.e2e.count(), 1);
        assert_eq!(obs.model.queue_wait.count(), 1);
        assert_eq!(obs.model.exec.count(), 1);
        assert_eq!(obs.ring.len(), 1);
        assert_eq!(
            obs.ring.snapshot()[0].get("trace").as_str(),
            Some("deadbeefdeadbeef")
        );
    }

    /// Deep profiler wiring: a profiled job comes back with a
    /// `"profile"` block (per-op self-times, memory gauges), the profile
    /// ring retains the trace-event JSON under the request id, and the
    /// replica hot-op table accumulates — while an unprofiled job on the
    /// same service leaves no `"profile"` key and no ring entry.
    #[test]
    fn profiled_jobs_attach_profile_and_feed_hub() {
        let runner = Arc::new(ModelRunner::load(&artifacts_dir(), "tiny-sim").unwrap());
        let store = Arc::new(ObjectStore::new());
        let state = Arc::new(SessionStateStore::default());
        let obs = ServiceObs {
            model: Arc::new(crate::obs::ModelObs::default()),
            ring: Arc::new(crate::obs::TraceRing::new(8)),
            profile: Arc::new(crate::obs::ProfileHub::new(8)),
        };
        let svc = ModelService::start(
            runner,
            Arc::clone(&store),
            state,
            CoTenancy::Sequential,
            Some(obs.clone()),
        );
        svc.submit_trace(
            "p0".into(),
            Prepared::raw(simple_graph(1.0)),
            SubmitOpts::new().profiled(true),
        )
        .unwrap();
        svc.submit_trace("q0".into(), Prepared::raw(simple_graph(2.0)), SubmitOpts::new())
            .unwrap();
        let json = store.wait_ready("p0", Duration::from_secs(30)).unwrap();
        let j = crate::json::parse(&json).unwrap();
        let prof = j.get("profile");
        assert!(prof.get("ops").as_i64().unwrap_or(0) > 0, "{json}");
        assert!(prof.get("total_self_us").as_i64().is_some());
        assert!(!prof.get("top_ops").as_array().unwrap().is_empty());
        assert!(prof.get("peak_bytes").as_i64().unwrap_or(0) > 0);
        // the getter's activation was allocated while armed
        assert!(prof.get("alloc_bytes").as_i64().unwrap_or(0) > 0);
        // ring entry is valid trace-event JSON keyed by the request id
        let ring = obs.profile.ring.get("p0").expect("profile ring entry");
        assert!(!ring.get("traceEvents").as_array().unwrap().is_empty());
        // hot-op table accumulated at least the getter and save
        let hot = obs.profile.hotops.to_json(16);
        assert!(hot.get("total_self_ns").as_i64().unwrap_or(0) > 0);
        // unprofiled job on the same worker: no profile key, no ring entry
        let json2 = store.wait_ready("q0", Duration::from_secs(30)).unwrap();
        let j2 = crate::json::parse(&json2).unwrap();
        assert!(j2.get("profile").is_null(), "{json2}");
        assert!(obs.profile.ring.get("q0").is_none());
        assert_eq!(obs.profile.ring.len(), 1);
    }

    #[test]
    fn failed_request_reports_error() {
        let (svc, store) = service(CoTenancy::Sequential);
        let mut g = simple_graph(0.0);
        g.nodes.clear();
        // invalid: getter of unknown module
        let bad = g.push(crate::graph::Op::Getter {
            module: "layer.99".into(),
            port: crate::graph::Port::Output,
        });
        g.push(crate::graph::Op::Save { arg: bad });
        submit_raw(&svc, "bad", g);
        let err = store
            .wait_outcome("bad", std::time::Duration::from_secs(30))
            .unwrap();
        assert!(err.is_err());
        assert_eq!(svc.metrics.failed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn tenant_depths_acquire_release_and_cap() {
        let td = TenantDepths::new(3);
        td.try_acquire(Some("a"), 2).unwrap();
        td.try_acquire(Some("a"), 1).unwrap();
        let err = td.try_acquire(Some("a"), 1).unwrap_err();
        assert_eq!(err.tenant, "a");
        assert_eq!(err.depth, 3);
        assert_eq!(err.cap, 3);
        // other tenants (and the anonymous pool) are isolated
        td.try_acquire(Some("b"), 3).unwrap();
        td.try_acquire(None, 3).unwrap();
        assert_eq!(td.depth(Some("a")), 3);
        td.release(Some("a"), 2);
        td.try_acquire(Some("a"), 1).unwrap();
        // releases never underflow, and a drained tenant is pruned
        td.release(Some("a"), 100);
        assert_eq!(td.depth(Some("a")), 0);
        assert!(td.map.lock().unwrap().get("a").is_none());
        // raising the cap admits more
        td.set_cap(5);
        td.try_acquire(Some("b"), 2).unwrap();
    }

    /// Per-tenant admission: with the worker pinned by a stream whose
    /// consumer never drains, a tenant at its in-flight cap gets a
    /// [`TenantCapExceeded`] rejection while other tenants still enqueue;
    /// once the queue drains the tenant is admitted again.
    #[test]
    fn tenant_cap_rejects_then_recovers() {
        let (svc, store) = service(CoTenancy::Sequential);
        svc.set_tenant_cap(2);
        // pin the worker: capacity-1 channel nobody drains, short timeout
        let mut tr = Trace::new("tiny-sim", &Tensor::zeros(&[1, 16]));
        let h = tr.output("layer.0");
        tr.step_hook(h);
        let (tx, _rx) = std::sync::mpsc::sync_channel(1);
        svc.submit_stream(
            Prepared::raw(tr.into_graph()),
            1000,
            tx,
            Duration::from_millis(200),
            SubmitOpts::new(),
        )
        .unwrap();
        // tenant "a" fills its cap while the worker is pinned
        svc.submit_trace(
            "a0".into(),
            Prepared::raw(simple_graph(0.0)),
            SubmitOpts::new().tenant(Some("a")),
        )
        .unwrap();
        svc.submit_trace(
            "a1".into(),
            Prepared::raw(simple_graph(1.0)),
            SubmitOpts::new().tenant(Some("a")),
        )
        .unwrap();
        let err = svc
            .submit_trace(
                "a2".into(),
                Prepared::raw(simple_graph(2.0)),
                SubmitOpts::new().tenant(Some("a")),
            )
            .unwrap_err();
        let cap = err
            .downcast_ref::<TenantCapExceeded>()
            .expect("typed cap error for the 429 mapping");
        assert_eq!(cap.tenant, "a");
        // a different tenant is unaffected
        svc.submit_trace(
            "b0".into(),
            Prepared::raw(simple_graph(3.0)),
            SubmitOpts::new().tenant(Some("b")),
        )
        .unwrap();
        // the pinned stream aborts on send timeout, traces drain, and the
        // tenant's in-flight units come back
        for id in ["a0", "a1", "b0"] {
            store.wait_ready(id, Duration::from_secs(30)).unwrap();
        }
        let deadline = Instant::now() + Duration::from_secs(30);
        while svc.tenant_depths().depth(Some("a")) > 0 {
            assert!(Instant::now() < deadline, "tenant units never released");
            std::thread::sleep(Duration::from_millis(5));
        }
        svc.submit_trace(
            "a3".into(),
            Prepared::raw(simple_graph(4.0)),
            SubmitOpts::new().tenant(Some("a")),
        )
        .unwrap();
        store.wait_ready("a3", Duration::from_secs(30)).unwrap();
    }

    /// The deprecated `submit`/`submit_prepared` shims remain wired to the
    /// unified entry point. This is the only in-repo caller of the old
    /// names.
    #[test]
    #[allow(deprecated)]
    fn deprecated_submit_shims_still_work() {
        let Some((svc, store)) = try_service(CoTenancy::Sequential) else { return };
        svc.submit("old0".into(), simple_graph(1.0)).unwrap();
        svc.submit_prepared("old1".into(), Prepared::raw(simple_graph(2.0))).unwrap();
        for id in ["old0", "old1"] {
            let json = store.wait_ready(id, Duration::from_secs(30)).unwrap();
            assert!(json.contains("values"), "{json}");
        }
        assert_eq!(svc.metrics.completed.load(Ordering::Relaxed), 2);
    }

    /// Continuous batching: a short stream submitted after a long one
    /// retires while the long one is still decoding (the old worker ran
    /// streams serially to completion), and a trace admitted mid-decode
    /// completes without waiting for the batch to drain.
    #[test]
    fn short_stream_retires_while_long_stream_decodes() {
        let Some((svc, store)) = try_service(CoTenancy::Sequential) else { return };
        let long_steps = 400usize;
        let mk = |steps: usize, cap: usize| {
            let mut tr = Trace::new("tiny-sim", &Tensor::zeros(&[1, 16]));
            let h = tr.output("layer.0");
            let m = tr.mean(h);
            tr.step_hook(m);
            let (tx, rx) = std::sync::mpsc::sync_channel(cap);
            svc.submit_stream(
                Prepared::raw(tr.into_graph()),
                steps,
                tx,
                Duration::from_secs(5),
                SubmitOpts::new(),
            )
            .unwrap();
            rx
        };
        let long_rx = mk(long_steps, long_steps + 8);
        let short_rx = mk(2, 8);
        // a trace admitted while both streams decode runs between ticks
        submit_raw(&svc, "mid", simple_graph(1.0));
        store.wait_ready("mid", Duration::from_secs(30)).unwrap();
        // block until the short stream's terminal frame...
        let mut short_events = 0;
        loop {
            match short_rx.recv_timeout(Duration::from_secs(30)).unwrap() {
                StreamChunk::Event(_) => short_events += 1,
                StreamChunk::Done(d) => {
                    assert!(d.contains("\"steps\":2"), "{d}");
                    break;
                }
                StreamChunk::Failed(e) => panic!("short stream failed: {e}"),
            }
        }
        assert_eq!(short_events, 2);
        // ...at which point the long stream must not have finished: with
        // round-robin ticks it has emitted only a handful of its 400 steps
        let buffered = long_rx.try_iter().count();
        assert!(
            buffered < long_steps,
            "long stream finished ({buffered} frames) before the short one retired — \
             streams are not interleaving"
        );
        // and the long stream still runs to a clean completion
        let mut long_frames = buffered;
        loop {
            match long_rx.recv_timeout(Duration::from_secs(60)).unwrap() {
                StreamChunk::Event(_) => long_frames += 1,
                StreamChunk::Done(d) => {
                    assert!(d.contains(&format!("\"steps\":{long_steps}")), "{d}");
                    break;
                }
                StreamChunk::Failed(e) => panic!("long stream failed: {e}"),
            }
        }
        assert_eq!(long_frames, long_steps);
    }
}
