//! nnscope CLI — serve, inspect, and exercise the NDIF reproduction.
//!
//! Subcommands:
//!   serve      start an NDIF server     (--models a,b --addr host:port
//!                                        --parallel-cotenancy --workers N
//!                                        --coordinator host:port)
//!   coordinate start an L3 fleet coordinator (--replicas a,b --policy p)
//!   models     list hosted model configs from the artifacts directory
//!   survey     print the Fig. 2 / Fig. 7 survey analyses
//!   trace      submit a demo intervention to a running server (--addr)
//!   profile    run a profiled logit-lens trace and print the op table
//!   selftest   quick sanity pass over the tiny model
//!
//! Artifacts are looked up in `$NNSCOPE_ARTIFACTS` or `<crate>/artifacts`
//! (build them with `make artifacts`).

use anyhow::Result;

use nnscope::client::{remote::NdifClient, Trace};
use nnscope::models::{artifacts_dir, ModelRunner};
use nnscope::runtime::Manifest;
use nnscope::scheduler::CoTenancy;
use nnscope::server::{NdifConfig, NdifServer};
use nnscope::survey;
use nnscope::tensor::Tensor;
use nnscope::util::cli::Args;
use nnscope::util::table::Table;

const USAGE: &str = "usage: nnscope <serve|coordinate|models|survey|trace|profile|selftest> [options]
  serve       --models tiny-sim[,..] [--addr 127.0.0.1:7757] [--workers 8]
              [--config deploy.json]
              [--parallel-cotenancy] [--max-merge 8]
              [--coordinator 127.0.0.1:7788] [--advertise host:port]
              [--heartbeat-ms 250] [--link-latency 0.0]
              [--stream-buffer 32] [--stream-send-timeout-s 10]
              [--no-opt]   (disable the admission graph compiler)
              [--no-plan-cache]   (disable AOT plan caching: full validate
                                   + optimize on every admission)
              [--plan-cache-cap 256]   (cached plans per replica, LRU)
              [--no-obs]   (disable latency histograms + request tracing)
              [--trace-ring 256]   (GET /v1/debug/requests retention)
              [--profile-ring 64]  (GET /v1/debug/profile/<id> retention)
              [--profile-sample-n N]   (deep-profile 1-in-N unsolicited requests)
              [--data-dir /path]   (journaled durable results, replayed on restart)
              [--rate-limit N] [--rate-burst M]   (per-tenant requests/s + burst)
              [--tenant-queue-cap N]   (per-tenant in-flight queue units)
              [--shed-anon-above N] [--shed-all-above M]   (load-shed watermarks)
  coordinate  [--addr 127.0.0.1:7788] [--replicas host:port[@latency_s],..]
              [--policy round-robin|least-loaded|latency-aware]
              [--probe-ms 250] [--retries 3] [--workers 8]
              [--rate-limit N] [--rate-burst M]   (front-door per-tenant limit)
  models
  survey
  trace       --addr 127.0.0.1:7757 [--model tiny-sim]
  profile     --addr 127.0.0.1:7757 [--model tiny-sim] [--top 10]
              [--trace-out trace.json]   (write Chrome/Perfetto trace-event JSON)
  selftest";

fn main() -> Result<()> {
    let args = Args::from_env(2);
    let cmd = std::env::args().nth(1).unwrap_or_default();
    match cmd.as_str() {
        "serve" => serve(&args),
        "coordinate" => coordinate(&args),
        "models" => models(),
        "survey" => survey_cmd(),
        "trace" => trace(&args),
        "profile" => profile_cmd(&args),
        "selftest" => selftest(),
        _ => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    }
}

fn serve(args: &Args) -> Result<()> {
    if let Some(path) = args.get("config") {
        let mut cfg = nnscope::server::config::from_file(std::path::Path::new(path))?;
        // CLI fleet flags override the config file
        if let Some(c) = args.get("coordinator") {
            cfg.coordinator = Some(c.to_string());
        }
        if let Some(a) = args.get("advertise") {
            cfg.advertise = Some(a.to_string());
        }
        if let Some(ms) = args.get("heartbeat-ms") {
            let ms: u64 = ms
                .parse()
                .map_err(|_| anyhow::anyhow!("invalid --heartbeat-ms '{ms}'"))?;
            cfg.heartbeat = std::time::Duration::from_millis(ms.max(1));
        }
        if let Some(l) = args.get("link-latency") {
            cfg.link_latency_s = l
                .parse()
                .map_err(|_| anyhow::anyhow!("invalid --link-latency '{l}'"))?;
        }
        if args.flag("no-opt") {
            cfg.optimize = false;
        }
        apply_plan_cache_flags(args, &mut cfg)?;
        if args.flag("no-obs") {
            cfg.obs = false;
        }
        apply_profile_flags(args, &mut cfg)?;
        apply_fault_tolerance_flags(args, &mut cfg)?;
        println!("preloading {:?} (from {path}) …", cfg.models);
        let server = NdifServer::start(cfg)?;
        announce_serving(&server);
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    let models: Vec<String> = args
        .str_or("models", "tiny-sim")
        .split(',')
        .map(str::to_string)
        .collect();
    let mut cfg = NdifConfig {
        addr: args.str_or("addr", "127.0.0.1:7757"),
        workers: args.usize_or("workers", 8),
        models: models.clone(),
        artifacts: artifacts_dir(),
        cotenancy: if args.flag("parallel-cotenancy") {
            CoTenancy::Parallel { max_merge: args.usize_or("max-merge", 8) }
        } else {
            CoTenancy::Sequential
        },
        auth: Default::default(),
        coordinator: args.get("coordinator").map(str::to_string),
        advertise: args.get("advertise").map(str::to_string),
        heartbeat: std::time::Duration::from_millis(args.u64_or("heartbeat-ms", 250).max(1)),
        link_latency_s: args.f64_or("link-latency", 0.0),
        state_limits: nnscope::server::StateLimits {
            ttl: std::time::Duration::from_secs(args.u64_or("state-ttl-s", 600).max(1)),
            ..Default::default()
        },
        stream_buffer: args.usize_or("stream-buffer", 32).max(1),
        stream_send_timeout: std::time::Duration::from_secs(
            args.u64_or("stream-send-timeout-s", 10).max(1),
        ),
        optimize: !args.flag("no-opt"),
        plan_cache: true,
        plan_cache_cap: 256,
        obs: !args.flag("no-obs"),
        trace_ring: args.usize_or("trace-ring", 256),
        profile_ring: args.usize_or("profile-ring", 64),
        profile_sample_n: args.usize_or("profile-sample-n", 0),
        data_dir: None,
        rate_limit: None,
        tenant_queue_cap: usize::MAX,
        shed: nnscope::server::admission::ShedPolicy::disabled(),
    };
    apply_plan_cache_flags(args, &mut cfg)?;
    apply_fault_tolerance_flags(args, &mut cfg)?;
    println!("preloading {models:?} …");
    let server = NdifServer::start(cfg)?;
    announce_serving(&server);
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Apply the AOT plan-cache CLI flags (shared by the config-file path,
/// where they override the file, and the flag-only path).
fn apply_plan_cache_flags(args: &Args, cfg: &mut NdifConfig) -> Result<()> {
    if args.flag("no-plan-cache") {
        cfg.plan_cache = false;
    }
    if let Some(n) = args.get("plan-cache-cap") {
        let n: usize = n
            .parse()
            .map_err(|_| anyhow::anyhow!("invalid --plan-cache-cap '{n}'"))?;
        cfg.plan_cache_cap = n.max(1);
    }
    Ok(())
}

/// Apply the profiler CLI flags on top of a config file (the flag-only
/// path reads them straight into its literal).
fn apply_profile_flags(args: &Args, cfg: &mut NdifConfig) -> Result<()> {
    if let Some(n) = args.get("profile-ring") {
        cfg.profile_ring = n
            .parse()
            .map_err(|_| anyhow::anyhow!("invalid --profile-ring '{n}'"))?;
    }
    if let Some(n) = args.get("profile-sample-n") {
        cfg.profile_sample_n = n
            .parse()
            .map_err(|_| anyhow::anyhow!("invalid --profile-sample-n '{n}'"))?;
    }
    Ok(())
}

/// Apply the fault-tolerance CLI flags (shared by the config-file path,
/// where they override the file, and the flag-only path).
fn apply_fault_tolerance_flags(args: &Args, cfg: &mut NdifConfig) -> Result<()> {
    if let Some(d) = args.get("data-dir") {
        cfg.data_dir = Some(d.into());
    }
    if let Some(rl) = rate_limit_from_args(args)? {
        cfg.rate_limit = Some(rl);
    }
    if let Some(n) = args.get("tenant-queue-cap") {
        let n: usize = n
            .parse()
            .map_err(|_| anyhow::anyhow!("invalid --tenant-queue-cap '{n}'"))?;
        cfg.tenant_queue_cap = n.max(1);
    }
    if let Some(a) = args.get("shed-anon-above") {
        let anon: usize = a
            .parse()
            .map_err(|_| anyhow::anyhow!("invalid --shed-anon-above '{a}'"))?;
        let all = match args.get("shed-all-above") {
            Some(s) => s
                .parse()
                .map_err(|_| anyhow::anyhow!("invalid --shed-all-above '{s}'"))?,
            None => anon.saturating_mul(2),
        };
        cfg.shed = nnscope::server::admission::ShedPolicy {
            shed_anon_above: anon,
            shed_all_above: all,
        };
    }
    Ok(())
}

/// Parse `--rate-limit N [--rate-burst M]` into a token-bucket config.
fn rate_limit_from_args(args: &Args) -> Result<Option<nnscope::server::admission::RateLimit>> {
    let Some(per_s) = args.get("rate-limit") else {
        return Ok(None);
    };
    let per_s: f64 = per_s
        .parse()
        .map_err(|_| anyhow::anyhow!("invalid --rate-limit '{per_s}'"))?;
    if per_s <= 0.0 {
        anyhow::bail!("--rate-limit must be positive");
    }
    let burst = match args.get("rate-burst") {
        Some(b) => b.parse().map_err(|_| anyhow::anyhow!("invalid --rate-burst '{b}'"))?,
        None => per_s.max(1.0),
    };
    Ok(Some(nnscope::server::admission::RateLimit::new(per_s, burst)))
}

fn announce_serving(server: &NdifServer) {
    println!("NDIF serving on {} — POST /v1/trace, GET /v1/models", server.addr());
    if let Some(id) = server.replica_id() {
        println!("registered with fleet coordinator as replica {id}");
    }
}

fn coordinate(args: &Args) -> Result<()> {
    use nnscope::coordinator::{Coordinator, CoordinatorConfig, Policy};
    let policy_s = args.str_or("policy", "least-loaded");
    let Some(policy) = Policy::parse(&policy_s) else {
        anyhow::bail!("unknown policy '{policy_s}' (round-robin | least-loaded | latency-aware)");
    };
    let mut cfg = CoordinatorConfig::local();
    cfg.addr = args.str_or("addr", "127.0.0.1:7788");
    cfg.workers = args.usize_or("workers", 8);
    cfg.policy = policy;
    cfg.max_retries = args.usize_or("retries", 3);
    cfg.probe_interval = std::time::Duration::from_millis(args.u64_or("probe-ms", 250));
    cfg.rate_limit = rate_limit_from_args(args)?;
    if let Some(reps) = args.get("replicas") {
        cfg.replicas = reps.split(',').map(str::to_string).collect();
    }
    let coord = Coordinator::start(cfg)?;
    println!("NDIF fleet coordinator on {} — policy {policy_s}", coord.addr());
    println!("  clients:  POST /v1/trace, POST /v1/session, GET /v1/models (proxied)");
    println!("  replicas: POST /v1/fleet/register, /v1/fleet/heartbeat");
    println!("  fleet:    GET /v1/fleet/status");
    for r in coord.replicas() {
        println!("  replica {} @ {} [{}]", r.id, r.addr, r.health.as_str());
    }
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn models() -> Result<()> {
    let dir = artifacts_dir();
    let mut table = Table::new(&format!("models in {}", dir.display())).header(vec![
        "name", "params", "layers", "d_model", "seq", "batches", "grad", "tp", "simulates",
    ]);
    for name in Manifest::list(&dir) {
        let m = Manifest::load(&dir, &name)?;
        table.row(vec![
            m.name.clone(),
            format!("{}", m.param_count),
            format!("{}", m.n_layers),
            format!("{}", m.d_model),
            format!("{}", m.seq),
            format!("{:?}", m.batches),
            format!("{}", m.grad),
            format!("{:?}", m.tp),
            m.simulates.clone(),
        ]);
    }
    table.print();
    Ok(())
}

fn survey_cmd() -> Result<()> {
    let (papers, released) = survey::survey_dataset(survey::data::DEFAULT_SEED);
    let s = survey::fig2_stats(&papers);
    println!("== Figure 2 (capability gap) ==");
    println!("papers surveyed               : {}", s.total_papers);
    println!("papers since Feb 2023         : {}", s.post_feb_2023);
    println!("  studying <40% MMLU models   : {:.1}%  (paper: 60.6%)", 100.0 * s.frac_sub40_post_2023);
    println!("papers on ≥70% MMLU models    : {}", s.count_ge70);
    println!("mean MMLU gap vs frontier     : {:.1} points", s.mean_gap_post_2023);
    println!();
    let mut table = Table::new("Figure 7 (research vs released model sizes)").header(vec![
        "bucket", "research median (B)", "released median (B)", "ratio",
    ]);
    for b in survey::fig7_buckets(&papers, &released) {
        table.row(vec![
            b.label.to_string(),
            format!("{:.2}", b.research_median_b),
            format!("{:.2}", b.released_median_b),
            format!("{:.1}x", b.ratio),
        ]);
    }
    table.print();
    println!("(paper endpoints: 2.7x in 2019-2020 → 10.3x in 2024)");
    Ok(())
}

fn trace(args: &Args) -> Result<()> {
    let addr: std::net::SocketAddr = args.str_or("addr", "127.0.0.1:7757").parse()?;
    let model = args.str_or("model", "tiny-sim");
    let client = NdifClient::new(addr);
    println!("hosted models: {:?}", client.models()?);
    let m = Manifest::load(&artifacts_dir(), &model)?;
    let tokens = Tensor::new(
        &[1, m.seq],
        (0..m.seq).map(|i| (i % m.vocab) as f32).collect(),
    );
    let mut tr = Trace::new(&model, &tokens);
    let h = tr.output(&format!("layer.{}", m.n_layers - 1));
    let s = tr.save(h);
    let res = tr.run_remote(&client)?;
    println!(
        "saved layer.{} output: shape {:?}, norm {:.4}",
        m.n_layers - 1,
        res.get(s).dims(),
        res.get(s).norm()
    );
    if let Some(r) = res.opt_report() {
        println!(
            "server graph compiler: {} -> {} nodes (dce {}, folded {}, cse {}, fused {})",
            r.nodes_before, r.nodes_after, r.dce_removed, r.folded, r.cse_merged, r.fused
        );
    }
    Ok(())
}

/// Run a profiled logit-lens trace (save every layer's output) against a
/// running server and pretty-print the deep profile: top ops by self-time,
/// phase totals, and allocation accounting. `--trace-out` additionally
/// fetches the retained Chrome/Perfetto trace-event JSON and writes it to
/// a file (load it at ui.perfetto.dev or chrome://tracing).
fn profile_cmd(args: &Args) -> Result<()> {
    let addr: std::net::SocketAddr = args.str_or("addr", "127.0.0.1:7757").parse()?;
    let model = args.str_or("model", "tiny-sim");
    let top = args.usize_or("top", 10);
    let client = NdifClient::new(addr);
    let m = Manifest::load(&artifacts_dir(), &model)?;
    let tokens = Tensor::new(
        &[1, m.seq],
        (0..m.seq).map(|i| (i % m.vocab) as f32).collect(),
    );
    // logit-lens: save every layer's output, so the profile exercises
    // every forward point
    let mut tr = Trace::new(&model, &tokens);
    for l in 0..m.n_layers {
        let h = tr.output(&format!("layer.{l}"));
        tr.save(h);
    }
    let out = client.run(tr.graph(), nnscope::client::ExecuteOptions::new().profiled())?;
    let (profile, id) = (out.profile.unwrap_or(nnscope::json::Json::Null), out.id);
    println!("request {id} profiled: {} ops recorded", profile.get("ops").as_i64().unwrap_or(0));
    let mut table = Table::new(&format!("top ops by self-time ({model})")).header(vec![
        "op", "count", "self (us)", "alloc (bytes)",
    ]);
    for o in profile.get("top_ops").as_array().unwrap_or(&[]).iter().take(top) {
        table.row(vec![
            o.get("op").as_str().unwrap_or("?").to_string(),
            format!("{}", o.get("count").as_i64().unwrap_or(0)),
            format!("{:.1}", o.get("self_us").as_f64().unwrap_or(0.0)),
            format!("{}", o.get("alloc_bytes").as_i64().unwrap_or(0)),
        ]);
    }
    table.print();
    for p in profile.get("phases").as_array().unwrap_or(&[]) {
        println!(
            "phase {:<10} {:>10.1} us",
            p.get("name").as_str().unwrap_or("?"),
            p.get("total_us").as_f64().unwrap_or(0.0)
        );
    }
    println!(
        "memory: {} bytes allocated, {} freed, peak {}",
        profile.get("alloc_bytes").as_i64().unwrap_or(0),
        profile.get("freed_bytes").as_i64().unwrap_or(0),
        profile.get("peak_bytes").as_i64().unwrap_or(0)
    );
    if let Some(path) = args.get("trace-out") {
        let events = client.profile_trace_events(&id)?;
        std::fs::write(path, events.to_string())?;
        println!("Chrome trace-event JSON written to {path} (open in ui.perfetto.dev)");
    }
    Ok(())
}

fn selftest() -> Result<()> {
    println!("engine: {}", nnscope::runtime::Engine::global().platform());
    let lm = ModelRunner::load(&artifacts_dir(), "tiny-sim")?;
    let tokens = Tensor::new(&[1, 16], (0..16).map(|i| (i % 7) as f32).collect());
    let logits = lm.forward_plain(&tokens)?;
    println!("tiny-sim forward OK, logits norm {:.4}", logits.norm());
    let mut tr = Trace::new("tiny-sim", &tokens);
    let h = tr.output("layer.0");
    let z = tr.scale(h, 0.0);
    tr.set_output("layer.0", z);
    let l = tr.output("lm_head");
    let s = tr.save(l);
    let res = tr.run_local(&lm)?;
    println!("ablated trace OK, logits norm {:.4}", res.get(s).norm());
    println!("selftest OK");
    Ok(())
}
