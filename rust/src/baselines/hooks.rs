//! Hook-based intervention mechanisms: baukit-like closure hooks and
//! pyvene-like declarative intervention schemes.

use std::cell::RefCell;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::models::workload::IoiBatch;
use crate::models::{Hooks, ModelRunner};
use crate::tensor::Tensor;

use super::{base_row_logit_diffs, patch_rows, Framework};

// ---------------------------------------------------------------------------
// baukit-like: register a closure at one access point
// ---------------------------------------------------------------------------

/// The minimal mechanism: one closure per access point, like
/// `baukit.TraceDict` / `register_forward_hook`. No intermediate
/// representation; the closure runs inline at the module boundary.
pub struct BaukitLike {
    runner: ModelRunner,
}

/// Adapter: closure at a single point → [`Hooks`].
struct ClosureHook<'f> {
    point: String,
    f: RefCell<Box<dyn FnMut(&mut Tensor) + 'f>>,
}

impl Hooks for ClosureHook<'_> {
    fn wants(&self, point: &str) -> bool {
        point == self.point
    }
    fn on_output(&mut self, _point: &str, t: &mut Tensor) -> bool {
        (self.f.borrow_mut())(t);
        true
    }
}

impl BaukitLike {
    /// Run a forward pass with a closure hook at `point` (the baukit
    /// pattern from the paper's Fig. 3a).
    pub fn run_with_hook(
        &self,
        tokens: &Tensor,
        point: &str,
        f: impl FnMut(&mut Tensor),
    ) -> Result<Tensor> {
        let mut hook = ClosureHook { point: point.to_string(), f: RefCell::new(Box::new(f)) };
        self.runner.forward(tokens, &mut hook)
    }

    pub fn runner(&self) -> &ModelRunner {
        &self.runner
    }
}

impl Framework for BaukitLike {
    fn name(&self) -> &'static str {
        "baukit"
    }

    fn setup(artifacts: &Path, model: &str) -> Result<BaukitLike> {
        let runner = ModelRunner::load_cold(artifacts, model)?;
        runner.precompile_forward()?;
        Ok(BaukitLike { runner })
    }

    fn activation_patch(&self, batch: &IoiBatch, layer: usize) -> Result<Tensor> {
        let tokens = batch.interleaved_tokens();
        let (padded, _) = self.runner.pad_tokens(&tokens)?;
        let seq = self.runner.manifest.seq;
        let logits =
            self.run_with_hook(&padded, &format!("layer.{layer}"), |t| patch_rows(t, seq))?;
        Ok(base_row_logit_diffs(&logits, batch))
    }
}

// ---------------------------------------------------------------------------
// pyvene-like: declarative intervention schemes compiled to hooks
// ---------------------------------------------------------------------------

/// What an intervention config does at its access point.
#[derive(Clone, Debug)]
pub enum InterventionType {
    /// Collect the activation (returned after the run).
    Collect,
    /// Copy source rows onto base rows at the last token (interchange
    /// intervention, pyvene's core operation).
    Interchange,
    /// Zero a span of neurons at the last token.
    ZeroNeurons { from: usize, to: usize },
}

/// One entry of an intervention scheme (pyvene's `IntervenableConfig`).
#[derive(Clone, Debug)]
pub struct InterventionConfig {
    pub point: String,
    pub kind: InterventionType,
}

/// pyvene-like: the user describes interventions declaratively; the
/// framework compiles the scheme into hooks and manages collected state.
pub struct PyveneLike {
    runner: ModelRunner,
}

/// The compiled scheme acting as hooks, collecting as it goes.
struct SchemeHooks {
    configs: Vec<InterventionConfig>,
    seq: usize,
    collected: Vec<(String, Tensor)>,
}

impl Hooks for SchemeHooks {
    fn wants(&self, point: &str) -> bool {
        self.configs.iter().any(|c| c.point == point)
    }
    fn on_output(&mut self, point: &str, t: &mut Tensor) -> bool {
        let mut modified = false;
        // clone configs indexes to avoid double borrow
        let matches: Vec<usize> = self
            .configs
            .iter()
            .enumerate()
            .filter(|(_, c)| c.point == point)
            .map(|(i, _)| i)
            .collect();
        for i in matches {
            match self.configs[i].kind.clone() {
                InterventionType::Collect => {
                    self.collected.push((point.to_string(), t.clone()));
                }
                InterventionType::Interchange => {
                    patch_rows(t, self.seq);
                    modified = true;
                }
                InterventionType::ZeroNeurons { from, to } => {
                    t.slice_fill(
                        &[
                            crate::tensor::Range1::all(),
                            crate::tensor::Range1::one(self.seq - 1),
                            crate::tensor::Range1::new(from, to),
                        ],
                        0.0,
                    );
                    modified = true;
                }
            }
        }
        modified
    }
}

impl PyveneLike {
    /// Execute a scheme; returns (logits, collected activations).
    pub fn run_scheme(
        &self,
        tokens: &Tensor,
        configs: &[InterventionConfig],
    ) -> Result<(Tensor, Vec<(String, Tensor)>)> {
        let mut hooks = SchemeHooks {
            configs: configs.to_vec(),
            seq: self.runner.manifest.seq,
            collected: Vec::new(),
        };
        let logits = self.runner.forward(tokens, &mut hooks)?;
        Ok((logits, hooks.collected))
    }

    pub fn runner(&self) -> &ModelRunner {
        &self.runner
    }
}

impl Framework for PyveneLike {
    fn name(&self) -> &'static str {
        "pyvene"
    }

    fn setup(artifacts: &Path, model: &str) -> Result<PyveneLike> {
        let runner = ModelRunner::load_cold(artifacts, model)?;
        runner.precompile_forward()?;
        Ok(PyveneLike { runner })
    }

    fn activation_patch(&self, batch: &IoiBatch, layer: usize) -> Result<Tensor> {
        let tokens = batch.interleaved_tokens();
        let (padded, _) = self.runner.pad_tokens(&tokens)?;
        let scheme = [InterventionConfig {
            point: format!("layer.{layer}"),
            kind: InterventionType::Interchange,
        }];
        let (logits, _) = self.run_scheme(&padded, &scheme)?;
        Ok(base_row_logit_diffs(&logits, batch))
    }
}

// ---------------------------------------------------------------------------
// NNsight path as a Framework (for Table 1 parity measurements)
// ---------------------------------------------------------------------------

/// The intervention-graph mechanism measured under the same harness.
pub struct NnsightLocal {
    runner: ModelRunner,
}

impl NnsightLocal {
    pub fn runner(&self) -> &ModelRunner {
        &self.runner
    }
}

impl Framework for NnsightLocal {
    fn name(&self) -> &'static str {
        "nnsight"
    }

    fn setup(artifacts: &Path, model: &str) -> Result<NnsightLocal> {
        let runner = ModelRunner::load_cold(artifacts, model)?;
        runner.precompile_forward()?;
        Ok(NnsightLocal { runner })
    }

    fn activation_patch(&self, batch: &IoiBatch, layer: usize) -> Result<Tensor> {
        use crate::client::Trace;
        use crate::tensor::Range1;
        let tokens = batch.interleaved_tokens();
        let (padded, _) = self.runner.pad_tokens(&tokens)?;
        let seq = self.runner.manifest.seq;

        let mut tr = Trace::new(&self.runner.manifest.name, &padded);
        let h = tr.output(&format!("layer.{layer}"));
        // build the interleaved patch as graph ops
        let mut patched = h;
        for i in (0..batch.len() * 2).step_by(2) {
            let src = tr.slice(h, &[Range1::one(i), Range1::one(seq - 1)]);
            patched = tr.assign(patched, &[Range1::one(i + 1), Range1::one(seq - 1)], src);
        }
        tr.set_output(&format!("layer.{layer}"), patched);
        let logits = tr.output("lm_head");
        let s = tr.save(logits);
        let res = tr.run_local(&self.runner)?;
        let logits = res.try_get(s).ok_or_else(|| anyhow!("missing logits"))?;
        Ok(base_row_logit_diffs(logits, batch))
    }
}
