//! Baseline intervention mechanisms, for the paper's comparisons.
//!
//! Table 1 compares NNsight against baukit, pyvene, and TransformerLens —
//! three ways of organizing the *same* intervention work, whose measured
//! differences come from how much machinery sits between the researcher
//! and the forward pass. Rather than mock numbers, this module implements
//! each mechanism's distinguishing architecture over the shared runtime
//! (DESIGN.md §3):
//!
//! * [`hooks::BaukitLike`] — closure hooks registered at one access point
//!   (the minimal mechanism);
//! * [`hooks::PyveneLike`] — declarative intervention-scheme configs
//!   compiled into hooks (an abstraction layer over the same hooks);
//! * [`tlens::TlensLike`] — performs a real whole-model weight-format
//!   conversion pass at load time (layernorm folding, writing-weight
//!   recentering, [in,out]→[out,in] transposes), which is exactly why
//!   TransformerLens setup is ~3× in the paper's Table 1;
//! * [`petals`] — the Petals-style distributed swarm (Fig. 6c): layer
//!   servers hold the blocks, the client holds embed/unembed, and every
//!   client-side intervention ships hidden states across the WAN.
//!
//! All mechanisms are cross-validated to produce identical patching
//! numerics (`rust/tests/baselines_integration.rs`); the benchmarks then
//! measure only their architectural costs.

pub mod hooks;
pub mod petals;
pub mod tlens;

use anyhow::Result;

use crate::models::workload::IoiBatch;
use crate::tensor::Tensor;

/// A Table-1 "framework": something that can be set up for a model and
/// then run the standard activation-patching workload.
pub trait Framework: Sized {
    fn name(&self) -> &'static str;

    /// Cold setup: weights from disk, device upload, executable
    /// compilation, plus any framework-specific preprocessing.
    fn setup(artifacts: &std::path::Path, model: &str) -> Result<Self>;

    /// The standard intervention workload: one batch of IOI examples,
    /// source-row hidden state patched into the base row at `layer`,
    /// returning per-example logit differences.
    fn activation_patch(&self, batch: &IoiBatch, layer: usize) -> Result<Tensor>;
}

/// Shared patching recipe over interleaved rows
/// `[src_0, base_0, src_1, base_1, ...]`: copy each source row's
/// last-token hidden state at `layer` into its base row. Every framework
/// funnels into this so numerics are identical by construction and only
/// the mechanism differs.
pub fn patch_rows(t: &mut Tensor, seq: usize) {
    let rows = t.dims()[0];
    // the last-token hidden state of row i is one contiguous block of
    // `numel / (rows·seq)` elements: patch by memcpy, no slice tensors
    let row_elems = t.numel() / rows;
    let d = row_elems / seq;
    let last = (seq - 1) * d;
    let data = t.data_mut();
    let mut i = 0;
    while i + 1 < rows {
        let src = i * row_elems + last;
        let dst = (i + 1) * row_elems + last;
        data.copy_within(src..src + d, dst);
        i += 2;
    }
}

/// Per-example target-vs-foil logit diffs for the base rows of an
/// interleaved batch.
pub fn base_row_logit_diffs(logits: &Tensor, batch: &IoiBatch) -> Tensor {
    let seq = batch.seq;
    let vocab = *logits.dims().last().unwrap();
    let data: Vec<f32> = batch
        .examples
        .iter()
        .enumerate()
        .map(|(i, e)| {
            let row = 2 * i + 1;
            let base = row * seq * vocab + (seq - 1) * vocab;
            logits.data()[base + e.target] - logits.data()[base + e.foil]
        })
        .collect();
    Tensor::new(&[batch.len()], data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn patch_rows_copies_even_into_odd() {
        let mut t = Tensor::iota(&[4, 3]);
        let before = t.clone();
        patch_rows(&mut t, 3);
        // row 1 last element becomes row 0's, row 3 becomes row 2's
        assert_eq!(t.at(&[1, 2]), before.at(&[0, 2]));
        assert_eq!(t.at(&[3, 2]), before.at(&[2, 2]));
        // non-last tokens untouched
        assert_eq!(t.at(&[1, 0]), before.at(&[1, 0]));
    }
}
