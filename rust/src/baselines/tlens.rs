//! TransformerLens-like mechanism: weight-format standardization at load.
//!
//! The paper's Table 1 finds TransformerLens setup ≈3× slower than the
//! other libraries and attributes it to "preprocessing steps to convert
//! weights into a standardized format across different models" (§4 fn 3).
//! We implement that preprocessing for real rather than sleeping:
//!
//! 1. **LayerNorm folding** (`fold_ln`): the LN gain is folded into the
//!    following weight matrix (`W ← diag(g)·W`), and the gain reset to 1 —
//!    TransformerLens's `fold_ln=True`;
//! 2. **Writing-weight centering** (`center_writing_weights`): outputs of
//!    matrices that write to the residual stream are mean-centered per
//!    input row;
//! 3. **Convention transposes**: HuggingFace's `[in, out]` weights are
//!    rearranged to TL's `[out, in]` head-indexed layout and back (the
//!    einsum-rearrange cost without keeping the layout, since our
//!    executables expect the original convention).
//!
//! Folding LN gains would change numerics against an executable that also
//! applies the gain, so after the measured conversion the *original*
//! weights are what get uploaded — preserving cross-framework numeric
//! equality while paying the true preprocessing cost, which is the
//! quantity Table 1 measures.

use std::path::Path;

use anyhow::Result;

use crate::models::workload::IoiBatch;
use crate::models::{ModelRunner, ModelWeights};
use crate::tensor::Tensor;

use super::{base_row_logit_diffs, patch_rows, Framework};

/// One layer's standardized-format weights (the artifact of conversion).
pub struct StandardizedLayer {
    /// LN-folded attention weights, `[out, in]` convention.
    pub wq_folded: Tensor,
    pub wk_folded: Tensor,
    pub wv_folded: Tensor,
    /// Centered + transposed writing weights.
    pub wo_centered: Tensor,
    pub w2_centered: Tensor,
    /// Folded MLP read-in.
    pub w1_folded: Tensor,
}

/// Fold an LN gain vector into the rows of a following matrix:
/// `W'[i, j] = g[i] · W[i, j]`.
pub fn fold_gain(gain: &Tensor, w: &Tensor) -> Tensor {
    assert_eq!(gain.numel(), w.dims()[0]);
    let (rows, cols) = (w.dims()[0], w.dims()[1]);
    let mut out = w.clone();
    for i in 0..rows {
        let g = gain.data()[i];
        for j in 0..cols {
            let off = i * cols + j;
            out.data_mut()[off] *= g;
        }
    }
    out
}

/// Mean-center each input row's contribution to the residual stream:
/// `W'[i, :] = W[i, :] - mean_j W[i, j]` (TL's center_writing_weights).
pub fn center_writing(w: &Tensor) -> Tensor {
    let (rows, cols) = (w.dims()[0], w.dims()[1]);
    let mut out = w.clone();
    for i in 0..rows {
        let row = &w.data()[i * cols..(i + 1) * cols];
        let mean: f32 = row.iter().sum::<f32>() / cols as f32;
        for j in 0..cols {
            out.data_mut()[i * cols + j] -= mean;
        }
    }
    out
}

/// Perform the full standardization pass over a model's weights. The
/// result is returned (and its cost is what Table 1's setup column sees),
/// but the runner keeps the original convention the executables expect.
pub fn standardize(weights: &ModelWeights, n_layers: usize) -> Vec<StandardizedLayer> {
    (0..n_layers)
        .map(|i| {
            let w = &weights.modules[&format!("layer.{i}")];
            let (ln1_g, wq, wk, wv, wo) = (&w[0], &w[2], &w[3], &w[4], &w[5]);
            let (ln2_g, w1, w2) = (&w[7], &w[9], &w[11]);
            StandardizedLayer {
                // fold_ln + convention transpose (and back for parity)
                wq_folded: fold_gain(ln1_g, wq).transpose2().transpose2(),
                wk_folded: fold_gain(ln1_g, wk).transpose2().transpose2(),
                wv_folded: fold_gain(ln1_g, wv).transpose2().transpose2(),
                wo_centered: center_writing(wo).transpose2(),
                w1_folded: fold_gain(ln2_g, w1),
                w2_centered: center_writing(w2).transpose2(),
            }
        })
        .collect()
}

/// TransformerLens-like framework state.
pub struct TlensLike {
    runner: ModelRunner,
    /// The standardized weights (kept so the conversion isn't dead code —
    /// TL exposes these as `blocks.*.attn.W_Q` etc.).
    pub standardized: Vec<StandardizedLayer>,
}

impl TlensLike {
    pub fn runner(&self) -> &ModelRunner {
        &self.runner
    }
}

impl Framework for TlensLike {
    fn name(&self) -> &'static str {
        "tlens"
    }

    fn setup(artifacts: &Path, model: &str) -> Result<TlensLike> {
        let runner = ModelRunner::load_cold(artifacts, model)?;
        // the distinguishing cost: whole-model weight standardization
        let standardized = standardize(&runner.weights, runner.manifest.n_layers);
        runner.precompile_forward()?;
        Ok(TlensLike { runner, standardized })
    }

    fn activation_patch(&self, batch: &IoiBatch, layer: usize) -> Result<Tensor> {
        // TL's run_with_hooks is the same closure-hook mechanism
        let tokens = batch.interleaved_tokens();
        let (padded, _) = self.runner.pad_tokens(&tokens)?;
        let seq = self.runner.manifest.seq;
        struct H {
            point: String,
            seq: usize,
        }
        impl crate::models::Hooks for H {
            fn wants(&self, p: &str) -> bool {
                p == self.point
            }
            fn on_output(&mut self, _p: &str, t: &mut Tensor) -> bool {
                patch_rows(t, self.seq);
                true
            }
        }
        let logits = self.runner.forward(
            &padded,
            &mut H { point: format!("layer.{layer}"), seq },
        )?;
        Ok(base_row_logit_diffs(&logits, batch))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fold_gain_scales_rows() {
        let g = Tensor::new(&[2], vec![2.0, 3.0]);
        let w = Tensor::iota(&[2, 2]);
        let f = fold_gain(&g, &w);
        assert_eq!(f.data(), &[0.0, 2.0, 6.0, 9.0]);
    }

    #[test]
    fn center_writing_zeroes_row_means() {
        let w = Tensor::new(&[2, 3], vec![1.0, 2.0, 3.0, 10.0, 10.0, 10.0]);
        let c = center_writing(&w);
        for i in 0..2 {
            let row = &c.data()[i * 3..(i + 1) * 3];
            let mean: f32 = row.iter().sum::<f32>() / 3.0;
            assert!(mean.abs() < 1e-6);
        }
    }
}
