//! Petals-like distributed inference (Borzunov et al. 2023), for the
//! Fig. 6c comparison.
//!
//! Architecture (paper §3.3 + Fig. 5 right): transformer *blocks* live on
//! swarm servers; the client holds the embedding and unembedding locally.
//! Standard inference ships token embeddings up and final hidden states
//! back. Crucially, Petals does **not** support server-side interventions:
//! a client-side intervention at layer ℓ forces the swarm to return the
//! layer-ℓ hidden state to the client, wait for the modified state, and
//! resume — two extra WAN transfers of a full hidden tensor per
//! intervention, which is exactly the cost NDIF's server-side intervention
//! graphs avoid.
//!
//! The swarm's compute runs in-process on the shared runtime (the paper's
//! private-instance comparison also used one machine); all client↔swarm
//! payloads are charged to a [`NetSim`] link at their true byte sizes.

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use crate::models::ModelRunner;
use crate::netsim::NetSim;
use crate::runtime::DeviceTensor;
use crate::tensor::Tensor;

/// A private Petals-style swarm hosting one model's blocks.
pub struct PetalsSwarm {
    runner: Arc<ModelRunner>,
    /// client ↔ swarm WAN (the paper measured ≈60 MB/s).
    pub link: NetSim,
}

impl PetalsSwarm {
    /// Start a private swarm: blocks preloaded server-side (as in a real
    /// swarm, joining is cheap for clients).
    pub fn start(artifacts: &Path, model: &str, link: NetSim) -> Result<PetalsSwarm> {
        let runner = Arc::new(ModelRunner::load(artifacts, model)?);
        Ok(PetalsSwarm { runner, link })
    }

    pub fn runner(&self) -> &Arc<ModelRunner> {
        &self.runner
    }

    fn hidden_bytes(&self, batch: usize) -> usize {
        self.runner.manifest.hidden_bytes(batch)
    }

    /// Client-side embedding (client holds wte/wpe).
    fn client_embed(&self, tokens: &Tensor) -> Result<Tensor> {
        let b = tokens.dims()[0];
        let exe = self.runner.executable("embed", b)?;
        let w = self.runner.weight_buffers("embed")?;
        let td = self.runner.engine().upload(tokens)?;
        let mut args: Vec<&DeviceTensor> = vec![&td];
        args.extend(w.iter());
        exe.run(&args, &self.runner.manifest.output_dims("embed", b))?
            .download()
    }

    /// Server-side: run blocks `[from, to)` over a hidden state.
    fn server_blocks(&self, x: &Tensor, from: usize, to: usize) -> Result<Tensor> {
        let b = x.dims()[0];
        let exe = self.runner.executable("layer", b)?;
        let out_dims = self.runner.manifest.output_dims("layer", b);
        let mut dev = self.runner.engine().upload(x)?;
        for i in from..to {
            let w = self.runner.weight_buffers(&format!("layer.{i}"))?;
            let mut args: Vec<&DeviceTensor> = vec![&dev];
            args.extend(w.iter());
            dev = exe.run(&args, &out_dims)?;
        }
        dev.download()
    }

    /// Client-side unembedding.
    fn client_lm_head(&self, x: &Tensor) -> Result<Tensor> {
        let b = x.dims()[0];
        let exe = self.runner.executable("lm_head", b)?;
        let w = self.runner.weight_buffers("lm_head")?;
        let xd = self.runner.engine().upload(x)?;
        let mut args: Vec<&DeviceTensor> = vec![&xd];
        args.extend(w.iter());
        exe.run(&args, &self.runner.manifest.output_dims("lm_head", b))?
            .download()
    }

    /// Standard remote inference: embeddings up, final hidden states
    /// down, unembed locally. Returns the final hidden state (what the
    /// paper's Fig. 6c "standard inference" comparison returns from both
    /// systems for fairness).
    pub fn infer_hidden(&self, tokens: &Tensor) -> Result<Tensor> {
        let n = self.runner.manifest.n_layers;
        let b = tokens.dims()[0];
        let x = self.client_embed(tokens)?;
        self.link.send(self.hidden_bytes(b)); // embeddings up
        let h = self.server_blocks(&x, 0, n)?;
        self.link.send(self.hidden_bytes(b)); // final hidden down
        Ok(h)
    }

    /// Standard inference through to logits (unembedded client-side).
    pub fn infer(&self, tokens: &Tensor) -> Result<Tensor> {
        let h = self.infer_hidden(tokens)?;
        self.client_lm_head(&h)
    }

    /// Client-side intervention at `layer`: the swarm pauses there, ships
    /// the hidden state to the client, applies the client's modification,
    /// and resumes — the extra two WAN hidden-state transfers that make
    /// Petals interventions expensive (Fig. 6c).
    pub fn patched_infer(
        &self,
        tokens: &Tensor,
        layer: usize,
        mut f: impl FnMut(&mut Tensor),
    ) -> Result<Tensor> {
        let n = self.runner.manifest.n_layers;
        let b = tokens.dims()[0];
        assert!(layer < n);
        let x = self.client_embed(tokens)?;
        self.link.send(self.hidden_bytes(b)); // embeddings up
        let mut h = self.server_blocks(&x, 0, layer + 1)?;
        self.link.send(self.hidden_bytes(b)); // hidden at ℓ down to client
        f(&mut h); // client-side modification
        self.link.send(self.hidden_bytes(b)); // modified hidden back up
        let h = self.server_blocks(&h, layer + 1, n)?;
        self.link.send(self.hidden_bytes(b)); // final hidden down
        self.client_lm_head(&h) // metric computed client-side
    }
}
