//! Host-side optimizers for client-driven training loops.
//!
//! The paper's Code Examples 5 and 8 train parameters (LoRA adapters,
//! linear probes) against remotely-fetched activations. The activations
//! come back through intervention graphs; the parameter updates run on the
//! researcher's side. These optimizers power `examples/probe_training.rs`
//! (the Code Example 8 analog).

use super::Tensor;

/// Plain SGD with optional momentum.
pub struct Sgd {
    pub lr: f32,
    pub momentum: f32,
    velocity: Vec<Tensor>,
}

impl Sgd {
    pub fn new(lr: f32, momentum: f32) -> Sgd {
        Sgd { lr, momentum, velocity: Vec::new() }
    }

    /// Apply one step; `params` and `grads` are parallel slices.
    pub fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        assert_eq!(params.len(), grads.len());
        if self.velocity.is_empty() {
            self.velocity = params.iter().map(|p| Tensor::zeros(p.dims())).collect();
        }
        let (lr, momentum) = (self.lr, self.momentum);
        for ((p, g), v) in params.iter_mut().zip(grads).zip(&mut self.velocity) {
            assert_eq!(p.dims(), g.dims());
            if momentum == 0.0 {
                // velocity is identically the gradient: one fused axpy
                p.scale_add_assign(-lr, g);
                v.data_mut().copy_from_slice(g.data());
                continue;
            }
            let pd = p.data_mut();
            let gd = g.data();
            let vd = v.data_mut();
            for ((pv, &gv), vv) in pd.iter_mut().zip(gd).zip(vd.iter_mut()) {
                let vel = momentum * *vv + gv;
                *vv = vel;
                *pv -= lr * vel;
            }
        }
    }
}

/// Adam (Kingma & Ba), the optimizer of the paper's probe example.
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    t: i32,
    m: Vec<Tensor>,
    v: Vec<Tensor>,
}

impl Adam {
    pub fn new(lr: f32) -> Adam {
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, t: 0, m: Vec::new(), v: Vec::new() }
    }

    pub fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        assert_eq!(params.len(), grads.len());
        if self.m.is_empty() {
            self.m = params.iter().map(|p| Tensor::zeros(p.dims())).collect();
            self.v = params.iter().map(|p| Tensor::zeros(p.dims())).collect();
        }
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t);
        let b2t = 1.0 - self.beta2.powi(self.t);
        let (lr, b1, b2, eps) = (self.lr, self.beta1, self.beta2, self.eps);
        for (((p, g), m), v) in params.iter_mut().zip(grads).zip(&mut self.m).zip(&mut self.v) {
            let pd = p.data_mut();
            let gd = g.data();
            let md = m.data_mut();
            let vd = v.data_mut();
            for (((pv, &gi), mv), vv) in
                pd.iter_mut().zip(gd).zip(md.iter_mut()).zip(vd.iter_mut())
            {
                let mi = b1 * *mv + (1.0 - b1) * gi;
                let vi = b2 * *vv + (1.0 - b2) * gi * gi;
                *mv = mi;
                *vv = vi;
                let mhat = mi / b1t;
                let vhat = vi / b2t;
                *pv -= lr * mhat / (vhat.sqrt() + eps);
            }
        }
    }
}

/// Mean-squared-error loss and its gradient w.r.t. `pred`.
pub fn mse(pred: &Tensor, target: &Tensor) -> (f32, Tensor) {
    assert_eq!(pred.dims(), target.dims());
    let n = pred.numel() as f32;
    let mut grad = Tensor::zeros(pred.dims());
    let gd = grad.data_mut();
    let mut loss = 0.0f32;
    for ((gv, &pv), &tv) in gd.iter_mut().zip(pred.data()).zip(target.data()) {
        let d = pv - tv;
        loss += d * d;
        *gv = 2.0 * d / n;
    }
    (loss / n, grad)
}

/// A linear probe `y = x @ w + b` trained with backprop on the host.
pub struct LinearProbe {
    pub w: Tensor,
    pub b: Tensor,
}

impl LinearProbe {
    pub fn new(d_in: usize, d_out: usize, rng: &mut crate::util::Prng) -> LinearProbe {
        let mut w = Tensor::zeros(&[d_in, d_out]);
        rng.fill_uniform_sym(w.data_mut(), 0.05);
        LinearProbe { w, b: Tensor::zeros(&[d_out]) }
    }

    /// Forward over `[rows, d_in]`.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        x.matmul(&self.w).add(&self.b)
    }

    /// One MSE training step; returns the loss.
    pub fn train_step(&mut self, x: &Tensor, target: &Tensor, opt: &mut Adam) -> f32 {
        let pred = self.forward(x);
        let (loss, gout) = mse(&pred, target);
        // grads: dW = xᵀ·g ; db = Σ_rows g
        let gw = x.transpose2().matmul(&gout);
        let gb = gout.mean_axis(0).scale(gout.dims()[0] as f32);
        // hand the parameters to the optimizer by move (scalar placeholders
        // are one element each) instead of cloning full weight matrices
        let mut params = [
            std::mem::replace(&mut self.w, Tensor::scalar(0.0)),
            std::mem::replace(&mut self.b, Tensor::scalar(0.0)),
        ];
        opt.step(&mut params, &[gw, gb]);
        let [w, b] = params;
        self.w = w;
        self.b = b;
        loss
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn sgd_reduces_quadratic() {
        // minimize ||p||² with grad 2p
        let mut p = vec![Tensor::new(&[3], vec![1.0, -2.0, 3.0])];
        let mut opt = Sgd::new(0.1, 0.0);
        for _ in 0..100 {
            let g = vec![p[0].scale(2.0)];
            opt.step(&mut p, &g);
        }
        assert!(p[0].norm() < 1e-3, "{:?}", p[0].data());
    }

    #[test]
    fn sgd_momentum_accelerates() {
        let run = |mom: f32| {
            let mut p = vec![Tensor::new(&[1], vec![10.0])];
            let mut opt = Sgd::new(0.01, mom);
            for _ in 0..50 {
                let g = vec![p[0].scale(2.0)];
                opt.step(&mut p, &g);
            }
            p[0].data()[0].abs()
        };
        assert!(run(0.9) < run(0.0));
    }

    #[test]
    fn adam_reduces_quadratic() {
        let mut p = vec![Tensor::new(&[4], vec![5.0, -5.0, 2.0, -0.5])];
        let mut opt = Adam::new(0.1);
        for _ in 0..300 {
            let g = vec![p[0].scale(2.0)];
            opt.step(&mut p, &g);
        }
        assert!(p[0].norm() < 1e-2, "{:?}", p[0].data());
    }

    #[test]
    fn mse_and_grad() {
        let pred = Tensor::new(&[2], vec![1.0, 3.0]);
        let target = Tensor::new(&[2], vec![0.0, 3.0]);
        let (loss, g) = mse(&pred, &target);
        assert!((loss - 0.5).abs() < 1e-6);
        assert_eq!(g.data(), &[1.0, 0.0]);
    }

    #[test]
    fn probe_learns_identity_map() {
        let mut rng = Prng::new(42);
        let mut probe = LinearProbe::new(4, 4, &mut rng);
        let mut opt = Adam::new(0.05);
        // target function: y = x (identity); train on random batches
        let mut last = f32::MAX;
        for step in 0..400 {
            let x = Tensor::from_randn(&[16, 4], &mut rng, 1.0);
            let loss = probe.train_step(&x, &x, &mut opt);
            if step == 0 {
                last = loss;
            }
        }
        let x = Tensor::from_randn(&[8, 4], &mut rng, 1.0);
        let (final_loss, _) = mse(&probe.forward(&x), &x);
        assert!(final_loss < last * 0.05, "{final_loss} vs initial {last}");
    }
}
