//! Host tensor engine.
//!
//! The intervention-graph interpreter manipulates activations *between*
//! AOT-compiled module executions: slicing, assignment, arithmetic,
//! softmax/argmax, logit-diff metrics, and the all-reduce used by the
//! simulated tensor-parallel shards. Those ops run on host buffers, so the
//! crate carries a small dense row-major `f32` tensor engine (token-id
//! tensors use `i64` stored losslessly in `f32` for vocab sizes ≪ 2^24,
//! which holds for every simulated config).
//!
//! The kernels on the request path are written for throughput (§Perf):
//! matmul is cache-blocked over a packed RHS and row-parallel across the
//! shared compute pool, slicing/broadcasting walk precomputed strides with
//! contiguous-run memcpy fast paths, and the interpreter hot loops use
//! in-place variants so hidden states are not cloned per op. The seed
//! per-element kernels are retained in [`ops::naive`] as oracles; see the
//! [`ops`] module docs for the blocking/packing scheme and the parity
//! contract.

mod shape;
pub mod ops;
pub mod optim;

pub use ops::{logit_diff, Range1};
pub use shape::Shape;

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Shape,
    data: Vec<f32>,
}

impl Tensor {
    /// Construct from raw data; panics if the element count mismatches.
    ///
    /// Every construction path funnels through here (or the sized
    /// variants below), so these are the profiler's allocation-accounting
    /// sites: when [`crate::obs::profile`] is armed on this thread, the
    /// buffer's bytes are attributed to the op being recorded. Disarmed,
    /// the note is a single thread-local check.
    pub fn new(dims: &[usize], data: Vec<f32>) -> Tensor {
        let shape = Shape::new(dims);
        assert_eq!(shape.numel(), data.len(), "shape {dims:?} vs {} elems", data.len());
        crate::obs::profile::note_alloc(data.len() * 4);
        Tensor { shape, data }
    }

    pub fn zeros(dims: &[usize]) -> Tensor {
        let shape = Shape::new(dims);
        let n = shape.numel();
        crate::obs::profile::note_alloc(n * 4);
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn full(dims: &[usize], v: f32) -> Tensor {
        let shape = Shape::new(dims);
        let n = shape.numel();
        crate::obs::profile::note_alloc(n * 4);
        Tensor { shape, data: vec![v; n] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor::new(&[], vec![v])
    }

    /// Sequential values 0..n reshaped — handy in tests.
    pub fn iota(dims: &[usize]) -> Tensor {
        let shape = Shape::new(dims);
        let n = shape.numel();
        crate::obs::profile::note_alloc(n * 4);
        Tensor { shape, data: (0..n).map(|i| i as f32).collect() }
    }

    pub fn from_randn(dims: &[usize], prng: &mut crate::util::Prng, std: f32) -> Tensor {
        let mut t = Tensor::zeros(dims);
        prng.fill_normal(&mut t.data, std);
        t
    }

    pub fn dims(&self) -> &[usize] {
        self.shape.dims()
    }

    pub fn shape(&self) -> &Shape {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.rank()
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Scalar extraction; panics unless numel == 1.
    pub fn item(&self) -> f32 {
        assert_eq!(self.numel(), 1, "item() on non-scalar tensor");
        self.data[0]
    }

    /// Reshape without copying; panics if element counts differ.
    pub fn reshape(mut self, dims: &[usize]) -> Tensor {
        let s = Shape::new(dims);
        assert_eq!(s.numel(), self.numel(), "reshape {:?} -> {:?}", self.dims(), dims);
        self.shape = s;
        self
    }

    /// Element access by multi-index.
    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.shape.offset(idx)]
    }

    pub fn set_at(&mut self, idx: &[usize], v: f32) {
        let o = self.shape.offset(idx);
        self.data[o] = v;
    }

    /// Max absolute difference vs another tensor (shape-checked).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.dims(), other.dims());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Approximate equality within tolerance.
    pub fn allclose(&self, other: &Tensor, tol: f32) -> bool {
        self.dims() == other.dims() && self.max_abs_diff(other) <= tol
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::iota(&[2, 3]);
        assert_eq!(t.dims(), &[2, 3]);
        assert_eq!(t.at(&[0, 0]), 0.0);
        assert_eq!(t.at(&[1, 2]), 5.0);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::iota(&[2, 3]).reshape(&[3, 2]);
        assert_eq!(t.at(&[2, 1]), 5.0);
    }

    #[test]
    #[should_panic]
    fn reshape_mismatch_panics() {
        let _ = Tensor::iota(&[2, 3]).reshape(&[4, 2]);
    }

    #[test]
    fn scalar_item() {
        assert_eq!(Tensor::scalar(2.5).item(), 2.5);
    }

    #[test]
    fn allclose_checks_shape_and_values() {
        let a = Tensor::iota(&[2, 2]);
        let mut b = a.clone();
        assert!(a.allclose(&b, 0.0));
        b.set_at(&[0, 1], 99.0);
        assert!(!a.allclose(&b, 1.0));
        let c = Tensor::iota(&[4]);
        assert!(!a.allclose(&c, 100.0));
    }
}
