//! Shapes and row-major stride arithmetic.

/// A tensor shape: dimensions plus cached row-major strides.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Shape {
    dims: Vec<usize>,
    strides: Vec<usize>,
}

impl Shape {
    pub fn new(dims: &[usize]) -> Shape {
        let mut strides = vec![1usize; dims.len()];
        for i in (0..dims.len().saturating_sub(1)).rev() {
            strides[i] = strides[i + 1] * dims[i + 1];
        }
        Shape { dims: dims.to_vec(), strides }
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn strides(&self) -> &[usize] {
        &self.strides
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }

    /// Flat offset of a multi-index (bounds-checked).
    pub fn offset(&self, idx: &[usize]) -> usize {
        assert_eq!(idx.len(), self.dims.len(), "index rank mismatch");
        let mut off = 0;
        for (i, (&ix, (&d, &s))) in idx.iter().zip(self.dims.iter().zip(&self.strides)).enumerate() {
            assert!(ix < d, "index {ix} out of bounds for dim {i} (size {d})");
            off += ix * s;
        }
        off
    }

    /// Inverse of `offset`: multi-index of a flat position.
    pub fn unravel(&self, mut flat: usize) -> Vec<usize> {
        let mut idx = vec![0; self.rank()];
        for i in 0..self.rank() {
            idx[i] = flat / self.strides[i];
            flat %= self.strides[i];
        }
        idx
    }

    /// Strides for walking this shape's data with a multi-index of the
    /// broadcast result `out_dims` (numpy rules): size-1 dims and missing
    /// leading dims get stride 0, so they re-read the same element instead
    /// of requiring a materialized expansion.
    pub fn broadcast_strides(&self, out_dims: &[usize]) -> Vec<usize> {
        assert!(self.rank() <= out_dims.len(), "broadcast to lower rank");
        let lead = out_dims.len() - self.rank();
        let mut out = vec![0usize; out_dims.len()];
        for i in 0..self.rank() {
            let d = self.dims[i];
            assert!(
                d == out_dims[lead + i] || d == 1,
                "dim {i} (size {d}) not broadcastable to {}",
                out_dims[lead + i]
            );
            out[lead + i] = if d == 1 && out_dims[lead + i] != 1 { 0 } else { self.strides[i] };
        }
        out
    }

    /// Broadcast two shapes (numpy rules); None if incompatible.
    pub fn broadcast(a: &[usize], b: &[usize]) -> Option<Vec<usize>> {
        let rank = a.len().max(b.len());
        let mut out = vec![0usize; rank];
        for i in 0..rank {
            let da = if i < rank - a.len() { 1 } else { a[i - (rank - a.len())] };
            let db = if i < rank - b.len() { 1 } else { b[i - (rank - b.len())] };
            out[i] = if da == db {
                da
            } else if da == 1 {
                db
            } else if db == 1 {
                da
            } else {
                return None;
            };
        }
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_row_major() {
        let s = Shape::new(&[2, 3, 4]);
        assert_eq!(s.strides(), &[12, 4, 1]);
        assert_eq!(s.numel(), 24);
    }

    #[test]
    fn offset_unravel_inverse() {
        let s = Shape::new(&[3, 4, 5]);
        for flat in 0..s.numel() {
            let idx = s.unravel(flat);
            assert_eq!(s.offset(&idx), flat);
        }
    }

    #[test]
    fn scalar_shape() {
        let s = Shape::new(&[]);
        assert_eq!(s.numel(), 1);
        assert_eq!(s.offset(&[]), 0);
    }

    #[test]
    #[should_panic]
    fn offset_out_of_bounds() {
        Shape::new(&[2, 2]).offset(&[2, 0]);
    }

    #[test]
    fn broadcast_strides_zero_out_expanded_dims() {
        // [3] broadcast into [2, 3]: leading dim is virtual (stride 0)
        let s = Shape::new(&[3]);
        assert_eq!(s.broadcast_strides(&[2, 3]), vec![0, 1]);
        // [2, 1] broadcast into [2, 4]: size-1 dim re-reads (stride 0)
        let s = Shape::new(&[2, 1]);
        assert_eq!(s.broadcast_strides(&[2, 4]), vec![1, 0]);
        // scalar broadcast anywhere: all strides 0
        let s = Shape::new(&[]);
        assert_eq!(s.broadcast_strides(&[2, 2]), vec![0, 0]);
        // exact match: native strides
        let s = Shape::new(&[2, 3]);
        assert_eq!(s.broadcast_strides(&[2, 3]), vec![3, 1]);
    }

    #[test]
    fn broadcast_rules() {
        assert_eq!(Shape::broadcast(&[2, 3], &[3]), Some(vec![2, 3]));
        assert_eq!(Shape::broadcast(&[2, 1], &[1, 4]), Some(vec![2, 4]));
        assert_eq!(Shape::broadcast(&[], &[5]), Some(vec![5]));
        assert_eq!(Shape::broadcast(&[2, 3], &[4]), None);
        assert_eq!(Shape::broadcast(&[2], &[2]), Some(vec![2]));
    }
}
