//! Tensor operations used by the intervention-graph interpreter and the
//! shard all-reduce.
//!
//! # Kernel architecture (§Perf)
//!
//! The ops on the request path are written for throughput; the seed
//! per-element implementations are retained verbatim in [`naive`] as
//! oracles for the property tests (`rust/tests/props.rs`) and as the
//! baseline for `benches/kernels.rs`.
//!
//! **Matmul** is a cache-blocked dot-product kernel over a packed RHS:
//! `B [k, n]` is transposed once into `Bt [n, k]` so both operands of
//! every inner product are contiguous (unit-stride, autovectorizable).
//! The kernel walks blocks of [`MATMUL_ROW_BLOCK`] LHS rows against one
//! `Bt` row at a time, so each packed row is streamed once per row-block
//! instead of once per output element. Row chunks are distributed across
//! the shared lazy compute pool ([`crate::threadpool::compute_pool`],
//! sized from `NNSCOPE_COMPUTE_THREADS` or `available_parallelism`);
//! products below [`MATMUL_SEQ_CUTOFF`] multiply-adds (and single-row
//! products, which cannot amortize the pack) take a direct sequential
//! axpy path with no packing. The 8-lane accumulator reassociates the
//! reduction, so matmul parity with [`naive::matmul`] is tolerance-based
//! (≤ 1e-4 max-abs-diff on unit-scale data); everything else is
//! bit-exact.
//!
//! **Slicing and broadcasting** never materialize per-element index
//! vectors. A slice is decomposed by [`plan_slice`] into an innermost
//! contiguous run (trailing whole dims fold into one `copy_from_slice` /
//! `fill` block) plus a precomputed-stride odometer over the remaining
//! outer dims; broadcasting walks both operands with
//! [`Shape::broadcast_strides`] (stride 0 on expanded dims) and a shared
//! odometer.
//!
//! **In-place / fused variants** (`gelu_inplace`, `scale_inplace`,
//! `softmax_last_inplace`, `scale_add_assign`) let the interpreter and
//! runner hot loops transform activations without cloning full hidden
//! states; `softmax_last` / `argmax_last` / `gelu` split large-vocab rows
//! across the compute pool (rows are independent, so parallelism does not
//! change numerics).

use super::{Shape, Tensor};
use crate::threadpool;

/// Below this many multiply-adds a matmul runs on the calling thread —
/// pool dispatch costs more than it saves (≈ a 64×64×64 product).
const MATMUL_SEQ_CUTOFF: usize = 1 << 18;

/// LHS rows per block of the matmul kernel: one packed RHS row is
/// streamed once per block, while the block's LHS rows stay cache-hot.
const MATMUL_ROW_BLOCK: usize = 16;

/// Below this many elements, elementwise/row kernels run sequentially.
const PAR_MIN_ELEMS: usize = 1 << 15;

// ---------------------------------------------------------------------------
// Parallel dispatch helpers
// ---------------------------------------------------------------------------

/// The shared chunk-sizing heuristic for splitting `units` of work across
/// the compute pool: floor division (≥ `size` chunks, so the queue stays
/// balanced when chunks finish unevenly), at least one unit per chunk.
fn par_chunk_units(units: usize, pool: &threadpool::ThreadPool) -> usize {
    (units / pool.size()).max(1)
}

/// Apply `f` to `data` in chunks that are multiples of `granule` elements
/// (the row boundary), in parallel across the compute pool when the input
/// is large enough to pay for dispatch. `granule` must divide `data.len()`.
fn par_chunks_mut(data: &mut [f32], granule: usize, f: impl Fn(&mut [f32]) + Send + Sync + Copy) {
    let pool = threadpool::compute_pool();
    if data.len() < PAR_MIN_ELEMS || pool.size() == 1 {
        f(data);
        return;
    }
    let units = data.len() / granule;
    let per = par_chunk_units(units, pool) * granule;
    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = data
        .chunks_mut(per)
        .map(|chunk| Box::new(move || f(chunk)) as Box<dyn FnOnce() + Send + '_>)
        .collect();
    pool.scoped(jobs);
}

// ---------------------------------------------------------------------------
// Elementwise with broadcasting
// ---------------------------------------------------------------------------

fn broadcast_binop(a: &Tensor, b: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
    if a.dims() == b.dims() {
        // fast path: no index arithmetic
        let data = a.data().iter().zip(b.data()).map(|(&x, &y)| f(x, y)).collect();
        return Tensor::new(a.dims(), data);
    }
    let out_dims = Shape::broadcast(a.dims(), b.dims())
        .unwrap_or_else(|| panic!("broadcast {:?} vs {:?}", a.dims(), b.dims()));
    // equal-dims was handled above, so the output has rank ≥ 1 here
    let rank = out_dims.len();
    let numel: usize = out_dims.iter().product();
    let mut data = Vec::with_capacity(numel);
    if numel == 0 {
        return Tensor::new(&out_dims, data);
    }
    let sa = a.shape().broadcast_strides(&out_dims);
    let sb = b.shape().broadcast_strides(&out_dims);
    let (ad, bd) = (a.data(), b.data());
    let inner = out_dims[rank - 1];
    let (ia, ib) = (sa[rank - 1], sb[rank - 1]);
    // odometer over dims 0..rank-1; the innermost dim is a tight loop
    let mut idx = vec![0usize; rank];
    let (mut oa, mut ob) = (0usize, 0usize);
    loop {
        match (ia, ib) {
            (1, 1) => {
                for i in 0..inner {
                    data.push(f(ad[oa + i], bd[ob + i]));
                }
            }
            (1, 0) => {
                let y = bd[ob];
                for i in 0..inner {
                    data.push(f(ad[oa + i], y));
                }
            }
            (0, 1) => {
                let x = ad[oa];
                for i in 0..inner {
                    data.push(f(x, bd[ob + i]));
                }
            }
            _ => {
                for i in 0..inner {
                    data.push(f(ad[oa + i * ia], bd[ob + i * ib]));
                }
            }
        }
        let mut d = rank - 1;
        loop {
            if d == 0 {
                return Tensor::new(&out_dims, data);
            }
            d -= 1;
            idx[d] += 1;
            oa += sa[d];
            ob += sb[d];
            if idx[d] < out_dims[d] {
                break;
            }
            oa -= sa[d] * out_dims[d];
            ob -= sb[d] * out_dims[d];
            idx[d] = 0;
        }
    }
}

fn gelu_slice(xs: &mut [f32]) {
    let c = (2.0f32 / std::f32::consts::PI).sqrt();
    for x in xs.iter_mut() {
        let v = *x;
        *x = 0.5 * v * (1.0 + (c * (v + 0.044715 * v * v * v)).tanh());
    }
}

impl Tensor {
    pub fn add(&self, other: &Tensor) -> Tensor {
        broadcast_binop(self, other, |a, b| a + b)
    }
    pub fn sub(&self, other: &Tensor) -> Tensor {
        broadcast_binop(self, other, |a, b| a - b)
    }
    pub fn mul(&self, other: &Tensor) -> Tensor {
        broadcast_binop(self, other, |a, b| a * b)
    }
    pub fn div(&self, other: &Tensor) -> Tensor {
        broadcast_binop(self, other, |a, b| a / b)
    }

    /// In-place scalar multiply.
    pub fn scale_inplace(&mut self, s: f32) {
        for v in self.data_mut().iter_mut() {
            *v *= s;
        }
    }

    pub fn scale(&self, s: f32) -> Tensor {
        let mut out = self.clone();
        out.scale_inplace(s);
        out
    }

    pub fn add_scalar(&self, s: f32) -> Tensor {
        let data = self.data().iter().map(|&x| x + s).collect();
        Tensor::new(self.dims(), data)
    }

    pub fn neg(&self) -> Tensor {
        self.scale(-1.0)
    }

    pub fn relu(&self) -> Tensor {
        let data = self.data().iter().map(|&x| x.max(0.0)).collect();
        Tensor::new(self.dims(), data)
    }

    /// tanh-approximation GELU, matching the model's MLP activation.
    pub fn gelu(&self) -> Tensor {
        let mut out = self.clone();
        out.gelu_inplace();
        out
    }

    /// In-place GELU — the interpreter's activation hot path. tanh is
    /// compute-bound, so large tensors are chunked across the compute pool.
    pub fn gelu_inplace(&mut self) {
        par_chunks_mut(self.data_mut(), 1, gelu_slice);
    }

    /// In-place add (same shape) — used by the shard all-reduce hot path.
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.dims(), other.dims());
        for (a, b) in self.data_mut().iter_mut().zip(other.data()) {
            *a += *b;
        }
    }

    /// Fused axpy `self += s · other` (same shape): one pass instead of a
    /// `scale` allocation followed by `add_assign` — the optimizer-update
    /// and weighted-all-reduce primitive.
    pub fn scale_add_assign(&mut self, s: f32, other: &Tensor) {
        assert_eq!(self.dims(), other.dims());
        for (a, &b) in self.data_mut().iter_mut().zip(other.data()) {
            *a += s * b;
        }
    }
}

// ---------------------------------------------------------------------------
// Slicing
// ---------------------------------------------------------------------------

/// A per-dimension slice `[start, stop)`; `stop == usize::MAX` means "end".
/// A negative-step or strided slice is not needed by the graph ops.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Range1 {
    pub start: usize,
    pub stop: usize,
}

impl Range1 {
    pub fn new(start: usize, stop: usize) -> Range1 {
        Range1 { start, stop }
    }
    pub fn all() -> Range1 {
        Range1 { start: 0, stop: usize::MAX }
    }
    pub fn one(i: usize) -> Range1 {
        Range1 { start: i, stop: i + 1 }
    }
    fn clamp(&self, dim: usize) -> (usize, usize) {
        let stop = if self.stop == usize::MAX { dim } else { self.stop };
        assert!(self.start <= stop && stop <= dim, "slice {self:?} out of bounds for dim {dim}");
        (self.start, stop)
    }
}

/// Precomputed walk for a multi-dimensional slice: an innermost contiguous
/// run (trailing dims taken whole fold into a single block, plus the
/// contiguous range of the first partial dim above them) and a stride
/// odometer over the remaining outer dims. Shared by `slice`,
/// `slice_assign`, and `slice_fill`, so a hidden-state row patch is one
/// `memcpy` instead of `d_model` scalar index computations.
struct SlicePlan {
    /// dims `[0, outer)` are walked by the odometer within their ranges.
    outer: usize,
    /// contiguous elements per visited offset.
    run: usize,
    /// flat offset of the slice's first element.
    start: usize,
    /// per-dim clamped `(start, stop)`.
    full: Vec<(usize, usize)>,
    /// source strides (owned, so callers can borrow their data mutably).
    strides: Vec<usize>,
    /// the slice's shape.
    out_dims: Vec<usize>,
    /// total elements in the slice.
    numel: usize,
}

fn plan_slice(shape: &Shape, ranges: &[Range1]) -> SlicePlan {
    let dims = shape.dims();
    assert!(ranges.len() <= dims.len());
    let mut full: Vec<(usize, usize)> = Vec::with_capacity(dims.len());
    for (i, &d) in dims.iter().enumerate() {
        let r = ranges.get(i).copied().unwrap_or_else(Range1::all);
        full.push(r.clamp(d));
    }
    let out_dims: Vec<usize> = full.iter().map(|&(s, e)| e - s).collect();
    let numel: usize = out_dims.iter().product();
    let strides = shape.strides().to_vec();
    // first dim (from the end) not taken whole bounds the contiguous run
    let mut k = dims.len();
    while k > 0 && full[k - 1] == (0, dims[k - 1]) {
        k -= 1;
    }
    let (run, start, outer) = if k == 0 {
        (shape.numel(), 0, 0)
    } else {
        let tail = strides[k - 1];
        ((full[k - 1].1 - full[k - 1].0) * tail, full[k - 1].0 * tail, k - 1)
    };
    let start =
        start + full[..outer].iter().zip(&strides).map(|(&(s, _), &st)| s * st).sum::<usize>();
    SlicePlan { outer, run, start, full, strides, out_dims, numel }
}

impl SlicePlan {
    /// Invoke `f(offset)` once per contiguous run, in row-major slice
    /// order; each run is `self.run` elements at `offset`.
    fn walk(&self, mut f: impl FnMut(usize)) {
        if self.numel == 0 {
            return;
        }
        let mut idx: Vec<usize> = self.full[..self.outer].iter().map(|&(s, _)| s).collect();
        let mut off = self.start;
        loop {
            f(off);
            let mut d = self.outer;
            loop {
                if d == 0 {
                    return;
                }
                d -= 1;
                idx[d] += 1;
                off += self.strides[d];
                if idx[d] < self.full[d].1 {
                    break;
                }
                off -= self.strides[d] * (self.full[d].1 - self.full[d].0);
                idx[d] = self.full[d].0;
            }
        }
    }
}

impl Tensor {
    /// Multi-dimensional slice. `ranges.len()` may be less than the rank;
    /// trailing dimensions are taken whole. The result keeps the sliced
    /// dimensions (no squeezing) — callers reshape if needed.
    pub fn slice(&self, ranges: &[Range1]) -> Tensor {
        let plan = plan_slice(self.shape(), ranges);
        let mut data = Vec::with_capacity(plan.numel);
        let src = self.data();
        plan.walk(|off| data.extend_from_slice(&src[off..off + plan.run]));
        Tensor::new(&plan.out_dims, data)
    }

    /// Assign `src` into the slice of `self` described by `ranges`
    /// (shape of `src` must equal the slice shape). This is the setter
    /// primitive: `layer.output[1, t, :] = v`.
    pub fn slice_assign(&mut self, ranges: &[Range1], src: &Tensor) {
        let plan = plan_slice(self.shape(), ranges);
        assert_eq!(
            &plan.out_dims[..],
            src.dims(),
            "slice_assign shape mismatch: slice {:?} vs src {:?}",
            plan.out_dims,
            src.dims()
        );
        let sd = src.data();
        let dst = self.data_mut();
        let mut spos = 0usize;
        plan.walk(|off| {
            dst[off..off + plan.run].copy_from_slice(&sd[spos..spos + plan.run]);
            spos += plan.run;
        });
    }

    /// Fill a slice with a constant (ablation setter), writing in place —
    /// no materialized constant tensor.
    pub fn slice_fill(&mut self, ranges: &[Range1], v: f32) {
        let plan = plan_slice(self.shape(), ranges);
        let dst = self.data_mut();
        plan.walk(|off| dst[off..off + plan.run].fill(v));
    }

    /// Gather rows along an axis by integer indices.
    pub fn index_select(&self, axis: usize, indices: &[usize]) -> Tensor {
        assert!(axis < self.rank());
        let dims = self.dims();
        let d = dims[axis];
        let inner: usize = dims[axis + 1..].iter().product();
        let outer: usize = dims[..axis].iter().product();
        let mut out_dims = dims.to_vec();
        out_dims[axis] = indices.len();
        let src = self.data();
        let mut data = Vec::with_capacity(outer * indices.len() * inner);
        for o in 0..outer {
            let base = o * d * inner;
            for &j in indices {
                assert!(j < d, "index {j} out of bounds for dim {axis} (size {d})");
                data.extend_from_slice(&src[base + j * inner..base + (j + 1) * inner]);
            }
        }
        Tensor::new(&out_dims, data)
    }
}

// ---------------------------------------------------------------------------
// Linear algebra & reductions
// ---------------------------------------------------------------------------

/// Unit-stride inner product with an 8-lane accumulator (autovectorizes).
/// Reassociates the reduction relative to a sequential sum.
#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    const LANES: usize = 8;
    debug_assert_eq!(a.len(), b.len());
    let split = a.len() - a.len() % LANES;
    let mut acc = [0.0f32; LANES];
    for (av, bv) in a[..split].chunks_exact(LANES).zip(b[..split].chunks_exact(LANES)) {
        for ((s, &x), &y) in acc.iter_mut().zip(av).zip(bv) {
            *s += x * y;
        }
    }
    let mut tail = 0.0f32;
    for (&x, &y) in a[split..].iter().zip(&b[split..]) {
        tail += x * y;
    }
    ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7])) + tail
}

/// Pack `b [k, n]` into its transpose `bt [n, k]` with square blocking so
/// both source rows and destination rows stay cache-resident.
fn pack_transposed(b: &[f32], k: usize, n: usize) -> Vec<f32> {
    const TB: usize = 32;
    let mut bt = vec![0.0f32; n * k];
    let mut i0 = 0;
    while i0 < k {
        let i1 = (i0 + TB).min(k);
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + TB).min(n);
            for i in i0..i1 {
                for j in j0..j1 {
                    bt[j * k + i] = b[i * n + j];
                }
            }
            j0 = j1;
        }
        i0 = i1;
    }
    bt
}

/// The small-product kernel: k-outer axpy straight over the un-packed
/// RHS — the seed formulation minus its `av == 0.0` branch. Below the
/// cutoff the O(k·n) pack would rival the product itself, so small and
/// single-row (vector × matrix) shapes must not pay it.
fn matmul_axpy(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize) {
    if k == 0 {
        return;
    }
    let rows = a.len() / k;
    for r in 0..rows {
        let arow = &a[r * k..(r + 1) * k];
        let orow = &mut out[r * n..(r + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// The blocked kernel: `out[r, j] = dot(a_row_r, bt_row_j)` for all rows
/// of the chunk. One `bt` row is streamed per [`MATMUL_ROW_BLOCK`] LHS
/// rows; the block's LHS rows stay in cache across the whole `j` sweep.
fn matmul_rows(a: &[f32], bt: &[f32], out: &mut [f32], k: usize, n: usize) {
    if k == 0 {
        return;
    }
    let rows = a.len() / k;
    let mut r0 = 0;
    while r0 < rows {
        let r1 = (r0 + MATMUL_ROW_BLOCK).min(rows);
        for j in 0..n {
            let bj = &bt[j * k..(j + 1) * k];
            for r in r0..r1 {
                out[r * n + j] = dot(&a[r * k..(r + 1) * k], bj);
            }
        }
        r0 = r1;
    }
}

impl Tensor {
    /// Matrix multiply. Supports 2-D × 2-D and batched N-D × 2-D (the last
    /// two axes of `self` contract with `other`). See the module docs for
    /// the blocking/packing scheme; agreement with [`naive::matmul`] is
    /// within reassociation tolerance (≤ 1e-4 on unit-scale data).
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(other.rank(), 2, "rhs of matmul must be 2-D");
        let (k2, n) = (other.dims()[0], other.dims()[1]);
        let k = *self.dims().last().expect("matmul on scalar");
        assert_eq!(k, k2, "contraction mismatch {k} vs {k2}");
        let rows: usize = self.numel() / k;
        let mut out = vec![0.0f32; rows * n];
        let a = self.data();
        let work = rows.saturating_mul(n).saturating_mul(k);
        if work < MATMUL_SEQ_CUTOFF || rows == 1 {
            // sequential small-size / single-row path: no pack, no
            // dispatch — the O(k·n) pack has nothing to amortize over
            matmul_axpy(a, other.data(), &mut out, k, n);
        } else {
            let pool = threadpool::compute_pool();
            let bt = pack_transposed(other.data(), k, n);
            if pool.size() == 1 {
                matmul_rows(a, &bt, &mut out, k, n);
            } else {
                // row-chunk parallelism: disjoint output row bands, shared
                // read-only A and packed B
                let per = par_chunk_units(rows, pool);
                let bts: &[f32] = &bt;
                let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = out
                    .chunks_mut(per * n)
                    .enumerate()
                    .map(|(ci, oc)| {
                        let ac = &a[ci * per * k..ci * per * k + (oc.len() / n) * k];
                        Box::new(move || matmul_rows(ac, bts, oc, k, n))
                            as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                pool.scoped(jobs);
            }
        }
        let mut out_dims = self.dims().to_vec();
        *out_dims.last_mut().unwrap() = n;
        Tensor::new(&out_dims, out)
    }

    /// Softmax over the last axis (numerically stabilized).
    pub fn softmax_last(&self) -> Tensor {
        let mut out = self.clone();
        out.softmax_last_inplace();
        out
    }

    /// In-place softmax over the last axis. Rows are independent, so
    /// large-vocab logits are processed row-parallel (identical numerics).
    pub fn softmax_last_inplace(&mut self) {
        let d = *self.dims().last().expect("softmax on scalar");
        par_chunks_mut(self.data_mut(), d, move |chunk| softmax_rows(chunk, d));
    }

    /// Argmax over the last axis; result drops that axis. Row-parallel for
    /// large inputs (the greedy-decode large-vocab path).
    pub fn argmax_last(&self) -> Tensor {
        let d = *self.dims().last().expect("argmax on scalar");
        let out_dims = &self.dims()[..self.rank() - 1];
        let rows = self.numel() / d;
        let mut data = vec![0.0f32; rows];
        let src = self.data();
        let pool = threadpool::compute_pool();
        if self.numel() < PAR_MIN_ELEMS || pool.size() == 1 {
            argmax_rows(src, &mut data, d);
        } else {
            let per = par_chunk_units(rows, pool);
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = data
                .chunks_mut(per)
                .zip(src.chunks(per * d))
                .map(|(oc, sc)| {
                    Box::new(move || argmax_rows(sc, oc, d)) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.scoped(jobs);
        }
        Tensor::new(out_dims, data)
    }

    pub fn sum_all(&self) -> f32 {
        self.data().iter().sum()
    }

    pub fn mean_all(&self) -> f32 {
        self.sum_all() / self.numel() as f32
    }

    /// Reduce-mean over one axis: contiguous inner-row accumulation
    /// instead of a per-element `unravel`. Accumulation order matches the
    /// naive oracle (ascending along the reduced axis), so results are
    /// bit-exact.
    pub fn mean_axis(&self, axis: usize) -> Tensor {
        assert!(axis < self.rank());
        let dims = self.dims();
        let n = dims[axis];
        let inner: usize = dims[axis + 1..].iter().product();
        let outer: usize = dims[..axis].iter().product();
        let mut out_dims = dims.to_vec();
        out_dims.remove(axis);
        let src = self.data();
        let mut data = vec![0.0f32; outer * inner];
        for o in 0..outer {
            let ibase = o * n * inner;
            let acc = &mut data[o * inner..(o + 1) * inner];
            for a in 0..n {
                let row = &src[ibase + a * inner..ibase + (a + 1) * inner];
                for (x, &y) in acc.iter_mut().zip(row) {
                    *x += y;
                }
            }
        }
        for v in data.iter_mut() {
            *v /= n as f32;
        }
        Tensor::new(&out_dims, data)
    }

    /// L2 norm of the whole tensor.
    pub fn norm(&self) -> f32 {
        self.data().iter().map(|&x| x * x).sum::<f32>().sqrt()
    }

    /// Concatenate along an axis: per-part block memcpy into the output's
    /// strided destination rows.
    pub fn concat(parts: &[&Tensor], axis: usize) -> Tensor {
        assert!(!parts.is_empty());
        let rank = parts[0].rank();
        assert!(axis < rank);
        for p in parts {
            assert_eq!(p.rank(), rank);
            for d in 0..rank {
                if d != axis {
                    assert_eq!(p.dims()[d], parts[0].dims()[d], "concat dim mismatch");
                }
            }
        }
        let inner: usize = parts[0].dims()[axis + 1..].iter().product();
        let outer: usize = parts[0].dims()[..axis].iter().product();
        let out_axis: usize = parts.iter().map(|p| p.dims()[axis]).sum();
        let mut out_dims = parts[0].dims().to_vec();
        out_dims[axis] = out_axis;
        let mut data = vec![0.0f32; outer * out_axis * inner];
        let mut offset = 0usize;
        for p in parts {
            let pa = p.dims()[axis];
            let block = pa * inner;
            let src = p.data();
            for o in 0..outer {
                let dst0 = (o * out_axis + offset) * inner;
                data[dst0..dst0 + block].copy_from_slice(&src[o * block..(o + 1) * block]);
            }
            offset += pa;
        }
        Tensor::new(&out_dims, data)
    }

    /// Split into equal chunks along an axis.
    pub fn split(&self, axis: usize, chunks: usize) -> Vec<Tensor> {
        assert!(axis < self.rank());
        let d = self.dims()[axis];
        assert_eq!(d % chunks, 0, "split {d} into {chunks}");
        let step = d / chunks;
        (0..chunks)
            .map(|c| {
                let mut ranges = vec![Range1::all(); axis + 1];
                ranges[axis] = Range1::new(c * step, (c + 1) * step);
                self.slice(&ranges)
            })
            .collect()
    }

    /// 2-D transpose.
    pub fn transpose2(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.dims()[0], self.dims()[1]);
        let data = pack_transposed(self.data(), m, n);
        Tensor::new(&[n, m], data)
    }
}

fn softmax_rows(chunk: &mut [f32], d: usize) {
    for row in chunk.chunks_mut(d) {
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

fn argmax_rows(src: &[f32], out: &mut [f32], d: usize) {
    for (row, o) in src.chunks(d).zip(out.iter_mut()) {
        let mut best = 0usize;
        for (i, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = i;
            }
        }
        *o = best as f32;
    }
}

/// The standard activation-patching metric: `logit[target] - logit[foil]`
/// on the last-token logits of each batch row. Returns shape `[batch]`.
pub fn logit_diff(logits: &Tensor, target: usize, foil: usize) -> Tensor {
    assert!(logits.rank() >= 2, "logit_diff expects [.., seq, vocab]");
    let vocab = *logits.dims().last().unwrap();
    let seq = logits.dims()[logits.rank() - 2];
    let batch: usize = logits.numel() / (vocab * seq);
    assert!(target < vocab && foil < vocab);
    let data: Vec<f32> = (0..batch)
        .map(|b| {
            let base = b * seq * vocab + (seq - 1) * vocab;
            logits.data()[base + target] - logits.data()[base + foil]
        })
        .collect();
    Tensor::new(&[batch], data)
}

// ---------------------------------------------------------------------------
// Decode-engine kernels: packed matmul, incremental attention, layernorm
// ---------------------------------------------------------------------------

/// A weight matrix packed once into transposed `[n, k]` layout for the
/// decode engine. Unlike [`Tensor::matmul`] — which picks the axpy or the
/// blocked kernel by product size — `PackedMat::matmul_bias` computes every
/// output row with the same [`dot`]-based reduction regardless of how many
/// rows are in flight. Per-row results therefore depend only on the row's
/// contents, so an n-position prefill and n single-row decode steps produce
/// bit-identical activations — the invariant the KV-cache parity suite
/// leans on.
pub struct PackedMat {
    bt: Vec<f32>,
    k: usize,
    n: usize,
}

impl PackedMat {
    pub fn pack(b: &[f32], k: usize, n: usize) -> PackedMat {
        assert_eq!(b.len(), k * n, "pack: {k}x{n} from {} elems", b.len());
        PackedMat { bt: pack_transposed(b, k, n), k, n }
    }

    /// Pack a 2-D weight tensor.
    pub fn from_tensor(t: &Tensor) -> PackedMat {
        assert_eq!(t.rank(), 2, "PackedMat expects a 2-D weight");
        PackedMat::pack(t.data(), t.dims()[0], t.dims()[1])
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// `out[r, j] = dot(a[r, :], b[:, j]) (+ bias[j])` for every row of `a`.
    /// Sequential by design: decode rows are tiny and determinism across
    /// call shapes matters more than intra-call parallelism (cross-sequence
    /// parallelism comes from stepping streams concurrently).
    pub fn matmul_bias(&self, a: &[f32], bias: Option<&[f32]>, out: &mut [f32]) {
        let rows = a.len() / self.k;
        assert_eq!(a.len(), rows * self.k, "lhs not a multiple of k={}", self.k);
        assert_eq!(out.len(), rows * self.n, "out shape mismatch");
        if let Some(bias) = bias {
            assert_eq!(bias.len(), self.n, "bias length mismatch");
        }
        for r in 0..rows {
            let arow = &a[r * self.k..(r + 1) * self.k];
            let orow = &mut out[r * self.n..(r + 1) * self.n];
            for (j, o) in orow.iter_mut().enumerate() {
                *o = dot(arow, &self.bt[j * self.k..(j + 1) * self.k]);
            }
            if let Some(bias) = bias {
                for (o, &bv) in orow.iter_mut().zip(bias) {
                    *o += bv;
                }
            }
        }
    }
}

/// One position of multi-head attention against a cached K/V prefix: `q`
/// is the packed `[d]` query row (head `h` occupies columns
/// `h·dh .. (h+1)·dh`), `kc`/`vc` are row-major `[t, d]` cache prefixes,
/// and the mixed output (pre out-projection) lands in `out`. Scores are
/// scaled by `1/sqrt(dh)` and softmaxed over the `t` cached positions —
/// O(t·d) per step instead of the O(t²·d) a full-window recompute pays.
/// `scratch` is the caller-owned score buffer (resized to `t`).
pub fn attn_mix_row(
    q: &[f32],
    kc: &[f32],
    vc: &[f32],
    t: usize,
    n_heads: usize,
    out: &mut [f32],
    scratch: &mut Vec<f32>,
) {
    let d = q.len();
    assert_eq!(out.len(), d);
    assert!(t > 0, "attention over an empty prefix");
    assert!(kc.len() >= t * d && vc.len() >= t * d, "cache shorter than t={t}");
    assert_eq!(d % n_heads, 0, "d={d} not divisible by {n_heads} heads");
    let dh = d / n_heads;
    let scale = 1.0 / (dh as f32).sqrt();
    scratch.resize(t, 0.0);
    out.fill(0.0);
    for h in 0..n_heads {
        let c0 = h * dh;
        let qh = &q[c0..c0 + dh];
        for (j, s) in scratch.iter_mut().enumerate() {
            *s = dot(qh, &kc[j * d + c0..j * d + c0 + dh]) * scale;
        }
        softmax_rows(scratch, t);
        let oh = &mut out[c0..c0 + dh];
        for (j, &w) in scratch.iter().enumerate() {
            let vrow = &vc[j * d + c0..j * d + c0 + dh];
            for (o, &v) in oh.iter_mut().zip(vrow) {
                *o += w * v;
            }
        }
    }
}

/// Causal self-attention for `rows` freshly cached positions: row `r`
/// (absolute position `base + r`) attends over cache rows `0..=base+r`.
/// Implemented as a loop over [`attn_mix_row`], so a multi-row prefill is
/// bit-identical to replaying the same positions one decode step at a
/// time — prefill/decode is a phase split, not a numerics fork.
pub fn attn_causal_rows(
    q: &[f32],
    kc: &[f32],
    vc: &[f32],
    rows: usize,
    base: usize,
    n_heads: usize,
    out: &mut [f32],
) {
    assert!(rows > 0, "causal attention over zero rows");
    let d = q.len() / rows;
    assert_eq!(q.len(), rows * d);
    assert_eq!(out.len(), rows * d);
    let mut scratch = Vec::new();
    for r in 0..rows {
        attn_mix_row(
            &q[r * d..(r + 1) * d],
            kc,
            vc,
            base + r + 1,
            n_heads,
            &mut out[r * d..(r + 1) * d],
            &mut scratch,
        );
    }
}

/// Row-wise layernorm with gain/bias over `[rows, d]` (d = `g.len()`).
/// Sequential reductions, so results never depend on pool size.
pub fn layernorm_rows(x: &[f32], g: &[f32], b: &[f32], eps: f32, out: &mut [f32]) {
    let d = g.len();
    assert_eq!(b.len(), d);
    assert_eq!(x.len() % d, 0, "rows not a multiple of d={d}");
    assert_eq!(out.len(), x.len());
    for (row, orow) in x.chunks(d).zip(out.chunks_mut(d)) {
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for ((o, &v), (&gv, &bv)) in orow.iter_mut().zip(row).zip(g.iter().zip(b)) {
            *o = (v - mean) * inv * gv + bv;
        }
    }
}

/// In-place tanh-approximation GELU over a raw slice — the decode engine's
/// MLP activation, sharing the exact formula with [`Tensor::gelu_inplace`].
pub fn gelu_rows(xs: &mut [f32]) {
    gelu_slice(xs);
}

// ---------------------------------------------------------------------------
// Naive oracles
// ---------------------------------------------------------------------------

/// The seed (pre-optimization) kernels, retained verbatim as oracles.
///
/// The optimized kernels above must stay bit-compatible with these
/// (tolerance-compatible for the reassociated matmul reduction); the
/// contract is enforced by the unit tests below and the randomized
/// property tests in `rust/tests/props.rs`, and `benches/kernels.rs`
/// reports speedups relative to them. Nothing here runs on a hot path.
pub mod naive {
    use super::super::{Shape, Tensor};
    use super::Range1;

    /// Seed broadcast elementwise op: per-element `unravel` + index `Vec`s.
    pub fn binop(a: &Tensor, b: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        if a.dims() == b.dims() {
            let data = a.data().iter().zip(b.data()).map(|(&x, &y)| f(x, y)).collect();
            return Tensor::new(a.dims(), data);
        }
        let out_dims = Shape::broadcast(a.dims(), b.dims())
            .unwrap_or_else(|| panic!("broadcast {:?} vs {:?}", a.dims(), b.dims()));
        let out_shape = Shape::new(&out_dims);
        let mut data = Vec::with_capacity(out_shape.numel());
        let ra = out_dims.len() - a.rank();
        let rb = out_dims.len() - b.rank();
        for flat in 0..out_shape.numel() {
            let idx = out_shape.unravel(flat);
            let ia: Vec<usize> = idx[ra..]
                .iter()
                .zip(a.dims())
                .map(|(&i, &d)| if d == 1 { 0 } else { i })
                .collect();
            let ib: Vec<usize> = idx[rb..]
                .iter()
                .zip(b.dims())
                .map(|(&i, &d)| if d == 1 { 0 } else { i })
                .collect();
            data.push(f(a.at(&ia), b.at(&ib)));
        }
        Tensor::new(&out_dims, data)
    }

    /// Seed matmul: k-outer axpy with the `av == 0.0` skip.
    pub fn matmul(lhs: &Tensor, other: &Tensor) -> Tensor {
        assert_eq!(other.rank(), 2, "rhs of matmul must be 2-D");
        let (k2, n) = (other.dims()[0], other.dims()[1]);
        let k = *lhs.dims().last().expect("matmul on scalar");
        assert_eq!(k, k2, "contraction mismatch {k} vs {k2}");
        let rows: usize = lhs.numel() / k;
        let mut out = vec![0.0f32; rows * n];
        let a = lhs.data();
        let b = other.data();
        for r in 0..rows {
            let arow = &a[r * k..(r + 1) * k];
            let orow = &mut out[r * n..(r + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &b[kk * n..(kk + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
        let mut out_dims = lhs.dims().to_vec();
        *out_dims.last_mut().unwrap() = n;
        Tensor::new(&out_dims, out)
    }

    /// Seed slice: output-index `unravel` per element.
    pub fn slice(t: &Tensor, ranges: &[Range1]) -> Tensor {
        assert!(ranges.len() <= t.rank());
        let mut full: Vec<(usize, usize)> = Vec::with_capacity(t.rank());
        for (i, &d) in t.dims().iter().enumerate() {
            let r = ranges.get(i).copied().unwrap_or_else(Range1::all);
            full.push(r.clamp(d));
        }
        let out_dims: Vec<usize> = full.iter().map(|(s, e)| e - s).collect();
        let out_shape = Shape::new(&out_dims);
        let mut data = Vec::with_capacity(out_shape.numel());
        let mut idx = vec![0usize; t.rank()];
        for flat in 0..out_shape.numel() {
            let oidx = out_shape.unravel(flat);
            for (k, &(s, _)) in full.iter().enumerate() {
                idx[k] = s + oidx[k];
            }
            data.push(t.at(&idx));
        }
        Tensor::new(&out_dims, data)
    }

    /// Seed slice_assign: per-element offset computation.
    pub fn slice_assign(t: &mut Tensor, ranges: &[Range1], src: &Tensor) {
        assert!(ranges.len() <= t.rank());
        let mut full: Vec<(usize, usize)> = Vec::with_capacity(t.rank());
        for (i, &d) in t.dims().iter().enumerate() {
            let r = ranges.get(i).copied().unwrap_or_else(Range1::all);
            full.push(r.clamp(d));
        }
        let slice_dims: Vec<usize> = full.iter().map(|(s, e)| e - s).collect();
        assert_eq!(
            slice_dims,
            src.dims(),
            "slice_assign shape mismatch: slice {slice_dims:?} vs src {:?}",
            src.dims()
        );
        let src_shape = Shape::new(&slice_dims);
        let mut idx = vec![0usize; t.rank()];
        for flat in 0..src_shape.numel() {
            let sidx = src_shape.unravel(flat);
            for (k, &(s, _)) in full.iter().enumerate() {
                idx[k] = s + sidx[k];
            }
            let off = t.shape().offset(&idx);
            t.data_mut()[off] = src.data()[flat];
        }
    }

    /// Seed index_select: per-element `unravel` and re-offset.
    pub fn index_select(t: &Tensor, axis: usize, indices: &[usize]) -> Tensor {
        assert!(axis < t.rank());
        let mut out_dims = t.dims().to_vec();
        out_dims[axis] = indices.len();
        let out_shape = Shape::new(&out_dims);
        let mut data = Vec::with_capacity(out_shape.numel());
        let mut idx;
        for flat in 0..out_shape.numel() {
            idx = out_shape.unravel(flat);
            idx[axis] = indices[idx[axis]];
            data.push(t.at(&idx));
        }
        Tensor::new(&out_dims, data)
    }

    /// Seed mean_axis: flat scatter-accumulate via `unravel`.
    pub fn mean_axis(t: &Tensor, axis: usize) -> Tensor {
        assert!(axis < t.rank());
        let mut out_dims = t.dims().to_vec();
        let n = out_dims.remove(axis);
        let out_shape = Shape::new(&out_dims);
        let mut data = vec![0.0f32; out_shape.numel()];
        for flat in 0..t.numel() {
            let mut idx = t.shape().unravel(flat);
            idx.remove(axis);
            data[out_shape.offset(&idx)] += t.data()[flat];
        }
        for v in data.iter_mut() {
            *v /= n as f32;
        }
        Tensor::new(&out_dims, data)
    }

    /// Seed concat: per-element `unravel` and re-offset into the output.
    pub fn concat(parts: &[&Tensor], axis: usize) -> Tensor {
        assert!(!parts.is_empty());
        let rank = parts[0].rank();
        assert!(axis < rank);
        let mut out_dims = parts[0].dims().to_vec();
        out_dims[axis] = parts.iter().map(|p| p.dims()[axis]).sum();
        let out_shape = Shape::new(&out_dims);
        let mut out = Tensor::zeros(&out_dims);
        let mut offset = 0usize;
        for p in parts {
            let mut idx;
            for flat in 0..p.numel() {
                idx = p.shape().unravel(flat);
                idx[axis] += offset;
                let o = out_shape.offset(&idx);
                out.data_mut()[o] = p.data()[flat];
            }
            offset += p.dims()[axis];
        }
        out
    }

    /// Seed softmax: sequential over rows.
    pub fn softmax_last(t: &Tensor) -> Tensor {
        let d = *t.dims().last().expect("softmax on scalar");
        let mut data = t.data().to_vec();
        for row in data.chunks_mut(d) {
            let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let mut sum = 0.0;
            for v in row.iter_mut() {
                *v = (*v - m).exp();
                sum += *v;
            }
            for v in row.iter_mut() {
                *v /= sum;
            }
        }
        Tensor::new(t.dims(), data)
    }

    /// Seed argmax: sequential over rows.
    pub fn argmax_last(t: &Tensor) -> Tensor {
        let d = *t.dims().last().expect("argmax on scalar");
        let out_dims = &t.dims()[..t.rank() - 1];
        let data: Vec<f32> = t
            .data()
            .chunks(d)
            .map(|row| {
                let mut best = 0usize;
                for (i, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = i;
                    }
                }
                best as f32
            })
            .collect();
        Tensor::new(out_dims, data)
    }

    /// Seed GELU: per-element map with a fresh output allocation.
    pub fn gelu(t: &Tensor) -> Tensor {
        let data = t
            .data()
            .iter()
            .map(|&x| {
                let c = (2.0f32 / std::f32::consts::PI).sqrt();
                0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
            })
            .collect();
        Tensor::new(t.dims(), data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elementwise_same_shape() {
        let a = Tensor::iota(&[2, 2]);
        let b = Tensor::full(&[2, 2], 2.0);
        assert_eq!(a.add(&b).data(), &[2.0, 3.0, 4.0, 5.0]);
        assert_eq!(a.mul(&b).data(), &[0.0, 2.0, 4.0, 6.0]);
        assert_eq!(a.sub(&b).data(), &[-2.0, -1.0, 0.0, 1.0]);
    }

    #[test]
    fn broadcast_row_and_scalar() {
        let a = Tensor::iota(&[2, 3]);
        let row = Tensor::new(&[3], vec![10.0, 20.0, 30.0]);
        assert_eq!(a.add(&row).data(), &[10.0, 21.0, 32.0, 13.0, 24.0, 35.0]);
        let s = Tensor::scalar(1.0);
        assert_eq!(a.add(&s).data(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn broadcast_middle_size_one_dim() {
        // [2, 1, 3] + [2, 3] broadcasts over the middle and leading dims
        let a = Tensor::iota(&[2, 1, 3]);
        let b = Tensor::iota(&[2, 3]);
        let c = a.add(&b);
        assert_eq!(c.dims(), &[2, 2, 3]);
        assert_eq!(c, naive::binop(&a, &b, |x, y| x + y));
    }

    #[test]
    #[should_panic]
    fn broadcast_incompatible_panics() {
        let _ = Tensor::iota(&[2, 3]).add(&Tensor::iota(&[4]));
    }

    #[test]
    fn slice_middle() {
        let t = Tensor::iota(&[3, 4]);
        let s = t.slice(&[Range1::new(1, 3), Range1::new(0, 2)]);
        assert_eq!(s.dims(), &[2, 2]);
        assert_eq!(s.data(), &[4.0, 5.0, 8.0, 9.0]);
    }

    #[test]
    fn slice_trailing_dims_whole() {
        let t = Tensor::iota(&[2, 3]);
        let s = t.slice(&[Range1::one(1)]);
        assert_eq!(s.dims(), &[1, 3]);
        assert_eq!(s.data(), &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn slice_empty_range() {
        let t = Tensor::iota(&[3, 4]);
        let s = t.slice(&[Range1::new(1, 1)]);
        assert_eq!(s.dims(), &[0, 4]);
        assert_eq!(s.numel(), 0);
    }

    #[test]
    fn slice_full_tensor_is_copy() {
        let t = Tensor::iota(&[2, 3, 4]);
        assert_eq!(t.slice(&[]), t);
        assert_eq!(t.slice(&[Range1::all(), Range1::all()]), t);
    }

    #[test]
    fn slice_assign_round_trip() {
        let mut t = Tensor::zeros(&[3, 3]);
        let patch = Tensor::full(&[1, 3], 7.0);
        t.slice_assign(&[Range1::one(1)], &patch);
        assert_eq!(t.data(), &[0.0, 0.0, 0.0, 7.0, 7.0, 7.0, 0.0, 0.0, 0.0]);
        // extract back
        let got = t.slice(&[Range1::one(1)]);
        assert_eq!(got, patch);
    }

    #[test]
    fn slice_fill_ablates() {
        let mut t = Tensor::iota(&[2, 4]);
        t.slice_fill(&[Range1::all(), Range1::new(1, 3)], 0.0);
        assert_eq!(t.data(), &[0.0, 0.0, 0.0, 3.0, 4.0, 0.0, 0.0, 7.0]);
    }

    #[test]
    fn slice_fill_empty_is_noop() {
        let mut t = Tensor::iota(&[2, 4]);
        let before = t.clone();
        t.slice_fill(&[Range1::new(1, 1)], 9.0);
        assert_eq!(t, before);
    }

    #[test]
    fn index_select_axis0_and_1() {
        let t = Tensor::iota(&[3, 2]);
        let g0 = t.index_select(0, &[2, 0]);
        assert_eq!(g0.data(), &[4.0, 5.0, 0.0, 1.0]);
        let g1 = t.index_select(1, &[1]);
        assert_eq!(g1.dims(), &[3, 1]);
        assert_eq!(g1.data(), &[1.0, 3.0, 5.0]);
    }

    #[test]
    fn matmul_2d() {
        let a = Tensor::new(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::new(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_batched() {
        let a = Tensor::iota(&[2, 2, 3]);
        let b = Tensor::new(&[3, 1], vec![1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.dims(), &[2, 2, 1]);
        assert_eq!(c.data(), &[3.0, 12.0, 21.0, 30.0]);
    }

    #[test]
    fn matmul_matches_oracle_above_parallel_cutoff() {
        // big enough to take the parallel blocked path
        let mut rng = crate::util::Prng::new(7);
        let a = Tensor::from_randn(&[96, 80], &mut rng, 1.0);
        let b = Tensor::from_randn(&[80, 72], &mut rng, 1.0);
        let got = a.matmul(&b);
        let want = naive::matmul(&a, &b);
        assert!(got.allclose(&want, 1e-4), "diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let t = Tensor::iota(&[4, 7]);
        let s = t.softmax_last();
        for row in s.data().chunks(7) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            assert!(row.windows(2).all(|w| w[0] <= w[1])); // monotone input -> monotone output
        }
    }

    #[test]
    fn softmax_handles_large_values() {
        let t = Tensor::new(&[1, 3], vec![1000.0, 1000.0, 1000.0]);
        let s = t.softmax_last();
        for &v in s.data() {
            assert!((v - 1.0 / 3.0).abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_inplace_matches_pure_and_parallel_matches_oracle() {
        let mut rng = crate::util::Prng::new(11);
        // large enough to cross the row-parallel threshold
        let t = Tensor::from_randn(&[64, 1024], &mut rng, 2.0);
        let pure = t.softmax_last();
        let mut inplace = t.clone();
        inplace.softmax_last_inplace();
        assert_eq!(pure, inplace);
        assert_eq!(pure, naive::softmax_last(&t));
    }

    #[test]
    fn argmax_last_axis() {
        let t = Tensor::new(&[2, 3], vec![0.0, 5.0, 1.0, 9.0, 2.0, 3.0]);
        let a = t.argmax_last();
        assert_eq!(a.dims(), &[2]);
        assert_eq!(a.data(), &[1.0, 0.0]);
    }

    #[test]
    fn argmax_parallel_matches_oracle() {
        let mut rng = crate::util::Prng::new(13);
        let t = Tensor::from_randn(&[128, 512], &mut rng, 1.0);
        assert_eq!(t.argmax_last(), naive::argmax_last(&t));
    }

    #[test]
    fn reductions() {
        let t = Tensor::iota(&[2, 3]);
        assert_eq!(t.sum_all(), 15.0);
        assert_eq!(t.mean_all(), 2.5);
        let m0 = t.mean_axis(0);
        assert_eq!(m0.dims(), &[3]);
        assert_eq!(m0.data(), &[1.5, 2.5, 3.5]);
        let m1 = t.mean_axis(1);
        assert_eq!(m1.data(), &[1.0, 4.0]);
    }

    #[test]
    fn concat_and_split_inverse() {
        let t = Tensor::iota(&[2, 6]);
        let parts = t.split(1, 3);
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0].dims(), &[2, 2]);
        let refs: Vec<&Tensor> = parts.iter().collect();
        let back = Tensor::concat(&refs, 1);
        assert_eq!(back, t);
    }

    #[test]
    fn concat_axis0() {
        let a = Tensor::iota(&[1, 2]);
        let b = Tensor::full(&[2, 2], 9.0);
        let c = Tensor::concat(&[&a, &b], 0);
        assert_eq!(c.dims(), &[3, 2]);
        assert_eq!(c.data(), &[0.0, 1.0, 9.0, 9.0, 9.0, 9.0]);
    }

    #[test]
    fn transpose2_involution() {
        let t = Tensor::iota(&[2, 3]);
        assert_eq!(t.transpose2().transpose2(), t);
        assert_eq!(t.transpose2().at(&[2, 1]), t.at(&[1, 2]));
    }

    #[test]
    fn logit_diff_last_token() {
        // batch=2, seq=2, vocab=3
        let logits = Tensor::new(
            &[2, 2, 3],
            vec![
                0.0, 0.0, 0.0, // b0 t0
                1.0, 4.0, 2.0, // b0 t1 (last)
                0.0, 0.0, 0.0, // b1 t0
                5.0, 1.0, 0.0, // b1 t1 (last)
            ],
        );
        let ld = logit_diff(&logits, 1, 0);
        assert_eq!(ld.data(), &[3.0, -4.0]);
    }

    #[test]
    fn add_assign_matches_add() {
        let mut a = Tensor::iota(&[3, 3]);
        let b = Tensor::full(&[3, 3], 2.0);
        let expect = a.add(&b);
        a.add_assign(&b);
        assert_eq!(a, expect);
    }

    #[test]
    fn scale_add_assign_is_fused_axpy() {
        let mut a = Tensor::iota(&[2, 3]);
        let b = Tensor::full(&[2, 3], 2.0);
        let expect = a.add(&b.scale(-0.5));
        a.scale_add_assign(-0.5, &b);
        assert_eq!(a, expect);
    }

    #[test]
    fn gelu_known_values() {
        let t = Tensor::new(&[3], vec![-10.0, 0.0, 10.0]);
        let g = t.gelu();
        assert!(g.data()[0].abs() < 1e-3);
        assert_eq!(g.data()[1], 0.0);
        assert!((g.data()[2] - 10.0).abs() < 1e-3);
    }

    #[test]
    fn gelu_inplace_matches_oracle_above_parallel_threshold() {
        let mut rng = crate::util::Prng::new(17);
        let t = Tensor::from_randn(&[80, 1024], &mut rng, 1.0);
        let mut got = t.clone();
        got.gelu_inplace();
        assert_eq!(got, naive::gelu(&t));
    }

    #[test]
    fn scale_inplace_matches_scale() {
        let t = Tensor::iota(&[4, 4]);
        let mut got = t.clone();
        got.scale_inplace(2.5);
        assert_eq!(got, t.scale(2.5));
    }

    #[test]
    fn packed_matmul_matches_oracle_and_is_row_deterministic() {
        let mut rng = crate::util::Prng::new(19);
        let a = Tensor::from_randn(&[6, 40], &mut rng, 1.0);
        let b = Tensor::from_randn(&[40, 24], &mut rng, 1.0);
        let p = PackedMat::from_tensor(&b);
        let mut all = vec![0.0f32; 6 * 24];
        p.matmul_bias(a.data(), None, &mut all);
        let want = naive::matmul(&a, &b);
        let got = Tensor::new(&[6, 24], all.clone());
        assert!(got.allclose(&want, 1e-4), "diff {}", got.max_abs_diff(&want));
        // row determinism: one row at a time is bit-identical to the batch
        for r in 0..6 {
            let mut row = vec![0.0f32; 24];
            p.matmul_bias(&a.data()[r * 40..(r + 1) * 40], None, &mut row);
            assert_eq!(&all[r * 24..(r + 1) * 24], &row[..], "row {r} diverged");
        }
    }

    #[test]
    fn packed_matmul_bias_adds_bias() {
        let b = Tensor::iota(&[2, 3]);
        let p = PackedMat::from_tensor(&b);
        let mut out = vec![0.0f32; 3];
        p.matmul_bias(&[1.0, 1.0], Some(&[10.0, 20.0, 30.0]), &mut out);
        assert_eq!(out, vec![13.0, 25.0, 37.0]);
    }

    /// Naive full causal attention: per-row score matrix, softmax, mix.
    fn naive_causal_attn(q: &[f32], k: &[f32], v: &[f32], rows: usize, n_heads: usize) -> Vec<f32> {
        let d = q.len() / rows;
        let dh = d / n_heads;
        let scale = 1.0 / (dh as f32).sqrt();
        let mut out = vec![0.0f32; rows * d];
        for r in 0..rows {
            for h in 0..n_heads {
                let c0 = h * dh;
                let mut scores: Vec<f32> = (0..=r)
                    .map(|j| {
                        (0..dh)
                            .map(|x| q[r * d + c0 + x] * k[j * d + c0 + x])
                            .sum::<f32>()
                            * scale
                    })
                    .collect();
                let m = scores.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                let mut sum = 0.0;
                for s in scores.iter_mut() {
                    *s = (*s - m).exp();
                    sum += *s;
                }
                for (j, s) in scores.iter().enumerate() {
                    let w = s / sum;
                    for x in 0..dh {
                        out[r * d + c0 + x] += w * v[j * d + c0 + x];
                    }
                }
            }
        }
        out
    }

    #[test]
    fn causal_attention_matches_naive_oracle() {
        let mut rng = crate::util::Prng::new(23);
        let (rows, heads, d) = (9, 4, 32);
        let q = Tensor::from_randn(&[rows, d], &mut rng, 1.0);
        let k = Tensor::from_randn(&[rows, d], &mut rng, 1.0);
        let v = Tensor::from_randn(&[rows, d], &mut rng, 1.0);
        let mut got = vec![0.0f32; rows * d];
        attn_causal_rows(q.data(), k.data(), v.data(), rows, 0, heads, &mut got);
        let want = naive_causal_attn(q.data(), k.data(), v.data(), rows, heads);
        for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-4, "elem {i}: {g} vs {w}");
        }
    }

    #[test]
    fn prefill_bit_identical_to_decode_replay() {
        // the KV-cache invariant: attending row-by-row over a growing
        // prefix reproduces the multi-row prefill bit for bit
        let mut rng = crate::util::Prng::new(29);
        let (rows, heads, d) = (7, 2, 16);
        let q = Tensor::from_randn(&[rows, d], &mut rng, 1.0);
        let k = Tensor::from_randn(&[rows, d], &mut rng, 1.0);
        let v = Tensor::from_randn(&[rows, d], &mut rng, 1.0);
        let mut prefill = vec![0.0f32; rows * d];
        attn_causal_rows(q.data(), k.data(), v.data(), rows, 0, heads, &mut prefill);
        let mut scratch = Vec::new();
        for r in 0..rows {
            let mut step = vec![0.0f32; d];
            attn_mix_row(
                &q.data()[r * d..(r + 1) * d],
                k.data(),
                v.data(),
                r + 1,
                heads,
                &mut step,
                &mut scratch,
            );
            assert_eq!(&prefill[r * d..(r + 1) * d], &step[..], "position {r} diverged");
        }
    }

    #[test]
    fn layernorm_rows_normalizes() {
        let x = vec![1.0f32, 2.0, 3.0, 4.0, -2.0, 0.0, 2.0, 4.0];
        let g = vec![1.0f32; 4];
        let b = vec![0.0f32; 4];
        let mut out = vec![0.0f32; 8];
        layernorm_rows(&x, &g, &b, 1e-5, &mut out);
        for row in out.chunks(4) {
            let mean: f32 = row.iter().sum::<f32>() / 4.0;
            let var: f32 = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
            assert!(mean.abs() < 1e-5, "mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "var {var}");
        }
    }
}
