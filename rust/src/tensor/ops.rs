//! Tensor operations used by the intervention-graph interpreter and the
//! shard all-reduce. Each op is exercised by unit tests against naive
//! oracles and by the interpreter's property tests.

use super::{Shape, Tensor};

// ---------------------------------------------------------------------------
// Elementwise with broadcasting
// ---------------------------------------------------------------------------

fn broadcast_binop(a: &Tensor, b: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
    if a.dims() == b.dims() {
        // fast path: no index arithmetic
        let data = a.data().iter().zip(b.data()).map(|(&x, &y)| f(x, y)).collect();
        return Tensor::new(a.dims(), data);
    }
    let out_dims = Shape::broadcast(a.dims(), b.dims())
        .unwrap_or_else(|| panic!("broadcast {:?} vs {:?}", a.dims(), b.dims()));
    let out_shape = Shape::new(&out_dims);
    let mut data = Vec::with_capacity(out_shape.numel());
    let ra = out_dims.len() - a.rank();
    let rb = out_dims.len() - b.rank();
    for flat in 0..out_shape.numel() {
        let idx = out_shape.unravel(flat);
        let ia: Vec<usize> = idx[ra..]
            .iter()
            .zip(a.dims())
            .map(|(&i, &d)| if d == 1 { 0 } else { i })
            .collect();
        let ib: Vec<usize> = idx[rb..]
            .iter()
            .zip(b.dims())
            .map(|(&i, &d)| if d == 1 { 0 } else { i })
            .collect();
        data.push(f(a.at(&ia), b.at(&ib)));
    }
    Tensor::new(&out_dims, data)
}

impl Tensor {
    pub fn add(&self, other: &Tensor) -> Tensor {
        broadcast_binop(self, other, |a, b| a + b)
    }
    pub fn sub(&self, other: &Tensor) -> Tensor {
        broadcast_binop(self, other, |a, b| a - b)
    }
    pub fn mul(&self, other: &Tensor) -> Tensor {
        broadcast_binop(self, other, |a, b| a * b)
    }
    pub fn div(&self, other: &Tensor) -> Tensor {
        broadcast_binop(self, other, |a, b| a / b)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        let data = self.data().iter().map(|&x| x * s).collect();
        Tensor::new(self.dims(), data)
    }

    pub fn add_scalar(&self, s: f32) -> Tensor {
        let data = self.data().iter().map(|&x| x + s).collect();
        Tensor::new(self.dims(), data)
    }

    pub fn neg(&self) -> Tensor {
        self.scale(-1.0)
    }

    pub fn relu(&self) -> Tensor {
        let data = self.data().iter().map(|&x| x.max(0.0)).collect();
        Tensor::new(self.dims(), data)
    }

    /// tanh-approximation GELU, matching the model's MLP activation.
    pub fn gelu(&self) -> Tensor {
        let data = self
            .data()
            .iter()
            .map(|&x| {
                let c = (2.0f32 / std::f32::consts::PI).sqrt();
                0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
            })
            .collect();
        Tensor::new(self.dims(), data)
    }

    /// In-place add (same shape) — used by the shard all-reduce hot path.
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.dims(), other.dims());
        for (a, b) in self.data_mut().iter_mut().zip(other.data()) {
            *a += *b;
        }
    }
}

// ---------------------------------------------------------------------------
// Slicing
// ---------------------------------------------------------------------------

/// A per-dimension slice `[start, stop)`; `stop == usize::MAX` means "end".
/// A negative-step or strided slice is not needed by the graph ops.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Range1 {
    pub start: usize,
    pub stop: usize,
}

impl Range1 {
    pub fn new(start: usize, stop: usize) -> Range1 {
        Range1 { start, stop }
    }
    pub fn all() -> Range1 {
        Range1 { start: 0, stop: usize::MAX }
    }
    pub fn one(i: usize) -> Range1 {
        Range1 { start: i, stop: i + 1 }
    }
    fn clamp(&self, dim: usize) -> (usize, usize) {
        let stop = if self.stop == usize::MAX { dim } else { self.stop };
        assert!(self.start <= stop && stop <= dim, "slice {self:?} out of bounds for dim {dim}");
        (self.start, stop)
    }
}

impl Tensor {
    /// Multi-dimensional slice. `ranges.len()` may be less than the rank;
    /// trailing dimensions are taken whole. The result keeps the sliced
    /// dimensions (no squeezing) — callers reshape if needed.
    pub fn slice(&self, ranges: &[Range1]) -> Tensor {
        assert!(ranges.len() <= self.rank());
        let mut full: Vec<(usize, usize)> = Vec::with_capacity(self.rank());
        for (i, &d) in self.dims().iter().enumerate() {
            let r = ranges.get(i).copied().unwrap_or_else(Range1::all);
            full.push(r.clamp(d));
        }
        let out_dims: Vec<usize> = full.iter().map(|(s, e)| e - s).collect();
        let out_shape = Shape::new(&out_dims);
        let mut data = Vec::with_capacity(out_shape.numel());
        // iterate output indices, map to input
        let mut idx = vec![0usize; self.rank()];
        for flat in 0..out_shape.numel() {
            let oidx = out_shape.unravel(flat);
            for (k, &(s, _)) in full.iter().enumerate() {
                idx[k] = s + oidx[k];
            }
            data.push(self.at(&idx));
        }
        Tensor::new(&out_dims, data)
    }

    /// Assign `src` into the slice of `self` described by `ranges`
    /// (shape of `src` must equal the slice shape). This is the setter
    /// primitive: `layer.output[1, t, :] = v`.
    pub fn slice_assign(&mut self, ranges: &[Range1], src: &Tensor) {
        assert!(ranges.len() <= self.rank());
        let mut full: Vec<(usize, usize)> = Vec::with_capacity(self.rank());
        for (i, &d) in self.dims().iter().enumerate() {
            let r = ranges.get(i).copied().unwrap_or_else(Range1::all);
            full.push(r.clamp(d));
        }
        let slice_dims: Vec<usize> = full.iter().map(|(s, e)| e - s).collect();
        assert_eq!(
            slice_dims,
            src.dims(),
            "slice_assign shape mismatch: slice {slice_dims:?} vs src {:?}",
            src.dims()
        );
        let src_shape = Shape::new(&slice_dims);
        let mut idx = vec![0usize; self.rank()];
        for flat in 0..src_shape.numel() {
            let sidx = src_shape.unravel(flat);
            for (k, &(s, _)) in full.iter().enumerate() {
                idx[k] = s + sidx[k];
            }
            let off = self.shape().offset(&idx);
            self.data_mut()[off] = src.data()[flat];
        }
    }

    /// Fill a slice with a constant (ablation setter).
    pub fn slice_fill(&mut self, ranges: &[Range1], v: f32) {
        let slice_dims: Vec<usize> = {
            let mut dims = Vec::new();
            for (i, &d) in self.dims().iter().enumerate() {
                let r = ranges.get(i).copied().unwrap_or_else(Range1::all);
                let (s, e) = r.clamp(d);
                dims.push(e - s);
            }
            dims
        };
        let src = Tensor::full(&slice_dims, v);
        self.slice_assign(ranges, &src);
    }

    /// Gather rows along an axis by integer indices.
    pub fn index_select(&self, axis: usize, indices: &[usize]) -> Tensor {
        assert!(axis < self.rank());
        let mut out_dims = self.dims().to_vec();
        out_dims[axis] = indices.len();
        let out_shape = Shape::new(&out_dims);
        let mut data = Vec::with_capacity(out_shape.numel());
        let mut idx;
        for flat in 0..out_shape.numel() {
            idx = out_shape.unravel(flat);
            idx[axis] = indices[idx[axis]];
            data.push(self.at(&idx));
        }
        Tensor::new(&out_dims, data)
    }
}

// ---------------------------------------------------------------------------
// Linear algebra & reductions
// ---------------------------------------------------------------------------

impl Tensor {
    /// Matrix multiply. Supports 2-D × 2-D and batched N-D × 2-D (the last
    /// two axes of `self` contract with `other`).
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(other.rank(), 2, "rhs of matmul must be 2-D");
        let (k2, n) = (other.dims()[0], other.dims()[1]);
        let k = *self.dims().last().expect("matmul on scalar");
        assert_eq!(k, k2, "contraction mismatch {k} vs {k2}");
        let rows: usize = self.numel() / k;
        let mut out = vec![0.0f32; rows * n];
        let a = self.data();
        let b = other.data();
        for r in 0..rows {
            let arow = &a[r * k..(r + 1) * k];
            let orow = &mut out[r * n..(r + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &b[kk * n..(kk + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
        let mut out_dims = self.dims().to_vec();
        *out_dims.last_mut().unwrap() = n;
        Tensor::new(&out_dims, out)
    }

    /// Softmax over the last axis (numerically stabilized).
    pub fn softmax_last(&self) -> Tensor {
        let d = *self.dims().last().expect("softmax on scalar");
        let mut data = self.data().to_vec();
        for row in data.chunks_mut(d) {
            let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let mut sum = 0.0;
            for v in row.iter_mut() {
                *v = (*v - m).exp();
                sum += *v;
            }
            for v in row.iter_mut() {
                *v /= sum;
            }
        }
        Tensor::new(self.dims(), data)
    }

    /// Argmax over the last axis; result drops that axis.
    pub fn argmax_last(&self) -> Tensor {
        let d = *self.dims().last().expect("argmax on scalar");
        let out_dims = &self.dims()[..self.rank() - 1];
        let data: Vec<f32> = self
            .data()
            .chunks(d)
            .map(|row| {
                let mut best = 0usize;
                for (i, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = i;
                    }
                }
                best as f32
            })
            .collect();
        Tensor::new(out_dims, data)
    }

    pub fn sum_all(&self) -> f32 {
        self.data().iter().sum()
    }

    pub fn mean_all(&self) -> f32 {
        self.sum_all() / self.numel() as f32
    }

    /// Reduce-mean over one axis.
    pub fn mean_axis(&self, axis: usize) -> Tensor {
        assert!(axis < self.rank());
        let mut out_dims = self.dims().to_vec();
        let n = out_dims.remove(axis);
        let out_shape = Shape::new(&out_dims);
        let mut data = vec![0.0f32; out_shape.numel()];
        for flat in 0..self.numel() {
            let mut idx = self.shape().unravel(flat);
            idx.remove(axis);
            data[out_shape.offset(&idx)] += self.data()[flat];
        }
        for v in data.iter_mut() {
            *v /= n as f32;
        }
        Tensor::new(&out_dims, data)
    }

    /// L2 norm of the whole tensor.
    pub fn norm(&self) -> f32 {
        self.data().iter().map(|&x| x * x).sum::<f32>().sqrt()
    }

    /// Concatenate along an axis.
    pub fn concat(parts: &[&Tensor], axis: usize) -> Tensor {
        assert!(!parts.is_empty());
        let rank = parts[0].rank();
        assert!(axis < rank);
        for p in parts {
            assert_eq!(p.rank(), rank);
            for d in 0..rank {
                if d != axis {
                    assert_eq!(p.dims()[d], parts[0].dims()[d], "concat dim mismatch");
                }
            }
        }
        let mut out_dims = parts[0].dims().to_vec();
        out_dims[axis] = parts.iter().map(|p| p.dims()[axis]).sum();
        let out_shape = Shape::new(&out_dims);
        let mut out = Tensor::zeros(&out_dims);
        let mut offset = 0usize;
        for p in parts {
            let mut idx;
            for flat in 0..p.numel() {
                idx = p.shape().unravel(flat);
                idx[axis] += offset;
                let o = out_shape.offset(&idx);
                out.data_mut()[o] = p.data()[flat];
            }
            offset += p.dims()[axis];
        }
        out
    }

    /// Split into equal chunks along an axis.
    pub fn split(&self, axis: usize, chunks: usize) -> Vec<Tensor> {
        assert!(axis < self.rank());
        let d = self.dims()[axis];
        assert_eq!(d % chunks, 0, "split {d} into {chunks}");
        let step = d / chunks;
        (0..chunks)
            .map(|c| {
                let mut ranges = vec![Range1::all(); axis + 1];
                ranges[axis] = Range1::new(c * step, (c + 1) * step);
                self.slice(&ranges)
            })
            .collect()
    }

    /// 2-D transpose.
    pub fn transpose2(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.dims()[0], self.dims()[1]);
        let mut data = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                data[j * m + i] = self.data()[i * n + j];
            }
        }
        Tensor::new(&[n, m], data)
    }
}

/// The standard activation-patching metric: `logit[target] - logit[foil]`
/// on the last-token logits of each batch row. Returns shape `[batch]`.
pub fn logit_diff(logits: &Tensor, target: usize, foil: usize) -> Tensor {
    assert!(logits.rank() >= 2, "logit_diff expects [.., seq, vocab]");
    let vocab = *logits.dims().last().unwrap();
    let seq = logits.dims()[logits.rank() - 2];
    let batch: usize = logits.numel() / (vocab * seq);
    assert!(target < vocab && foil < vocab);
    let data: Vec<f32> = (0..batch)
        .map(|b| {
            let base = b * seq * vocab + (seq - 1) * vocab;
            logits.data()[base + target] - logits.data()[base + foil]
        })
        .collect();
    Tensor::new(&[batch], data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elementwise_same_shape() {
        let a = Tensor::iota(&[2, 2]);
        let b = Tensor::full(&[2, 2], 2.0);
        assert_eq!(a.add(&b).data(), &[2.0, 3.0, 4.0, 5.0]);
        assert_eq!(a.mul(&b).data(), &[0.0, 2.0, 4.0, 6.0]);
        assert_eq!(a.sub(&b).data(), &[-2.0, -1.0, 0.0, 1.0]);
    }

    #[test]
    fn broadcast_row_and_scalar() {
        let a = Tensor::iota(&[2, 3]);
        let row = Tensor::new(&[3], vec![10.0, 20.0, 30.0]);
        assert_eq!(a.add(&row).data(), &[10.0, 21.0, 32.0, 13.0, 24.0, 35.0]);
        let s = Tensor::scalar(1.0);
        assert_eq!(a.add(&s).data(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    #[should_panic]
    fn broadcast_incompatible_panics() {
        let _ = Tensor::iota(&[2, 3]).add(&Tensor::iota(&[4]));
    }

    #[test]
    fn slice_middle() {
        let t = Tensor::iota(&[3, 4]);
        let s = t.slice(&[Range1::new(1, 3), Range1::new(0, 2)]);
        assert_eq!(s.dims(), &[2, 2]);
        assert_eq!(s.data(), &[4.0, 5.0, 8.0, 9.0]);
    }

    #[test]
    fn slice_trailing_dims_whole() {
        let t = Tensor::iota(&[2, 3]);
        let s = t.slice(&[Range1::one(1)]);
        assert_eq!(s.dims(), &[1, 3]);
        assert_eq!(s.data(), &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn slice_assign_round_trip() {
        let mut t = Tensor::zeros(&[3, 3]);
        let patch = Tensor::full(&[1, 3], 7.0);
        t.slice_assign(&[Range1::one(1)], &patch);
        assert_eq!(t.data(), &[0.0, 0.0, 0.0, 7.0, 7.0, 7.0, 0.0, 0.0, 0.0]);
        // extract back
        let got = t.slice(&[Range1::one(1)]);
        assert_eq!(got, patch);
    }

    #[test]
    fn slice_fill_ablates() {
        let mut t = Tensor::iota(&[2, 4]);
        t.slice_fill(&[Range1::all(), Range1::new(1, 3)], 0.0);
        assert_eq!(t.data(), &[0.0, 0.0, 0.0, 3.0, 4.0, 0.0, 0.0, 7.0]);
    }

    #[test]
    fn index_select_axis0_and_1() {
        let t = Tensor::iota(&[3, 2]);
        let g0 = t.index_select(0, &[2, 0]);
        assert_eq!(g0.data(), &[4.0, 5.0, 0.0, 1.0]);
        let g1 = t.index_select(1, &[1]);
        assert_eq!(g1.dims(), &[3, 1]);
        assert_eq!(g1.data(), &[1.0, 3.0, 5.0]);
    }

    #[test]
    fn matmul_2d() {
        let a = Tensor::new(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::new(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_batched() {
        let a = Tensor::iota(&[2, 2, 3]);
        let b = Tensor::new(&[3, 1], vec![1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.dims(), &[2, 2, 1]);
        assert_eq!(c.data(), &[3.0, 12.0, 21.0, 30.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let t = Tensor::iota(&[4, 7]);
        let s = t.softmax_last();
        for row in s.data().chunks(7) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            assert!(row.windows(2).all(|w| w[0] <= w[1])); // monotone input -> monotone output
        }
    }

    #[test]
    fn softmax_handles_large_values() {
        let t = Tensor::new(&[1, 3], vec![1000.0, 1000.0, 1000.0]);
        let s = t.softmax_last();
        for &v in s.data() {
            assert!((v - 1.0 / 3.0).abs() < 1e-6);
        }
    }

    #[test]
    fn argmax_last_axis() {
        let t = Tensor::new(&[2, 3], vec![0.0, 5.0, 1.0, 9.0, 2.0, 3.0]);
        let a = t.argmax_last();
        assert_eq!(a.dims(), &[2]);
        assert_eq!(a.data(), &[1.0, 0.0]);
    }

    #[test]
    fn reductions() {
        let t = Tensor::iota(&[2, 3]);
        assert_eq!(t.sum_all(), 15.0);
        assert_eq!(t.mean_all(), 2.5);
        let m0 = t.mean_axis(0);
        assert_eq!(m0.dims(), &[3]);
        assert_eq!(m0.data(), &[1.5, 2.5, 3.5]);
        let m1 = t.mean_axis(1);
        assert_eq!(m1.data(), &[1.0, 4.0]);
    }

    #[test]
    fn concat_and_split_inverse() {
        let t = Tensor::iota(&[2, 6]);
        let parts = t.split(1, 3);
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0].dims(), &[2, 2]);
        let refs: Vec<&Tensor> = parts.iter().collect();
        let back = Tensor::concat(&refs, 1);
        assert_eq!(back, t);
    }

    #[test]
    fn concat_axis0() {
        let a = Tensor::iota(&[1, 2]);
        let b = Tensor::full(&[2, 2], 9.0);
        let c = Tensor::concat(&[&a, &b], 0);
        assert_eq!(c.dims(), &[3, 2]);
        assert_eq!(c.data(), &[0.0, 1.0, 9.0, 9.0, 9.0, 9.0]);
    }

    #[test]
    fn transpose2_involution() {
        let t = Tensor::iota(&[2, 3]);
        assert_eq!(t.transpose2().transpose2(), t);
        assert_eq!(t.transpose2().at(&[2, 1]), t.at(&[1, 2]));
    }

    #[test]
    fn logit_diff_last_token() {
        // batch=2, seq=2, vocab=3
        let logits = Tensor::new(
            &[2, 2, 3],
            vec![
                0.0, 0.0, 0.0, // b0 t0
                1.0, 4.0, 2.0, // b0 t1 (last)
                0.0, 0.0, 0.0, // b1 t0
                5.0, 1.0, 0.0, // b1 t1 (last)
            ],
        );
        let ld = logit_diff(&logits, 1, 0);
        assert_eq!(ld.data(), &[3.0, -4.0]);
    }

    #[test]
    fn add_assign_matches_add() {
        let mut a = Tensor::iota(&[3, 3]);
        let b = Tensor::full(&[3, 3], 2.0);
        let expect = a.add(&b);
        a.add_assign(&b);
        assert_eq!(a, expect);
    }

    #[test]
    fn gelu_known_values() {
        let t = Tensor::new(&[3], vec![-10.0, 0.0, 10.0]);
        let g = t.gelu();
        assert!(g.data()[0].abs() < 1e-3);
        assert_eq!(g.data()[1], 0.0);
        assert!((g.data()[2] - 10.0).abs() < 1e-3);
    }
}
