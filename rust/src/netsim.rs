//! Simulated wide-area network link.
//!
//! The paper's Petals-vs-NDIF comparison (§4, Fig. 6c) ran over "a network
//! with a bandwidth of about 60 MB/s"; the NDIF remote-overhead result
//! (Fig. 6b) measures a roughly constant client↔server communication cost.
//! This testbed has only loopback, so client↔server transports route their
//! payloads through a [`NetSim`] that charges latency + serialization time
//! against the *actual* byte counts being moved. The simulation either
//! sleeps for the computed duration (`Mode::Sleep`, used by benchmarks so
//! wallclock reflects the link) or merely accounts it (`Mode::Account`,
//! used by fast tests).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::util::Prng;

/// How the simulated link manifests its cost.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Sleep for the computed transfer time (benchmarks).
    Sleep,
    /// Only record the cost; no sleeping (unit tests).
    Account,
}

/// A point-to-point link with fixed one-way latency and symmetric bandwidth.
#[derive(Clone)]
pub struct NetSim {
    /// One-way latency in seconds.
    pub latency_s: f64,
    /// Bandwidth in bytes per second.
    pub bandwidth_bps: f64,
    pub mode: Mode,
    /// Total bytes charged (shared across clones).
    bytes_total: Arc<AtomicU64>,
    /// Total simulated seconds charged, in nanoseconds (shared).
    nanos_total: Arc<AtomicU64>,
}

impl NetSim {
    pub fn new(latency_s: f64, bandwidth_bps: f64, mode: Mode) -> NetSim {
        assert!(bandwidth_bps > 0.0);
        NetSim {
            latency_s,
            bandwidth_bps,
            mode,
            bytes_total: Arc::new(AtomicU64::new(0)),
            nanos_total: Arc::new(AtomicU64::new(0)),
        }
    }

    /// The paper's measured link: ~60 MB/s, 10 ms one-way latency.
    pub fn paper_wan(mode: Mode) -> NetSim {
        NetSim::new(0.010, 60.0e6, mode)
    }

    /// An ideal link: zero cost (local execution paths).
    pub fn ideal() -> NetSim {
        NetSim::new(0.0, f64::INFINITY, Mode::Account)
    }

    /// Seconds a one-way transfer of `bytes` takes on this link.
    pub fn transfer_time(&self, bytes: usize) -> f64 {
        if self.bandwidth_bps.is_infinite() {
            return self.latency_s;
        }
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }

    /// Record (and in `Mode::Sleep`, wait out) a transfer of `bytes`
    /// taking `t` seconds.
    fn charge(&self, bytes: usize, t: f64) -> f64 {
        self.bytes_total.fetch_add(bytes as u64, Ordering::Relaxed);
        self.nanos_total
            .fetch_add((t * 1e9) as u64, Ordering::Relaxed);
        if self.mode == Mode::Sleep && t > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(t));
        }
        t
    }

    /// Charge a one-way transfer; sleeps in `Mode::Sleep`.
    pub fn send(&self, bytes: usize) -> f64 {
        self.charge(bytes, self.transfer_time(bytes))
    }

    /// Charge a continuation of an already-open stream: bytes move at the
    /// link bandwidth but pay no propagation latency (the pipeline is
    /// full — chunked-transfer frames after the first). Sleeps in
    /// `Mode::Sleep`.
    pub fn send_streamed(&self, bytes: usize) -> f64 {
        let t = if self.bandwidth_bps.is_infinite() {
            0.0
        } else {
            bytes as f64 / self.bandwidth_bps
        };
        self.charge(bytes, t)
    }

    /// Charge a round trip of `up` then `down` bytes.
    pub fn round_trip(&self, up: usize, down: usize) -> f64 {
        self.send(up) + self.send(down)
    }

    /// Total bytes charged so far (across clones).
    pub fn bytes_transferred(&self) -> u64 {
        self.bytes_total.load(Ordering::Relaxed)
    }

    /// Total simulated seconds charged so far (across clones).
    pub fn seconds_charged(&self) -> f64 {
        self.nanos_total.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn reset(&self) {
        self.bytes_total.store(0, Ordering::Relaxed);
        self.nanos_total.store(0, Ordering::Relaxed);
    }
}

/// Open-loop arrival process: how long until the *next* request starts,
/// independent of when earlier requests finish. Closed-loop drivers (N
/// users issuing back-to-back requests) self-throttle when the server
/// slows down and therefore understate tail latency; an open-loop
/// generator keeps arriving on schedule, which is what exposes queue-wait
/// percentiles under overload (the Fig. 9 regime).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Arrivals {
    /// Deterministic gaps of exactly `1/rate` seconds (a metronome).
    Uniform { rate: f64 },
    /// Poisson process — exponential gaps with mean `1/rate`.
    Poisson { rate: f64 },
    /// Heavy-tailed lognormal gaps with mean `1/rate` and log-σ `sigma`
    /// (`sigma ≈ 1.5` gives the burst-then-lull clustering of real
    /// inference traffic). `mu` is solved from `E[X] = exp(mu + σ²/2)`.
    Lognormal { rate: f64, sigma: f64 },
}

impl Arrivals {
    /// Parse a CLI spelling: `uniform` | `poisson` | `lognormal`.
    /// `sigma` only applies to `lognormal`.
    pub fn parse(kind: &str, rate: f64, sigma: f64) -> Option<Arrivals> {
        if !(rate > 0.0) {
            return None;
        }
        match kind {
            "uniform" => Some(Arrivals::Uniform { rate }),
            "poisson" | "exp" | "exponential" => Some(Arrivals::Poisson { rate }),
            "lognormal" | "heavy" => Some(Arrivals::Lognormal { rate, sigma }),
            _ => None,
        }
    }

    /// Mean inter-arrival gap in seconds (`1/rate` for every variant).
    pub fn mean_gap(&self) -> f64 {
        match *self {
            Arrivals::Uniform { rate }
            | Arrivals::Poisson { rate }
            | Arrivals::Lognormal { rate, .. } => 1.0 / rate,
        }
    }

    /// Sample the gap before the next arrival, in seconds.
    pub fn next_gap(&self, rng: &mut Prng) -> f64 {
        match *self {
            Arrivals::Uniform { rate } => 1.0 / rate,
            Arrivals::Poisson { rate } => rng.exponential(rate),
            Arrivals::Lognormal { rate, sigma } => {
                // choose mu so the mean gap stays 1/rate regardless of sigma
                let mu = (1.0 / rate).ln() - sigma * sigma / 2.0;
                rng.lognormal(mu, sigma)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_formula() {
        let l = NetSim::new(0.010, 1_000_000.0, Mode::Account);
        // 1 MB over 1 MB/s + 10 ms latency = 1.01 s
        assert!((l.transfer_time(1_000_000) - 1.010).abs() < 1e-9);
        assert!((l.transfer_time(0) - 0.010).abs() < 1e-12);
    }

    #[test]
    fn accounting_accumulates_across_clones() {
        let l = NetSim::new(0.0, 100.0, Mode::Account);
        let l2 = l.clone();
        l.send(50);
        l2.send(150);
        assert_eq!(l.bytes_transferred(), 200);
        assert!((l.seconds_charged() - 2.0).abs() < 1e-6);
        l.reset();
        assert_eq!(l2.bytes_transferred(), 0);
    }

    #[test]
    fn ideal_link_is_free() {
        let l = NetSim::ideal();
        assert_eq!(l.send(1_000_000_000), 0.0);
    }

    #[test]
    fn streamed_send_pays_bandwidth_but_not_latency() {
        let l = NetSim::new(0.010, 1000.0, Mode::Account);
        // opening transfer: latency + bytes; continuation: bytes only
        let t0 = l.send(1000);
        let t1 = l.send_streamed(1000);
        assert!((t0 - 1.010).abs() < 1e-9);
        assert!((t1 - 1.000).abs() < 1e-9);
        assert_eq!(l.bytes_transferred(), 2000);
    }

    #[test]
    fn round_trip_charges_both_ways() {
        let l = NetSim::new(0.001, 1000.0, Mode::Account);
        let t = l.round_trip(1000, 2000);
        assert!((t - (0.001 + 1.0 + 0.001 + 2.0)).abs() < 1e-9);
    }

    #[test]
    fn arrivals_parse_and_mean_gap() {
        let a = Arrivals::parse("poisson", 50.0, 1.5).unwrap();
        assert_eq!(a, Arrivals::Poisson { rate: 50.0 });
        assert!((a.mean_gap() - 0.02).abs() < 1e-12);
        assert!(Arrivals::parse("lognormal", 10.0, 1.5).is_some());
        assert!(Arrivals::parse("uniform", 10.0, 0.0).is_some());
        assert!(Arrivals::parse("bogus", 10.0, 0.0).is_none());
        assert!(Arrivals::parse("poisson", 0.0, 0.0).is_none());
    }

    #[test]
    fn arrivals_preserve_mean_rate() {
        let mut rng = Prng::new(31);
        for a in [
            Arrivals::Uniform { rate: 20.0 },
            Arrivals::Poisson { rate: 20.0 },
            Arrivals::Lognormal { rate: 20.0, sigma: 1.5 },
        ] {
            let n = 200_000;
            let mean: f64 = (0..n).map(|_| a.next_gap(&mut rng)).sum::<f64>() / n as f64;
            // every process is calibrated to the same 1/rate mean gap;
            // the lognormal tail converges slowly, hence the loose band
            assert!(
                (mean - 0.05).abs() < 0.01,
                "{a:?} mean gap {mean} (want 0.05)"
            );
        }
    }

    #[test]
    fn lognormal_arrivals_are_heavier_tailed_than_poisson() {
        let mut rng = Prng::new(37);
        let n = 50_000;
        let max_of = |a: Arrivals, rng: &mut Prng| -> f64 {
            (0..n).map(|_| a.next_gap(rng)).fold(0.0, f64::max)
        };
        let pois = max_of(Arrivals::Poisson { rate: 10.0 }, &mut rng);
        let logn = max_of(Arrivals::Lognormal { rate: 10.0, sigma: 1.5 }, &mut rng);
        assert!(logn > pois, "lognormal max {logn} <= poisson max {pois}");
    }

    #[test]
    fn sleep_mode_actually_sleeps() {
        let l = NetSim::new(0.005, f64::MAX, Mode::Sleep);
        let t0 = std::time::Instant::now();
        l.send(10);
        assert!(t0.elapsed().as_secs_f64() >= 0.004);
    }
}
