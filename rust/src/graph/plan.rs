//! Ahead-of-time execution plans for intervention graphs.
//!
//! The paper's decoupling claim — the intervention graph separates
//! experimental design from model runtime — is what makes ahead-of-time
//! compilation of *hot graph shapes* possible: a dashboard or logit-lens
//! sweep submits the same graph shape thousands of times with different
//! constant payloads, and everything the admission compiler and executor
//! derive from the graph except those payloads (validation verdict,
//! optimization decisions, per-hook schedule, value lifetimes) is a pure
//! function of the graph's *structure*. This module captures that
//! derivation once as an [`ExecPlan`]:
//!
//! - [`structural_key`] hashes a graph's structure, masking constant
//!   payloads (a `Const`'s `data` values) while keeping everything that
//!   changes execution shape: op kinds, dependency wiring, module points,
//!   slice ranges, scale factors, `Const` dims (and element count),
//!   batch/shard/token geometry, and the execution mode. Two submissions
//!   that differ only in constant payloads collide; any structural
//!   difference diverges.
//! - [`compile`] runs the PR 5 pipeline in *parametric* form — identical
//!   passes, but CSE never merges `Const` nodes by payload — producing a
//!   template graph whose constants are holes, plus the recipe
//!   ([`ExecPlan::bind`]) to re-evaluate each hole from a freshly
//!   submitted graph. Binding is payload-only: validate, optimize, and
//!   scheduling prep are all skipped on a plan-cache hit.
//! - [`plan_memory`] assigns every interpreter value an arena slot by
//!   last use (the §B.1 freed-at-zero-listeners rule, simulated ahead of
//!   time), so a planned executor reuses slots in place — a chain of
//!   fused kernels runs in O(live values) slots instead of O(nodes).
//!
//! Plans are cached per model by [`super::plan_cache::PlanCache`]; the
//! invalidation contract (model swap, optimizer-flag change) is
//! documented there and in `docs/ARCHITECTURE.md`.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::opt::{self, OptReport, Prepared};
use super::{InterventionGraph, Node, NodeId, Op, Port};
use crate::tensor::Tensor;

/// Which execution mode a plan was compiled for. The mode participates in
/// the structural key because the three admission paths validate against
/// different rule sets (`StepHook` is stream-only, `LoadState`/`StoreState`
/// are session-only), so a hit must never cross modes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanMode {
    /// One-shot trace (`POST /v1/trace`).
    Trace,
    /// Streaming generation (`POST /v1/stream`).
    Stream,
    /// A trace inside a stateful session (`POST /v1/session`).
    Session,
}

impl PlanMode {
    fn tag(self) -> u64 {
        match self {
            PlanMode::Trace => 0,
            PlanMode::Stream => 1,
            PlanMode::Session => 2,
        }
    }
}

/// The executor's node ordering, computed once per plan: pre-phase nodes,
/// per-hook sub-graphs keyed by forward-sequence position (§B.1), and
/// post-phase nodes. Mirrors exactly what `interp::Executor` derives at
/// construction — the executor itself delegates here, so the two can
/// never drift.
#[derive(Clone, Debug, Default)]
pub struct ExecOrder {
    /// Nodes with no model dependencies, run before the forward pass.
    pub pre: Vec<NodeId>,
    /// `fwd[k]` = nodes to run at the hook of forward position `k`.
    pub fwd: Vec<Vec<NodeId>>,
    /// Nodes depending on gradients, run after the backward pass.
    pub post: Vec<NodeId>,
}

/// Compute the pre/fwd/post schedule for `graph` against a model's
/// forward sequence (§B.1: each sub-graph keyed by the *latest* module
/// activation it transitively depends on; setters pinned to the hook of
/// the module they write). Errors exactly when executor construction
/// would: unknown modules, input-of-the-first-module getters.
pub fn execution_order(
    graph: &InterventionGraph,
    forward_sequence: &[String],
) -> Result<ExecOrder> {
    #[derive(Clone, Copy, PartialEq, Eq)]
    enum Phase {
        Pre,
        Fwd(usize),
        Post,
    }

    let order: HashMap<&str, usize> = forward_sequence
        .iter()
        .enumerate()
        .map(|(i, m)| (m.as_str(), i))
        .collect();
    let point_of = |module: &str, port: Port| -> Result<usize> {
        let k = *order
            .get(module)
            .ok_or_else(|| anyhow!("unknown module {module}"))?;
        match port {
            Port::Output => Ok(k),
            Port::Input => {
                if k == 0 {
                    Err(anyhow!("module {module} has no observable input (it is first)"))
                } else {
                    Ok(k - 1)
                }
            }
        }
    };

    let n = graph.nodes.len();
    let mut phase = vec![Phase::Pre; n];
    for node in &graph.nodes {
        let mut p = match &node.op {
            Op::Getter { module, port } => Phase::Fwd(point_of(module, *port)?),
            Op::Grad { .. } => Phase::Post,
            _ => Phase::Pre,
        };
        for d in node.op.deps() {
            p = match (p, phase[d]) {
                (Phase::Post, _) | (_, Phase::Post) => Phase::Post,
                (Phase::Fwd(a), Phase::Fwd(b)) => Phase::Fwd(a.max(b)),
                (Phase::Fwd(a), Phase::Pre) | (Phase::Pre, Phase::Fwd(a)) => Phase::Fwd(a),
                (Phase::Pre, Phase::Pre) => Phase::Pre,
            };
        }
        // setters run at the hook of the module they write
        if let Op::Setter { module, port, .. } = &node.op {
            p = Phase::Fwd(point_of(module, *port)?);
        }
        phase[node.id] = p;
    }

    let mut out = ExecOrder {
        pre: Vec::new(),
        fwd: vec![Vec::new(); forward_sequence.len()],
        post: Vec::new(),
    };
    for node in &graph.nodes {
        match phase[node.id] {
            Phase::Pre => out.pre.push(node.id),
            Phase::Fwd(k) => out.fwd[k].push(node.id),
            Phase::Post => out.post.push(node.id),
        }
    }
    Ok(out)
}

/// Per-node lock flags: `Save`/`StepHook` lock their dependency's value
/// for return to the user (LockProtocol), exempting it from the
/// freed-at-zero-listeners rule.
pub fn locked_flags(graph: &InterventionGraph) -> Vec<bool> {
    let mut locked = vec![false; graph.nodes.len()];
    for node in &graph.nodes {
        if let Op::Save { arg } | Op::StepHook { arg } = node.op {
            locked[arg] = true;
        }
    }
    locked
}

/// A liveness-derived arena assignment: which slot each node's value
/// occupies, and how many slots the arena needs in total.
#[derive(Clone, Debug, Default)]
pub struct MemoryPlan {
    /// `slot_of[id]` = the arena slot node `id`'s value lives in; `None`
    /// for values that are never materialized (dead on arrival: no
    /// listeners and not locked).
    pub slot_of: Vec<Option<usize>>,
    /// Arena size; always ≤ the node count, and equal to the executor's
    /// peak simultaneously-held value count for this graph.
    pub n_slots: usize,
}

/// Simulate the executor's §B.1 memory discipline over the planned node
/// order and assign each value the lowest slot that is free at its birth.
/// Within one node, dependency slots are released *before* the node's own
/// value is placed — a single-listener chain (the shape the fusion pass
/// produces) therefore reuses one slot in place down the whole chain.
///
/// The simulation mirrors the interpreter exactly: each dependency edge
/// consumes one listener claim (a node listed twice decrements twice), a
/// value is freed when its claims reach zero unless a `Save`/`StepHook`
/// locked it, and a node whose value nothing will ever read (zero
/// listeners, unlocked) is never allocated at all.
pub fn plan_memory(graph: &InterventionGraph, order: &ExecOrder, locked: &[bool]) -> MemoryPlan {
    let n = graph.nodes.len();
    let init = graph.listener_counts();
    let mut listeners = init.clone();
    let mut slot_of: Vec<Option<usize>> = vec![None; n];
    let mut resident = vec![false; n];
    let mut free: BTreeSet<usize> = BTreeSet::new();
    let mut n_slots = 0usize;

    // Linear execution order: pre-phase, each hook in forward order, then
    // the post phase (gradient values are injected before the remaining
    // post nodes run — same order as `Executor::run_post`).
    let mut linear: Vec<NodeId> = Vec::with_capacity(n);
    linear.extend(order.pre.iter().copied());
    for hook in &order.fwd {
        linear.extend(hook.iter().copied());
    }
    linear.extend(
        order
            .post
            .iter()
            .copied()
            .filter(|&id| matches!(graph.nodes[id].op, Op::Grad { .. })),
    );
    linear.extend(
        order
            .post
            .iter()
            .copied()
            .filter(|&id| !matches!(graph.nodes[id].op, Op::Grad { .. })),
    );

    for &id in &linear {
        // release dependency claims first (the executor's take_dep runs
        // before its put), so this node may inherit a dep's slot in place
        for d in graph.nodes[id].op.deps() {
            listeners[d] = listeners[d].saturating_sub(1);
            if listeners[d] == 0 && !locked[d] && resident[d] {
                resident[d] = false;
                free.insert(slot_of[d].expect("resident value has a slot"));
            }
        }
        // dead-on-arrival values are never placed (mirrors `put`)
        if init[id] > 0 || locked[id] {
            let s = free.pop_first().unwrap_or_else(|| {
                let s = n_slots;
                n_slots += 1;
                s
            });
            slot_of[id] = Some(s);
            resident[id] = true;
        }
    }
    MemoryPlan { slot_of, n_slots }
}

/// 64-bit FNV-1a accumulator for the structural key.
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }
    fn u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100_0000_01b3);
        }
    }
    fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }
    fn str(&mut self, s: &str) {
        self.usize(s.len());
        for b in s.bytes() {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100_0000_01b3);
        }
    }
    fn f32bits(&mut self, v: f32) {
        self.u64(v.to_bits() as u64);
    }
}

/// Hash everything about `graph` that determines the outcome of
/// validation, optimization, scheduling, and memory planning — and
/// nothing that doesn't.
///
/// Masked (rebound per submission by [`ExecPlan::bind`]): `Const`
/// payload values, the token payload, target *values*, and the saved-id
/// space (normalized by construction: the save-remap is itself a pure
/// function of structure).
///
/// Hashed: the mode and optimizer flag, batch/shard geometry, token
/// count, batch-group placement, target presence and length, and per
/// node the op kind, every dependency edge, module points, slice ranges,
/// reshape dims, scale/fill factors (bit-exact: a factor is part of the
/// *computation*, not a payload), `Const` dims **and element count** (so
/// a malformed `data.len() != prod(dims)` graph hashes consistently and
/// both cold and hot admission reject it identically), and state keys.
///
/// The model name is deliberately *not* hashed — it is the cache's outer
/// key, so model-swap invalidation can evict by name.
pub fn structural_key(graph: &InterventionGraph, mode: PlanMode, optimize: bool) -> u64 {
    let mut h = Fnv::new();
    h.u64(mode.tag());
    h.u64(optimize as u64);
    h.usize(graph.batch);
    h.usize(graph.shards);
    h.usize(graph.tokens.len());
    match graph.batch_group {
        None => h.u64(0),
        Some((off, rows)) => {
            h.u64(1);
            h.usize(off);
            h.usize(rows);
        }
    }
    match &graph.targets {
        None => h.u64(0),
        Some(t) => {
            h.u64(1);
            h.usize(t.len());
        }
    }
    h.usize(graph.nodes.len());
    for node in &graph.nodes {
        h.str(node.op.tag());
        let deps = node.op.deps();
        h.usize(deps.len());
        for d in deps {
            h.usize(d);
        }
        match &node.op {
            Op::Getter { module, port } | Op::Setter { module, port, .. } => {
                h.str(module);
                h.u64(matches!(port, Port::Output) as u64);
            }
            Op::Grad { module } => h.str(module),
            Op::Const { dims, data } => {
                h.usize(dims.len());
                for &d in dims {
                    h.usize(d);
                }
                h.usize(data.len()); // payload masked, shape kept
            }
            Op::Slice { ranges, .. } | Op::Assign { ranges, .. } => {
                h.str(&format!("{ranges:?}"));
            }
            Op::Fill { ranges, value, .. } => {
                h.str(&format!("{ranges:?}"));
                h.f32bits(*value);
            }
            Op::Scale { factor, .. }
            | Op::FusedScaleAdd { factor, .. }
            | Op::FusedScaleSoftmax { factor, .. } => h.f32bits(*factor),
            Op::Reshape { dims, .. } => {
                h.usize(dims.len());
                for &d in dims {
                    h.usize(d);
                }
            }
            Op::MeanAxis { axis, .. } => h.usize(*axis),
            Op::LogitDiff { target, foil, .. } => {
                h.usize(*target);
                h.usize(*foil);
            }
            Op::LoadState { key } | Op::StoreState { key, .. } => h.str(key),
            Op::Add { .. }
            | Op::Sub { .. }
            | Op::Mul { .. }
            | Op::Matmul { .. }
            | Op::Gelu { .. }
            | Op::Softmax { .. }
            | Op::Argmax { .. }
            | Op::Mean { .. }
            | Op::Sum { .. }
            | Op::Transpose { .. }
            | Op::Save { .. }
            | Op::StepHook { .. }
            | Op::FusedMatmulGelu { .. } => {}
        }
    }
    h.0
}

/// A compiled, reusable execution plan for one graph structure: the
/// optimized template with constant holes, the rebind recipe, the
/// executor schedule, and the arena assignment. Immutable once built —
/// cache hits share it behind an `Arc` and bind per submission.
#[derive(Debug)]
pub struct ExecPlan {
    /// The optimized (or raw, under `--no-opt`) graph whose constant
    /// payloads get re-stamped at bind time.
    template: InterventionGraph,
    /// `submitted id → template id` for every `Save`/`StepHook` node
    /// (`None` when compiled without optimization).
    save_remap: Option<BTreeMap<NodeId, NodeId>>,
    /// What the parametric pipeline did (`None` without optimization).
    report: Option<OptReport>,
    /// Pre/per-hook/post schedule of the template.
    order: ExecOrder,
    /// Lock flags of the template (Save/StepHook args).
    locked: Vec<bool>,
    /// Liveness-derived arena assignment for the template.
    memory: Arc<MemoryPlan>,
    /// `(template const id, submitted source id)` pairs: each template
    /// `Const` re-evaluates from the submitted graph's subtree at bind.
    consts: Vec<(NodeId, NodeId)>,
    /// Ascending submitted-graph node ids to evaluate at bind time (the
    /// transitive constant closure; all pure with `Const` leaves).
    fold_nodes: Vec<NodeId>,
    /// Node count a bindable submission must have.
    n_submitted: usize,
    /// The structural key this plan was compiled under.
    key: u64,
    /// The execution mode this plan was compiled for.
    mode: PlanMode,
}

impl ExecPlan {
    /// The structural key this plan was compiled under.
    pub fn key(&self) -> u64 {
        self.key
    }

    /// The execution mode this plan was compiled for.
    pub fn mode(&self) -> PlanMode {
        self.mode
    }

    /// The optimization report of the parametric compile (`None` when the
    /// plan wraps a raw graph).
    pub fn report(&self) -> Option<OptReport> {
        self.report
    }

    /// The template's executor schedule.
    pub fn order(&self) -> &ExecOrder {
        &self.order
    }

    /// The template's lock flags.
    pub fn locked(&self) -> &[bool] {
        &self.locked
    }

    /// The template's arena assignment.
    pub fn memory(&self) -> &Arc<MemoryPlan> {
        &self.memory
    }

    /// The template graph (constants hold the payloads of the compile-time
    /// submission until [`ExecPlan::bind`] re-stamps them).
    pub fn template(&self) -> &InterventionGraph {
        &self.template
    }

    /// Arena slot count of the planned executor.
    pub fn slots(&self) -> usize {
        self.memory.n_slots
    }

    /// How many template values actually get materialized (nodes with an
    /// arena slot) — the numerator of the slots-per-value gauge.
    pub fn planned_values(&self) -> usize {
        self.memory.slot_of.iter().filter(|s| s.is_some()).count()
    }

    /// Rebind this plan against a freshly submitted graph with the same
    /// structure: re-evaluate the constant closure with the submission's
    /// payloads, stamp the template's constant holes, and carry over the
    /// request payloads (tokens, targets, batch group). This is the whole
    /// cost of a plan-cache hit — validation, optimization passes, and
    /// scheduling prep are all skipped.
    ///
    /// The caller guarantees the submission's structural key matches the
    /// plan's; shape guards here are defense in depth, not a contract.
    pub fn bind(self: &Arc<Self>, graph: &InterventionGraph) -> Result<Prepared> {
        if graph.nodes.len() != self.n_submitted {
            return Err(anyhow!(
                "plan bind: graph has {} nodes, plan expects {}",
                graph.nodes.len(),
                self.n_submitted
            ));
        }
        if graph.model != self.template.model {
            return Err(anyhow!(
                "plan bind: graph targets model '{}', plan was compiled for '{}'",
                graph.model,
                self.template.model
            ));
        }
        // Evaluate the constant closure bottom-up with the submission's
        // payloads. Every failure condition of `eval_pure` is shape-
        // dependent, and shapes are structural — so a structure that
        // compiled cleanly binds cleanly.
        let mut val: HashMap<NodeId, Tensor> = HashMap::with_capacity(self.fold_nodes.len());
        for &i in &self.fold_nodes {
            let v = opt::eval_pure(&graph.nodes[i].op, &|d: NodeId| {
                val.get(&d).expect("fold closure is dep-closed").clone()
            })?;
            val.insert(i, v);
        }
        let mut bound = self.template.clone();
        for &(t, s) in &self.consts {
            let v = val
                .get(&s)
                .ok_or_else(|| anyhow!("plan bind: missing value for source node {s}"))?;
            match &mut bound.nodes[t].op {
                Op::Const { dims, data } => {
                    if v.dims() != &dims[..] {
                        return Err(anyhow!(
                            "plan bind: node {t} shape {:?} != template {:?}",
                            v.dims(),
                            dims
                        ));
                    }
                    *data = v.data().to_vec();
                }
                other => {
                    return Err(anyhow!(
                        "plan bind: template node {t} is '{}', expected const",
                        other.tag()
                    ))
                }
            }
        }
        bound.tokens = graph.tokens.clone();
        bound.batch = graph.batch;
        bound.targets = graph.targets.clone();
        bound.batch_group = graph.batch_group;
        bound.shards = graph.shards;
        Ok(Prepared {
            graph: bound,
            save_remap: self.save_remap.clone(),
            report: self.report,
            plan: Some(Arc::clone(self)),
        })
    }
}

/// Compile a structural plan for `graph`: run the admission pipeline in
/// parametric form (when `optimize` is set), derive the schedule, lock
/// flags, and arena assignment of the resulting template, and record the
/// constant-rebind recipe. Errors are admission errors (unknown modules,
/// failing constant subtrees) — exactly the set `opt::prepare` reports,
/// so a plan-compiling admission path rejects the same graphs the
/// pre-plan path did.
pub fn compile(
    graph: &InterventionGraph,
    forward_sequence: &[String],
    mode: PlanMode,
    optimize: bool,
) -> Result<ExecPlan> {
    let n = graph.nodes.len();
    let key = structural_key(graph, mode, optimize);

    // Which template constants rebind from which submitted nodes. With
    // optimization the pipeline rewrites folded nodes to `Const` *in
    // place* (index preserved before compaction), so the submitted source
    // of template node `new_id[i]` is always `i`; without optimization
    // every submitted `Const` maps to itself.
    let mut consts: Vec<(NodeId, NodeId)> = Vec::new();
    let (template_nodes, save_remap, report) = if optimize {
        let rw = opt::rewrite(graph, forward_sequence, false)?;
        let mut save_remap = BTreeMap::new();
        for node in &graph.nodes {
            if matches!(node.op, Op::Save { .. } | Op::StepHook { .. }) {
                save_remap.insert(node.id, rw.new_id[node.id]);
            }
        }
        for (i, &ni) in rw.new_id.iter().enumerate() {
            if ni != usize::MAX && matches!(rw.nodes[ni].op, Op::Const { .. }) {
                consts.push((ni, i));
            }
        }
        (rw.nodes, Some(save_remap), Some(rw.report))
    } else {
        for node in &graph.nodes {
            if matches!(node.op, Op::Const { .. }) {
                consts.push((node.id, node.id));
            }
        }
        (graph.nodes.clone(), None, None)
    };

    // Transitive dependency closure (in the submitted graph) of every
    // constant source: the nodes bind must re-evaluate, ascending so
    // dependencies always precede their consumers.
    let mut need = vec![false; n];
    let mut stack: Vec<NodeId> = consts.iter().map(|&(_, s)| s).collect();
    while let Some(i) = stack.pop() {
        if need[i] {
            continue;
        }
        need[i] = true;
        for d in graph.nodes[i].op.deps() {
            stack.push(d);
        }
    }
    let fold_nodes: Vec<NodeId> = (0..n).filter(|&i| need[i]).collect();

    let template = InterventionGraph {
        model: graph.model.clone(),
        tokens: graph.tokens.clone(),
        batch: graph.batch,
        nodes: template_nodes,
        targets: graph.targets.clone(),
        batch_group: graph.batch_group,
        shards: graph.shards,
    };
    let order = execution_order(&template, forward_sequence)?;
    let locked = locked_flags(&template);
    let memory = Arc::new(plan_memory(&template, &order, &locked));
    Ok(ExecPlan {
        template,
        save_remap,
        report,
        order,
        locked,
        memory,
        consts,
        fold_nodes,
        n_submitted: n,
        key,
        mode,
    })
}

#[cfg(test)]
mod tests {
    use std::collections::BTreeMap;

    use super::*;
    use crate::graph::GraphResult;
    use crate::interp::Executor;
    use crate::models::Hooks;
    use crate::tensor::Tensor;

    fn fseq() -> Vec<String> {
        vec!["embed".into(), "layer.0".into(), "layer.1".into(), "lm_head".into()]
    }

    fn acts(batch: usize) -> BTreeMap<String, Tensor> {
        let mut m = BTreeMap::new();
        m.insert("embed".to_string(), Tensor::iota(&[batch, 4]));
        m.insert("layer.0".to_string(), Tensor::iota(&[batch, 4]).scale(2.0));
        m.insert("layer.1".to_string(), Tensor::iota(&[batch, 4]).scale(3.0));
        m.insert("lm_head".to_string(), Tensor::iota(&[batch, 4]).scale(4.0));
        m
    }

    fn drive(ex: &mut Executor, acts: &mut BTreeMap<String, Tensor>) {
        for point in fseq() {
            if let Some(t) = acts.get_mut(&point) {
                if ex.wants(&point) {
                    ex.on_output(&point, t);
                }
            }
        }
    }

    /// A representative graph: getter math, a const subtree that folds,
    /// fusion fodder, and two saves.
    fn sample(payload: f32) -> InterventionGraph {
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let h = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        let c = g.push(Op::Const { dims: vec![1, 4], data: vec![payload; 4] });
        let cs = g.push(Op::Scale { arg: c, factor: 2.0 });
        let sum = g.push(Op::Add { a: h, b: cs });
        let sc = g.push(Op::Scale { arg: sum, factor: 0.5 });
        let sm = g.push(Op::Softmax { arg: sc });
        g.push(Op::Save { arg: sm });
        let m = g.push(Op::Mean { arg: h });
        g.push(Op::Save { arg: m });
        g
    }

    fn run_raw(g: &InterventionGraph) -> GraphResult {
        let mut ex = Executor::new(g, &fseq()).unwrap();
        ex.run_pre().unwrap();
        let mut a = acts(1);
        drive(&mut ex, &mut a);
        ex.into_result().unwrap()
    }

    fn run_planned(plan: &Arc<ExecPlan>, g: &InterventionGraph) -> GraphResult {
        let p = plan.bind(g).unwrap();
        let mut ex = Executor::planned(&p.graph, &fseq(), crate::interp::StateView::new(), plan);
        ex.run_pre().unwrap();
        let mut a = acts(1);
        drive(&mut ex, &mut a);
        p.remap_values(ex.into_result().unwrap())
    }

    #[test]
    fn same_structure_different_payload_collides() {
        let a = sample(1.0);
        let b = sample(42.5);
        assert_eq!(
            structural_key(&a, PlanMode::Trace, true),
            structural_key(&b, PlanMode::Trace, true)
        );
    }

    #[test]
    fn structural_differences_diverge() {
        let base = sample(1.0);
        let k = structural_key(&base, PlanMode::Trace, true);
        // different const DIMS is structural
        let mut g = sample(1.0);
        if let Op::Const { dims, data } = &mut g.nodes[1].op {
            *dims = vec![4];
            data.truncate(4);
        }
        assert_ne!(structural_key(&g, PlanMode::Trace, true), k);
        // different scale factor is structural
        let mut g = sample(1.0);
        if let Op::Scale { factor, .. } = &mut g.nodes[2].op {
            *factor = 3.0;
        }
        assert_ne!(structural_key(&g, PlanMode::Trace, true), k);
        // an extra node is structural
        let mut g = sample(1.0);
        let last = g.nodes.len() - 1;
        g.push(Op::Save { arg: last });
        assert_ne!(structural_key(&g, PlanMode::Trace, true), k);
        // mode and optimizer flag partition the key space
        assert_ne!(structural_key(&base, PlanMode::Stream, true), k);
        assert_ne!(structural_key(&base, PlanMode::Trace, false), k);
    }

    #[test]
    fn memory_plan_no_overlap_and_reuse() {
        let g = sample(1.0);
        let order = execution_order(&g, &fseq()).unwrap();
        let locked = locked_flags(&g);
        let plan = plan_memory(&g, &order, &locked);
        // no two simultaneously-live nodes share a slot: re-simulate
        // liveness independently and check residency per slot
        let init = g.listener_counts();
        let mut listeners = init.clone();
        let mut owner: Vec<Option<NodeId>> = vec![None; plan.n_slots];
        let mut linear: Vec<NodeId> = Vec::new();
        linear.extend(&order.pre);
        for f in &order.fwd {
            linear.extend(f);
        }
        linear.extend(order.post.iter().copied());
        for &id in &linear {
            for d in g.nodes[id].op.deps() {
                listeners[d] = listeners[d].saturating_sub(1);
                if listeners[d] == 0 && !locked[d] {
                    if let Some(s) = plan.slot_of[d] {
                        if owner[s] == Some(d) {
                            owner[s] = None;
                        }
                    }
                }
            }
            if init[id] > 0 || locked[id] {
                let s = plan.slot_of[id].expect("live node has a slot");
                assert!(owner[s].is_none(), "slot {s} still owned by {:?}", owner[s]);
                owner[s] = Some(id);
            }
        }
        // slots are genuinely reused: fewer slots than placed values
        let placed = plan.slot_of.iter().filter(|s| s.is_some()).count();
        assert!(plan.n_slots < placed, "{} slots for {placed} values", plan.n_slots);
    }

    #[test]
    fn compile_bind_matches_raw_interpreter() {
        let compiled_from = sample(1.0);
        let plan = Arc::new(compile(&compiled_from, &fseq(), PlanMode::Trace, true).unwrap());
        // bind against a DIFFERENT payload than the plan was compiled from
        let fresh = sample(-3.25);
        let planned = run_planned(&plan, &fresh);
        let raw = run_raw(&fresh);
        assert_eq!(planned.values, raw.values);
        // and the cache-compile submission itself
        let planned0 = run_planned(&plan, &compiled_from);
        let raw0 = run_raw(&compiled_from);
        assert_eq!(planned0.values, raw0.values);
    }

    #[test]
    fn unoptimized_plan_binds_and_matches() {
        let g = sample(7.0);
        let plan = Arc::new(compile(&g, &fseq(), PlanMode::Trace, false).unwrap());
        assert!(plan.report().is_none());
        let fresh = sample(0.125);
        let planned = run_planned(&plan, &fresh);
        assert_eq!(planned.values, run_raw(&fresh).values);
    }

    #[test]
    fn compile_fails_on_failing_const_subtree() {
        // mean of an empty const slice fails at plan compile — the same
        // admission error `opt::prepare` reports
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let c = g.push(Op::Const { dims: vec![4], data: vec![1.0; 4] });
        let e = g.push(Op::Slice { arg: c, ranges: vec![crate::tensor::Range1::new(2, 2)] });
        let m = g.push(Op::Mean { arg: e });
        g.push(Op::Save { arg: m });
        let err = compile(&g, &fseq(), PlanMode::Trace, true).unwrap_err().to_string();
        assert!(err.contains("empty"), "{err}");
    }

    #[test]
    fn execution_order_matches_phase_rules() {
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        g.targets = Some(vec![1.0]);
        let c = g.push(Op::Const { dims: vec![1], data: vec![2.0] });
        let h = g.push(Op::Getter { module: "layer.1".into(), port: Port::Input });
        let m = g.push(Op::Mul { a: h, b: c });
        g.push(Op::Setter { module: "layer.1".into(), port: Port::Output, arg: m });
        let gr = g.push(Op::Grad { module: "layer.0".into() });
        let s = g.push(Op::Scale { arg: gr, factor: -1.0 });
        g.push(Op::Save { arg: s });
        let order = execution_order(&g, &fseq()).unwrap();
        assert_eq!(order.pre, vec![c]);
        // getter at layer.1 INPUT = layer.0 output (position 1); the mul
        // joins it there; the setter is pinned to layer.1 (position 2)
        assert_eq!(order.fwd[1], vec![h, m]);
        assert_eq!(order.fwd[2], vec![3]);
        assert_eq!(order.post, vec![gr, s, 6]);
    }

    #[test]
    fn bind_rejects_structural_mismatch() {
        let plan = Arc::new(compile(&sample(1.0), &fseq(), PlanMode::Trace, true).unwrap());
        let mut other = sample(1.0);
        other.nodes.pop();
        assert!(plan.bind(&other).is_err());
        let mut wrong_model = sample(1.0);
        wrong_model.model = "other-model".into();
        assert!(plan.bind(&wrong_model).is_err());
    }
}
