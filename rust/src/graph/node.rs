//! Intervention-graph nodes.
//!
//! In the paper's formalism (§3.1) an intervention component C′ is a
//! computation graph of *apply nodes* (operations) and *variable nodes*
//! (their results), attached to the model's computation graph C by
//! *getter* edges (C → C′) and *setter* edges (C′ → C). In this IR each
//! [`Node`] is an apply node whose single output is its implicit variable
//! node (the many-to-one form; Appendix E of the paper shows the
//! equivalence with Theano's many-to-many form). Getter/Setter ops carry
//! the attachment points.
//!
//! A graph is a *description* of the experiment, not a fixed execution
//! recipe: because the intervention graph decouples experimental design
//! from the model runtime, the fabric is free to rewrite a submitted
//! graph — dead-code elimination, constant folding, common-subexpression
//! elimination, and kernel fusion ([`crate::graph::opt`]) — as long as
//! every saved value is bit-identical to the unoptimized execution. The
//! `Fused*` variants below are the internal ops that rewriting produces;
//! clients never need to build them directly.

use crate::tensor::Range1;

/// Node identifier. Construction keeps graphs topologically ordered:
/// arguments always reference lower ids.
pub type NodeId = usize;

/// Which side of a module a Getter/Setter attaches to. `Input` of module
/// `layer.i` is the same variable node as `Output` of the previous module
/// in the sequence (our modules are layer-granular), but the distinction
/// is kept for API fidelity with NNsight's `.input`/`.output`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Port {
    /// The module's input activation (= the previous module's output).
    Input,
    /// The module's output activation.
    Output,
}

/// A slice specification used by Slice/Assign/Fill ops.
pub type Ranges = Vec<Range1>;

/// Operations. Every op produces exactly one value (tensor or scalar
/// tensor). `arg`/`a`/`b` are dependencies (edges from their variable
/// nodes into this apply node).
#[derive(Clone, Debug, PartialEq)]
pub enum Op {
    /// Read a module activation (getter edge from C into C′).
    Getter { module: String, port: Port },
    /// Write a value back into a module activation, replacing rows/slices
    /// (setter edge from C′ into C). Produces the written value.
    Setter { module: String, port: Port, arg: NodeId },
    /// Gradient of the request loss w.r.t. a module's output
    /// (GradProtocol; requires the request to carry targets).
    Grad { module: String },
    /// A literal tensor shipped with the graph.
    Const { dims: Vec<usize>, data: Vec<f32> },
    /// Multi-dimensional slice.
    Slice { arg: NodeId, ranges: Ranges },
    /// Functional slice-assign: `dst` with `src` written at `ranges`.
    Assign { dst: NodeId, ranges: Ranges, src: NodeId },
    /// Functional fill: `dst` with `ranges` set to `value` (ablation).
    Fill { dst: NodeId, ranges: Ranges, value: f32 },
    /// Elementwise (broadcasting) addition.
    Add { a: NodeId, b: NodeId },
    /// Elementwise (broadcasting) subtraction.
    Sub { a: NodeId, b: NodeId },
    /// Elementwise (broadcasting) multiplication.
    Mul { a: NodeId, b: NodeId },
    /// Scalar multiply.
    Scale { arg: NodeId, factor: f32 },
    /// Matrix product (`b` must be 2-D; contracts `a`'s last axis).
    Matmul { a: NodeId, b: NodeId },
    /// tanh-approximation GELU (the model's MLP activation).
    Gelu { arg: NodeId },
    /// Softmax over the last axis.
    Softmax { arg: NodeId },
    /// Argmax over the last axis (drops that axis).
    Argmax { arg: NodeId },
    /// Mean over all elements (scalar result). Empty inputs are an
    /// execution error, not NaN — see `docs/PROTOCOL.md`.
    Mean { arg: NodeId },
    /// Sum over all elements (scalar result). Empty inputs are an
    /// execution error, matching [`Op::Mean`].
    Sum { arg: NodeId },
    /// 2-D transpose (probe/optimizer math: `xᵀ·g` weight gradients).
    Transpose { arg: NodeId },
    /// Reshape to `dims` (element count must match).
    Reshape { arg: NodeId, dims: Vec<usize> },
    /// Reduce-mean over one axis.
    MeanAxis { arg: NodeId, axis: usize },
    /// The standard patching metric on last-token logits.
    LogitDiff { logits: NodeId, target: usize, foil: usize },
    /// LockProtocol: pin the value for return to the user (`.save()`).
    Save { arg: NodeId },
    /// Per-step emission marker for streaming generation: like `Save`, but
    /// the graph re-executes at every decode step and this value is
    /// emitted in that step's `StepEvent` instead of one final result.
    /// Only valid in a streaming request (`POST /v1/stream`).
    StepHook { arg: NodeId },
    /// Read a named session-state variable (server-side parameter state,
    /// paper Code Example 5). Resolved in the pre-phase from the session's
    /// state view — within one trace a load always observes the value the
    /// key had when the trace started.
    LoadState { key: String },
    /// Write a value into a named session-state variable. Commits after
    /// the trace completes (post-phase), so later traces in the same
    /// session observe it. Produces the stored value.
    StoreState { key: String, arg: NodeId },
    /// Internal fused op (`a + factor·b`), produced by the optimizer's
    /// fusion pass from an `Add` whose operand is a single-use `Scale`;
    /// dispatches to the in-place `scale_add_assign` kernel. Numerically
    /// bit-identical to the unfused pair.
    FusedScaleAdd { a: NodeId, b: NodeId, factor: f32 },
    /// Internal fused op (`gelu(matmul(a, b))`), produced from a `Gelu`
    /// consuming a single-use `Matmul`; the GELU runs in place on the
    /// product (`gelu_inplace`) with no intermediate node.
    FusedMatmulGelu { a: NodeId, b: NodeId },
    /// Internal fused op (`softmax(arg · factor)` over the last axis),
    /// produced from a `Softmax` consuming a single-use `Scale`; runs
    /// `scale_inplace` + `softmax_last_inplace` on one buffer.
    FusedScaleSoftmax { arg: NodeId, factor: f32 },
}

impl Op {
    /// Dependency node ids of this op (edges into this apply node).
    pub fn deps(&self) -> Vec<NodeId> {
        match self {
            Op::Getter { .. } | Op::Grad { .. } | Op::Const { .. } | Op::LoadState { .. } => vec![],
            Op::Setter { arg, .. }
            | Op::Slice { arg, .. }
            | Op::Scale { arg, .. }
            | Op::Gelu { arg }
            | Op::Softmax { arg }
            | Op::Argmax { arg }
            | Op::Mean { arg }
            | Op::Sum { arg }
            | Op::Transpose { arg }
            | Op::Reshape { arg, .. }
            | Op::MeanAxis { arg, .. }
            | Op::Save { arg }
            | Op::StepHook { arg }
            | Op::StoreState { arg, .. }
            | Op::FusedScaleSoftmax { arg, .. } => vec![*arg],
            Op::Fill { dst, .. } => vec![*dst],
            Op::Assign { dst, src, .. } => vec![*dst, *src],
            Op::Add { a, b }
            | Op::Sub { a, b }
            | Op::Mul { a, b }
            | Op::Matmul { a, b }
            | Op::FusedScaleAdd { a, b, .. }
            | Op::FusedMatmulGelu { a, b } => {
                vec![*a, *b]
            }
            Op::LogitDiff { logits, .. } => vec![*logits],
        }
    }

    /// Rewrite every dependency id through `f` (used by the optimizer when
    /// it redirects consumers to a merged node or renumbers a compacted
    /// graph). The mapping is applied to each edge exactly once.
    pub fn map_deps(&mut self, mut f: impl FnMut(NodeId) -> NodeId) {
        match self {
            Op::Getter { .. } | Op::Grad { .. } | Op::Const { .. } | Op::LoadState { .. } => {}
            Op::Setter { arg, .. }
            | Op::Slice { arg, .. }
            | Op::Scale { arg, .. }
            | Op::Gelu { arg }
            | Op::Softmax { arg }
            | Op::Argmax { arg }
            | Op::Mean { arg }
            | Op::Sum { arg }
            | Op::Transpose { arg }
            | Op::Reshape { arg, .. }
            | Op::MeanAxis { arg, .. }
            | Op::Save { arg }
            | Op::StepHook { arg }
            | Op::StoreState { arg, .. }
            | Op::FusedScaleSoftmax { arg, .. } => *arg = f(*arg),
            Op::Fill { dst, .. } => *dst = f(*dst),
            Op::Assign { dst, src, .. } => {
                *dst = f(*dst);
                *src = f(*src);
            }
            Op::Add { a, b }
            | Op::Sub { a, b }
            | Op::Mul { a, b }
            | Op::Matmul { a, b }
            | Op::FusedScaleAdd { a, b, .. }
            | Op::FusedMatmulGelu { a, b } => {
                *a = f(*a);
                *b = f(*b);
            }
            Op::LogitDiff { logits, .. } => *logits = f(*logits),
        }
    }

    /// The wire-format tag.
    pub fn tag(&self) -> &'static str {
        match self {
            Op::Getter { .. } => "getter",
            Op::Setter { .. } => "setter",
            Op::Grad { .. } => "grad",
            Op::Const { .. } => "const",
            Op::Slice { .. } => "slice",
            Op::Assign { .. } => "assign",
            Op::Fill { .. } => "fill",
            Op::Add { .. } => "add",
            Op::Sub { .. } => "sub",
            Op::Mul { .. } => "mul",
            Op::Scale { .. } => "scale",
            Op::Matmul { .. } => "matmul",
            Op::Gelu { .. } => "gelu",
            Op::Softmax { .. } => "softmax",
            Op::Argmax { .. } => "argmax",
            Op::Mean { .. } => "mean",
            Op::Sum { .. } => "sum",
            Op::Transpose { .. } => "transpose",
            Op::Reshape { .. } => "reshape",
            Op::MeanAxis { .. } => "mean_axis",
            Op::LogitDiff { .. } => "logit_diff",
            Op::Save { .. } => "save",
            Op::StepHook { .. } => "step_hook",
            Op::LoadState { .. } => "load_state",
            Op::StoreState { .. } => "store_state",
            Op::FusedScaleAdd { .. } => "fused_scale_add",
            Op::FusedMatmulGelu { .. } => "fused_matmul_gelu",
            Op::FusedScaleSoftmax { .. } => "fused_scale_softmax",
        }
    }
}

/// One apply node.
#[derive(Clone, Debug, PartialEq)]
pub struct Node {
    /// Dense position in the graph's node list (ids ascend with order).
    pub id: NodeId,
    /// The operation this node applies.
    pub op: Op,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deps_extraction() {
        assert!(Op::Getter { module: "layer.0".into(), port: Port::Output }
            .deps()
            .is_empty());
        assert_eq!(Op::Add { a: 1, b: 2 }.deps(), vec![1, 2]);
        assert_eq!(
            Op::Assign { dst: 3, ranges: vec![], src: 5 }.deps(),
            vec![3, 5]
        );
        assert_eq!(Op::Save { arg: 7 }.deps(), vec![7]);
        assert_eq!(Op::StepHook { arg: 7 }.deps(), vec![7]);
        assert!(Op::LoadState { key: "w".into() }.deps().is_empty());
        assert_eq!(Op::StoreState { key: "w".into(), arg: 4 }.deps(), vec![4]);
        assert_eq!(Op::Transpose { arg: 2 }.deps(), vec![2]);
        assert_eq!(Op::Reshape { arg: 3, dims: vec![2, 2] }.deps(), vec![3]);
        assert_eq!(Op::MeanAxis { arg: 1, axis: 0 }.deps(), vec![1]);
        assert_eq!(Op::FusedScaleAdd { a: 1, b: 2, factor: 0.5 }.deps(), vec![1, 2]);
        assert_eq!(Op::FusedMatmulGelu { a: 3, b: 4 }.deps(), vec![3, 4]);
        assert_eq!(Op::FusedScaleSoftmax { arg: 5, factor: 2.0 }.deps(), vec![5]);
    }

    #[test]
    fn map_deps_rewrites_every_edge() {
        let mut op = Op::Assign { dst: 3, ranges: vec![], src: 5 };
        op.map_deps(|d| d + 10);
        assert_eq!(op.deps(), vec![13, 15]);
        let mut op = Op::FusedScaleAdd { a: 1, b: 2, factor: 0.5 };
        op.map_deps(|d| d * 2);
        assert_eq!(op.deps(), vec![2, 4]);
        let mut op = Op::Getter { module: "m".into(), port: Port::Output };
        op.map_deps(|_| unreachable!("no deps to map"));
        assert!(op.deps().is_empty());
    }

    #[test]
    fn tags_are_distinct() {
        let ops = [
            Op::Getter { module: "m".into(), port: Port::Output },
            Op::Setter { module: "m".into(), port: Port::Output, arg: 0 },
            Op::Add { a: 0, b: 0 },
            Op::Save { arg: 0 },
            Op::StepHook { arg: 0 },
            Op::LogitDiff { logits: 0, target: 0, foil: 1 },
            Op::Transpose { arg: 0 },
            Op::Reshape { arg: 0, dims: vec![1] },
            Op::MeanAxis { arg: 0, axis: 0 },
            Op::LoadState { key: "w".into() },
            Op::StoreState { key: "w".into(), arg: 0 },
            Op::FusedScaleAdd { a: 0, b: 0, factor: 1.0 },
            Op::FusedMatmulGelu { a: 0, b: 0 },
            Op::FusedScaleSoftmax { arg: 0, factor: 1.0 },
        ];
        let tags: std::collections::BTreeSet<_> = ops.iter().map(|o| o.tag()).collect();
        assert_eq!(tags.len(), ops.len());
    }
}
