//! The intervention graph — the paper's core architectural contribution.
//!
//! An [`InterventionGraph`] is a portable, JSON-serializable description of
//! an experiment on a model's internals: extra computation (apply nodes)
//! attached to the model's forward pass via getter edges (read a module
//! activation) and setter edges (write one back). Graphs are built by the
//! [`crate::client`] tracing API, validated ([`validate`]), serialized
//! ([`serde`]), optionally transmitted to an NDIF server, **optimized**
//! by the admission compiler ([`opt`]: dead-code elimination, constant
//! folding, CSE, kernel fusion — saved values stay bit-identical), and
//! interleaved with model execution by the [`crate::interp`] executor.
//! The full request lifecycle is documented in `docs/ARCHITECTURE.md`.
//!
//! # Examples
//!
//! Build a graph directly (the [`crate::client::Trace`] builder is the
//! ergonomic front end for the same thing) and validate it:
//!
//! ```
//! use nnscope::graph::{validate::validate, InterventionGraph, Op, Port};
//!
//! let fseq: Vec<String> = vec!["embed".into(), "layer.0".into(), "lm_head".into()];
//! let mut g = InterventionGraph::new("tiny-sim");
//! let h = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
//! let m = g.push(Op::Mean { arg: h });
//! let s = g.push(Op::Save { arg: m });
//! validate(&g, &fseq).unwrap();
//! assert_eq!(g.saves(), vec![s]);
//! assert_eq!(g.listener_counts(), vec![1, 1, 0]);
//! ```

#![warn(missing_docs)]

pub mod node;
pub mod opt;
pub mod plan;
pub mod plan_cache;
pub mod serde;
pub mod validate;

pub use node::{Node, NodeId, Op, Port};

use std::collections::BTreeMap;

use crate::tensor::Tensor;

/// A complete intervention graph: topologically-ordered apply nodes plus
/// the request context (model, input tokens, optional grad targets, and
/// the batch group used for parallel co-tenancy).
#[derive(Clone, Debug, Default)]
pub struct InterventionGraph {
    /// Target model name.
    pub model: String,
    /// Input token rows, flattened `[batch * seq]` (shaped by the model's
    /// seq); may be empty when merged into a co-tenant batch.
    pub tokens: Vec<f32>,
    /// Number of token rows.
    pub batch: usize,
    /// Nodes in topological order: `node.op.deps()` always reference
    /// earlier nodes (enforced by the builder; checked by the validator).
    pub nodes: Vec<Node>,
    /// Per-example grad targets (token ids), required by `Op::Grad`.
    pub targets: Option<Vec<f32>>,
    /// `(row_offset, rows)` of this user's slice within a merged co-tenant
    /// batch; `None` for a standalone request (offset 0, all rows).
    pub batch_group: Option<(usize, usize)>,
    /// How many shards to run the forward pass across (1 = unsharded).
    pub shards: usize,
}

impl InterventionGraph {
    /// An empty graph targeting `model` (unsharded, no tokens yet).
    pub fn new(model: &str) -> InterventionGraph {
        InterventionGraph { model: model.to_string(), shards: 1, ..Default::default() }
    }

    /// Append a node; returns its id. Panics if any dep is a forward
    /// reference (builder bug) — the wire-format validator reports the
    /// same condition as an error for untrusted graphs.
    pub fn push(&mut self, op: Op) -> NodeId {
        let id = self.nodes.len();
        for d in op.deps() {
            assert!(d < id, "forward reference {d} from node {id}");
        }
        self.nodes.push(Node { id, op });
        id
    }

    /// The node with id `id` (ids are dense positions).
    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    /// Ids of all Save nodes (the values returned to the user).
    pub fn saves(&self) -> Vec<NodeId> {
        self.nodes
            .iter()
            .filter(|n| matches!(n.op, Op::Save { .. }))
            .map(|n| n.id)
            .collect()
    }

    /// Module points read by getters.
    pub fn getter_points(&self) -> Vec<String> {
        self.nodes
            .iter()
            .filter_map(|n| match &n.op {
                Op::Getter { module, .. } => Some(module.clone()),
                _ => None,
            })
            .collect()
    }

    /// Module points written by setters.
    pub fn setter_points(&self) -> Vec<String> {
        self.nodes
            .iter()
            .filter_map(|n| match &n.op {
                Op::Setter { module, .. } => Some(module.clone()),
                _ => None,
            })
            .collect()
    }

    /// Ids of all StepHook nodes (values emitted per decode step when the
    /// graph runs as a streaming request).
    pub fn step_hooks(&self) -> Vec<NodeId> {
        self.nodes
            .iter()
            .filter(|n| matches!(n.op, Op::StepHook { .. }))
            .map(|n| n.id)
            .collect()
    }

    /// Does this graph carry per-step emission markers (stream-only)?
    pub fn uses_step_hooks(&self) -> bool {
        self.nodes
            .iter()
            .any(|n| matches!(n.op, Op::StepHook { .. }))
    }

    /// Keys read from session state (`Op::LoadState`).
    pub fn state_loads(&self) -> Vec<String> {
        self.nodes
            .iter()
            .filter_map(|n| match &n.op {
                Op::LoadState { key } => Some(key.clone()),
                _ => None,
            })
            .collect()
    }

    /// Keys written to session state (`Op::StoreState`).
    pub fn state_stores(&self) -> Vec<String> {
        self.nodes
            .iter()
            .filter_map(|n| match &n.op {
                Op::StoreState { key, .. } => Some(key.clone()),
                _ => None,
            })
            .collect()
    }

    /// Does this graph touch session state at all?
    pub fn uses_state(&self) -> bool {
        self.nodes
            .iter()
            .any(|n| matches!(n.op, Op::LoadState { .. } | Op::StoreState { .. }))
    }

    /// Module points whose gradients are requested.
    pub fn grad_points(&self) -> Vec<String> {
        self.nodes
            .iter()
            .filter_map(|n| match &n.op {
                Op::Grad { module } => Some(module.clone()),
                _ => None,
            })
            .collect()
    }

    /// Listener counts: for each node, how many later nodes consume it.
    /// The executor frees a value when its remaining listeners reach zero
    /// (§B.1 "when a Node's remaining listeners reaches zero … its memory
    /// [is] freed immediately"); Save nodes lock their dep.
    pub fn listener_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.nodes.len()];
        for n in &self.nodes {
            for d in n.op.deps() {
                counts[d] += 1;
            }
        }
        counts
    }

    /// Approximate serialized payload size in bytes (netsim accounting).
    pub fn wire_bytes(&self) -> usize {
        serde::to_json(self).to_string().len()
    }
}

/// The result of executing an intervention graph: saved values keyed by
/// node id.
#[derive(Clone, Debug, Default)]
pub struct GraphResult {
    /// Saved tensors keyed by the id of the `Save`/`StepHook` node that
    /// locked them — always the ids of the graph *as submitted*, even
    /// when the server rewrote it ([`opt::Optimized::remap_result`]).
    pub values: BTreeMap<NodeId, Tensor>,
}

impl GraphResult {
    /// The value locked by save node `id`, if present.
    pub fn get(&self, id: NodeId) -> Option<&Tensor> {
        self.values.get(&id)
    }

    /// Approximate serialized size (netsim accounting for the download).
    pub fn wire_bytes(&self) -> usize {
        16 + self
            .values
            .values()
            .map(|t| 32 + t.numel() * 16 / 3) // b64-packed f32 ≈ 5.33 B/val
            .sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_listeners() {
        let mut g = InterventionGraph::new("tiny-sim");
        let a = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        let b = g.push(Op::Scale { arg: a, factor: 2.0 });
        let c = g.push(Op::Add { a, b });
        let _s = g.push(Op::Save { arg: c });
        assert_eq!(g.listener_counts(), vec![2, 1, 1, 0]);
        assert_eq!(g.saves(), vec![3]);
        assert_eq!(g.getter_points(), vec!["layer.0"]);
    }

    #[test]
    #[should_panic]
    fn forward_reference_panics() {
        let mut g = InterventionGraph::new("m");
        g.push(Op::Scale { arg: 5, factor: 1.0 });
    }
}
