//! Bounded LRU cache of compiled execution plans, keyed by
//! `(model, structural hash)`.
//!
//! A hit returns the shared [`ExecPlan`] so admission skips validation,
//! the optimization pipeline, and scheduling prep entirely, paying only
//! [`ExecPlan::bind`] (constant re-evaluation + payload stamping). The
//! cache is the fabric's memory of hot graph shapes — dashboards and
//! sweeps that submit one structure thousands of times compile it once.
//!
//! # Invalidation contract
//!
//! Staleness is handled by **keying and explicit eviction**, never by
//! TTL luck:
//!
//! - The structural key folds in the execution mode and the optimizer
//!   flag, so a `--no-opt` (or config-file) change can never hit a plan
//!   compiled under different passes — the key simply differs.
//! - The model name is the *outer* key (deliberately not hashed), so a
//!   reloaded/swapped model is evicted by name via
//!   [`PlanCache::invalidate_model`]; a stale plan for a reloaded model
//!   must never execute.
//! - Failed compiles are never inserted, so an invalid structure fails
//!   identically on every resubmission (both-fail parity).
//! - Capacity pressure evicts the least-recently-used entry.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::plan::ExecPlan;

/// Cache key: model name plus the structural hash (which already encodes
/// the mode and optimizer flag).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct PlanKey {
    model: String,
    key: u64,
}

struct Slot {
    plan: Arc<ExecPlan>,
    last_used: u64,
}

#[derive(Default)]
struct Inner {
    map: HashMap<PlanKey, Slot>,
    tick: u64,
}

/// Point-in-time cache statistics (the `/v1/metrics` `_plan` object).
#[derive(Clone, Copy, Debug, Default)]
pub struct PlanCacheStats {
    /// Lookups that found a plan.
    pub hits: u64,
    /// Lookups that found nothing (a compile follows).
    pub misses: u64,
    /// Entries evicted by capacity pressure (LRU).
    pub evictions: u64,
    /// Entries evicted by model invalidation.
    pub invalidations: u64,
    /// Entries currently cached.
    pub size: usize,
    /// Capacity bound.
    pub capacity: usize,
    /// Sum of arena slots across cached plans (planner gauge).
    pub slots_planned: u64,
    /// Sum of materialized values across cached plans; with
    /// `slots_planned` this shows the in-place reuse ratio.
    pub values_planned: u64,
}

/// A bounded, thread-safe LRU plan cache shared across admission paths.
pub struct PlanCache {
    cap: usize,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    invalidations: AtomicU64,
}

impl PlanCache {
    /// A cache holding at most `cap` plans (minimum 1).
    pub fn new(cap: usize) -> PlanCache {
        PlanCache {
            cap: cap.max(1),
            inner: Mutex::new(Inner::default()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            invalidations: AtomicU64::new(0),
        }
    }

    /// Look up a plan for `(model, key)`, bumping hit/miss counters and
    /// recency on hit.
    pub fn get(&self, model: &str, key: u64) -> Option<Arc<ExecPlan>> {
        let mut inner = self.inner.lock().expect("plan cache lock");
        inner.tick += 1;
        let tick = inner.tick;
        let k = PlanKey { model: model.to_string(), key };
        match inner.map.get_mut(&k) {
            Some(slot) => {
                slot.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(Arc::clone(&slot.plan))
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert a freshly compiled plan, evicting the least-recently-used
    /// entry when at capacity. Inserting over an existing key replaces it
    /// (no eviction counted).
    pub fn insert(&self, model: &str, key: u64, plan: Arc<ExecPlan>) {
        let mut inner = self.inner.lock().expect("plan cache lock");
        inner.tick += 1;
        let tick = inner.tick;
        let k = PlanKey { model: model.to_string(), key };
        if !inner.map.contains_key(&k) && inner.map.len() >= self.cap {
            if let Some(victim) = inner
                .map
                .iter()
                .min_by_key(|(_, s)| s.last_used)
                .map(|(k, _)| k.clone())
            {
                inner.map.remove(&victim);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        inner.map.insert(k, Slot { plan, last_used: tick });
    }

    /// Drop every plan compiled for `model` (keyed eviction on model
    /// swap/reload — a stale plan for a reloaded model must never
    /// execute). Returns how many entries were removed.
    pub fn invalidate_model(&self, model: &str) -> usize {
        let mut inner = self.inner.lock().expect("plan cache lock");
        let before = inner.map.len();
        inner.map.retain(|k, _| k.model != model);
        let removed = before - inner.map.len();
        self.invalidations.fetch_add(removed as u64, Ordering::Relaxed);
        removed
    }

    /// Number of cached plans.
    pub fn len(&self) -> usize {
        self.inner.lock().expect("plan cache lock").map.len()
    }

    /// Is the cache empty?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot the counters and per-plan gauges.
    pub fn stats(&self) -> PlanCacheStats {
        let inner = self.inner.lock().expect("plan cache lock");
        let mut slots = 0u64;
        let mut values = 0u64;
        for s in inner.map.values() {
            slots += s.plan.slots() as u64;
            values += s.plan.planned_values() as u64;
        }
        PlanCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            invalidations: self.invalidations.load(Ordering::Relaxed),
            size: inner.map.len(),
            capacity: self.cap,
            slots_planned: slots,
            values_planned: values,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::plan::{compile, structural_key, PlanMode};
    use super::*;
    use crate::graph::{InterventionGraph, Op, Port};

    fn fseq() -> Vec<String> {
        vec!["embed".into(), "layer.0".into(), "layer.1".into(), "lm_head".into()]
    }

    fn graph(factor: f32) -> InterventionGraph {
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let h = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        let s = g.push(Op::Scale { arg: h, factor });
        g.push(Op::Save { arg: s });
        g
    }

    fn plan_for(g: &InterventionGraph) -> Arc<super::super::plan::ExecPlan> {
        Arc::new(compile(g, &fseq(), PlanMode::Trace, true).unwrap())
    }

    #[test]
    fn hit_miss_and_lru_eviction() {
        let cache = PlanCache::new(2);
        let g1 = graph(1.0);
        let g2 = graph(2.0);
        let g3 = graph(3.0);
        let (k1, k2, k3) = (
            structural_key(&g1, PlanMode::Trace, true),
            structural_key(&g2, PlanMode::Trace, true),
            structural_key(&g3, PlanMode::Trace, true),
        );
        assert!(cache.get("m", k1).is_none());
        cache.insert("m", k1, plan_for(&g1));
        cache.insert("m", k2, plan_for(&g2));
        assert!(cache.get("m", k1).is_some()); // k1 now most recent
        cache.insert("m", k3, plan_for(&g3)); // evicts k2 (LRU)
        assert!(cache.get("m", k2).is_none());
        assert!(cache.get("m", k1).is_some());
        assert!(cache.get("m", k3).is_some());
        let s = cache.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.size, 2);
        assert_eq!(s.capacity, 2);
        assert_eq!(s.hits, 4);
        assert_eq!(s.misses, 2);
        assert!(s.slots_planned > 0 && s.values_planned >= s.slots_planned);
    }

    #[test]
    fn invalidate_model_is_keyed_not_global() {
        let cache = PlanCache::new(8);
        let g = graph(1.0);
        let k = structural_key(&g, PlanMode::Trace, true);
        cache.insert("m", k, plan_for(&g));
        cache.insert("other", k, plan_for(&g));
        assert_eq!(cache.invalidate_model("m"), 1);
        assert!(cache.get("m", k).is_none());
        assert!(cache.get("other", k).is_some());
        assert_eq!(cache.stats().invalidations, 1);
    }
}
