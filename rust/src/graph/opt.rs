//! The intervention-graph compiler: optimization passes that run between
//! validation and execution.
//!
//! The paper's central architectural claim — the intervention graph
//! "decouples experimental design from model runtime" — is exactly what
//! makes server-side optimization legal: the fabric may rewrite a
//! request's graph freely as long as every value the user asked for
//! (`Save`, `StepHook`, `StoreState`) is **bit-identical** to what the
//! submitted graph would have produced. [`optimize`] runs four passes:
//!
//! 1. **Dead-code elimination** — drop every node not (transitively)
//!    reachable from a `Save`/`StepHook`/`StoreState`/`Setter` root, so a
//!    speculative getter that feeds nothing never materializes an
//!    activation and never forces its hook to fire.
//! 2. **Constant folding** — evaluate `Const`-only subtrees once at
//!    admission with the same tensor kernels the executor uses. This is
//!    the big win for streams, where the graph re-executes at every
//!    decode step: a folded subtree is paid once per request instead of
//!    once per token. Folding never crosses `Getter`, `Grad`, or
//!    `LoadState` (their values are unknown at admission), and a folding
//!    error (e.g. `mean` of an empty tensor) fails the request at
//!    admission instead of mid-execution.
//! 3. **Common-subexpression elimination** — hash-cons structurally
//!    identical pure nodes so repeated `Getter{module, port}` reads and
//!    duplicated op chains share one evaluation. Getters merge on their
//!    *normalized* forward point (a module's `Input` is the previous
//!    module's `Output`) and never merge across a `Setter` writing the
//!    same point. `Grad` nodes are a CSE **barrier**: gradient values are
//!    injected per-node by the post-phase driver, so they are kept
//!    distinct rather than hash-consed.
//! 4. **Fusion** — rewrite `Add`-of-`Scale`, `Gelu`-after-`Matmul`, and
//!    `Softmax`-after-`Scale` patterns into the internal
//!    [`Op::FusedScaleAdd`] / [`Op::FusedMatmulGelu`] /
//!    [`Op::FusedScaleSoftmax`] ops, which dispatch to the in-place
//!    `tensor::ops` kernels (`scale_add_assign`, `gelu_inplace`,
//!    `softmax_last_inplace`). A node is only fused away when the fused
//!    consumer is its *sole* listener and it is not locked by a save.
//!
//! Node ids change under rewriting, but the user addressed their results
//! by the ids of the graph they submitted. [`Optimized::save_remap`]
//! records `original id → optimized id` for every `Save`/`StepHook`
//! node; [`Optimized::remap_result`] (and [`Prepared::remap_values`])
//! re-key an executed [`GraphResult`] back into the submitted id space
//! before it reaches the result assembler.
//!
//! # Examples
//!
//! A `Const`-only chain folds to a single literal and a dangling getter
//! is eliminated, without touching the saved value's id:
//!
//! ```
//! use nnscope::graph::{opt, InterventionGraph, Op, Port};
//!
//! let fseq = vec!["embed".to_string(), "layer.0".to_string()];
//! let mut g = InterventionGraph::new("m");
//! let a = g.push(Op::Const { dims: vec![2], data: vec![1.0, 2.0] });
//! let b = g.push(Op::Scale { arg: a, factor: 3.0 });
//! let save = g.push(Op::Save { arg: b });
//! // a speculative getter nobody reads: dead code
//! g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
//!
//! let o = opt::optimize(&g, &fseq).unwrap();
//! assert_eq!(o.report.nodes_before, 4);
//! assert_eq!(o.report.nodes_after, 2); // folded const + save
//! assert_eq!(o.report.dce_removed, 2); // the getter and the folded-away const
//! assert_eq!(o.report.folded, 1);
//! assert!(o.save_remap.contains_key(&save));
//! ```

use std::collections::{BTreeMap, HashMap};
use std::fmt::Write as _;

use anyhow::{anyhow, Result};

use crate::json::Json;
use crate::tensor::{logit_diff, Tensor};

use super::{GraphResult, InterventionGraph, Node, NodeId, Op, Port};

/// Per-request optimization report: what each pass did. Surfaced in
/// `/v1/result` metadata (and the streaming `done` event) as the `"opt"`
/// object so users can see what the fabric rewrote.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OptReport {
    /// Node count of the submitted graph.
    pub nodes_before: usize,
    /// Node count after all passes.
    pub nodes_after: usize,
    /// Nodes removed by dead-code elimination (both sweeps).
    pub dce_removed: usize,
    /// Nodes replaced by a precomputed `Const` (constant folding).
    pub folded: usize,
    /// Duplicate nodes merged into a representative (CSE).
    pub cse_merged: usize,
    /// Pattern rewrites into fused ops (each absorbs one node).
    pub fused: usize,
}

impl OptReport {
    /// Serialize as the `"opt"` result-metadata object.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("nodes_before", Json::from(self.nodes_before as i64)),
            ("nodes_after", Json::from(self.nodes_after as i64)),
            ("dce_removed", Json::from(self.dce_removed as i64)),
            ("folded", Json::from(self.folded as i64)),
            ("cse_merged", Json::from(self.cse_merged as i64)),
            ("fused", Json::from(self.fused as i64)),
        ])
    }

    /// Parse the `"opt"` result-metadata object; `None` when absent or
    /// malformed (e.g. the server ran with `--no-opt`).
    pub fn from_json(j: &Json) -> Option<OptReport> {
        let nodes_before = j.get("nodes_before").as_usize()?;
        Some(OptReport {
            nodes_before,
            nodes_after: j.get("nodes_after").as_usize()?,
            dce_removed: j.get("dce_removed").as_usize().unwrap_or(0),
            folded: j.get("folded").as_usize().unwrap_or(0),
            cse_merged: j.get("cse_merged").as_usize().unwrap_or(0),
            fused: j.get("fused").as_usize().unwrap_or(0),
        })
    }
}

/// The output of [`optimize`]: the rewritten graph, the saved-id remap
/// table, and the per-pass report.
#[derive(Clone, Debug)]
pub struct Optimized {
    /// The rewritten graph (dense, topologically ordered, same metadata).
    pub graph: InterventionGraph,
    /// `original id → optimized id` for every `Save`/`StepHook` node.
    pub save_remap: BTreeMap<NodeId, NodeId>,
    /// What each pass did.
    pub report: OptReport,
}

impl Optimized {
    /// Re-key an executed result from optimized ids back to the ids of
    /// the submitted graph (the result assembler's contract: users
    /// address values by the ids they built).
    pub fn remap_result(&self, res: GraphResult) -> GraphResult {
        let mut values = res.values;
        let mut out = BTreeMap::new();
        for (&orig, &new) in &self.save_remap {
            if let Some(t) = values.remove(&new) {
                out.insert(orig, t);
            }
        }
        GraphResult { values: out }
    }
}

/// A graph ready for execution: either optimized at admission (with the
/// remap/report needed by the result assembler) or raw (`--no-opt`, or a
/// caller that bypasses the compiler). This is what scheduler jobs carry.
#[derive(Clone, Debug)]
pub struct Prepared {
    /// The graph the executor will run.
    pub graph: InterventionGraph,
    /// Saved-id remap (`None` when the graph was not rewritten).
    pub save_remap: Option<BTreeMap<NodeId, NodeId>>,
    /// Optimization report (`None` when the graph was not rewritten).
    pub report: Option<OptReport>,
    /// The AOT plan this graph was bound from, when admission went
    /// through the plan cache ([`super::plan`]): carries the precomputed
    /// schedule and arena assignment so the executor skips scheduling
    /// prep and allocates values into planned slots.
    pub plan: Option<std::sync::Arc<super::plan::ExecPlan>>,
}

impl Prepared {
    /// Wrap a graph for unoptimized execution.
    pub fn raw(graph: InterventionGraph) -> Prepared {
        Prepared { graph, save_remap: None, report: None, plan: None }
    }

    /// Re-key executed values back into submitted-graph ids (identity for
    /// raw graphs).
    pub fn remap_values(&self, res: GraphResult) -> GraphResult {
        match &self.save_remap {
            None => res,
            Some(remap) => {
                let mut values = res.values;
                let mut out = BTreeMap::new();
                for (&orig, &new) in remap {
                    if let Some(t) = values.remove(&new) {
                        out.insert(orig, t);
                    }
                }
                GraphResult { values: out }
            }
        }
    }
}

/// Run the pipeline (or don't) on an owned graph, producing the form the
/// scheduler executes. With `optimize` set, errors surfaced here (folding
/// failures, unknown modules) are admission errors — the server maps them
/// to 400 instead of failing mid-execution.
pub fn prepare(
    graph: InterventionGraph,
    forward_sequence: &[String],
    optimize_graph: bool,
) -> Result<Prepared> {
    if !optimize_graph {
        return Ok(Prepared::raw(graph));
    }
    let o = optimize(&graph, forward_sequence)?;
    Ok(Prepared {
        graph: o.graph,
        save_remap: Some(o.save_remap),
        report: Some(o.report),
        plan: None,
    })
}

/// Run all four passes (DCE → fold → DCE → CSE → fuse) and renumber.
///
/// Errors mirror what execution of the submitted graph would hit —
/// unknown module points, input-of-first-module getters, and failing
/// constant subtrees all error here, at admission, rather than
/// mid-forward-pass. A graph that would execute cleanly never fails to
/// optimize.
pub fn optimize(g: &InterventionGraph, forward_sequence: &[String]) -> Result<Optimized> {
    let rw = rewrite(g, forward_sequence, true)?;
    let mut save_remap = BTreeMap::new();
    for node in &g.nodes {
        if matches!(node.op, Op::Save { .. } | Op::StepHook { .. }) {
            save_remap.insert(node.id, rw.new_id[node.id]);
        }
    }
    let graph = InterventionGraph {
        model: g.model.clone(),
        tokens: g.tokens.clone(),
        batch: g.batch,
        nodes: rw.nodes,
        targets: g.targets.clone(),
        batch_group: g.batch_group,
        shards: g.shards,
    };
    Ok(Optimized { graph, save_remap, report: rw.report })
}

/// The raw output of the pass pipeline before graph assembly: compacted
/// nodes, the `submitted id → compacted id` table (`usize::MAX` for
/// eliminated nodes), and the per-pass report. Shared by [`optimize`]
/// (payload-keyed CSE) and the AOT plan compiler
/// ([`super::plan::compile`], structure-only CSE).
pub(crate) struct Rewritten {
    /// Compacted, renumbered nodes.
    pub(crate) nodes: Vec<Node>,
    /// `submitted id → compacted id`; `usize::MAX` for eliminated nodes.
    pub(crate) new_id: Vec<usize>,
    /// What each pass did.
    pub(crate) report: OptReport,
}

/// Run all four passes (DCE → fold → DCE → CSE → fuse) and renumber.
/// `payload_consts` controls whether CSE may merge `Const` nodes by
/// payload: admission optimization says yes; the plan compiler says no,
/// so the rewritten *structure* stays a pure function of the submitted
/// structure (two payload-variants of one shape must produce identical
/// templates).
pub(crate) fn rewrite(
    g: &InterventionGraph,
    forward_sequence: &[String],
    payload_consts: bool,
) -> Result<Rewritten> {
    let n = g.nodes.len();
    let mut report = OptReport { nodes_before: n, ..OptReport::default() };

    // Normalized forward point per node (getters and setters), mirroring
    // the executor's `point_of` so optimization fails exactly when
    // executor construction would.
    let points = normalize_points(g, forward_sequence)?;

    let mut ops: Vec<Op> = g.nodes.iter().map(|node| node.op.clone()).collect();
    let mut alive = vec![true; n];

    // Pass 1: DCE (so dead constant subtrees are never folded — a dead
    // failing subtree costs nothing, it does not fail the request).
    report.dce_removed += dce(&ops, &mut alive);

    // Pass 2: constant folding, then a second DCE sweep for the
    // now-unreferenced literals that fed the folded nodes.
    report.folded = fold(&mut ops, &alive)?;
    report.dce_removed += dce(&ops, &mut alive);

    // Pass 3: CSE (redirects consumers onto representatives).
    report.cse_merged = cse(&mut ops, &mut alive, &points, payload_consts);

    // Pass 4: fusion of single-use kernel patterns.
    report.fused = fuse(&mut ops, &mut alive);

    // Compact + renumber, preserving relative order.
    let mut new_id = vec![usize::MAX; n];
    let mut nodes = Vec::new();
    for i in 0..n {
        if !alive[i] {
            continue;
        }
        new_id[i] = nodes.len();
        let mut op = ops[i].clone();
        op.map_deps(|d| {
            debug_assert!(new_id[d] != usize::MAX, "dep {d} of node {i} was eliminated");
            new_id[d]
        });
        nodes.push(Node { id: nodes.len(), op });
    }
    report.nodes_after = nodes.len();
    Ok(Rewritten { nodes, new_id, report })
}

// ---------------------------------------------------------------------------
// Pass helpers
// ---------------------------------------------------------------------------

/// Normalized forward point of every Getter/Setter (input of module k =
/// output of module k-1), `None` for other ops. Errors match the
/// executor's: unknown modules and input-of-the-first-module.
fn normalize_points(
    g: &InterventionGraph,
    forward_sequence: &[String],
) -> Result<Vec<Option<usize>>> {
    let order: HashMap<&str, usize> = forward_sequence
        .iter()
        .enumerate()
        .map(|(i, m)| (m.as_str(), i))
        .collect();
    let point_of = |module: &str, port: Port| -> Result<usize> {
        let k = *order
            .get(module)
            .ok_or_else(|| anyhow!("unknown module {module}"))?;
        match port {
            Port::Output => Ok(k),
            Port::Input if k == 0 => {
                Err(anyhow!("module {module} has no observable input (it is first)"))
            }
            Port::Input => Ok(k - 1),
        }
    };
    g.nodes
        .iter()
        .map(|node| match &node.op {
            Op::Getter { module, port } | Op::Setter { module, port, .. } => {
                point_of(module, *port).map(Some)
            }
            _ => Ok(None),
        })
        .collect()
}

/// Is this op a root the optimizer must keep: an effect on the model pass
/// (`Setter`), on session state (`StoreState`), or a value the user asked
/// for (`Save`/`StepHook`)?
fn is_root(op: &Op) -> bool {
    matches!(
        op,
        Op::Setter { .. } | Op::StoreState { .. } | Op::Save { .. } | Op::StepHook { .. }
    )
}

/// Mark nodes unreachable from any root as dead; returns how many were
/// newly killed. One descending sweep suffices: deps always point to
/// lower ids, so a consumer is visited before its dependencies.
fn dce(ops: &[Op], alive: &mut [bool]) -> usize {
    let n = ops.len();
    let mut keep = vec![false; n];
    for i in (0..n).rev() {
        if alive[i] && (is_root(&ops[i]) || keep[i]) {
            keep[i] = true;
            for d in ops[i].deps() {
                keep[d] = true;
            }
        }
    }
    let mut removed = 0;
    for i in 0..n {
        if alive[i] && !keep[i] {
            alive[i] = false;
            removed += 1;
        }
    }
    removed
}

/// Is this op a pure value computation (no model, gradient, or state
/// access, no lock/emit semantics)? Pure ops with all-constant inputs are
/// foldable; pure ops are also the CSE candidates.
fn is_pure_value(op: &Op) -> bool {
    matches!(
        op,
        Op::Const { .. }
            | Op::Slice { .. }
            | Op::Assign { .. }
            | Op::Fill { .. }
            | Op::Add { .. }
            | Op::Sub { .. }
            | Op::Mul { .. }
            | Op::Scale { .. }
            | Op::Matmul { .. }
            | Op::Gelu { .. }
            | Op::Softmax { .. }
            | Op::Argmax { .. }
            | Op::Mean { .. }
            | Op::Sum { .. }
            | Op::Transpose { .. }
            | Op::Reshape { .. }
            | Op::MeanAxis { .. }
            | Op::LogitDiff { .. }
            | Op::FusedScaleAdd { .. }
            | Op::FusedMatmulGelu { .. }
            | Op::FusedScaleSoftmax { .. }
    )
}

/// Evaluate one pure op over already-computed inputs, using the same
/// kernels (and the same error conditions) as `interp`'s `exec_node`, so
/// a folded value is bit-identical to the executed one and a folding
/// failure is exactly the failure execution would have hit.
pub(crate) fn eval_pure(op: &Op, input: &dyn Fn(NodeId) -> Tensor) -> Result<Tensor> {
    Ok(match op {
        Op::Const { dims, data } => Tensor::new(dims, data.clone()),
        Op::Slice { arg, ranges } => input(*arg).slice(ranges),
        Op::Assign { dst, ranges, src } => {
            let mut d = input(*dst);
            d.slice_assign(ranges, &input(*src));
            d
        }
        Op::Fill { dst, ranges, value } => {
            let mut d = input(*dst);
            d.slice_fill(ranges, *value);
            d
        }
        Op::Add { a, b } => input(*a).add(&input(*b)),
        Op::Sub { a, b } => input(*a).sub(&input(*b)),
        Op::Mul { a, b } => input(*a).mul(&input(*b)),
        Op::Matmul { a, b } => input(*a).matmul(&input(*b)),
        Op::Scale { arg, factor } => {
            let mut t = input(*arg);
            t.scale_inplace(*factor);
            t
        }
        Op::Gelu { arg } => {
            let mut t = input(*arg);
            t.gelu_inplace();
            t
        }
        Op::Softmax { arg } => {
            let mut t = input(*arg);
            t.softmax_last_inplace();
            t
        }
        Op::Argmax { arg } => input(*arg).argmax_last(),
        Op::Mean { arg } => {
            let t = input(*arg);
            if t.numel() == 0 {
                return Err(anyhow!(
                    "mean of an empty tensor; empty reductions are rejected rather than \
                     producing NaN (see docs/PROTOCOL.md)"
                ));
            }
            Tensor::scalar(t.mean_all())
        }
        Op::Sum { arg } => {
            let t = input(*arg);
            if t.numel() == 0 {
                return Err(anyhow!(
                    "sum of an empty tensor; empty reductions are rejected rather than \
                     producing a silent zero (see docs/PROTOCOL.md)"
                ));
            }
            Tensor::scalar(t.sum_all())
        }
        Op::Transpose { arg } => {
            let t = input(*arg);
            if t.rank() != 2 {
                return Err(anyhow!("transpose needs a 2-D tensor, got {:?}", t.dims()));
            }
            t.transpose2()
        }
        Op::Reshape { arg, dims } => {
            let t = input(*arg);
            let want: usize = dims.iter().product();
            if want != t.numel() {
                return Err(anyhow!("reshape {:?} -> {dims:?} changes element count", t.dims()));
            }
            t.reshape(dims)
        }
        Op::MeanAxis { arg, axis } => {
            let t = input(*arg);
            if *axis >= t.rank() {
                return Err(anyhow!("mean_axis axis {axis} out of rank {}", t.rank()));
            }
            if t.dims()[*axis] == 0 {
                return Err(anyhow!(
                    "mean_axis over an empty axis {axis}; empty reductions are rejected \
                     rather than producing NaN (see docs/PROTOCOL.md)"
                ));
            }
            t.mean_axis(*axis)
        }
        Op::LogitDiff { logits, target, foil } => logit_diff(&input(*logits), *target, *foil),
        Op::FusedScaleAdd { a, b, factor } => {
            let mut x = input(*a);
            let y = input(*b);
            if x.dims() == y.dims() {
                x.scale_add_assign(*factor, &y);
                x
            } else {
                let mut s = y;
                s.scale_inplace(*factor);
                x.add(&s)
            }
        }
        Op::FusedMatmulGelu { a, b } => {
            let mut t = input(*a).matmul(&input(*b));
            t.gelu_inplace();
            t
        }
        Op::FusedScaleSoftmax { arg, factor } => {
            let mut t = input(*arg);
            t.scale_inplace(*factor);
            t.softmax_last_inplace();
            t
        }
        _ => return Err(anyhow!("eval_pure on non-pure op '{}'", op.tag())),
    })
}

/// Replace every live pure node whose inputs are all constants with a
/// precomputed `Const`. Returns the number of nodes folded (pre-existing
/// `Const` nodes don't count). Errors abort the whole optimization — a
/// live constant subtree that cannot evaluate cannot execute either.
fn fold(ops: &mut [Op], alive: &[bool]) -> Result<usize> {
    let n = ops.len();
    let mut val: Vec<Option<Tensor>> = vec![None; n];
    let mut folded = 0;
    for i in 0..n {
        if !alive[i] {
            continue;
        }
        if let Op::Const { dims, data } = &ops[i] {
            val[i] = Some(Tensor::new(dims, data.clone()));
            continue;
        }
        if !is_pure_value(&ops[i]) {
            continue;
        }
        if !ops[i].deps().iter().all(|&d| val[d].is_some()) {
            continue;
        }
        let v = eval_pure(&ops[i], &|d: NodeId| {
            val[d].clone().expect("const input checked above")
        })?;
        ops[i] = Op::Const { dims: v.dims().to_vec(), data: v.data().to_vec() };
        val[i] = Some(v);
        folded += 1;
    }
    Ok(folded)
}

/// Structural hash-cons key for CSE candidates; `None` for ops that must
/// not merge (effects, `Grad` barriers). Getter keys use the normalized
/// forward point so `input`-of-layer-k and `output`-of-layer-(k-1) merge.
/// With `payload_consts` unset, `Const` nodes never key (the plan
/// compiler's parametric mode: merging by payload would make the
/// rewritten structure payload-dependent).
fn cse_key(op: &Op, point: Option<usize>, payload_consts: bool) -> Option<String> {
    let mut k = String::new();
    let deps = op.deps();
    match op {
        // effects and per-node-injected values never merge
        Op::Setter { .. }
        | Op::Save { .. }
        | Op::StepHook { .. }
        | Op::StoreState { .. }
        | Op::Grad { .. } => return None,
        Op::Const { .. } if !payload_consts => return None,
        Op::Getter { .. } => {
            write!(k, "get@{}", point.expect("getter point normalized")).unwrap();
            return Some(k);
        }
        // loads observe the pre-trace snapshot: all loads of one key are
        // the same value within a trace
        Op::LoadState { key } => {
            write!(k, "load:{}:{key}", key.len()).unwrap();
            return Some(k);
        }
        Op::Const { dims, data } => {
            write!(k, "const:{dims:?}:").unwrap();
            for v in data {
                write!(k, "{:08x}", v.to_bits()).unwrap();
            }
            return Some(k);
        }
        Op::Slice { ranges, .. } => write!(k, "slice:{ranges:?}").unwrap(),
        Op::Assign { ranges, .. } => write!(k, "assign:{ranges:?}").unwrap(),
        Op::Fill { ranges, value, .. } => {
            write!(k, "fill:{ranges:?}:{:08x}", value.to_bits()).unwrap()
        }
        Op::Add { .. } => k.push_str("add"),
        Op::Sub { .. } => k.push_str("sub"),
        Op::Mul { .. } => k.push_str("mul"),
        Op::Matmul { .. } => k.push_str("matmul"),
        Op::Scale { factor, .. } => write!(k, "scale:{:08x}", factor.to_bits()).unwrap(),
        Op::Gelu { .. } => k.push_str("gelu"),
        Op::Softmax { .. } => k.push_str("softmax"),
        Op::Argmax { .. } => k.push_str("argmax"),
        Op::Mean { .. } => k.push_str("mean"),
        Op::Sum { .. } => k.push_str("sum"),
        Op::Transpose { .. } => k.push_str("transpose"),
        Op::Reshape { dims, .. } => write!(k, "reshape:{dims:?}").unwrap(),
        Op::MeanAxis { axis, .. } => write!(k, "mean_axis:{axis}").unwrap(),
        Op::LogitDiff { target, foil, .. } => {
            write!(k, "logit_diff:{target}:{foil}").unwrap()
        }
        Op::FusedScaleAdd { factor, .. } => {
            write!(k, "fused_scale_add:{:08x}", factor.to_bits()).unwrap()
        }
        Op::FusedMatmulGelu { .. } => k.push_str("fused_matmul_gelu"),
        Op::FusedScaleSoftmax { factor, .. } => {
            write!(k, "fused_scale_softmax:{:08x}", factor.to_bits()).unwrap()
        }
    }
    write!(k, ":{deps:?}").unwrap();
    Some(k)
}

/// Hash-cons structurally identical pure nodes: consumers of a duplicate
/// are redirected to the first (or, for getters, the latest
/// non-interfering) representative, and the duplicate dies. Returns the
/// number of merged nodes.
fn cse(ops: &mut [Op], alive: &mut [bool], points: &[Option<usize>], payload_consts: bool) -> usize {
    let n = ops.len();
    // setters by normalized point, for the getter interference rule:
    // a getter must not merge across a setter writing its point, because
    // in-hook execution order makes the two reads observe different
    // activations.
    let setters: Vec<(usize, usize)> = (0..n)
        .filter(|&i| alive[i] && matches!(ops[i], Op::Setter { .. }))
        .map(|i| (points[i].expect("setter point normalized"), i))
        .collect();

    let mut repr: HashMap<String, NodeId> = HashMap::new();
    let mut target: Vec<NodeId> = (0..n).collect();
    let mut merged = 0;
    for i in 0..n {
        if !alive[i] {
            continue;
        }
        // route this node's edges through earlier merges first
        ops[i].map_deps(|d| target[d]);
        let Some(key) = cse_key(&ops[i], points[i], payload_consts) else {
            continue;
        };
        match repr.get(&key).copied() {
            Some(r) => {
                let interferes = matches!(ops[i], Op::Getter { .. })
                    && setters.iter().any(|&(p, sid)| {
                        Some(p) == points[i] && r < sid && sid < i
                    });
                if interferes {
                    // reads on opposite sides of the write: the later read
                    // becomes the representative for what follows
                    repr.insert(key, i);
                } else {
                    target[i] = r;
                    alive[i] = false;
                    merged += 1;
                }
            }
            None => {
                repr.insert(key, i);
            }
        }
    }
    merged
}

/// Rewrite single-use kernel patterns into fused internal ops. The inner
/// node must have exactly one listener (the fusing consumer) and must not
/// be locked by a `Save`/`StepHook`, so absorbing it cannot change any
/// other node's input or any returned value.
fn fuse(ops: &mut [Op], alive: &mut [bool]) -> usize {
    let n = ops.len();
    let mut listeners = vec![0usize; n];
    let mut locked = vec![false; n];
    for i in 0..n {
        if !alive[i] {
            continue;
        }
        for d in ops[i].deps() {
            listeners[d] += 1;
        }
        if let Op::Save { arg } | Op::StepHook { arg } = ops[i] {
            locked[arg] = true;
        }
    }
    let absorbable = |inner: usize, listeners: &[usize], locked: &[bool]| {
        listeners[inner] == 1 && !locked[inner]
    };
    let mut fused = 0;
    for i in 0..n {
        if !alive[i] {
            continue;
        }
        let rewrite = match &ops[i] {
            Op::Add { a, b } => {
                if let Op::Scale { arg, factor } = &ops[*b] {
                    absorbable(*b, &listeners, &locked)
                        .then(|| (*b, Op::FusedScaleAdd { a: *a, b: *arg, factor: *factor }))
                } else if let Op::Scale { arg, factor } = &ops[*a] {
                    // addition commutes bitwise for f32, so the scaled side
                    // may sit on either operand
                    absorbable(*a, &listeners, &locked)
                        .then(|| (*a, Op::FusedScaleAdd { a: *b, b: *arg, factor: *factor }))
                } else {
                    None
                }
            }
            Op::Gelu { arg } => {
                if let Op::Matmul { a, b } = &ops[*arg] {
                    absorbable(*arg, &listeners, &locked)
                        .then(|| (*arg, Op::FusedMatmulGelu { a: *a, b: *b }))
                } else {
                    None
                }
            }
            Op::Softmax { arg } => {
                if let Op::Scale { arg: inner, factor } = &ops[*arg] {
                    absorbable(*arg, &listeners, &locked)
                        .then(|| (*arg, Op::FusedScaleSoftmax { arg: *inner, factor: *factor }))
                } else {
                    None
                }
            }
            _ => None,
        };
        if let Some((inner, op)) = rewrite {
            ops[i] = op;
            alive[inner] = false;
            listeners[inner] = 0;
            fused += 1;
        }
    }
    fused
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate::validate;
    use crate::interp::Executor;
    use crate::models::Hooks;
    use crate::tensor::Range1;

    fn fseq() -> Vec<String> {
        vec!["embed".into(), "layer.0".into(), "layer.1".into(), "lm_head".into()]
    }

    /// Drive an executor by hand against fake activations (no model).
    fn drive(ex: &mut Executor, acts: &mut BTreeMap<String, Tensor>) {
        for point in fseq() {
            if let Some(t) = acts.get_mut(&point) {
                if ex.wants(&point) {
                    ex.on_output(&point, t);
                }
            }
        }
    }

    fn acts(batch: usize) -> BTreeMap<String, Tensor> {
        let mut m = BTreeMap::new();
        m.insert("embed".to_string(), Tensor::iota(&[batch, 4]));
        m.insert("layer.0".to_string(), Tensor::iota(&[batch, 4]).scale(2.0));
        m.insert("layer.1".to_string(), Tensor::iota(&[batch, 4]).scale(3.0));
        m.insert("lm_head".to_string(), Tensor::iota(&[batch, 4]).scale(4.0));
        m
    }

    /// Execute a graph by hand-driving an executor; returns values keyed
    /// by the ORIGINAL graph's ids (through the remap when optimized).
    fn run(g: &InterventionGraph, optimized: bool) -> GraphResult {
        if optimized {
            let o = optimize(g, &fseq()).unwrap();
            let mut ex = Executor::new(&o.graph, &fseq()).unwrap();
            ex.run_pre().unwrap();
            let mut a = acts(g.batch.max(1));
            drive(&mut ex, &mut a);
            o.remap_result(ex.into_result().unwrap())
        } else {
            let mut ex = Executor::new(g, &fseq()).unwrap();
            ex.run_pre().unwrap();
            let mut a = acts(g.batch.max(1));
            drive(&mut ex, &mut a);
            ex.into_result().unwrap()
        }
    }

    #[test]
    fn dce_drops_speculative_getters_but_keeps_setters() {
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        // dead: a getter chain feeding nothing
        let dead = g.push(Op::Getter { module: "lm_head".into(), port: Port::Output });
        g.push(Op::Softmax { arg: dead });
        // alive: a setter side effect with its feeding const
        let c = g.push(Op::Const { dims: vec![1, 4], data: vec![9.0; 4] });
        g.push(Op::Setter { module: "layer.0".into(), port: Port::Output, arg: c });
        let o = optimize(&g, &fseq()).unwrap();
        assert_eq!(o.report.dce_removed, 2);
        assert_eq!(o.graph.nodes.len(), 2);
        assert_eq!(o.graph.setter_points(), vec!["layer.0"]);
        // the setter still fires: downstream activation is overwritten
        let mut ex = Executor::new(&o.graph, &fseq()).unwrap();
        ex.run_pre().unwrap();
        let mut a = acts(1);
        drive(&mut ex, &mut a);
        assert_eq!(a["layer.0"].data(), &[9.0; 4]);
        // and the dead getter no longer forces its hook
        assert!(!ex.wants("lm_head"));
    }

    #[test]
    fn folding_collapses_const_subtrees_bit_identically() {
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let a = g.push(Op::Const { dims: vec![2, 2], data: vec![1.0, -2.0, 3.0, 0.5] });
        let b = g.push(Op::Const { dims: vec![2, 2], data: vec![0.25, 1.5, -1.0, 2.0] });
        let mm = g.push(Op::Matmul { a, b });
        let gl = g.push(Op::Gelu { arg: mm });
        let sm = g.push(Op::Softmax { arg: gl });
        let save = g.push(Op::Save { arg: sm });
        let o = optimize(&g, &fseq()).unwrap();
        // everything folds into one literal + the save
        assert_eq!(o.graph.nodes.len(), 2);
        assert!(o.report.folded >= 1);
        assert!(matches!(o.graph.nodes[0].op, Op::Const { .. }));
        let unopt = run(&g, false);
        let opt = run(&g, true);
        assert_eq!(unopt.get(save).unwrap(), opt.get(save).unwrap());
    }

    #[test]
    fn folding_never_crosses_load_state() {
        let keys: std::collections::BTreeSet<String> = ["w".to_string()].into();
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let w = g.push(Op::LoadState { key: "w".into() });
        let s = g.push(Op::Scale { arg: w, factor: 2.0 });
        g.push(Op::StoreState { key: "w".into(), arg: s });
        let o = optimize(&g, &fseq()).unwrap();
        assert_eq!(o.report.folded, 0, "state-dependent subtree must not fold");
        assert!(o
            .graph
            .nodes
            .iter()
            .any(|n| matches!(n.op, Op::LoadState { .. })));
        assert!(o
            .graph
            .nodes
            .iter()
            .any(|n| matches!(n.op, Op::StoreState { .. })));
        crate::graph::validate::validate_with_state(&o.graph, &fseq(), &keys).unwrap();
    }

    #[test]
    fn folding_error_surfaces_at_admission() {
        // mean over a zero-width const slice would NaN at execution; the
        // compiler rejects it up front
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let c = g.push(Op::Const { dims: vec![4], data: vec![1.0; 4] });
        let empty = g.push(Op::Slice { arg: c, ranges: vec![Range1::new(2, 2)] });
        let m = g.push(Op::Mean { arg: empty });
        g.push(Op::Save { arg: m });
        let err = optimize(&g, &fseq()).unwrap_err().to_string();
        assert!(err.contains("empty"), "{err}");

        // ...but the same subtree DEAD costs nothing and fails nothing
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let c = g.push(Op::Const { dims: vec![4], data: vec![1.0; 4] });
        let empty = g.push(Op::Slice { arg: c, ranges: vec![Range1::new(2, 2)] });
        g.push(Op::Mean { arg: empty });
        let h = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        g.push(Op::Save { arg: h });
        let o = optimize(&g, &fseq()).unwrap();
        assert_eq!(o.graph.nodes.len(), 2);
    }

    #[test]
    fn cse_merges_duplicate_getters_and_chains() {
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let h1 = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        let h2 = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        let s1 = g.push(Op::Scale { arg: h1, factor: 2.0 });
        let s2 = g.push(Op::Scale { arg: h2, factor: 2.0 });
        let sv1 = g.push(Op::Save { arg: s1 });
        let sv2 = g.push(Op::Save { arg: s2 });
        let o = optimize(&g, &fseq()).unwrap();
        assert_eq!(o.report.cse_merged, 2); // getter + scale duplicates
        // one getter, one scale, two saves
        assert_eq!(o.graph.nodes.len(), 4);
        let opt = run(&g, true);
        let unopt = run(&g, false);
        assert_eq!(opt.get(sv1).unwrap(), unopt.get(sv1).unwrap());
        assert_eq!(opt.get(sv2).unwrap(), unopt.get(sv2).unwrap());
    }

    #[test]
    fn cse_normalizes_input_port_to_previous_output() {
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let a = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        let b = g.push(Op::Getter { module: "layer.1".into(), port: Port::Input });
        let sa = g.push(Op::Save { arg: a });
        let sb = g.push(Op::Save { arg: b });
        let o = optimize(&g, &fseq()).unwrap();
        assert_eq!(o.report.cse_merged, 1);
        let opt = run(&g, true);
        let unopt = run(&g, false);
        assert_eq!(opt.get(sa).unwrap(), unopt.get(sa).unwrap());
        assert_eq!(opt.get(sb).unwrap(), unopt.get(sb).unwrap());
    }

    #[test]
    fn cse_does_not_merge_getters_across_a_setter_to_the_same_point() {
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let before = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        let z = g.push(Op::Scale { arg: before, factor: 0.0 });
        g.push(Op::Setter { module: "layer.0".into(), port: Port::Output, arg: z });
        let after = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        let s1 = g.push(Op::Save { arg: before });
        let s2 = g.push(Op::Save { arg: after });
        let o = optimize(&g, &fseq()).unwrap();
        // the two reads observe different activations and must both survive
        let getters = o
            .graph
            .nodes
            .iter()
            .filter(|n| matches!(n.op, Op::Getter { .. }))
            .count();
        assert_eq!(getters, 2);
        let opt = run(&g, true);
        let unopt = run(&g, false);
        assert_eq!(opt.get(s1).unwrap(), unopt.get(s1).unwrap());
        assert_eq!(opt.get(s2).unwrap(), unopt.get(s2).unwrap());
        assert_eq!(opt.get(s2).unwrap().data(), &[0.0; 4]);
    }

    #[test]
    fn cse_respects_grad_barriers() {
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        g.targets = Some(vec![1.0]);
        let g1 = g.push(Op::Grad { module: "layer.0".into() });
        let g2 = g.push(Op::Grad { module: "layer.0".into() });
        g.push(Op::Save { arg: g1 });
        g.push(Op::Save { arg: g2 });
        let o = optimize(&g, &fseq()).unwrap();
        let grads = o
            .graph
            .nodes
            .iter()
            .filter(|n| matches!(n.op, Op::Grad { .. }))
            .count();
        assert_eq!(grads, 2, "grad nodes are a CSE barrier: injected per-node");
        assert_eq!(o.report.cse_merged, 0);
    }

    #[test]
    fn fusion_rewrites_patterns_and_preserves_values() {
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        // h + 0.5·h₂  →  FusedScaleAdd
        let h = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        let h2 = g.push(Op::Getter { module: "layer.1".into(), port: Port::Output });
        let sc = g.push(Op::Scale { arg: h2, factor: 0.5 });
        let add = g.push(Op::Add { a: h, b: sc });
        let s1 = g.push(Op::Save { arg: add });
        // gelu(h · W)  →  FusedMatmulGelu
        let wdata: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect();
        let w = g.push(Op::Const { dims: vec![4, 4], data: wdata });
        let mm = g.push(Op::Matmul { a: h, b: w });
        let gl = g.push(Op::Gelu { arg: mm });
        let s2 = g.push(Op::Save { arg: gl });
        // softmax(h · 3)  →  FusedScaleSoftmax
        let t = g.push(Op::Scale { arg: h, factor: 3.0 });
        let sm = g.push(Op::Softmax { arg: t });
        let s3 = g.push(Op::Save { arg: sm });
        let o = optimize(&g, &fseq()).unwrap();
        assert_eq!(o.report.fused, 3);
        assert!(o.graph.nodes.iter().any(|n| matches!(n.op, Op::FusedScaleAdd { .. })));
        assert!(o.graph.nodes.iter().any(|n| matches!(n.op, Op::FusedMatmulGelu { .. })));
        assert!(o.graph.nodes.iter().any(|n| matches!(n.op, Op::FusedScaleSoftmax { .. })));
        let opt = run(&g, true);
        let unopt = run(&g, false);
        for s in [s1, s2, s3] {
            assert_eq!(opt.get(s).unwrap(), unopt.get(s).unwrap(), "save {s}");
        }
    }

    #[test]
    fn fusion_refuses_shared_or_saved_inner_nodes() {
        // the scaled value is ALSO saved: fusing it away would lose it
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let h = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        let sc = g.push(Op::Scale { arg: h, factor: 0.5 });
        let add = g.push(Op::Add { a: h, b: sc });
        let s_sc = g.push(Op::Save { arg: sc });
        let s_add = g.push(Op::Save { arg: add });
        let o = optimize(&g, &fseq()).unwrap();
        assert_eq!(o.report.fused, 0);
        let opt = run(&g, true);
        let unopt = run(&g, false);
        assert_eq!(opt.get(s_sc).unwrap(), unopt.get(s_sc).unwrap());
        assert_eq!(opt.get(s_add).unwrap(), unopt.get(s_add).unwrap());
    }

    #[test]
    fn save_remap_preserves_submitted_ids() {
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        // a pile of foldable junk in front so ids shift a lot
        let mut c = g.push(Op::Const { dims: vec![2], data: vec![1.0, 2.0] });
        for _ in 0..5 {
            c = g.push(Op::Scale { arg: c, factor: 1.5 });
        }
        let save_c = g.push(Op::Save { arg: c });
        let h = g.push(Op::Getter { module: "layer.1".into(), port: Port::Output });
        let save_h = g.push(Op::Save { arg: h });
        let o = optimize(&g, &fseq()).unwrap();
        assert!(o.graph.nodes.len() < g.nodes.len());
        let opt = run(&g, true);
        let unopt = run(&g, false);
        // results keyed by the ORIGINAL ids in both worlds
        assert_eq!(opt.get(save_c).unwrap(), unopt.get(save_c).unwrap());
        assert_eq!(opt.get(save_h).unwrap(), unopt.get(save_h).unwrap());
    }

    #[test]
    fn optimized_graphs_stay_valid() {
        let mut g = InterventionGraph::new("m");
        g.batch = 2;
        let h = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        let h_dup = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        let s = g.push(Op::Scale { arg: h_dup, factor: 0.5 });
        let a = g.push(Op::Add { a: h, b: s });
        g.push(Op::Setter { module: "layer.1".into(), port: Port::Output, arg: a });
        let logits = g.push(Op::Getter { module: "lm_head".into(), port: Port::Output });
        let ld = g.push(Op::LogitDiff { logits, target: 1, foil: 2 });
        g.push(Op::Save { arg: ld });
        let o = optimize(&g, &fseq()).unwrap();
        validate(&o.graph, &fseq()).unwrap();
        // ids stay dense and topologically ordered
        for (i, n) in o.graph.nodes.iter().enumerate() {
            assert_eq!(n.id, i);
            assert!(n.op.deps().iter().all(|&d| d < i));
        }
    }

    #[test]
    fn report_round_trips_through_json() {
        let r = OptReport {
            nodes_before: 12,
            nodes_after: 5,
            dce_removed: 3,
            folded: 2,
            cse_merged: 1,
            fused: 1,
        };
        let j = crate::json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(OptReport::from_json(&j), Some(r));
        assert_eq!(OptReport::from_json(&Json::Null), None);
    }

    #[test]
    fn prepare_raw_is_identity() {
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let h = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        g.push(Op::Getter { module: "lm_head".into(), port: Port::Output }); // dead
        g.push(Op::Save { arg: h });
        let p = prepare(g.clone(), &fseq(), false).unwrap();
        assert_eq!(p.graph.nodes.len(), 3);
        assert!(p.report.is_none());
        let p = prepare(g, &fseq(), true).unwrap();
        assert_eq!(p.graph.nodes.len(), 2);
        assert_eq!(p.report.unwrap().dce_removed, 1);
    }
}
