//! Graph validation: the §3.1 well-formedness rules, plus the formal
//! bipartite view.
//!
//! A graph is valid iff:
//! 1. node ids are dense/ascending and all deps point backwards (checked
//!    at deserialization; re-checked here for programmatically-built
//!    graphs);
//! 2. every Getter/Setter/Grad names a module point that exists in the
//!    target model's forward sequence;
//! 3. the **acyclicity rule**: for every setter edge (v′ₖ, aₗ) and getter
//!    edge (vᵢ, a′ⱼ), there is no directed path from aₗ back to vᵢ. In the
//!    module-sequence realization this is: *a setter writing module m may
//!    only (transitively) depend on getters of modules at or before m* —
//!    a later getter's value would require executing past m, creating a
//!    cycle through the augmented graph;
//! 4. at most one setter per (module, port) (last-write-wins ambiguity is
//!    rejected rather than silently resolved);
//! 5. grad nodes require the request to carry targets, and may not feed
//!    setters (the backward pass runs after the forward pass completes —
//!    a grad-driven setter would need a second forward, which is a
//!    Session, not a single trace);
//! 6. batch groups fit the declared batch;
//! 7. the **state dataflow rule**: every `LoadState` key must already
//!    exist when the trace starts — created by a `StoreState` in an
//!    *earlier* trace of the same session (or pre-existing session state).
//!    Loading a key first stored later — even later in the same trace — is
//!    a load-before-store error, because loads resolve in the pre-phase
//!    from the session's state view while stores commit post-phase.
//!    `StoreState` may depend on gradients (unlike setters): the store
//!    commits after the backward pass, which is exactly what in-fabric
//!    optimizer steps need. [`validate_session`] threads the key set
//!    across an ordered trace bundle.
//!
//! [`bipartite_view`] exports the formal C′ = (V′, A′, E′) structure so
//! tests can check the paper's graph-theoretic properties directly
//! (bipartiteness, apply-nodes-one-output, weak connectivity of each
//! component).

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{anyhow, Result};

use super::{InterventionGraph, NodeId, Op};

/// Positions of module points in the forward sequence.
fn order_map(forward_sequence: &[String]) -> BTreeMap<&str, usize> {
    forward_sequence
        .iter()
        .enumerate()
        .map(|(i, m)| (m.as_str(), i))
        .collect()
}

/// Validate a standalone graph against a model's forward sequence. No
/// session state is in scope, so any `LoadState` is a load-before-store
/// error; use [`validate_with_state`] when executing inside a session.
pub fn validate(g: &InterventionGraph, forward_sequence: &[String]) -> Result<()> {
    validate_with_state(g, forward_sequence, &BTreeSet::new())
}

/// Validate an ordered session bundle: trace `i` may load any key in
/// `initial_keys` or stored by traces `0..i`.
pub fn validate_session(
    graphs: &[InterventionGraph],
    forward_sequence: &[String],
    initial_keys: &BTreeSet<String>,
) -> Result<()> {
    let mut keys = initial_keys.clone();
    for (i, g) in graphs.iter().enumerate() {
        validate_with_state(g, forward_sequence, &keys)
            .map_err(|e| anyhow!("session trace {i}: {e}"))?;
        keys.extend(g.state_stores());
    }
    Ok(())
}

/// Validate a graph against a model's forward sequence, with
/// `state_keys` naming the session-state variables that exist when the
/// trace starts.
pub fn validate_with_state(
    g: &InterventionGraph,
    forward_sequence: &[String],
    state_keys: &BTreeSet<String>,
) -> Result<()> {
    validate_impl(g, forward_sequence, state_keys, false)
}

/// Validate a graph for streaming generation (`POST /v1/stream`): the
/// graph re-executes at every decode step, so `StepHook` markers are
/// legal, while gradients (the backward pass runs once per request, not
/// per step) and session-state ops (streams are not ordered sessions) are
/// rejected — the **stream execution rule** (rule 8).
pub fn validate_stream(g: &InterventionGraph, forward_sequence: &[String]) -> Result<()> {
    for n in &g.nodes {
        match &n.op {
            Op::Grad { module } => {
                return Err(anyhow!(
                    "streaming generation cannot use gradients (grad of '{module}', node {}): \
                     the backward pass is per-request, not per-step",
                    n.id
                ));
            }
            Op::LoadState { .. } | Op::StoreState { .. } => {
                return Err(anyhow!(
                    "streaming generation cannot use session-state ops (node {}); \
                     submit stateful work via POST /v1/session",
                    n.id
                ));
            }
            _ => {}
        }
    }
    validate_impl(g, forward_sequence, &BTreeSet::new(), true)
}

fn validate_impl(
    g: &InterventionGraph,
    forward_sequence: &[String],
    state_keys: &BTreeSet<String>,
    streaming: bool,
) -> Result<()> {
    let order = order_map(forward_sequence);

    // rule 1: topological ordering (dense ids are structural in `nodes`)
    for (i, n) in g.nodes.iter().enumerate() {
        if n.id != i {
            return Err(anyhow!("node id {} at position {i}", n.id));
        }
        for d in n.op.deps() {
            if d >= i {
                return Err(anyhow!("node {i} depends on later/self node {d}"));
            }
        }
    }

    // rule 2: module points exist
    for n in &g.nodes {
        if let Op::Getter { module, .. } | Op::Setter { module, .. } | Op::Grad { module } = &n.op
        {
            if !order.contains_key(module.as_str()) {
                return Err(anyhow!(
                    "node {} references unknown module point '{module}'",
                    n.id
                ));
            }
        }
    }

    // compute, per node, the latest getter module order it transitively
    // depends on (None = independent of the model), and whether it
    // transitively depends on a Grad node.
    let mut latest_getter: Vec<Option<usize>> = vec![None; g.nodes.len()];
    let mut uses_grad: Vec<bool> = vec![false; g.nodes.len()];
    for (i, n) in g.nodes.iter().enumerate() {
        let mut latest = match &n.op {
            Op::Getter { module, .. } => Some(order[module.as_str()]),
            _ => None,
        };
        let mut grad = matches!(n.op, Op::Grad { .. });
        for d in n.op.deps() {
            latest = match (latest, latest_getter[d]) {
                (Some(a), Some(b)) => Some(a.max(b)),
                (a, b) => a.or(b),
            };
            grad |= uses_grad[d];
        }
        latest_getter[i] = latest;
        uses_grad[i] = grad;
    }

    // rules 3–5
    let mut setter_seen: BTreeMap<(String, super::Port), NodeId> = BTreeMap::new();
    let mut has_grad = false;
    for n in &g.nodes {
        match &n.op {
            Op::Setter { module, port, arg } => {
                let m_ord = order[module.as_str()];
                if let Some(dep_ord) = latest_getter[*arg] {
                    if dep_ord > m_ord {
                        return Err(anyhow!(
                            "acyclicity violation: setter at '{module}' (node {}) depends on a \
                             getter of module '{}' which executes later",
                            n.id,
                            forward_sequence[dep_ord]
                        ));
                    }
                }
                if uses_grad[*arg] {
                    return Err(anyhow!(
                        "setter at '{module}' depends on a gradient; grads are only available \
                         after the forward pass (use a Session for iterative experiments)"
                    ));
                }
                if let Some(prev) = setter_seen.insert((module.clone(), *port), n.id) {
                    return Err(anyhow!(
                        "duplicate setter at '{module}' (nodes {prev} and {})",
                        n.id
                    ));
                }
            }
            Op::Grad { .. } => has_grad = true,
            _ => {}
        }
    }
    if has_grad && g.targets.is_none() {
        return Err(anyhow!("graph uses grad nodes but request carries no targets"));
    }

    // rule 7: state dataflow — loads require the key to exist at trace
    // start (keys stored by this trace only become visible to LATER
    // traces: stores commit post-phase, loads resolve pre-phase)
    for n in &g.nodes {
        if let Op::LoadState { key } = &n.op {
            if !state_keys.contains(key) {
                return Err(anyhow!(
                    "load-before-store: state key '{key}' does not exist at trace start \
                     (node {}); create it with a store in an earlier trace of the session",
                    n.id
                ));
            }
        }
    }

    // rule 8: per-step emission markers only exist in streaming requests
    // (a one-shot trace has no step to attach them to)
    if !streaming {
        for n in &g.nodes {
            if matches!(n.op, Op::StepHook { .. }) {
                return Err(anyhow!(
                    "step_hook (node {}) outside a streaming request; \
                     submit the graph via POST /v1/stream",
                    n.id
                ));
            }
        }
    }

    // rule 6: batch group
    if let Some((off, rows)) = g.batch_group {
        if rows == 0 || g.batch != 0 && off + rows > g.batch && g.tokens.is_empty() {
            return Err(anyhow!("batch_group [{off}, {rows}) outside batch {}", g.batch));
        }
    }

    Ok(())
}

// ---------------------------------------------------------------------------
// Formal bipartite view (Appendix E structure)
// ---------------------------------------------------------------------------

/// The formal bipartite graph: apply nodes A′ (ops) and variable nodes V′
/// (their outputs), with E′ ⊆ (V′×A′) ∪ (A′×V′); getter and setter edge
/// sets G ⊆ V×A′ and S ⊆ V′×A identified by module point.
#[derive(Debug, Default)]
pub struct BipartiteView {
    /// apply→variable edges: (apply id, its one output variable id).
    pub apply_out: Vec<(usize, usize)>,
    /// variable→apply edges.
    pub var_in: Vec<(usize, usize)>,
    /// getter attachments: (model module point, apply id).
    pub getters: Vec<(String, usize)>,
    /// setter attachments: (variable id, model module point).
    pub setters: Vec<(usize, String)>,
}

/// Export the formal view: apply node i has variable node i (one output —
/// the many-to-one form), edges follow deps.
pub fn bipartite_view(g: &InterventionGraph) -> BipartiteView {
    let mut v = BipartiteView::default();
    for n in &g.nodes {
        v.apply_out.push((n.id, n.id));
        for d in n.op.deps() {
            v.var_in.push((d, n.id));
        }
        match &n.op {
            Op::Getter { module, .. } => v.getters.push((module.clone(), n.id)),
            Op::Setter { module, arg, .. } => v.setters.push((*arg, module.clone())),
            _ => {}
        }
    }
    v
}

impl BipartiteView {
    /// Every apply node has exactly one outgoing (apply→variable) edge.
    pub fn applies_one_to_one_output(&self) -> bool {
        let mut seen = std::collections::BTreeSet::new();
        self.apply_out.iter().all(|(a, _)| seen.insert(*a))
    }

    /// No edge connects two nodes of the same type (structural here, but
    /// asserts the construction stayed bipartite).
    pub fn is_bipartite(&self) -> bool {
        // apply_out edges go A→V, var_in edges go V→A by construction;
        // bipartiteness = no (a, a) self-pairing collapses the types,
        // which is impossible unless ids were reused across both lists
        // inconsistently. Check ids referenced as variables exist as
        // apply outputs (every variable is produced by exactly one apply).
        let produced: std::collections::BTreeSet<_> =
            self.apply_out.iter().map(|(_, v)| *v).collect();
        self.var_in.iter().all(|(v, _)| produced.contains(v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{InterventionGraph, Op, Port};
    use crate::tensor::Range1;

    fn fseq() -> Vec<String> {
        vec![
            "embed".into(),
            "layer.0".into(),
            "layer.1".into(),
            "layer.2".into(),
            "lm_head".into(),
        ]
    }

    #[test]
    fn accepts_activation_patching_graph() {
        let mut g = InterventionGraph::new("m");
        g.batch = 2;
        let get = g.push(Op::Getter { module: "layer.1".into(), port: Port::Output });
        let src = g.push(Op::Slice { arg: get, ranges: vec![Range1::one(0)] });
        let asn = g.push(Op::Assign { dst: get, ranges: vec![Range1::one(1)], src });
        g.push(Op::Setter { module: "layer.1".into(), port: Port::Output, arg: asn });
        let logits = g.push(Op::Getter { module: "lm_head".into(), port: Port::Output });
        let ld = g.push(Op::LogitDiff { logits, target: 5, foil: 9 });
        g.push(Op::Save { arg: ld });
        validate(&g, &fseq()).unwrap();
    }

    #[test]
    fn rejects_setter_depending_on_later_getter() {
        // read lm_head, write it into layer.0 — needs time travel
        let mut g = InterventionGraph::new("m");
        let logits = g.push(Op::Getter { module: "lm_head".into(), port: Port::Output });
        g.push(Op::Setter { module: "layer.0".into(), port: Port::Output, arg: logits });
        let err = validate(&g, &fseq()).unwrap_err().to_string();
        assert!(err.contains("acyclicity"), "{err}");
    }

    #[test]
    fn accepts_setter_at_same_module_as_getter() {
        let mut g = InterventionGraph::new("m");
        let h = g.push(Op::Getter { module: "layer.1".into(), port: Port::Output });
        let scaled = g.push(Op::Scale { arg: h, factor: 0.0 });
        g.push(Op::Setter { module: "layer.1".into(), port: Port::Output, arg: scaled });
        validate(&g, &fseq()).unwrap();
    }

    #[test]
    fn rejects_unknown_module() {
        let mut g = InterventionGraph::new("m");
        g.push(Op::Getter { module: "layer.99".into(), port: Port::Output });
        assert!(validate(&g, &fseq()).is_err());
    }

    #[test]
    fn rejects_duplicate_setter() {
        let mut g = InterventionGraph::new("m");
        let c = g.push(Op::Const { dims: vec![1], data: vec![0.0] });
        g.push(Op::Setter { module: "layer.0".into(), port: Port::Output, arg: c });
        g.push(Op::Setter { module: "layer.0".into(), port: Port::Output, arg: c });
        let err = validate(&g, &fseq()).unwrap_err().to_string();
        assert!(err.contains("duplicate setter"), "{err}");
    }

    #[test]
    fn rejects_grad_without_targets() {
        let mut g = InterventionGraph::new("m");
        let gr = g.push(Op::Grad { module: "layer.0".into() });
        g.push(Op::Save { arg: gr });
        assert!(validate(&g, &fseq()).is_err());
        g.targets = Some(vec![1.0]);
        validate(&g, &fseq()).unwrap();
    }

    #[test]
    fn rejects_grad_fed_setter() {
        let mut g = InterventionGraph::new("m");
        g.targets = Some(vec![1.0]);
        let gr = g.push(Op::Grad { module: "layer.1".into() });
        let s = g.push(Op::Scale { arg: gr, factor: 0.1 });
        g.push(Op::Setter { module: "layer.2".into(), port: Port::Output, arg: s });
        let err = validate(&g, &fseq()).unwrap_err().to_string();
        assert!(err.contains("gradient"), "{err}");
    }

    #[test]
    fn rejects_load_before_store() {
        // standalone: any load fails
        let mut g = InterventionGraph::new("m");
        let w = g.push(Op::LoadState { key: "w".into() });
        g.push(Op::Save { arg: w });
        let err = validate(&g, &fseq()).unwrap_err().to_string();
        assert!(err.contains("load-before-store"), "{err}");

        // a store later in the SAME trace does not legalize the load
        let mut g = InterventionGraph::new("m");
        let w = g.push(Op::LoadState { key: "w".into() });
        g.push(Op::StoreState { key: "w".into(), arg: w });
        assert!(validate(&g, &fseq()).is_err());

        // with the key in scope, the load is fine
        let keys: BTreeSet<String> = ["w".to_string()].into();
        let mut g = InterventionGraph::new("m");
        let w = g.push(Op::LoadState { key: "w".into() });
        g.push(Op::Save { arg: w });
        validate_with_state(&g, &fseq(), &keys).unwrap();
    }

    #[test]
    fn session_threads_keys_across_traces() {
        let store = |key: &str| {
            let mut g = InterventionGraph::new("m");
            let c = g.push(Op::Const { dims: vec![1], data: vec![1.0] });
            g.push(Op::StoreState { key: key.into(), arg: c });
            g
        };
        let load = |key: &str| {
            let mut g = InterventionGraph::new("m");
            let w = g.push(Op::LoadState { key: key.into() });
            g.push(Op::Save { arg: w });
            g
        };
        // store in trace 0 → load in trace 1: ok
        validate_session(&[store("w"), load("w")], &fseq(), &BTreeSet::new()).unwrap();
        // load in trace 0 → store in trace 1: rejected, names the trace
        let err = validate_session(&[load("w"), store("w")], &fseq(), &BTreeSet::new())
            .unwrap_err()
            .to_string();
        assert!(err.contains("session trace 0"), "{err}");
        // cross-session key access: a key another session stored is not
        // in this session's initial set
        assert!(validate_session(&[load("other")], &fseq(), &BTreeSet::new()).is_err());
    }

    #[test]
    fn store_state_may_depend_on_grad() {
        // unlike setters, stores commit post-phase — grads are legal deps
        let mut g = InterventionGraph::new("m");
        g.targets = Some(vec![1.0]);
        let gr = g.push(Op::Grad { module: "layer.1".into() });
        let s = g.push(Op::Scale { arg: gr, factor: -0.1 });
        g.push(Op::StoreState { key: "w".into(), arg: s });
        validate(&g, &fseq()).unwrap();
    }

    #[test]
    fn step_hooks_are_stream_only() {
        // a step hook in a plain trace is rejected with a pointer to the
        // streaming endpoint...
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let h = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        g.push(Op::StepHook { arg: h });
        let err = validate(&g, &fseq()).unwrap_err().to_string();
        assert!(err.contains("/v1/stream"), "{err}");
        // ...and accepted by the streaming validator
        validate_stream(&g, &fseq()).unwrap();
    }

    #[test]
    fn stream_rejects_grads_and_state_ops() {
        let mut g = InterventionGraph::new("m");
        g.targets = Some(vec![1.0]);
        let gr = g.push(Op::Grad { module: "layer.0".into() });
        g.push(Op::Save { arg: gr });
        let err = validate_stream(&g, &fseq()).unwrap_err().to_string();
        assert!(err.contains("per-step"), "{err}");

        let mut g = InterventionGraph::new("m");
        let c = g.push(Op::Const { dims: vec![1], data: vec![0.0] });
        g.push(Op::StoreState { key: "w".into(), arg: c });
        let err = validate_stream(&g, &fseq()).unwrap_err().to_string();
        assert!(err.contains("session"), "{err}");
    }

    #[test]
    fn stream_keeps_structural_rules() {
        // acyclicity still applies when validating for a stream
        let mut g = InterventionGraph::new("m");
        let logits = g.push(Op::Getter { module: "lm_head".into(), port: Port::Output });
        g.push(Op::Setter { module: "layer.0".into(), port: Port::Output, arg: logits });
        let err = validate_stream(&g, &fseq()).unwrap_err().to_string();
        assert!(err.contains("acyclicity"), "{err}");
    }

    #[test]
    fn bipartite_view_properties() {
        let mut g = InterventionGraph::new("m");
        let a = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        let b = g.push(Op::Scale { arg: a, factor: 2.0 });
        g.push(Op::Setter { module: "layer.1".into(), port: Port::Output, arg: b });
        let v = bipartite_view(&g);
        assert!(v.applies_one_to_one_output());
        assert!(v.is_bipartite());
        assert_eq!(v.getters, vec![("layer.0".to_string(), 0)]);
        assert_eq!(v.setters, vec![(1, "layer.1".to_string())]);
    }

    #[test]
    fn property_random_valid_graphs_pass_random_cycles_fail() {
        use crate::util::Prng;
        let seq = fseq();
        let mut rng = Prng::new(0xC0FFEE);
        let mut accepted = 0;
        let mut rejected = 0;
        for _ in 0..200 {
            let mut g = InterventionGraph::new("m");
            // random getter at module gi, chain of ops, setter at module si
            let gi = rng.range(0, seq.len());
            let si = rng.range(0, seq.len());
            let mut cur = g.push(Op::Getter { module: seq[gi].clone(), port: Port::Output });
            for _ in 0..rng.range(0, 5) {
                cur = g.push(Op::Scale { arg: cur, factor: 0.9 });
            }
            g.push(Op::Setter { module: seq[si].clone(), port: Port::Output, arg: cur });
            let ok = validate(&g, &seq).is_ok();
            assert_eq!(ok, gi <= si, "getter {gi} setter {si}");
            if ok {
                accepted += 1;
            } else {
                rejected += 1;
            }
        }
        assert!(accepted > 0 && rejected > 0);
    }
}
