//! Wire format: intervention graphs ⇄ the custom JSON format (§B.2).
//!
//! The format is deliberately explicit and boring — it is version-
//! controlled experiment description. (The *execution* of a graph is an
//! optimization target — see [`crate::graph::opt`] — but the wire form a
//! client writes is not: the server rewrites its own in-memory copy and
//! answers in the submitted graph's node ids.) The full wire protocol is
//! documented in `docs/PROTOCOL.md`.
//!
//! ```json
//! { "model": "llama8b-sim", "batch": 2, "tokens": [..],
//!   "shards": 1, "batch_group": [0, 2], "targets": [..],
//!   "nodes": [
//!     {"id":0, "op":"getter", "module":"layer.5", "port":"output"},
//!     {"id":1, "op":"slice",  "arg":0, "ranges":[[0,1],[31,32]]},
//!     {"id":2, "op":"setter", "module":"layer.5", "port":"output", "arg":1},
//!     {"id":3, "op":"save",   "arg":1} ] }
//! ```
//!
//! Ranges serialize as `[start, stop]` pairs with `stop = -1` meaning
//! "to the end" (`Range1::all()`).

use anyhow::{anyhow, Result};

use crate::json::Json;
use crate::tensor::Range1;

use super::{InterventionGraph, Node, NodeId, Op, Port};

// ---------------------------------------------------------------------------
// Serialize
// ---------------------------------------------------------------------------

fn ranges_to_json(rs: &[Range1]) -> Json {
    Json::Array(
        rs.iter()
            .map(|r| {
                let stop: i64 = if r.stop == usize::MAX { -1 } else { r.stop as i64 };
                Json::arr(vec![Json::from(r.start as i64), Json::from(stop)])
            })
            .collect(),
    )
}

fn port_str(p: Port) -> &'static str {
    match p {
        Port::Input => "input",
        Port::Output => "output",
    }
}

fn node_to_json(n: &Node) -> Json {
    let mut o = Json::obj(vec![
        ("id", Json::from(n.id as i64)),
        ("op", Json::from(n.op.tag())),
    ]);
    match &n.op {
        Op::Getter { module, port } => {
            o.set("module", Json::from(module.as_str()));
            o.set("port", Json::from(port_str(*port)));
        }
        Op::Setter { module, port, arg } => {
            o.set("module", Json::from(module.as_str()));
            o.set("port", Json::from(port_str(*port)));
            o.set("arg", Json::from(*arg as i64));
        }
        Op::Grad { module } => o.set("module", Json::from(module.as_str())),
        Op::Const { dims, data } => {
            o.set("dims", Json::from(dims.clone()));
            o.set("data", Json::from(data.clone()));
        }
        Op::Slice { arg, ranges } => {
            o.set("arg", Json::from(*arg as i64));
            o.set("ranges", ranges_to_json(ranges));
        }
        Op::Assign { dst, ranges, src } => {
            o.set("dst", Json::from(*dst as i64));
            o.set("src", Json::from(*src as i64));
            o.set("ranges", ranges_to_json(ranges));
        }
        Op::Fill { dst, ranges, value } => {
            o.set("dst", Json::from(*dst as i64));
            o.set("value", Json::from(*value));
            o.set("ranges", ranges_to_json(ranges));
        }
        Op::Add { a, b } | Op::Sub { a, b } | Op::Mul { a, b } | Op::Matmul { a, b } => {
            o.set("a", Json::from(*a as i64));
            o.set("b", Json::from(*b as i64));
        }
        Op::Scale { arg, factor } => {
            o.set("arg", Json::from(*arg as i64));
            o.set("factor", Json::from(*factor));
        }
        Op::Gelu { arg } | Op::Softmax { arg } | Op::Argmax { arg } | Op::Mean { arg }
        | Op::Sum { arg } | Op::Transpose { arg } | Op::Save { arg } | Op::StepHook { arg } => {
            o.set("arg", Json::from(*arg as i64))
        }
        Op::FusedScaleAdd { a, b, factor } => {
            o.set("a", Json::from(*a as i64));
            o.set("b", Json::from(*b as i64));
            o.set("factor", Json::from(*factor));
        }
        Op::FusedMatmulGelu { a, b } => {
            o.set("a", Json::from(*a as i64));
            o.set("b", Json::from(*b as i64));
        }
        Op::FusedScaleSoftmax { arg, factor } => {
            o.set("arg", Json::from(*arg as i64));
            o.set("factor", Json::from(*factor));
        }
        Op::Reshape { arg, dims } => {
            o.set("arg", Json::from(*arg as i64));
            o.set("dims", Json::from(dims.clone()));
        }
        Op::MeanAxis { arg, axis } => {
            o.set("arg", Json::from(*arg as i64));
            o.set("axis", Json::from(*axis as i64));
        }
        Op::LoadState { key } => o.set("key", Json::from(key.as_str())),
        Op::StoreState { key, arg } => {
            o.set("key", Json::from(key.as_str()));
            o.set("arg", Json::from(*arg as i64));
        }
        Op::LogitDiff { logits, target, foil } => {
            o.set("logits", Json::from(*logits as i64));
            o.set("target", Json::from(*target as i64));
            o.set("foil", Json::from(*foil as i64));
        }
    }
    o
}

/// Serialize a graph to its JSON wire form.
pub fn to_json(g: &InterventionGraph) -> Json {
    let mut o = Json::obj(vec![
        ("model", Json::from(g.model.as_str())),
        ("batch", Json::from(g.batch as i64)),
        ("tokens", Json::from(g.tokens.clone())),
        ("shards", Json::from(g.shards.max(1) as i64)),
        (
            "nodes",
            Json::Array(g.nodes.iter().map(node_to_json).collect()),
        ),
    ]);
    if let Some(t) = &g.targets {
        o.set("targets", Json::from(t.clone()));
    }
    if let Some((off, rows)) = g.batch_group {
        o.set(
            "batch_group",
            Json::arr(vec![Json::from(off as i64), Json::from(rows as i64)]),
        );
    }
    o
}

// ---------------------------------------------------------------------------
// Deserialize
// ---------------------------------------------------------------------------

fn json_to_ranges(j: &Json) -> Result<Vec<Range1>> {
    j.as_array()
        .ok_or_else(|| anyhow!("ranges must be an array"))?
        .iter()
        .map(|r| {
            let pair = r.as_array().ok_or_else(|| anyhow!("range must be [start, stop]"))?;
            if pair.len() != 2 {
                return Err(anyhow!("range must have 2 entries"));
            }
            let start = pair[0].as_i64().ok_or_else(|| anyhow!("bad range start"))?;
            let stop = pair[1].as_i64().ok_or_else(|| anyhow!("bad range stop"))?;
            if start < 0 {
                return Err(anyhow!("negative range start"));
            }
            Ok(Range1 {
                start: start as usize,
                stop: if stop == -1 { usize::MAX } else { stop as usize },
            })
        })
        .collect()
}

fn parse_port(j: &Json) -> Result<Port> {
    match j.as_str() {
        Some("input") => Ok(Port::Input),
        Some("output") => Ok(Port::Output),
        other => Err(anyhow!("bad port {other:?}")),
    }
}

fn req_id(j: &Json, key: &str) -> Result<NodeId> {
    j.get(key)
        .as_usize()
        .ok_or_else(|| anyhow!("node missing id field '{key}'"))
}

fn req_str(j: &Json, key: &str) -> Result<String> {
    Ok(j.get(key)
        .as_str()
        .ok_or_else(|| anyhow!("node missing string field '{key}'"))?
        .to_string())
}

fn json_to_op(j: &Json) -> Result<Op> {
    let tag = req_str(j, "op")?;
    Ok(match tag.as_str() {
        "getter" => Op::Getter { module: req_str(j, "module")?, port: parse_port(j.get("port"))? },
        "setter" => Op::Setter {
            module: req_str(j, "module")?,
            port: parse_port(j.get("port"))?,
            arg: req_id(j, "arg")?,
        },
        "grad" => Op::Grad { module: req_str(j, "module")? },
        "const" => {
            let dims = j
                .get("dims")
                .as_usize_vec()
                .ok_or_else(|| anyhow!("const missing dims"))?;
            let data: Vec<f32> = j
                .get("data")
                .as_f64_vec()
                .ok_or_else(|| anyhow!("const missing data"))?
                .into_iter()
                .map(|v| v as f32)
                .collect();
            let numel: usize = dims.iter().product();
            if numel != data.len() {
                return Err(anyhow!("const dims/data mismatch"));
            }
            Op::Const { dims, data }
        }
        "slice" => Op::Slice { arg: req_id(j, "arg")?, ranges: json_to_ranges(j.get("ranges"))? },
        "assign" => Op::Assign {
            dst: req_id(j, "dst")?,
            ranges: json_to_ranges(j.get("ranges"))?,
            src: req_id(j, "src")?,
        },
        "fill" => Op::Fill {
            dst: req_id(j, "dst")?,
            ranges: json_to_ranges(j.get("ranges"))?,
            value: j.get("value").as_f64().ok_or_else(|| anyhow!("fill missing value"))? as f32,
        },
        "add" => Op::Add { a: req_id(j, "a")?, b: req_id(j, "b")? },
        "sub" => Op::Sub { a: req_id(j, "a")?, b: req_id(j, "b")? },
        "mul" => Op::Mul { a: req_id(j, "a")?, b: req_id(j, "b")? },
        "matmul" => Op::Matmul { a: req_id(j, "a")?, b: req_id(j, "b")? },
        "scale" => Op::Scale {
            arg: req_id(j, "arg")?,
            factor: j.get("factor").as_f64().ok_or_else(|| anyhow!("scale missing factor"))? as f32,
        },
        "gelu" => Op::Gelu { arg: req_id(j, "arg")? },
        "softmax" => Op::Softmax { arg: req_id(j, "arg")? },
        "argmax" => Op::Argmax { arg: req_id(j, "arg")? },
        "mean" => Op::Mean { arg: req_id(j, "arg")? },
        "sum" => Op::Sum { arg: req_id(j, "arg")? },
        "transpose" => Op::Transpose { arg: req_id(j, "arg")? },
        "reshape" => Op::Reshape {
            arg: req_id(j, "arg")?,
            dims: j
                .get("dims")
                .as_usize_vec()
                .ok_or_else(|| anyhow!("reshape missing dims"))?,
        },
        "mean_axis" => Op::MeanAxis {
            arg: req_id(j, "arg")?,
            axis: j
                .get("axis")
                .as_usize()
                .ok_or_else(|| anyhow!("mean_axis missing axis"))?,
        },
        "load_state" => Op::LoadState { key: req_str(j, "key")? },
        "store_state" => Op::StoreState { key: req_str(j, "key")?, arg: req_id(j, "arg")? },
        "logit_diff" => Op::LogitDiff {
            logits: req_id(j, "logits")?,
            target: req_id(j, "target")?,
            foil: req_id(j, "foil")?,
        },
        "save" => Op::Save { arg: req_id(j, "arg")? },
        "step_hook" => Op::StepHook { arg: req_id(j, "arg")? },
        // internal fused ops: produced by the admission compiler
        // (graph::opt) rather than by clients, but round-tripping them
        // keeps optimized graphs first-class wire citizens
        "fused_scale_add" => Op::FusedScaleAdd {
            a: req_id(j, "a")?,
            b: req_id(j, "b")?,
            factor: j
                .get("factor")
                .as_f64()
                .ok_or_else(|| anyhow!("fused_scale_add missing factor"))? as f32,
        },
        "fused_matmul_gelu" => Op::FusedMatmulGelu { a: req_id(j, "a")?, b: req_id(j, "b")? },
        "fused_scale_softmax" => Op::FusedScaleSoftmax {
            arg: req_id(j, "arg")?,
            factor: j
                .get("factor")
                .as_f64()
                .ok_or_else(|| anyhow!("fused_scale_softmax missing factor"))? as f32,
        },
        other => return Err(anyhow!("unknown op tag '{other}'")),
    })
}

/// Deserialize a graph from its JSON wire form. Node ids must be dense,
/// ascending, and topologically ordered (checked; the validator re-checks
/// semantic invariants).
pub fn from_json(j: &Json) -> Result<InterventionGraph> {
    let mut g = InterventionGraph::new(
        j.get("model")
            .as_str()
            .ok_or_else(|| anyhow!("request missing model"))?,
    );
    g.batch = j.get("batch").as_usize().unwrap_or(0);
    g.tokens = j
        .get("tokens")
        .as_f64_vec()
        .unwrap_or_default()
        .into_iter()
        .map(|v| v as f32)
        .collect();
    g.shards = j.get("shards").as_usize().unwrap_or(1).max(1);
    g.targets = j
        .get("targets")
        .as_f64_vec()
        .map(|v| v.into_iter().map(|x| x as f32).collect());
    if let Some(bg) = j.get("batch_group").as_usize_vec() {
        if bg.len() != 2 {
            return Err(anyhow!("batch_group must be [offset, rows]"));
        }
        g.batch_group = Some((bg[0], bg[1]));
    }
    let nodes = j
        .get("nodes")
        .as_array()
        .ok_or_else(|| anyhow!("request missing nodes"))?;
    for (i, nj) in nodes.iter().enumerate() {
        let id = req_id(nj, "id")?;
        if id != i {
            return Err(anyhow!("node ids must be dense and ascending (got {id} at {i})"));
        }
        let op = json_to_op(nj)?;
        for d in op.deps() {
            if d >= i {
                return Err(anyhow!("node {i} references later/self node {d}"));
            }
        }
        g.nodes.push(Node { id, op });
    }
    Ok(g)
}

// ---------------------------------------------------------------------------
// Results
// ---------------------------------------------------------------------------

/// Serialize a node-id → tensor map to the `{"<id>": {"dims": [..],
/// "b64": ..}}` wire object (shared by final results and per-step
/// streaming events).
pub fn values_to_json(values: &std::collections::BTreeMap<NodeId, crate::tensor::Tensor>) -> Json {
    let mut out = std::collections::BTreeMap::new();
    for (id, t) in values {
        // base64-packed f32 payload: ~2.4x smaller than JSON floats and
        // parse-free on the client (§Perf L3, EXPERIMENTS.md)
        out.insert(
            id.to_string(),
            Json::obj(vec![
                ("dims", Json::from(t.dims().to_vec())),
                ("b64", Json::from(crate::util::b64::encode_f32(t.data()))),
            ]),
        );
    }
    Json::Object(out)
}

/// Serialize saved values: `{"values": {"<id>": {"dims": [..], "b64": ..}}}`.
pub fn result_to_json(r: &super::GraphResult) -> Json {
    Json::obj(vec![("values", values_to_json(&r.values))])
}

/// [`result_to_json`] plus the per-request optimization report as the
/// `"opt"` metadata object (omitted when the request ran unoptimized —
/// `--no-opt`, or a scheduler path that bypassed the compiler).
pub fn result_to_json_with_opt(
    r: &super::GraphResult,
    opt: Option<&super::opt::OptReport>,
) -> Json {
    let mut o = result_to_json(r);
    if let Some(report) = opt {
        o.set("opt", report.to_json());
    }
    o
}

/// Deserialize saved values.
pub fn result_from_json(j: &Json) -> Result<super::GraphResult> {
    let mut r = super::GraphResult::default();
    let values = j
        .get("values")
        .as_object()
        .ok_or_else(|| anyhow!("result missing values"))?;
    for (id, v) in values {
        let id: NodeId = id.parse().map_err(|_| anyhow!("bad node id {id}"))?;
        let dims = v
            .get("dims")
            .as_usize_vec()
            .ok_or_else(|| anyhow!("value missing dims"))?;
        let data: Vec<f32> = if let Some(b64) = v.get("b64").as_str() {
            crate::util::b64::decode_f32(b64).ok_or_else(|| anyhow!("bad b64 payload"))?
        } else {
            // legacy/explicit form: a JSON float array
            v.get("data")
                .as_f64_vec()
                .ok_or_else(|| anyhow!("value missing data"))?
                .into_iter()
                .map(|x| x as f32)
                .collect()
        };
        r.values.insert(id, crate::tensor::Tensor::new(&dims, data));
    }
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;
    use crate::util::Prng;

    #[test]
    fn result_round_trip() {
        let mut r = crate::graph::GraphResult::default();
        r.values.insert(3, crate::tensor::Tensor::iota(&[2, 2]));
        r.values.insert(7, crate::tensor::Tensor::scalar(-1.5));
        let back = result_from_json(&parse(&result_to_json(&r).to_string()).unwrap()).unwrap();
        assert_eq!(back.values, r.values);
    }

    fn demo_graph() -> InterventionGraph {
        let mut g = InterventionGraph::new("tiny-sim");
        g.batch = 2;
        g.tokens = vec![1.0; 32];
        let get = g.push(Op::Getter { module: "layer.1".into(), port: Port::Output });
        let sl = g.push(Op::Slice {
            arg: get,
            ranges: vec![Range1::one(0), Range1::all()],
        });
        let c = g.push(Op::Const { dims: vec![1], data: vec![2.0] });
        let m = g.push(Op::Mul { a: sl, b: c });
        let asn = g.push(Op::Assign { dst: get, ranges: vec![Range1::one(1)], src: m });
        let _set = g.push(Op::Setter { module: "layer.1".into(), port: Port::Output, arg: asn });
        let _save = g.push(Op::Save { arg: m });
        g.batch_group = Some((0, 2));
        g
    }

    #[test]
    fn round_trip_preserves_graph() {
        let g = demo_graph();
        let j = to_json(&g);
        let text = j.to_string();
        let back = from_json(&parse(&text).unwrap()).unwrap();
        assert_eq!(back.model, g.model);
        assert_eq!(back.batch, g.batch);
        assert_eq!(back.tokens, g.tokens);
        assert_eq!(back.batch_group, g.batch_group);
        assert_eq!(back.nodes, g.nodes);
    }

    #[test]
    fn all_range_round_trips() {
        let rs = vec![Range1::all(), Range1::new(2, 5)];
        let back = json_to_ranges(&ranges_to_json(&rs)).unwrap();
        assert_eq!(back, rs);
    }

    #[test]
    fn state_and_shape_ops_round_trip() {
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let w = g.push(Op::LoadState { key: "probe.w".into() });
        let t = g.push(Op::Transpose { arg: w });
        let r = g.push(Op::Reshape { arg: t, dims: vec![4, 1] });
        let m = g.push(Op::MeanAxis { arg: r, axis: 0 });
        g.push(Op::StoreState { key: "probe.w".into(), arg: m });
        let text = to_json(&g).to_string();
        let back = from_json(&parse(&text).unwrap()).unwrap();
        assert_eq!(back.nodes, g.nodes);
        assert_eq!(back.state_loads(), vec!["probe.w"]);
        assert_eq!(back.state_stores(), vec!["probe.w"]);
    }

    #[test]
    fn step_hook_round_trips() {
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let get = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        let top = g.push(Op::Argmax { arg: get });
        g.push(Op::StepHook { arg: top });
        let text = to_json(&g).to_string();
        let back = from_json(&parse(&text).unwrap()).unwrap();
        assert_eq!(back.nodes, g.nodes);
        assert_eq!(back.step_hooks(), vec![2]);
        assert!(back.uses_step_hooks());
    }

    #[test]
    fn fused_ops_round_trip() {
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let h = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        let w = g.push(Op::Const { dims: vec![2, 2], data: vec![0.0; 4] });
        let fma = g.push(Op::FusedMatmulGelu { a: h, b: w });
        let fsa = g.push(Op::FusedScaleAdd { a: h, b: fma, factor: -0.25 });
        let fss = g.push(Op::FusedScaleSoftmax { arg: fsa, factor: 2.0 });
        g.push(Op::Save { arg: fss });
        let text = to_json(&g).to_string();
        let back = from_json(&parse(&text).unwrap()).unwrap();
        assert_eq!(back.nodes, g.nodes);
    }

    #[test]
    fn rejects_forward_reference() {
        let bad = r#"{"model":"m","batch":1,"tokens":[],"nodes":[
            {"id":0,"op":"scale","arg":1,"factor":2.0},
            {"id":1,"op":"const","dims":[1],"data":[1.0]}]}"#;
        assert!(from_json(&parse(bad).unwrap()).is_err());
    }

    #[test]
    fn rejects_sparse_ids() {
        let bad = r#"{"model":"m","batch":1,"tokens":[],"nodes":[
            {"id":3,"op":"const","dims":[1],"data":[1.0]}]}"#;
        assert!(from_json(&parse(bad).unwrap()).is_err());
    }

    #[test]
    fn rejects_unknown_op() {
        let bad = r#"{"model":"m","batch":1,"tokens":[],"nodes":[
            {"id":0,"op":"exfiltrate"}]}"#;
        assert!(from_json(&parse(bad).unwrap()).is_err());
    }

    #[test]
    fn rejects_const_shape_mismatch() {
        let bad = r#"{"model":"m","batch":1,"tokens":[],"nodes":[
            {"id":0,"op":"const","dims":[3],"data":[1.0]}]}"#;
        assert!(from_json(&parse(bad).unwrap()).is_err());
    }

    #[test]
    fn wire_bytes_positive() {
        assert!(demo_graph().wire_bytes() > 100);
    }

    #[test]
    fn property_random_graphs_round_trip() {
        use crate::util::Prng;
        let mut rng = Prng::new(0xA11CE);
        for case in 0..100 {
            let g = random_graph(&mut rng);
            let text = to_json(&g).to_string();
            let back = from_json(&parse(&text).unwrap())
                .unwrap_or_else(|e| panic!("case {case}: {e}"));
            assert_eq!(back.nodes, g.nodes, "case {case}");
        }
    }

    fn random_graph(rng: &mut Prng) -> InterventionGraph {
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        for _ in 0..rng.range(1, 12) {
            let n = g.nodes.len();
            let pick = |rng: &mut Prng| rng.range(0, n);
            let op = match rng.range(0, 11) {
                0 => Op::Const { dims: vec![2], data: vec![1.0, -2.5] },
                1 => Op::Scale { arg: pick(rng), factor: 0.5 },
                2 => Op::Add { a: pick(rng), b: pick(rng) },
                3 => Op::Slice { arg: pick(rng), ranges: vec![Range1::new(0, 1)] },
                4 => Op::Fill { dst: pick(rng), ranges: vec![Range1::all()], value: 0.0 },
                5 => Op::Softmax { arg: pick(rng) },
                6 => Op::Save { arg: pick(rng) },
                7 => Op::Transpose { arg: pick(rng) },
                8 => Op::LoadState { key: format!("k{}", rng.range(0, 3)) },
                9 => Op::StoreState { key: format!("k{}", rng.range(0, 3)), arg: pick(rng) },
                _ => Op::Mean { arg: pick(rng) },
            };
            g.push(op);
        }
        g
    }
}
