//! The unified execution engine: one door for every way a graph can run.
//!
//! Before this module the crate had three parallel execution paths —
//! one-shot traces (`interp::execute*`), stateful sessions
//! (`interp::execute_stateful*`), and streaming decode
//! (`interp::execute_stream*`) — each with its own entry-point matrix
//! (optimizer toggle × report × state view). [`Engine::run`] collapses
//! them: an [`ExecSpec`] says *what* to run (graph, optimizer on/off,
//! session state, streaming steps) and a single [`ExecOutcome`] carries
//! everything any caller needs (saved values, uncommitted state updates,
//! the optimizer report, the greedy trajectory). The server, the
//! scheduler worker, and the tests all go through this door; the old
//! `interp` names survive only as thin deprecated shims.
//!
//! The module also houses the decode substrate the scheduler batches
//! over:
//!
//! - [`NativeModel`]/[`KvCache`] ([`model`]): a host-resident forward
//!   with an explicit prefill/decode split and per-sequence KV blocks, so
//!   a decode step attends over cached keys instead of re-running the
//!   full window — O(1) weight matmuls per step in generated length.
//! - [`RunnerStream`]/[`KvStream`]/[`ContinuousBatch`] ([`batch`]): one
//!   in-flight decode per sequence plus the vLLM-style loop that
//!   interleaves single-token steps from many concurrent streams,
//!   admitting between steps and retiring mid-batch.
//!
//! ```text
//! ExecSpec lifecycle
//!   ExecSpec::trace(g)            one-shot, optimized      ┐
//!   ExecSpec::raw(g)              as-given (--no-opt,      │ Engine::run
//!                                 admission-compiled)      │    │
//!     .with_state(view)           session state in scope   ┘    ▼
//!     .stream(steps)              greedy decode, per-step   ExecOutcome
//!                                 graph re-entry (use
//!                                 run_streaming for a sink)
//! ```

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::graph::{
    opt::{OptReport, Prepared},
    plan::{self, PlanMode},
    plan_cache::PlanCache,
    validate::{validate_stream, validate_with_state},
    GraphResult, InterventionGraph,
};
use crate::interp::{self, StateView, StepOutcome};
use crate::models::generate::Generation;
use crate::models::ModelRunner;
use crate::tensor::Tensor;

pub mod batch;
pub mod model;

pub use batch::{ContinuousBatch, KvStream, RunnerStream};
pub use model::{KvCache, NativeModel};

/// What to execute: one graph plus the execution-mode knobs that used to
/// be spread across ten `interp::execute_*` signatures.
pub struct ExecSpec<'g> {
    graph: &'g InterventionGraph,
    steps: Option<usize>,
    optimize: bool,
    state: StateView,
}

impl<'g> ExecSpec<'g> {
    /// Run through the admission compiler (DCE, folding, CSE, fusion) —
    /// the default for user-submitted graphs.
    pub fn trace(graph: &'g InterventionGraph) -> ExecSpec<'g> {
        ExecSpec { graph, steps: None, optimize: true, state: StateView::new() }
    }

    /// Run the graph exactly as given — the `--no-opt` escape hatch, the
    /// scheduler's path for graphs already compiled at admission, and the
    /// oracle side of the optimizer-parity tests.
    pub fn raw(graph: &'g InterventionGraph) -> ExecSpec<'g> {
        ExecSpec { graph, steps: None, optimize: false, state: StateView::new() }
    }

    /// Resolve `LoadState` ops against `state`; collected store updates
    /// come back in [`ExecOutcome::state_updates`] (uncommitted — the
    /// session layer owns the commit).
    pub fn with_state(mut self, state: StateView) -> ExecSpec<'g> {
        self.state = state;
        self
    }

    /// Greedy-decode `steps` tokens, re-entering the graph at every step.
    pub fn stream(mut self, steps: usize) -> ExecSpec<'g> {
        self.steps = Some(steps);
        self
    }
}

/// Everything a run can produce. Fields are `None`/empty when the spec
/// didn't ask for them.
pub struct ExecOutcome {
    /// Saved values, keyed by the ids of the graph as submitted. Empty
    /// for streaming runs — per-step values flow through the sink.
    pub result: GraphResult,
    /// Store updates a session layer should commit on success.
    pub state_updates: BTreeMap<String, Tensor>,
    /// Admission-compiler report (`None` when the spec was raw).
    pub report: Option<OptReport>,
    /// Greedy trajectory (`Some` only for streaming runs).
    pub generation: Option<Generation>,
}

/// The unified execution door: binds a loaded model to [`ExecSpec`]s.
/// With [`Engine::with_plans`] every run goes through the AOT plan cache:
/// a structural hit skips validation, the optimization pipeline, and
/// scheduling prep, paying only the constant rebind.
pub struct Engine<'r> {
    runner: &'r ModelRunner,
    plans: Option<Arc<PlanCache>>,
}

impl<'r> Engine<'r> {
    pub fn new(runner: &'r ModelRunner) -> Engine<'r> {
        Engine { runner, plans: None }
    }

    /// An engine whose runs are admitted through `plans` (the shared AOT
    /// plan cache). Session-mode graphs still revalidate per run — state-
    /// key availability is per-request state, not structure — but reuse
    /// the cached template/schedule/arena like everything else.
    pub fn with_plans(runner: &'r ModelRunner, plans: Arc<PlanCache>) -> Engine<'r> {
        Engine { runner, plans: Some(plans) }
    }

    /// Look up or compile the plan for `graph` and bind it. `validated`
    /// says whether the caller already validated this submission; on a
    /// cache miss an unvalidated graph is validated before compiling, so
    /// cold admission rejects exactly what the pre-plan path rejected.
    fn prepared_for(
        &self,
        graph: &InterventionGraph,
        mode: PlanMode,
        optimize: bool,
        cache: &PlanCache,
        validated: bool,
    ) -> Result<Prepared> {
        let fseq = self.runner.manifest.forward_sequence();
        let key = plan::structural_key(graph, mode, optimize);
        let plan = match cache.get(&graph.model, key) {
            Some(p) => p,
            None => {
                if !validated {
                    match mode {
                        PlanMode::Stream => validate_stream(graph, &fseq)?,
                        _ => {
                            validate_with_state(graph, &fseq, &Default::default())?;
                        }
                    }
                }
                let p = Arc::new(plan::compile(graph, &fseq, mode, optimize)?);
                cache.insert(&graph.model, key, Arc::clone(&p));
                p
            }
        };
        plan.bind(graph)
    }

    /// Execute one spec. Streaming specs decode to completion (every
    /// step's sink is accepted); use [`Engine::run_streaming`] to consume
    /// per-step outcomes or stop early.
    pub fn run(&self, spec: ExecSpec) -> Result<ExecOutcome> {
        if spec.steps.is_some() {
            return self.run_streaming(spec, &mut |_, _| true);
        }
        if let Some(cache) = self.plans.clone() {
            let uses_state = spec.graph.uses_state() || !spec.state.is_empty();
            let mode = if uses_state { PlanMode::Session } else { PlanMode::Trace };
            // session runs always revalidate (key availability is not
            // structural); trace hits skip validation entirely
            let validated = if uses_state {
                let keys = spec.state.keys().cloned().collect();
                validate_with_state(spec.graph, &self.runner.manifest.forward_sequence(), &keys)?;
                true
            } else {
                false
            };
            let prepared =
                self.prepared_for(spec.graph, mode, spec.optimize, &cache, validated)?;
            let (res, state_updates) =
                interp::execute_view_prepared(&prepared, self.runner, spec.state)?;
            return Ok(ExecOutcome {
                result: prepared.remap_values(res),
                state_updates,
                report: prepared.report,
                generation: None,
            });
        }
        let (result, state_updates, report) =
            interp::execute_full(spec.graph, self.runner, spec.state, spec.optimize)?;
        Ok(ExecOutcome { result, state_updates, report, generation: None })
    }

    /// Execute a streaming spec, delivering each [`StepOutcome`] to
    /// `sink` as the step completes; `sink` returns `false` to stop
    /// decoding early (a gone consumer).
    pub fn run_streaming(
        &self,
        spec: ExecSpec,
        sink: &mut dyn FnMut(usize, StepOutcome) -> bool,
    ) -> Result<ExecOutcome> {
        let steps = spec
            .steps
            .ok_or_else(|| anyhow!("streaming run requires ExecSpec::stream(steps)"))?;
        if !spec.state.is_empty() {
            return Err(anyhow!(
                "streaming decode does not take session state (validation rule 8)"
            ));
        }
        if let Some(cache) = self.plans.clone() {
            let prepared =
                self.prepared_for(spec.graph, PlanMode::Stream, spec.optimize, &cache, false)?;
            let report = prepared.report;
            let mut wrapped = |step: usize, mut out: StepOutcome| {
                out.values = prepared.remap_values(out.values);
                sink(step, out)
            };
            let gen =
                interp::execute_stream_prepared(&prepared, self.runner, steps, &mut wrapped)?;
            return Ok(ExecOutcome {
                result: GraphResult { values: BTreeMap::new() },
                state_updates: BTreeMap::new(),
                report,
                generation: Some(gen),
            });
        }
        let (gen, report) =
            interp::execute_stream_opt(spec.graph, self.runner, steps, spec.optimize, sink)?;
        Ok(ExecOutcome {
            result: GraphResult { values: BTreeMap::new() },
            state_updates: BTreeMap::new(),
            report,
            generation: Some(gen),
        })
    }

    /// Execute an ordered trace bundle against shared session state,
    /// committing each trace's store updates before the next runs. On
    /// error the failing trace's updates are discarded and `state` keeps
    /// every earlier trace's commits (the session stays resumable).
    pub fn run_session(
        &self,
        graphs: &[InterventionGraph],
        state: &mut StateView,
        optimize: bool,
    ) -> Result<Vec<GraphResult>> {
        let mut results = Vec::with_capacity(graphs.len());
        for (i, g) in graphs.iter().enumerate() {
            let r = match self.plans.clone() {
                Some(cache) => self
                    .session_step_planned(g, state, optimize, &cache)
                    .map_err(|e| anyhow!("session trace {i}: {e}"))?,
                None => interp::execute_stateful_inner(g, self.runner, state, optimize)
                    .map_err(|e| anyhow!("session trace {i}: {e}"))?,
            };
            results.push(r);
        }
        Ok(results)
    }

    /// One session trace through the plan cache: snapshot the loaded keys,
    /// revalidate against them (always — key availability is per-request
    /// state), bind the cached or freshly compiled plan, execute, commit
    /// updates on success.
    fn session_step_planned(
        &self,
        g: &InterventionGraph,
        state: &mut StateView,
        optimize: bool,
        cache: &PlanCache,
    ) -> Result<GraphResult> {
        let mut view = StateView::new();
        for key in g.state_loads() {
            if let Some(t) = state.get(&key) {
                view.insert(key, t.clone());
            }
        }
        let keys = view.keys().cloned().collect();
        validate_with_state(g, &self.runner.manifest.forward_sequence(), &keys)?;
        let prepared = self.prepared_for(g, PlanMode::Session, optimize, cache, true)?;
        let (res, updates) = interp::execute_view_prepared(&prepared, self.runner, view)?;
        for (k, v) in updates {
            state.insert(k, v);
        }
        Ok(prepared.remap_values(res))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::Trace;

    #[test]
    fn spec_builders_set_modes() {
        let g = InterventionGraph::new("m");
        let s = ExecSpec::trace(&g);
        assert!(s.optimize && s.steps.is_none() && s.state.is_empty());
        let s = ExecSpec::raw(&g).stream(7);
        assert!(!s.optimize);
        assert_eq!(s.steps, Some(7));
        let mut view = StateView::new();
        view.insert("k".into(), Tensor::new(&[1], vec![0.0]));
        let s = ExecSpec::trace(&g).with_state(view);
        assert_eq!(s.state.len(), 1);
    }

    #[test]
    fn native_engine_streams_through_the_same_graph_contract() {
        // the native KV substrate accepts the same client-built graphs as
        // the artifact path — no artifacts needed
        let m = NativeModel::new(crate::runtime::artifacts::Manifest::synthetic(
            "door-test", 16, 2, 2, 32, 13, 32,
        ));
        let t = Tensor::new(&[1, 3], vec![1.0, 5.0, 2.0]);
        let mut tr = Trace::new("door-test", &t);
        let h = tr.output("layer.1");
        let mean = tr.mean(h);
        let hook = tr.step_hook(mean);
        let mut s = KvStream::new(tr.into_graph(), &m, 3).unwrap();
        let mut steps = 0;
        while let Some(out) = s.step(&m).unwrap() {
            assert!(out.values.get(hook.0).is_some(), "step {steps} missing hooked value");
            steps += 1;
        }
        assert_eq!(steps, 3);
    }
}
