//! Decode streams and the continuous-batching loop.
//!
//! A *stream* is one sequence's decode in progress: its intervention
//! graph (validated once at admission), its greedy trajectory so far, and
//! whatever forward state the substrate needs — a sliding `[1, seq]`
//! context window for [`RunnerStream`] (AOT artifacts), or a per-sequence
//! [`KvCache`](super::KvCache) for [`KvStream`] (native engine, explicit
//! prefill/decode split). Both expose the same one-token `step()` so a
//! scheduler can interleave many of them.
//!
//! [`ContinuousBatch`] is that scheduler in miniature: it admits new
//! streams between steps, issues one decode step per active stream per
//! tick, and retires finished streams without draining the rest — the
//! vLLM-style loop. Per-tick stepping may fan out across threads
//! (streams are independent: separate caches, separate executors, shared
//! immutable weights); event emission is always in admission order so
//! batched output is deterministic.
//!
//! Interventions stay per-sequence: every step builds a fresh
//! [`Executor`] over *that stream's* graph and re-enters it against that
//! step's hidden state, so `step_hook` emission, setters, and
//! profiler/phase attribution (`profile::set_step`) are scoped to one
//! request even when eight streams share a tick.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::graph::{plan::ExecPlan, validate::validate_stream, InterventionGraph};
use crate::interp::{Executor, StateView, StepOutcome};
use crate::models::generate::{advance_window, argmax_row, Generation};
use crate::models::ModelRunner;
use crate::obs::{phases, profile};
use crate::tensor::Tensor;

use super::model::{KvCache, NativeModel};

/// One in-flight decode over the fixed-window artifacts: each step runs a
/// full `[1, seq]` forward through [`ModelRunner`] and slides the window.
/// This is the stream form of the interpreter's original streaming loop —
/// `interp::execute_stream` now drives one of these to completion, and
/// the scheduler steps many of them interleaved.
pub struct RunnerStream {
    graph: InterventionGraph,
    fseq: Vec<String>,
    ctx: Tensor,
    seq: usize,
    vocab: usize,
    steps: usize,
    step: usize,
    gen: Generation,
    /// AOT plan the graph was bound from: every step's executor is built
    /// from its precomputed schedule and arena instead of rederiving them.
    plan: Option<Arc<ExecPlan>>,
}

impl RunnerStream {
    /// Validate and admit a stream. All checks are paid here, once —
    /// `step()` re-enters the graph prevalidated.
    pub fn new(graph: InterventionGraph, runner: &ModelRunner, steps: usize) -> Result<RunnerStream> {
        RunnerStream::with_plan(graph, runner, steps, None)
    }

    /// Admit a plan-bound stream: the stream-rule validation already
    /// happened when the plan's structure first compiled, so only the
    /// cheap geometry guards run here; each decode step then executes on
    /// a planned executor. With `plan` unset this is exactly [`Self::new`].
    pub(crate) fn with_plan(
        graph: InterventionGraph,
        runner: &ModelRunner,
        steps: usize,
        plan: Option<Arc<ExecPlan>>,
    ) -> Result<RunnerStream> {
        let fseq = runner.manifest.forward_sequence();
        if plan.is_none() {
            validate_stream(&graph, &fseq)?;
        }
        if graph.shards > 1 {
            return Err(anyhow!("streaming decode is unsharded (shards = {})", graph.shards));
        }
        if graph.batch_group.is_some() {
            return Err(anyhow!("streaming decode does not merge into co-tenant batches"));
        }
        let seq = runner.manifest.seq;
        if graph.batch != 1 || graph.tokens.len() != seq {
            return Err(anyhow!(
                "streaming generation is single-sequence: need [1, {seq}] tokens, got batch {} × {}",
                graph.batch,
                graph.tokens.len()
            ));
        }
        let ctx = Tensor::new(&[1, seq], graph.tokens.clone());
        let vocab = runner.manifest.vocab;
        Ok(RunnerStream {
            graph,
            fseq,
            ctx,
            seq,
            vocab,
            steps,
            step: 0,
            gen: Generation { tokens: Vec::with_capacity(steps), scores: Vec::new() },
            plan,
        })
    }

    /// Decode one token: fresh executor over this stream's graph →
    /// pre-phase → hooked forward → saved values → greedy window slide.
    /// Returns `None` once `steps` tokens have been emitted.
    pub fn step(&mut self, runner: &ModelRunner) -> Result<Option<StepOutcome>> {
        if self.step >= self.steps {
            return Ok(None);
        }
        let timed = phases::armed();
        let profiled = profile::armed();
        // per-step granularity: every op and phase recorded below carries
        // the decode step index (no-op when the profiler is disarmed)
        profile::set_step(self.step as i64);
        let res = (|| {
            let mut ex = match &self.plan {
                Some(p) => Executor::planned(&self.graph, &self.fseq, StateView::new(), p),
                None => Executor::prevalidated(&self.graph, &self.fseq, StateView::new())?,
            };
            ex.run_pre()?;
            let tf = (timed || profiled).then(std::time::Instant::now);
            let logits = runner.forward(&self.ctx, &mut ex)?;
            if let Some(t) = tf {
                if timed {
                    phases::record("forward", t.elapsed().as_nanos() as u64);
                }
                if profiled {
                    profile::record_phase("forward", t);
                }
            }
            if let Some(e) = ex.take_error() {
                return Err(e);
            }
            let values = ex.into_result()?;
            let (token, score) = advance_window(&mut self.ctx, &logits, self.seq, self.vocab);
            Ok(StepOutcome { token, score, values })
        })();
        profile::set_step(profile::NO_STEP);
        let out = res?;
        self.gen.tokens.push(out.token);
        self.gen.scores.push(out.score);
        self.step += 1;
        Ok(Some(out))
    }

    /// True once all requested steps have been emitted.
    pub fn finished(&self) -> bool {
        self.step >= self.steps
    }

    /// The greedy trajectory emitted so far.
    pub fn generation(&self) -> &Generation {
        &self.gen
    }

    pub fn into_generation(self) -> Generation {
        self.gen
    }
}

/// One in-flight decode over the native KV-cached engine: step 0 prefills
/// the whole prompt in a single pass, every later step embeds exactly one
/// token and attends over the cached prefix — O(1) weight matmuls per
/// step regardless of how many tokens were generated before.
///
/// Every step (prefill included) emits one greedy token and re-enters the
/// intervention graph; hooks observe `[1, prompt_len, d]` at step 0 and
/// `[1, 1, d]` afterwards.
pub struct KvStream {
    graph: InterventionGraph,
    fseq: Vec<String>,
    cache: KvCache,
    prompt: Vec<usize>,
    last: usize,
    steps: usize,
    step: usize,
    gen: Generation,
    /// AOT plan the graph was bound from (see [`RunnerStream::plan`]).
    plan: Option<Arc<ExecPlan>>,
}

impl KvStream {
    /// Validate and admit a KV stream. The graph's tokens are the prompt
    /// (`[1, prompt_len]`, unpadded — the native engine has no fixed
    /// window); the stream must fit the model context: `prompt_len +
    /// steps − 1 ≤ seq` (the final generated token is never fed back).
    pub fn new(graph: InterventionGraph, model: &NativeModel, steps: usize) -> Result<KvStream> {
        KvStream::with_plan(graph, model, steps, None)
    }

    /// Admit a plan-bound KV stream: stream-rule validation is skipped on
    /// a plan hit (the structure already passed it at compile time); the
    /// geometry/vocab guards below are payload-dependent and always run.
    pub(crate) fn with_plan(
        graph: InterventionGraph,
        model: &NativeModel,
        steps: usize,
        plan: Option<Arc<ExecPlan>>,
    ) -> Result<KvStream> {
        let fseq = model.manifest().forward_sequence();
        if plan.is_none() {
            validate_stream(&graph, &fseq)?;
        }
        if graph.shards > 1 {
            return Err(anyhow!("streaming decode is unsharded (shards = {})", graph.shards));
        }
        if graph.batch_group.is_some() {
            return Err(anyhow!("streaming decode does not merge into co-tenant batches"));
        }
        if graph.batch != 1 || graph.tokens.is_empty() {
            return Err(anyhow!(
                "streaming generation is single-sequence: need [1, prompt_len] tokens, got batch {} × {}",
                graph.batch,
                graph.tokens.len()
            ));
        }
        let vocab = model.manifest().vocab;
        let mut prompt = Vec::with_capacity(graph.tokens.len());
        for &t in &graph.tokens {
            if t < 0.0 || t >= vocab as f32 {
                bail!("prompt token {t} out of vocab {vocab}");
            }
            prompt.push(t as usize);
        }
        let seq = model.manifest().seq;
        if prompt.len() + steps.saturating_sub(1) > seq {
            bail!(
                "stream overruns the model context: {} prompt + {steps} steps > {seq} positions",
                prompt.len()
            );
        }
        Ok(KvStream {
            graph,
            fseq,
            cache: model.kv_cache(),
            prompt,
            last: 0,
            steps,
            step: 0,
            gen: Generation { tokens: Vec::with_capacity(steps), scores: Vec::new() },
            plan,
        })
    }

    /// Emit one greedy token. Step 0 is the prefill pass (prompt → cache,
    /// first token from the last prompt position's logits); later steps
    /// decode the previously chosen token against the cache.
    pub fn step(&mut self, model: &NativeModel) -> Result<Option<StepOutcome>> {
        if self.step >= self.steps {
            return Ok(None);
        }
        let timed = phases::armed();
        let profiled = profile::armed();
        profile::set_step(self.step as i64);
        let res = (|| {
            let mut ex = match &self.plan {
                Some(p) => Executor::planned(&self.graph, &self.fseq, StateView::new(), p),
                None => Executor::prevalidated(&self.graph, &self.fseq, StateView::new())?,
            };
            ex.run_pre()?;
            let tf = (timed || profiled).then(std::time::Instant::now);
            let phase = if self.step == 0 { "prefill" } else { "decode" };
            let logits = if self.step == 0 {
                model.prefill(&self.prompt, &mut self.cache, &mut ex)?
            } else {
                model.decode_step(self.last, &mut self.cache, &mut ex)?
            };
            if let Some(t) = tf {
                if timed {
                    phases::record(phase, t.elapsed().as_nanos() as u64);
                }
                if profiled {
                    profile::record_phase(phase, t);
                }
            }
            if let Some(e) = ex.take_error() {
                return Err(e);
            }
            let values = ex.into_result()?;
            let data = logits.data();
            let vocab = model.manifest().vocab;
            let (token, score) = argmax_row(&data[data.len() - vocab..]);
            Ok(StepOutcome { token, score, values })
        })();
        profile::set_step(profile::NO_STEP);
        let out = res?;
        self.last = out.token;
        self.gen.tokens.push(out.token);
        self.gen.scores.push(out.score);
        self.step += 1;
        Ok(Some(out))
    }

    pub fn finished(&self) -> bool {
        self.step >= self.steps
    }

    /// Cached positions so far (prompt + decoded-and-fed tokens).
    pub fn cached_len(&self) -> usize {
        self.cache.len()
    }

    pub fn generation(&self) -> &Generation {
        &self.gen
    }

    pub fn into_generation(self) -> Generation {
        self.gen
    }
}

/// The continuous-batching loop: many concurrent streams, one decode step
/// each per tick, admission between ticks, retirement without draining.
///
/// Invariants (the golden-parity suite holds batched output to these):
///
/// 1. **Per-stream isolation** — a step only touches its own stream's
///    state, so a stream's trajectory is bit-identical whether it runs
///    alone or interleaved with others, parallel or sequential.
/// 2. **Deterministic emission** — events within a tick are delivered in
///    admission order, regardless of which thread finished first.
/// 3. **Atomic ticks** — admission and retirement happen only between
///    ticks; a mid-batch completion never stalls or reorders the rest.
///
/// The first step error poisons the whole batch (`tick` returns it and
/// drops that tick's events); the server's scheduler does per-stream
/// error routing itself and uses this type's building blocks instead.
pub struct ContinuousBatch<S> {
    pending: Vec<(u64, usize, S)>,
    active: Vec<(usize, S)>,
    tick: u64,
}

impl<S> Default for ContinuousBatch<S> {
    fn default() -> Self {
        ContinuousBatch::new()
    }
}

impl<S> ContinuousBatch<S> {
    pub fn new() -> ContinuousBatch<S> {
        ContinuousBatch { pending: Vec::new(), active: Vec::new(), tick: 0 }
    }

    /// Admit a stream immediately (joins the next tick).
    pub fn admit(&mut self, id: usize, stream: S) {
        self.pending.push((self.tick, id, stream));
    }

    /// Admit a stream once `tick` ticks have elapsed — staggered arrival,
    /// the parity suite's mid-batch admission case.
    pub fn admit_at(&mut self, tick: u64, id: usize, stream: S) {
        self.pending.push((tick, id, stream));
    }

    pub fn is_idle(&self) -> bool {
        self.pending.is_empty() && self.active.is_empty()
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    pub fn ticks(&self) -> u64 {
        self.tick
    }

    /// One scheduler tick: admit due streams, step every active stream
    /// once (across threads when `parallel` — streams share only
    /// immutable weights), emit this tick's outcomes in admission order,
    /// retire streams that report completion.
    pub fn tick(
        &mut self,
        parallel: bool,
        step: impl Fn(&mut S) -> Result<Option<StepOutcome>> + Sync,
        on_event: &mut dyn FnMut(usize, StepOutcome),
    ) -> Result<()>
    where
        S: Send,
    {
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].0 <= self.tick {
                let (_, id, s) = self.pending.remove(i);
                self.active.push((id, s));
            } else {
                i += 1;
            }
        }
        self.tick += 1;
        if self.active.is_empty() {
            return Ok(());
        }

        let results: Vec<Result<Option<StepOutcome>>> = if parallel && self.active.len() > 1 {
            let stepr = &step;
            let mut slots: Vec<Option<Result<Option<StepOutcome>>>> =
                (0..self.active.len()).map(|_| None).collect();
            std::thread::scope(|scope| {
                for ((_, s), slot) in self.active.iter_mut().zip(slots.iter_mut()) {
                    scope.spawn(move || *slot = Some(stepr(s)));
                }
            });
            slots.into_iter().map(|r| r.expect("scoped step completed")).collect()
        } else {
            self.active.iter_mut().map(|(_, s)| step(s)).collect()
        };

        // propagate the first error before emitting anything: a tick is
        // all-or-nothing for observers
        let mut outs = Vec::with_capacity(results.len());
        for r in results {
            outs.push(r?);
        }
        let mut keep = Vec::with_capacity(self.active.len());
        for ((id, s), out) in std::mem::take(&mut self.active).into_iter().zip(outs) {
            if let Some(o) = out {
                on_event(id, o);
                keep.push((id, s));
            }
        }
        self.active = keep;
        Ok(())
    }

    /// Tick until every admitted stream has completed.
    pub fn run(
        &mut self,
        parallel: bool,
        step: impl Fn(&mut S) -> Result<Option<StepOutcome>> + Sync,
        on_event: &mut dyn FnMut(usize, StepOutcome),
    ) -> Result<()>
    where
        S: Send,
    {
        while !self.is_idle() {
            self.tick(parallel, &step, on_event)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::Trace;
    use crate::runtime::artifacts::Manifest;

    fn model() -> NativeModel {
        NativeModel::new(Manifest::synthetic("batch-test", 16, 2, 2, 32, 13, 32))
    }

    fn stream_graph(model: &NativeModel, prompt: &[f32]) -> InterventionGraph {
        let t = Tensor::new(&[1, prompt.len()], prompt.to_vec());
        let mut tr = Trace::new(&model.manifest().name, &t);
        let h = tr.output("layer.0");
        let m = tr.mean(h);
        tr.step_hook(m);
        tr.into_graph()
    }

    #[test]
    fn kv_stream_decodes_requested_steps() {
        let m = model();
        let g = stream_graph(&m, &[1.0, 5.0, 2.0]);
        let mut s = KvStream::new(g, &m, 4).unwrap();
        let mut n = 0;
        while let Some(out) = s.step(&m).unwrap() {
            assert!(out.token < m.manifest().vocab);
            assert!(!out.values.values.is_empty(), "step hook must emit per step");
            n += 1;
        }
        assert_eq!(n, 4);
        assert!(s.finished());
        assert_eq!(s.generation().tokens.len(), 4);
        // prompt + 3 fed tokens cached (the 4th is never fed back)
        assert_eq!(s.cached_len(), 6);
    }

    #[test]
    fn kv_stream_rejects_context_overrun_at_admission() {
        let m = model();
        let g = stream_graph(&m, &[1.0, 2.0]);
        // 2 prompt + 31 fed tokens > 32 positions
        assert!(KvStream::new(g, &m, 32).is_err());
    }

    #[test]
    fn continuous_batch_matches_solo_streams_with_staggered_admission() {
        let m = model();
        let prompts: Vec<Vec<f32>> = vec![
            vec![1.0, 5.0, 2.0],
            vec![7.0, 3.0],
            vec![2.0, 2.0, 9.0, 4.0],
        ];
        let steps = [5usize, 2, 4]; // mid-batch completion: stream 1 retires first
        // oracle: each stream alone
        let mut solo = Vec::new();
        for (p, &st) in prompts.iter().zip(&steps) {
            let mut s = KvStream::new(stream_graph(&m, p), &m, st).unwrap();
            while s.step(&m).unwrap().is_some() {}
            solo.push(s.into_generation());
        }
        // batched, staggered admission, parallel stepping
        let mut batch = ContinuousBatch::new();
        for (i, (p, &st)) in prompts.iter().zip(&steps).enumerate() {
            let s = KvStream::new(stream_graph(&m, p), &m, st).unwrap();
            batch.admit_at(i as u64, i, s);
        }
        let mut got: Vec<Vec<(usize, f32)>> = vec![Vec::new(); prompts.len()];
        batch
            .run(true, |s: &mut KvStream| s.step(&m), &mut |id, out| {
                got[id].push((out.token, out.score));
            })
            .unwrap();
        for (i, g) in got.iter().enumerate() {
            let tokens: Vec<usize> = g.iter().map(|e| e.0).collect();
            let scores: Vec<f32> = g.iter().map(|e| e.1).collect();
            assert_eq!(tokens, solo[i].tokens, "stream {i} tokens diverged under batching");
            assert_eq!(scores, solo[i].scores, "stream {i} scores diverged under batching");
        }
    }

    #[test]
    fn batch_admits_and_retires_without_draining() {
        let m = model();
        let mut batch = ContinuousBatch::new();
        batch.admit(0, KvStream::new(stream_graph(&m, &[1.0]), &m, 1).unwrap());
        batch.admit_at(1, 1, KvStream::new(stream_graph(&m, &[2.0]), &m, 3).unwrap());
        let mut order = Vec::new();
        batch
            .run(false, |s: &mut KvStream| s.step(&m), &mut |id, out| {
                order.push((id, out.token));
            })
            .unwrap();
        // stream 0 emits once and retires while stream 1 keeps going
        assert_eq!(order.len(), 4);
        assert_eq!(order[0].0, 0);
        assert!(order[1..].iter().all(|e| e.0 == 1));
        assert!(batch.is_idle());
    }
}
