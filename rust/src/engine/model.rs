//! The native decode substrate: a pure-host transformer forward with an
//! append-only per-sequence KV cache.
//!
//! `NativeModel` runs the same OPT-style decoder math as the AOT
//! artifacts (`python/compile/model.py`) directly on the host kernels —
//! no PJRT, no artifact files — which is what lets the decode engine
//! split prefill from decode: the artifacts are shape-specialized to a
//! full `[batch, seq]` window, but a host forward can process exactly the
//! new positions and attend over cached K/V rows ([`KvCache`]).
//!
//! Every matmul goes through [`PackedMat::matmul_bias`] and attention
//! through [`attn_causal_rows`]/`attn_mix_row`, both of which compute
//! per-row results independent of how many rows are in flight. That is
//! the bit-parity contract of the engine: **prefill(n) ≡ prefill(k) +
//! (n−k) decode steps**, bit for bit, so the continuous-batching parity
//! suite can hold batched decode to a sequential oracle with
//! `assert_eq!` instead of tolerances.
//!
//! Intervention hook points match the artifact forward sequence —
//! `embed`, `layer.<i>`, `lm_head` — and fire per forward call with the
//! activation shaped `[1, rows, d]`: `rows = prompt_len` during prefill,
//! `rows = 1` during decode. A setter's effect on a position is baked
//! into the K/V rows of **later** layers at the step that computes that
//! position (each position is computed exactly once under a KV cache,
//! unlike the sliding-window path which recomputes the whole window every
//! step).

use anyhow::{anyhow, bail, Result};

use crate::models::{weights::ModelWeights, Hooks};
use crate::runtime::artifacts::Manifest;
use crate::tensor::ops::{attn_causal_rows, gelu_rows, layernorm_rows, PackedMat};
use crate::tensor::Tensor;

/// Layernorm epsilon of the native forward (free choice: parity is
/// native-vs-native, the AOT path never mixes with this one).
const LN_EPS: f32 = 1e-5;

/// Append-only per-sequence K/V rows, one block pair per layer. Rows are
/// packed `[len, d]` with head `h` in columns `h·dh..(h+1)·dh`, matching
/// the attention kernels. Capacity is the model's position-embedding
/// table (`manifest.seq`): a sequence cannot decode past the positions
/// the model was trained to embed.
pub struct KvCache {
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    len: usize,
    cap: usize,
    d: usize,
}

impl KvCache {
    fn new(layers: usize, d: usize, cap: usize) -> KvCache {
        KvCache {
            k: vec![Vec::new(); layers],
            v: vec![Vec::new(); layers],
            len: 0,
            cap,
            d,
        }
    }

    /// Cached positions so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum positions this sequence can ever hold.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Approximate resident bytes (f32 K+V rows across all layers).
    pub fn bytes(&self) -> usize {
        self.k.len() * self.len * self.d * 4 * 2
    }

    fn append_layer(&mut self, layer: usize, krows: &[f32], vrows: &[f32]) {
        self.k[layer].extend_from_slice(krows);
        self.v[layer].extend_from_slice(vrows);
    }

    fn layer(&self, layer: usize) -> (&[f32], &[f32]) {
        (&self.k[layer], &self.v[layer])
    }

    fn advance(&mut self, rows: usize) {
        self.len += rows;
    }
}

struct LayerWeights {
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    wq: PackedMat,
    wk: PackedMat,
    wv: PackedMat,
    wo: PackedMat,
    bo: Vec<f32>,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
    w1: PackedMat,
    b1: Vec<f32>,
    w2: PackedMat,
    b2: Vec<f32>,
}

/// A host-resident decoder with weights pre-packed for row-deterministic
/// matmuls. Shared immutably across streams (`&NativeModel` is `Sync`);
/// all per-sequence state lives in the caller's [`KvCache`].
pub struct NativeModel {
    manifest: Manifest,
    wte: Vec<f32>,
    wpe: Vec<f32>,
    layers: Vec<LayerWeights>,
    lnf_g: Vec<f32>,
    lnf_b: Vec<f32>,
    wout: PackedMat,
}

impl NativeModel {
    /// Build from deterministically generated weights (the same
    /// name-seeded contract the artifact runner uses).
    pub fn new(manifest: Manifest) -> NativeModel {
        let w = ModelWeights::generate(&manifest);
        NativeModel::from_weights(manifest, &w).expect("generated weights match manifest")
    }

    /// Build from explicit weights (e.g. loaded from `weights.bin`).
    pub fn from_weights(manifest: Manifest, w: &ModelWeights) -> Result<NativeModel> {
        let module = |key: &str| -> Result<&Vec<Tensor>> {
            w.modules
                .get(key)
                .ok_or_else(|| anyhow!("weights missing module '{key}'"))
        };
        let vec1 = |t: &Tensor| t.data().to_vec();
        let embed = module("embed")?;
        if embed.len() != 2 {
            bail!("embed expects [wte, wpe], got {} tensors", embed.len());
        }
        let mut layers = Vec::with_capacity(manifest.n_layers);
        for i in 0..manifest.n_layers {
            let p = module(&format!("layer.{i}"))?;
            if p.len() != 13 {
                bail!("layer.{i} expects 13 params, got {}", p.len());
            }
            layers.push(LayerWeights {
                ln1_g: vec1(&p[0]),
                ln1_b: vec1(&p[1]),
                wq: PackedMat::from_tensor(&p[2]),
                wk: PackedMat::from_tensor(&p[3]),
                wv: PackedMat::from_tensor(&p[4]),
                wo: PackedMat::from_tensor(&p[5]),
                bo: vec1(&p[6]),
                ln2_g: vec1(&p[7]),
                ln2_b: vec1(&p[8]),
                w1: PackedMat::from_tensor(&p[9]),
                b1: vec1(&p[10]),
                w2: PackedMat::from_tensor(&p[11]),
                b2: vec1(&p[12]),
            });
        }
        let head = module("lm_head")?;
        if head.len() != 3 {
            bail!("lm_head expects [lnf_g, lnf_b, wout], got {} tensors", head.len());
        }
        Ok(NativeModel {
            wte: vec1(&embed[0]),
            wpe: vec1(&embed[1]),
            layers,
            lnf_g: vec1(&head[0]),
            lnf_b: vec1(&head[1]),
            wout: PackedMat::from_tensor(&head[2]),
            manifest,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// A fresh, empty per-sequence cache.
    pub fn kv_cache(&self) -> KvCache {
        KvCache::new(self.manifest.n_layers, self.manifest.d_model, self.manifest.seq)
    }

    /// Prefill: run the whole prompt through the model in one pass,
    /// populating `cache` with one K/V row per layer per position.
    /// Returns `[1, prompt_len, vocab]` logits.
    pub fn prefill(
        &self,
        tokens: &[usize],
        cache: &mut KvCache,
        hooks: &mut dyn Hooks,
    ) -> Result<Tensor> {
        if !cache.is_empty() {
            bail!("prefill requires an empty cache (len {})", cache.len());
        }
        if tokens.is_empty() {
            bail!("prefill with an empty prompt");
        }
        self.forward_rows(tokens, cache, hooks)
    }

    /// One decode step: embed the single new token at the next position,
    /// attend over the cached prefix, append its K/V rows. O(cache len)
    /// attention + O(1) weight matmuls — never a function of how many
    /// tokens were generated before. Returns `[1, 1, vocab]` logits.
    pub fn decode_step(
        &self,
        token: usize,
        cache: &mut KvCache,
        hooks: &mut dyn Hooks,
    ) -> Result<Tensor> {
        if cache.is_empty() {
            bail!("decode_step before prefill");
        }
        self.forward_rows(&[token], cache, hooks)
    }

    /// The shared forward over `rows = tokens.len()` new positions
    /// starting at `cache.len()`. Prefill and decode are the same code —
    /// the phase split is purely how many rows the caller sends.
    fn forward_rows(
        &self,
        tokens: &[usize],
        cache: &mut KvCache,
        hooks: &mut dyn Hooks,
    ) -> Result<Tensor> {
        let (d, heads, vocab) =
            (self.manifest.d_model, self.manifest.n_heads, self.manifest.vocab);
        let n = tokens.len();
        let base = cache.len();
        if base + n > cache.capacity() {
            bail!(
                "decode overruns the model context: {} cached + {n} new > {}",
                base,
                cache.capacity()
            );
        }
        if let Some(&t) = tokens.iter().find(|&&t| t >= vocab) {
            bail!("token {t} out of vocab {vocab}");
        }

        // embed: wte[token] + wpe[position]
        let mut x = vec![0.0f32; n * d];
        for (r, &t) in tokens.iter().enumerate() {
            let row = &mut x[r * d..(r + 1) * d];
            row.copy_from_slice(&self.wte[t * d..(t + 1) * d]);
            let pos = base + r;
            for (o, &p) in row.iter_mut().zip(&self.wpe[pos * d..(pos + 1) * d]) {
                *o += p;
            }
        }
        apply_hook(hooks, "embed", &mut x, n, d)?;

        let mut xn = vec![0.0f32; n * d];
        let mut q = vec![0.0f32; n * d];
        let mut k = vec![0.0f32; n * d];
        let mut v = vec![0.0f32; n * d];
        let mut o = vec![0.0f32; n * d];
        let mut a = vec![0.0f32; n * d];
        for (l, lw) in self.layers.iter().enumerate() {
            // attention block over the cached prefix + these rows
            layernorm_rows(&x, &lw.ln1_g, &lw.ln1_b, LN_EPS, &mut xn);
            lw.wq.matmul_bias(&xn, None, &mut q);
            lw.wk.matmul_bias(&xn, None, &mut k);
            lw.wv.matmul_bias(&xn, None, &mut v);
            cache.append_layer(l, &k, &v);
            let (kc, vc) = cache.layer(l);
            attn_causal_rows(&q, kc, vc, n, base, heads, &mut o);
            lw.wo.matmul_bias(&o, Some(&lw.bo), &mut a);
            for (h, &av) in x.iter_mut().zip(&a) {
                *h += av;
            }
            // MLP block
            layernorm_rows(&x, &lw.ln2_g, &lw.ln2_b, LN_EPS, &mut xn);
            let mut m = vec![0.0f32; n * lw.b1.len()];
            lw.w1.matmul_bias(&xn, Some(&lw.b1), &mut m);
            gelu_rows(&mut m);
            lw.w2.matmul_bias(&m, Some(&lw.b2), &mut a);
            for (h, &mv) in x.iter_mut().zip(&a) {
                *h += mv;
            }
            apply_hook(hooks, &format!("layer.{l}"), &mut x, n, d)?;
        }
        cache.advance(n);

        layernorm_rows(&x, &self.lnf_g, &self.lnf_b, LN_EPS, &mut xn);
        let mut logits = vec![0.0f32; n * vocab];
        self.wout.matmul_bias(&xn, None, &mut logits);
        apply_hook(hooks, "lm_head", &mut logits, n, vocab)?;
        Ok(Tensor::new(&[1, n, vocab], logits))
    }
}

/// Fire one intervention hook point with the activation as `[1, rows, d]`,
/// writing any setter mutation back into the raw buffer. Same contract as
/// the artifact runner: the hook may rewrite values but not reshape.
fn apply_hook(
    hooks: &mut dyn Hooks,
    point: &str,
    buf: &mut Vec<f32>,
    rows: usize,
    d: usize,
) -> Result<()> {
    if !hooks.wants(point) {
        return Ok(());
    }
    let mut t = Tensor::new(&[1, rows, d], std::mem::take(buf));
    hooks.on_output(point, &mut t);
    if t.dims() != [1, rows, d] {
        bail!("intervention at {point} changed activation shape to {:?}", t.dims());
    }
    *buf = t.into_data();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::NoHooks;

    fn model() -> NativeModel {
        NativeModel::new(Manifest::synthetic("kv-test", 16, 2, 2, 32, 11, 24))
    }

    #[test]
    fn prefill_then_decode_matches_full_recompute_bitwise() {
        let m = model();
        let prompt = [1usize, 4, 2, 7];
        // path A: prefill 4, then decode 3 more greedily
        let mut cache = m.kv_cache();
        let mut logits = m.prefill(&prompt, &mut cache, &mut NoHooks).unwrap();
        let mut toks: Vec<usize> = prompt.to_vec();
        for _ in 0..3 {
            let vocab = m.manifest().vocab;
            let data = logits.data();
            let row = &data[data.len() - vocab..];
            let (t, _) = crate::models::generate::argmax_row(row);
            toks.push(t);
            logits = m.decode_step(t, &mut cache, &mut NoHooks).unwrap();
        }
        // path B: a fresh prefill over the full extended sequence must
        // reproduce the last-row logits of every decode step bit-for-bit
        let mut cache_b = m.kv_cache();
        let full = m.prefill(&toks, &mut cache_b, &mut NoHooks).unwrap();
        let vocab = m.manifest().vocab;
        let last_a = &logits.data()[..vocab];
        let last_b = &full.data()[(toks.len() - 1) * vocab..];
        assert_eq!(last_a, last_b, "KV decode diverged from full recompute");
    }

    #[test]
    fn cache_len_tracks_positions_and_overflow_errors() {
        let m = model();
        let mut cache = m.kv_cache();
        assert_eq!(cache.capacity(), 24);
        m.prefill(&[1, 2, 3], &mut cache, &mut NoHooks).unwrap();
        assert_eq!(cache.len(), 3);
        m.decode_step(5, &mut cache, &mut NoHooks).unwrap();
        assert_eq!(cache.len(), 4);
        for _ in 0..20 {
            let _ = m.decode_step(1, &mut cache, &mut NoHooks);
        }
        let err = m.decode_step(1, &mut cache, &mut NoHooks).unwrap_err();
        assert!(err.to_string().contains("context"), "got: {err}");
    }

    #[test]
    fn decode_before_prefill_rejected() {
        let m = model();
        let mut cache = m.kv_cache();
        assert!(m.decode_step(0, &mut cache, &mut NoHooks).is_err());
    }

    #[test]
    fn out_of_vocab_token_rejected() {
        let m = model();
        let mut cache = m.kv_cache();
        assert!(m.prefill(&[999], &mut cache, &mut NoHooks).is_err());
    }
}
