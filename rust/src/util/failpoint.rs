//! Deterministic fault injection — named failpoints threaded through the
//! fabric's hot paths (journal appends, replica dispatch, heartbeats,
//! stream frames).
//!
//! Chaos testing is only useful when a failing run can be replayed: every
//! probabilistic failpoint draws from its own seeded [`Prng`] stream, so a
//! chaos schedule is a pure function of `(name, seed, hit count)` and a CI
//! failure reproduces locally with the same seed. The facility is compiled
//! into the library (integration tests and benches link against the
//! release lib), but the disarmed cost is a single relaxed atomic load —
//! no lock, no map lookup — so production paths pay nothing measurable.
//!
//! A failpoint *site* names a place in the code
//! (`failpoint::hit("journal.append")`); a *spec* arms it with a window
//! (`skip` passes, then fire `take` times, each firing gated by `prob`)
//! and an action (error, skip the guarded operation, delay, or truncate a
//! write after N bytes). Sites are no-ops until armed by a test or bench.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

use crate::util::prng::Prng;

/// What an armed failpoint does when it fires.
#[derive(Clone, Debug, PartialEq)]
pub enum FailAction {
    /// Fail the guarded operation with this message.
    Error(String),
    /// Silently skip the guarded operation (drop a heartbeat, lose a
    /// frame, swallow a write).
    Skip,
    /// Stall before continuing (slow-consumer / slow-disk simulation).
    Delay(Duration),
    /// Truncate the guarded write after this many bytes, then fail it
    /// (torn journal tails: the crash landed mid-record).
    Truncate(usize),
}

/// Arming spec: `skip` hits pass through untouched, then the next `take`
/// hits fire (each with probability `prob` drawn from the seeded stream).
#[derive(Clone, Debug)]
pub struct Spec {
    pub skip: u64,
    pub take: u64,
    pub prob: f64,
    pub seed: u64,
    pub action: FailAction,
}

impl Spec {
    /// Fire forever with the given action (skip 0, take ∞, prob 1).
    pub fn always(action: FailAction) -> Spec {
        Spec { skip: 0, take: u64::MAX, prob: 1.0, seed: 0, action }
    }

    /// Fire exactly once, on the `n`-th hit (0-based).
    pub fn nth(n: u64, action: FailAction) -> Spec {
        Spec { skip: n, take: 1, prob: 1.0, seed: 0, action }
    }

    /// Fire each hit independently with probability `p`, deterministically
    /// driven by `seed`.
    pub fn prob(p: f64, seed: u64, action: FailAction) -> Spec {
        Spec { skip: 0, take: u64::MAX, prob: p, seed, action }
    }
}

struct Point {
    spec: Spec,
    prng: Prng,
    hits: u64,
    fired: u64,
}

static ANY_ARMED: AtomicBool = AtomicBool::new(false);

fn registry() -> &'static Mutex<HashMap<String, Point>> {
    static REG: OnceLock<Mutex<HashMap<String, Point>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Arm a failpoint site. Re-arming replaces the previous spec and resets
/// the hit/fired counters.
pub fn arm(name: &str, spec: Spec) {
    let mut reg = registry().lock().unwrap();
    let prng = Prng::new(spec.seed);
    reg.insert(name.to_string(), Point { spec, prng, hits: 0, fired: 0 });
    ANY_ARMED.store(true, Ordering::Release);
}

/// Disarm one site (no-op if it was not armed).
pub fn disarm(name: &str) {
    let mut reg = registry().lock().unwrap();
    reg.remove(name);
    if reg.is_empty() {
        ANY_ARMED.store(false, Ordering::Release);
    }
}

/// Disarm everything (test teardown).
pub fn reset() {
    let mut reg = registry().lock().unwrap();
    reg.clear();
    ANY_ARMED.store(false, Ordering::Release);
}

/// How many times a site has fired (assertion helper for tests).
pub fn fired(name: &str) -> u64 {
    registry().lock().unwrap().get(name).map_or(0, |p| p.fired)
}

/// Evaluate a failpoint site. Returns the action to apply when the site
/// fires, `None` otherwise. The disarmed fast path is one relaxed atomic
/// load.
#[inline]
pub fn hit(name: &str) -> Option<FailAction> {
    if !ANY_ARMED.load(Ordering::Acquire) {
        return None;
    }
    hit_slow(name)
}

#[cold]
fn hit_slow(name: &str) -> Option<FailAction> {
    let mut reg = registry().lock().unwrap();
    let p = reg.get_mut(name)?;
    let n = p.hits;
    p.hits += 1;
    if n < p.spec.skip || p.fired >= p.spec.take {
        return None;
    }
    if p.spec.prob < 1.0 && p.prng.uniform() >= p.spec.prob {
        return None;
    }
    p.fired += 1;
    Some(p.spec.action.clone())
}

/// Convenience for call sites whose only meaningful injected failure is an
/// error: applies `Delay` inline, maps `Error` to `Err`, and treats
/// `Skip`/`Truncate` as errors too (the guarded operation did not happen).
pub fn check(name: &str) -> Result<(), String> {
    match hit(name) {
        None => Ok(()),
        Some(FailAction::Delay(d)) => {
            std::thread::sleep(d);
            Ok(())
        }
        Some(FailAction::Error(msg)) => Err(msg),
        Some(FailAction::Skip) => Err(format!("failpoint {name}: skipped")),
        Some(FailAction::Truncate(_)) => Err(format!("failpoint {name}: truncated")),
    }
}

/// RAII guard: arms a site on construction, disarms it on drop — keeps
/// test failpoints from leaking into later tests in the same process.
pub struct Armed {
    name: String,
}

impl Armed {
    pub fn new(name: &str, spec: Spec) -> Armed {
        arm(name, spec);
        Armed { name: name.to_string() }
    }
}

impl Drop for Armed {
    fn drop(&mut self) {
        disarm(&self.name);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Failpoint tests share the process-global registry; unique site names
    // keep parallel test threads from interfering.

    #[test]
    fn disarmed_site_is_silent() {
        assert_eq!(hit("fp.test.unarmed"), None);
    }

    #[test]
    fn nth_fires_exactly_once() {
        let _g = Armed::new("fp.test.nth", Spec::nth(2, FailAction::Skip));
        assert_eq!(hit("fp.test.nth"), None);
        assert_eq!(hit("fp.test.nth"), None);
        assert_eq!(hit("fp.test.nth"), Some(FailAction::Skip));
        assert_eq!(hit("fp.test.nth"), None);
        assert_eq!(fired("fp.test.nth"), 1);
    }

    #[test]
    fn always_fires_until_disarmed() {
        arm("fp.test.always", Spec::always(FailAction::Error("boom".into())));
        for _ in 0..5 {
            assert_eq!(hit("fp.test.always"), Some(FailAction::Error("boom".into())));
        }
        disarm("fp.test.always");
        assert_eq!(hit("fp.test.always"), None);
    }

    #[test]
    fn probabilistic_is_deterministic_per_seed() {
        let run = |seed: u64| -> Vec<bool> {
            let _g = Armed::new(
                "fp.test.prob",
                Spec::prob(0.3, seed, FailAction::Skip),
            );
            (0..64).map(|_| hit("fp.test.prob").is_some()).collect()
        };
        let a = run(7);
        let b = run(7);
        let c = run(8);
        assert_eq!(a, b, "same seed must replay the same schedule");
        assert_ne!(a, c, "different seeds must differ");
        let rate = a.iter().filter(|&&x| x).count();
        assert!(rate > 5 && rate < 40, "~30% of 64 hits, got {rate}");
    }

    #[test]
    fn check_maps_error_and_passes_delay() {
        let _g = Armed::new(
            "fp.test.check",
            Spec::nth(0, FailAction::Error("injected".into())),
        );
        assert_eq!(check("fp.test.check"), Err("injected".into()));
        assert_eq!(check("fp.test.check"), Ok(()));
    }

    #[test]
    fn rearm_resets_counters() {
        arm("fp.test.rearm", Spec::nth(0, FailAction::Skip));
        assert!(hit("fp.test.rearm").is_some());
        arm("fp.test.rearm", Spec::nth(0, FailAction::Skip));
        assert!(hit("fp.test.rearm").is_some(), "re-arm must reset skip window");
        disarm("fp.test.rearm");
    }
}
