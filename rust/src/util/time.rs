//! Timing helpers for the hand-rolled benchmark harness (criterion is
//! unavailable offline). Provides warmed, repeated measurement with
//! per-iteration wallclock capture in seconds.

use std::time::Instant;

/// Time a closure once; returns (seconds, result).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (f64, T) {
    let t0 = Instant::now();
    let r = f();
    (t0.elapsed().as_secs_f64(), r)
}

/// Run `warmup` untimed iterations then `n` timed iterations, returning
/// per-iteration seconds. The closure receives the iteration index.
pub fn sample(warmup: usize, n: usize, mut f: impl FnMut(usize)) -> Vec<f64> {
    for i in 0..warmup {
        f(i);
    }
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let t0 = Instant::now();
        f(i);
        out.push(t0.elapsed().as_secs_f64());
    }
    out
}

/// A simple stopwatch accumulating named segments — used in profiling the
/// request hot path (§Perf).
#[derive(Default, Debug)]
pub struct Stopwatch {
    segments: Vec<(String, f64)>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch::default()
    }

    pub fn measure<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let r = f();
        self.segments.push((name.to_string(), t0.elapsed().as_secs_f64()));
        r
    }

    pub fn segments(&self) -> &[(String, f64)] {
        &self.segments
    }

    pub fn report(&self) -> String {
        let total: f64 = self.segments.iter().map(|(_, t)| t).sum();
        let mut s = String::new();
        for (name, t) in &self.segments {
            s.push_str(&format!(
                "{name:<24} {:>10.6}s  {:>5.1}%\n",
                t,
                if total > 0.0 { 100.0 * t / total } else { 0.0 }
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_returns_n() {
        let xs = sample(2, 5, |_| std::thread::sleep(std::time::Duration::from_micros(10)));
        assert_eq!(xs.len(), 5);
        assert!(xs.iter().all(|&t| t > 0.0));
    }

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        let v = sw.measure("a", || 41 + 1);
        assert_eq!(v, 42);
        sw.measure("b", || ());
        assert_eq!(sw.segments().len(), 2);
        assert!(sw.report().contains("a"));
    }
}
