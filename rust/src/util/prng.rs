//! Deterministic pseudo-random number generation.
//!
//! `rand` is not available offline, so the repo carries its own generator:
//! SplitMix64 for seeding and xoshiro256++ for the stream (the same pairing
//! the `rand` ecosystem recommends). Determinism matters here: synthetic
//! model weights are generated from a seed derived from the model name, so
//! the Rust runtime, the Python oracle tests, and every benchmark see the
//! same parameters without shipping weight files in the repo.

/// SplitMix64 step: used to expand a single `u64` seed into the xoshiro
/// state. Reference: Steele, Lea & Flood, "Fast splittable pseudorandom
/// number generators" (OOPSLA 2014).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ pseudo-random generator (Blackman & Vigna).
///
/// Not cryptographic; statistical quality is more than sufficient for
/// synthetic weights, workload generation, and property-test case
/// generation.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Prng { s }
    }

    /// Derive a seed from a string (FNV-1a hash) — used to key weight
    /// streams by model/module/parameter name.
    pub fn from_name(name: &str) -> Self {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in name.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        Prng::new(h)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)` (53-bit mantissa method).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in `[0, n)` via Lemire's rejection-free-ish method
    /// (simple modulo is fine for our non-adversarial uses, but we debias).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // rejection sampling to remove modulo bias
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal sample (Box–Muller; one value per call, the twin is
    /// discarded for simplicity — weight generation is not on a hot path).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            return r * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Normal `f32` with the given mean and standard deviation.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Exponential sample with the given rate (mean `1/rate`) — the
    /// inter-arrival gap of a Poisson arrival process, the standard
    /// open-loop load model.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0, "exponential rate must be positive");
        loop {
            let u = self.uniform();
            if u > 0.0 {
                return -u.ln() / rate;
            }
        }
    }

    /// Lognormal sample `exp(mu + sigma·Z)`. Heavy-tailed for `sigma ≳ 1`:
    /// most gaps are short but occasional gaps are very long, which is how
    /// real inference traffic burst-clusters (and what stresses queue-wait
    /// percentiles in a way exponential arrivals cannot).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fill a buffer with normal samples scaled by `std` — the synthetic
    /// weight initializer (truncation at 3σ to keep activations tame).
    pub fn fill_normal(&mut self, buf: &mut [f32], std: f32) {
        for v in buf.iter_mut() {
            let mut z = self.normal() as f32;
            if z > 3.0 {
                z = 3.0;
            } else if z < -3.0 {
                z = -3.0;
            }
            *v = z * std;
        }
    }

    /// Fill a buffer with symmetric-uniform samples `(2u - 1) * a` — the
    /// synthetic weight initializer. Unlike [`Prng::fill_normal`] this is
    /// **bit-exact reproducible in Python** (`python/compile/prng.py`
    /// mirrors it), which lets pytest regenerate identical weights for the
    /// cross-language oracle checks. Variance = a²/3, so `a = std·√3`.
    pub fn fill_uniform_sym(&mut self, buf: &mut [f32], a: f64) {
        for v in buf.iter_mut() {
            *v = ((2.0 * self.uniform() - 1.0) * a) as f32;
        }
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn from_name_is_stable() {
        let x = Prng::from_name("llama8b-sim/layer.0/wq").next_u64();
        let y = Prng::from_name("llama8b-sim/layer.0/wq").next_u64();
        let z = Prng::from_name("llama8b-sim/layer.0/wk").next_u64();
        assert_eq!(x, y);
        assert_ne!(x, z);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut p = Prng::new(7);
        for _ in 0..10_000 {
            let u = p.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut p = Prng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| p.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_and_in_range() {
        let mut p = Prng::new(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[p.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut p = Prng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| p.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut p = Prng::new(21);
        let n = 100_000;
        let rate = 4.0;
        let mean: f64 = (0..n).map(|_| p.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn lognormal_mean_and_tail() {
        let mut p = Prng::new(23);
        let n = 200_000;
        let (mu, sigma) = (0.0, 1.0);
        let xs: Vec<f64> = (0..n).map(|_| p.lognormal(mu, sigma)).collect();
        // E[X] = exp(mu + sigma^2/2)
        let expect = (mu + sigma * sigma / 2.0).exp();
        let mean: f64 = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - expect).abs() < 0.05, "mean={mean} expect={expect}");
        // heavy tail: max far above the mean, all samples positive
        assert!(xs.iter().all(|&x| x > 0.0));
        assert!(xs.iter().cloned().fold(0.0, f64::max) > 10.0 * mean);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        p.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // overwhelmingly likely
    }

    /// Known-answer test shared with `python/compile/prng.py` — if either
    /// side drifts, the cross-language weight contract is broken.
    #[test]
    fn cross_language_known_answers() {
        let mut p = Prng::from_name("xcheck");
        assert_eq!(p.next_u64(), 0x1c801f4c48a0b4ec);
        assert_eq!(p.next_u64(), 0xa6b3ee2bb4a9612c);
        assert_eq!(p.next_u64(), 0x3ff86e8d2fea04d6);
        assert_eq!(p.next_u64(), 0x09274f6ed2dbf80f);
        let mut buf = [0.0f32; 4];
        Prng::from_name("xcheck").fill_uniform_sym(&mut buf, 0.5);
        assert_eq!(buf, [-0.38867, 0.15118302, -0.25011548, -0.46424392]);
    }

    #[test]
    fn fill_uniform_sym_bounded() {
        let mut p = Prng::new(17);
        let mut buf = vec![0.0f32; 10_000];
        p.fill_uniform_sym(&mut buf, 0.1);
        assert!(buf.iter().all(|v| v.abs() <= 0.1));
        let mean: f32 = buf.iter().sum::<f32>() / buf.len() as f32;
        assert!(mean.abs() < 0.005);
    }

    #[test]
    fn fill_normal_truncates() {
        let mut p = Prng::new(13);
        let mut buf = vec![0.0f32; 50_000];
        p.fill_normal(&mut buf, 0.02);
        for &v in &buf {
            assert!(v.abs() <= 0.06 + 1e-6);
        }
    }
}
