//! Small self-contained utilities: PRNG, statistics, table formatting,
//! CLI parsing, and timing — the pieces normally pulled from crates.io
//! (`rand`, `criterion`, `clap`) that are unavailable in this offline
//! build and are therefore first-class substrates of the repo.

pub mod b64;
pub mod failpoint;
pub mod prng;
pub mod stats;
pub mod table;
pub mod cli;
pub mod time;

pub use prng::Prng;
pub use stats::Summary;
