//! Base64 (standard alphabet, padded) — used to pack f32 tensor payloads
//! in result messages. JSON float arrays cost ~13 bytes/value and a parse;
//! base64-packed little-endian f32 costs 5.33 bytes/value and a memcpy —
//! a §Perf L3 win measured in EXPERIMENTS.md (the paper's NDIF likewise
//! returns binary tensors, not JSON numbers).

const ALPHABET: &[u8; 64] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Encode bytes to base64.
pub fn encode(data: &[u8]) -> String {
    let mut out = String::with_capacity(data.len().div_ceil(3) * 4);
    for chunk in data.chunks(3) {
        let b = [chunk[0], *chunk.get(1).unwrap_or(&0), *chunk.get(2).unwrap_or(&0)];
        let n = ((b[0] as u32) << 16) | ((b[1] as u32) << 8) | b[2] as u32;
        out.push(ALPHABET[(n >> 18) as usize & 63] as char);
        out.push(ALPHABET[(n >> 12) as usize & 63] as char);
        out.push(if chunk.len() > 1 { ALPHABET[(n >> 6) as usize & 63] as char } else { '=' });
        out.push(if chunk.len() > 2 { ALPHABET[n as usize & 63] as char } else { '=' });
    }
    out
}

fn decode_char(c: u8) -> Option<u32> {
    match c {
        b'A'..=b'Z' => Some((c - b'A') as u32),
        b'a'..=b'z' => Some((c - b'a' + 26) as u32),
        b'0'..=b'9' => Some((c - b'0' + 52) as u32),
        b'+' => Some(62),
        b'/' => Some(63),
        _ => None,
    }
}

/// Decode base64 (rejects malformed input).
pub fn decode(s: &str) -> Option<Vec<u8>> {
    let b = s.as_bytes();
    if b.len() % 4 != 0 {
        return None;
    }
    let mut out = Vec::with_capacity(b.len() / 4 * 3);
    for chunk in b.chunks(4) {
        let pad = chunk.iter().filter(|&&c| c == b'=').count();
        if pad > 2 || (pad > 0 && chunk[..4 - pad].iter().any(|&c| c == b'=')) {
            return None;
        }
        let mut n = 0u32;
        for (i, &c) in chunk.iter().enumerate() {
            let v = if c == b'=' { 0 } else { decode_char(c)? };
            n |= v << (18 - 6 * i);
        }
        out.push((n >> 16) as u8);
        if pad < 2 {
            out.push((n >> 8) as u8);
        }
        if pad < 1 {
            out.push(n as u8);
        }
    }
    Some(out)
}

/// Pack f32s little-endian and base64-encode.
pub fn encode_f32(data: &[f32]) -> String {
    let bytes = unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    encode(bytes)
}

/// Decode base64 into f32s (must be a multiple of 4 bytes).
pub fn decode_f32(s: &str) -> Option<Vec<f32>> {
    let bytes = decode(s)?;
    if bytes.len() % 4 != 0 {
        return None;
    }
    let mut out = vec![0.0f32; bytes.len() / 4];
    unsafe {
        std::ptr::copy_nonoverlapping(bytes.as_ptr(), out.as_mut_ptr() as *mut u8, bytes.len());
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        assert_eq!(encode(b""), "");
        assert_eq!(encode(b"f"), "Zg==");
        assert_eq!(encode(b"fo"), "Zm8=");
        assert_eq!(encode(b"foo"), "Zm9v");
        assert_eq!(encode(b"foobar"), "Zm9vYmFy");
        assert_eq!(decode("Zm9vYmFy").unwrap(), b"foobar");
        assert_eq!(decode("Zg==").unwrap(), b"f");
    }

    #[test]
    fn rejects_malformed() {
        assert!(decode("abc").is_none()); // not multiple of 4
        assert!(decode("ab=c").is_none()); // pad in middle
        assert!(decode("a\nb=").is_none()); // bad char
    }

    #[test]
    fn f32_round_trip() {
        let xs = vec![0.0f32, -1.5, 3.25e7, f32::MIN_POSITIVE, -0.0];
        let enc = encode_f32(&xs);
        assert_eq!(decode_f32(&enc).unwrap(), xs);
    }

    #[test]
    fn random_bytes_round_trip() {
        let mut rng = crate::util::Prng::new(64);
        for _ in 0..50 {
            let n = rng.range(0, 100);
            let data: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            assert_eq!(decode(&encode(&data)).unwrap(), data);
        }
    }

    #[test]
    fn packing_is_compact() {
        let xs = vec![1.2345678f32; 1000];
        let b64 = encode_f32(&xs).len();
        let json: usize = xs.iter().map(|v| format!("{v},").len()).sum();
        assert!(b64 as f64 * 1.8 < json as f64, "b64 {b64} vs json {json}");
    }
}
