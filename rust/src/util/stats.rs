//! Descriptive statistics for benchmark reporting.
//!
//! The paper reports `mean ± std` for every table and quantile bands for
//! the load test (Fig. 9); this module provides those plus the simple
//! linear regression used to check "median response time is approximately
//! linear in the number of concurrent users".

/// Summary statistics of a sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    /// Sample standard deviation (n-1 denominator).
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub q25: f64,
    pub q75: f64,
}

impl Summary {
    /// Compute a summary; panics on an empty sample.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: s[0],
            max: s[n - 1],
            median: quantile_sorted(&s, 0.5),
            q25: quantile_sorted(&s, 0.25),
            q75: quantile_sorted(&s, 0.75),
        }
    }

    /// Format as the paper's `mean ± std` (3 decimal places, seconds).
    pub fn pm(&self) -> String {
        format!("{:.3} ± {:.3}", self.mean, self.std)
    }

    /// 95% confidence half-width of the mean (normal approximation).
    pub fn ci95(&self) -> f64 {
        1.96 * self.std / (self.n as f64).sqrt()
    }
}

/// Linear-interpolated quantile of a **sorted** sample, q in [0, 1].
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Quantile of an unsorted sample.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile_sorted(&s, q)
}

/// Ordinary least squares fit `y = a + b x`. Returns `(a, b, r2)`.
pub fn linfit(x: &[f64], y: &[f64]) -> (f64, f64, f64) {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2);
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let sxy: f64 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
    let sxx: f64 = x.iter().map(|a| (a - mx) * (a - mx)).sum();
    let syy: f64 = y.iter().map(|b| (b - my) * (b - my)).sum();
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

/// Pearson correlation coefficient.
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    let (_, _, r2) = linfit(x, y);
    let (_, b, _) = linfit(x, y);
    r2.sqrt() * b.signum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 3.0);
    }

    #[test]
    fn summary_single_sample() {
        let s = Summary::of(&[2.5]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.median, 2.5);
        assert_eq!(s.q25, 2.5);
    }

    #[test]
    fn quantiles_interpolate() {
        let s = [0.0, 10.0];
        assert_eq!(quantile(&s, 0.5), 5.0);
        assert_eq!(quantile(&s, 0.25), 2.5);
        assert_eq!(quantile(&s, 0.0), 0.0);
        assert_eq!(quantile(&s, 1.0), 10.0);
    }

    #[test]
    fn quantile_unsorted_input() {
        let s = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(quantile(&s, 0.5), 3.0);
    }

    #[test]
    fn linfit_exact_line() {
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 2.0 + 3.0 * v).collect();
        let (a, b, r2) = linfit(&x, &y);
        assert!((a - 2.0).abs() < 1e-9);
        assert!((b - 3.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn linfit_noise_reduces_r2() {
        let x: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let y: Vec<f64> = x
            .iter()
            .enumerate()
            .map(|(i, v)| v + if i % 2 == 0 { 5.0 } else { -5.0 })
            .collect();
        let (_, b, r2) = linfit(&x, &y);
        assert!(b > 0.5 && b < 1.5);
        assert!(r2 < 1.0);
    }

    #[test]
    fn pm_formatting() {
        let s = Summary::of(&[1.0, 1.0, 1.0]);
        assert_eq!(s.pm(), "1.000 ± 0.000");
    }

    #[test]
    fn ci95_shrinks_with_n() {
        let a = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        let many: Vec<f64> = (0..400).map(|i| 1.0 + (i % 4) as f64).collect();
        let b = Summary::of(&many);
        assert!(b.ci95() < a.ci95());
    }
}
