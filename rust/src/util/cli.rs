//! Minimal declarative command-line argument parsing (clap is unavailable
//! offline). Supports `--flag`, `--key value`, `--key=value`, and
//! positional arguments, with typed accessors and generated help text.

use std::collections::BTreeMap;

/// Parsed arguments: options plus positionals, with declared help lines.
#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some(eq) = rest.find('=') {
                    out.opts.insert(rest[..eq].to_string(), rest[eq + 1..].to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.opts.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process args after the first `skip` entries.
    pub fn from_env(skip: usize) -> Args {
        Args::parse(std::env::args().skip(skip))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.opts.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(String::as_str)
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).map(|v| v.parse().expect("invalid integer arg")).unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name).map(|v| v.parse().expect("invalid integer arg")).unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).map(|v| v.parse().expect("invalid float arg")).unwrap_or(default)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn key_value_forms() {
        let a = parse(&["--model", "opt-sim", "--port=8080", "serve", "--verbose"]);
        assert_eq!(a.get("model"), Some("opt-sim"));
        assert_eq!(a.usize_or("port", 0), 8080);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["serve".to_string()]);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.str_or("model", "x"), "x");
        assert_eq!(a.usize_or("n", 7), 7);
        assert_eq!(a.f64_or("bw", 60.0), 60.0);
        assert!(!a.flag("remote"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--a", "--b", "val"]);
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("val"));
    }
}
