//! Paper-style ASCII table formatting for benchmark output.
//!
//! Every bench binary prints the same rows/series as the paper's tables and
//! figures; this keeps that output consistent and legible.

/// A simple column-aligned table builder.
#[derive(Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str) -> Self {
        Table { title: title.to_string(), ..Default::default() }
    }

    pub fn header<S: Into<String>>(mut self, cols: Vec<S>) -> Self {
        self.header = cols.into_iter().map(Into::into).collect();
        self
    }

    pub fn row<S: Into<String>>(&mut self, cols: Vec<S>) -> &mut Self {
        self.rows.push(cols.into_iter().map(Into::into).collect());
        self
    }

    /// Render to a string with aligned columns and a rule under the header.
    pub fn render(&self) -> String {
        let ncols = self
            .header
            .len()
            .max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for (i, w) in widths.iter().enumerate() {
                let cell = cells.get(i).map(String::as_str).unwrap_or("");
                line.push_str(&format!("{:<width$}", cell, width = w));
                if i + 1 < widths.len() {
                    line.push_str("  ");
                }
            }
            line.trim_end().to_string()
        };
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header));
            out.push('\n');
            let total: usize = widths.iter().sum::<usize>() + 2 * (ncols.saturating_sub(1));
            out.push_str(&"-".repeat(total));
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo").header(vec!["a", "long-column"]);
        t.row(vec!["x", "1"]);
        t.row(vec!["yyyy", "22"]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        let lines: Vec<&str> = r.lines().collect();
        // header + rule + 2 rows + title
        assert_eq!(lines.len(), 5);
        // columns aligned: "1" and "22" start at the same offset
        let off1 = lines[3].find('1').unwrap();
        let off2 = lines[4].find('2').unwrap();
        assert_eq!(off1, off2);
    }

    #[test]
    fn handles_ragged_rows() {
        let mut t = Table::new("").header(vec!["a", "b", "c"]);
        t.row(vec!["1"]);
        let r = t.render();
        assert!(r.contains('1'));
    }
}
