//! Runtime: PJRT execution of AOT-compiled artifacts.
//!
//! Wraps the `xla` crate (PJRT C API) to load the HLO-text artifacts
//! produced by `python/compile/aot.py`, compile them on the CPU client,
//! and execute them from the Rust request path. Python never runs here.
//!
//! * [`pjrt`] — client/executable/buffer plumbing and tensor conversion;
//! * [`artifacts`] — `manifest.json` parsing: module specs, arg schemas,
//!   shape resolution.

pub mod artifacts;
pub mod pjrt;

pub use artifacts::{ArgKind, ArgSpec, Manifest, ModuleSpec};
pub use pjrt::{DeviceTensor, Engine, Executable};
