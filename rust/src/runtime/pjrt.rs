//! PJRT plumbing: one process-wide CPU client, executable compilation from
//! HLO text, and host-tensor ⇄ device-buffer conversion.
//!
//! Single-output modules are exported with a non-tuple root, so their
//! output buffer chains directly into the next module via `execute_b` —
//! hidden states stay "on device" between layers and only cross to the
//! host at module boundaries that an intervention actually touches (§Perf).

use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{anyhow, Context, Result};

use crate::tensor::Tensor;

/// The process-wide PJRT CPU client.
///
/// PJRT clients are heavyweight (thread pools, allocator state); NDIF's
/// model services all share this one, mirroring the paper's single shared
/// deployment per host.
pub struct Engine {
    client: xla::PjRtClient,
}

static ENGINE: OnceLock<Arc<Engine>> = OnceLock::new();

// The xla crate's raw pointers are not marked Send/Sync but the PJRT CPU
// client is internally synchronized; the crate simply lacks the markers.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Get (or create) the shared engine.
    pub fn global() -> Arc<Engine> {
        ENGINE
            .get_or_init(|| {
                let client = xla::PjRtClient::cpu().expect("create PJRT CPU client");
                Arc::new(Engine { client })
            })
            .clone()
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text artifact into an executable.
    pub fn compile_file(self: &Arc<Self>, path: &std::path::Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        self.compile_proto(&proto)
            .with_context(|| format!("compile {path:?}"))
    }

    /// Compile HLO text already in memory.
    pub fn compile_text(self: &Arc<Self>, text: &str) -> Result<Executable> {
        let proto = xla::HloModuleProto::parse_and_return_unverified_module(text.as_bytes())
            .map_err(|e| anyhow!("parse hlo text: {e:?}"))?;
        self.compile_proto(&proto)
    }

    fn compile_proto(self: &Arc<Self>, proto: &xla::HloModuleProto) -> Result<Executable> {
        let comp = xla::XlaComputation::from_proto(proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("xla compile: {e:?}"))?;
        Ok(Executable { exe: Mutex::new(exe), engine: Arc::clone(self) })
    }

    /// Upload a host tensor to a device buffer.
    pub fn upload(&self, t: &Tensor) -> Result<DeviceTensor> {
        let buf = self
            .client
            .buffer_from_host_buffer::<f32>(t.data(), t.dims(), None)
            .map_err(|e| anyhow!("upload: {e:?}"))?;
        Ok(DeviceTensor { buf, dims: t.dims().to_vec() })
    }
}

/// A device buffer plus its logical dims (PJRT shapes are row-major f32
/// arrays throughout this codebase).
pub struct DeviceTensor {
    buf: xla::PjRtBuffer,
    dims: Vec<usize>,
}

unsafe impl Send for DeviceTensor {}
// PJRT CPU buffers are immutable after creation; concurrent reads are safe.
unsafe impl Sync for DeviceTensor {}

impl DeviceTensor {
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Download to a host tensor.
    pub fn download(&self) -> Result<Tensor> {
        let lit = self
            .buf
            .to_literal_sync()
            .map_err(|e| anyhow!("download: {e:?}"))?;
        let data = lit.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e:?}"))?;
        Ok(Tensor::new(&self.dims, data))
    }
}

/// A compiled module executable.
///
/// The inner `PjRtLoadedExecutable` is behind a mutex: PJRT CPU execution
/// is itself thread-safe, but the xla crate wrapper offers `&self` methods
/// over raw pointers without the marker traits, so we serialize calls per
/// executable (distinct modules still run concurrently, which is what the
/// shard workers need).
pub struct Executable {
    exe: Mutex<xla::PjRtLoadedExecutable>,
    engine: Arc<Engine>,
}

unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with device-resident args; returns the raw output buffers.
    fn run_buffers(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        let exe = self.exe.lock().unwrap();
        let mut out = exe
            .execute_b(args)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        Ok(out.swap_remove(0))
    }

    /// Execute a single-output module: device args → device output.
    pub fn run(&self, args: &[&DeviceTensor], out_dims: &[usize]) -> Result<DeviceTensor> {
        let bufs: Vec<&xla::PjRtBuffer> = args.iter().map(|a| &a.buf).collect();
        let mut outs = self.run_buffers(&bufs)?;
        if outs.len() != 1 {
            return Err(anyhow!("expected 1 output buffer, got {}", outs.len()));
        }
        Ok(DeviceTensor { buf: outs.swap_remove(0), dims: out_dims.to_vec() })
    }

    /// Execute a module with a tuple root (e.g. lm_head_grad): device args
    /// → host tensors (tuple leaves), with the dims provided per leaf.
    pub fn run_tupled(&self, args: &[&DeviceTensor], out_dims: &[Vec<usize>]) -> Result<Vec<Tensor>> {
        let bufs: Vec<&xla::PjRtBuffer> = args.iter().map(|a| &a.buf).collect();
        let outs = self.run_buffers(&bufs)?;
        if outs.len() != 1 {
            return Err(anyhow!("expected 1 tuple buffer, got {}", outs.len()));
        }
        let mut lit = outs[0]
            .to_literal_sync()
            .map_err(|e| anyhow!("tuple download: {e:?}"))?;
        let leaves = lit
            .decompose_tuple()
            .map_err(|e| anyhow!("decompose tuple: {e:?}"))?;
        if leaves.len() != out_dims.len() {
            return Err(anyhow!("expected {} leaves, got {}", out_dims.len(), leaves.len()));
        }
        leaves
            .into_iter()
            .zip(out_dims)
            .map(|(l, dims)| {
                let data = l.to_vec::<f32>().map_err(|e| anyhow!("leaf to_vec: {e:?}"))?;
                Ok(Tensor::new(dims, data))
            })
            .collect()
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }
}
