//! Artifact manifests: the contract between `python/compile/aot.py` and the
//! Rust runtime. The Rust side is driven entirely by `manifest.json` —
//! model dimensions, module argument schemas (inputs vs. parameters, with
//! `-1` as the batch placeholder), exported batch sizes, and file names.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::json::{parse, Json};

/// Whether a module argument is a runtime input or a model parameter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArgKind {
    Input,
    Param,
}

/// One argument of a module executable, in positional order.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub kind: ArgKind,
    pub name: String,
    /// Shape with `-1` as the batch placeholder.
    pub shape: Vec<i64>,
}

impl ArgSpec {
    /// Concrete shape at a given batch size.
    pub fn resolve(&self, batch: usize) -> Vec<usize> {
        self.shape
            .iter()
            .map(|&d| if d == -1 { batch } else { d as usize })
            .collect()
    }
}

/// One exported module (embed / layer / lm_head / grad / tp shards).
#[derive(Clone, Debug)]
pub struct ModuleSpec {
    pub name: String,
    /// batch size -> artifact file name
    pub files: BTreeMap<usize, String>,
    pub args: Vec<ArgSpec>,
    pub outputs: usize,
}

impl ModuleSpec {
    pub fn file_for(&self, batch: usize) -> Result<&str> {
        self.files
            .get(&batch)
            .map(String::as_str)
            .ok_or_else(|| {
                anyhow!(
                    "module {} not exported at batch {batch} (available: {:?})",
                    self.name,
                    self.files.keys().collect::<Vec<_>>()
                )
            })
    }

    /// The parameter arguments, in order.
    pub fn params(&self) -> impl Iterator<Item = &ArgSpec> {
        self.args.iter().filter(|a| a.kind == ArgKind::Param)
    }

    pub fn inputs(&self) -> impl Iterator<Item = &ArgSpec> {
        self.args.iter().filter(|a| a.kind == ArgKind::Input)
    }
}

/// A model's manifest: dimensions + module specs.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batches: Vec<usize>,
    pub grad: bool,
    pub tp: Vec<usize>,
    pub simulates: String,
    pub param_count: usize,
    pub modules: BTreeMap<String, ModuleSpec>,
    /// Directory the artifacts live in.
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `artifacts/<name>/manifest.json`.
    pub fn load(artifacts_dir: &Path, name: &str) -> Result<Manifest> {
        let dir = artifacts_dir.join(name);
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read manifest {path:?}"))?;
        let j = parse(&text).map_err(|e| anyhow!("parse manifest {path:?}: {e}"))?;
        Self::from_json(&j, dir)
    }

    pub fn from_json(j: &Json, dir: PathBuf) -> Result<Manifest> {
        let req_usize = |key: &str| -> Result<usize> {
            j.get(key)
                .as_usize()
                .ok_or_else(|| anyhow!("manifest missing integer field '{key}'"))
        };
        let mut modules = BTreeMap::new();
        let mods = j
            .get("modules")
            .as_object()
            .ok_or_else(|| anyhow!("manifest missing modules"))?;
        for (mod_name, m) in mods {
            let mut files = BTreeMap::new();
            for (b, f) in m
                .get("files")
                .as_object()
                .ok_or_else(|| anyhow!("module {mod_name} missing files"))?
            {
                let batch: usize = b.parse().context("batch key")?;
                files.insert(
                    batch,
                    f.as_str()
                        .ok_or_else(|| anyhow!("bad file entry"))?
                        .to_string(),
                );
            }
            let args = m
                .get("args")
                .as_array()
                .ok_or_else(|| anyhow!("module {mod_name} missing args"))?
                .iter()
                .map(|a| {
                    let kind = match a.get("kind").as_str() {
                        Some("input") => ArgKind::Input,
                        Some("param") => ArgKind::Param,
                        other => return Err(anyhow!("bad arg kind {other:?}")),
                    };
                    Ok(ArgSpec {
                        kind,
                        name: a
                            .get("name")
                            .as_str()
                            .ok_or_else(|| anyhow!("arg missing name"))?
                            .to_string(),
                        shape: a
                            .get("shape")
                            .as_i64_vec()
                            .ok_or_else(|| anyhow!("arg missing shape"))?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            modules.insert(
                mod_name.clone(),
                ModuleSpec {
                    name: mod_name.clone(),
                    files,
                    args,
                    outputs: m.get("outputs").as_usize().unwrap_or(1),
                },
            );
        }
        Ok(Manifest {
            name: j
                .get("name")
                .as_str()
                .ok_or_else(|| anyhow!("manifest missing name"))?
                .to_string(),
            d_model: req_usize("d_model")?,
            n_layers: req_usize("n_layers")?,
            n_heads: req_usize("n_heads")?,
            d_ff: req_usize("d_ff")?,
            vocab: req_usize("vocab")?,
            seq: req_usize("seq")?,
            batches: j
                .get("batches")
                .as_usize_vec()
                .ok_or_else(|| anyhow!("manifest missing batches"))?,
            grad: j.get("grad").as_bool().unwrap_or(false),
            tp: j.get("tp").as_usize_vec().unwrap_or_default(),
            simulates: j.get("simulates").as_str().unwrap_or("").to_string(),
            param_count: req_usize("param_count")?,
            modules,
            dir,
        })
    }

    /// Build an artifact-free manifest for the native decode engine: the
    /// same module/argument schemas `python/compile/aot.py` exports (so
    /// [`crate::models::ModelWeights::generate`] works unchanged), but with
    /// no HLO files behind them — `engine::NativeModel` runs the forward on
    /// the host kernels, so tests and benches need no `make artifacts`.
    pub fn synthetic(
        name: &str,
        d_model: usize,
        n_layers: usize,
        n_heads: usize,
        d_ff: usize,
        vocab: usize,
        seq: usize,
    ) -> Manifest {
        assert!(d_model % n_heads == 0, "d_model must divide into heads");
        let d = d_model as i64;
        let input = |nm: &str, shape: Vec<i64>| ArgSpec {
            kind: ArgKind::Input,
            name: nm.to_string(),
            shape,
        };
        let param = |nm: &str, shape: Vec<i64>| ArgSpec {
            kind: ArgKind::Param,
            name: nm.to_string(),
            shape,
        };
        let spec = |nm: &str, args: Vec<ArgSpec>| ModuleSpec {
            name: nm.to_string(),
            files: BTreeMap::new(),
            args,
            outputs: 1,
        };
        let embed = spec(
            "embed",
            vec![
                input("tokens", vec![-1, seq as i64]),
                param("wte", vec![vocab as i64, d]),
                param("wpe", vec![seq as i64, d]),
            ],
        );
        let layer = spec(
            "layer",
            vec![
                input("x", vec![-1, seq as i64, d]),
                param("ln1_g", vec![d]),
                param("ln1_b", vec![d]),
                param("wq", vec![d, d]),
                param("wk", vec![d, d]),
                param("wv", vec![d, d]),
                param("wo", vec![d, d]),
                param("bo", vec![d]),
                param("ln2_g", vec![d]),
                param("ln2_b", vec![d]),
                param("w1", vec![d, d_ff as i64]),
                param("b1", vec![d_ff as i64]),
                param("w2", vec![d_ff as i64, d]),
                param("b2", vec![d]),
            ],
        );
        let lm_head = spec(
            "lm_head",
            vec![
                input("x", vec![-1, seq as i64, d]),
                param("lnf_g", vec![d]),
                param("lnf_b", vec![d]),
                param("wout", vec![d, vocab as i64]),
            ],
        );
        let per_module = |s: &ModuleSpec| -> usize {
            s.params().map(|p| p.shape.iter().product::<i64>() as usize).sum()
        };
        let param_count =
            per_module(&embed) + n_layers * per_module(&layer) + per_module(&lm_head);
        let mut modules = BTreeMap::new();
        modules.insert("embed".to_string(), embed);
        modules.insert("layer".to_string(), layer);
        modules.insert("lm_head".to_string(), lm_head);
        Manifest {
            name: name.to_string(),
            d_model,
            n_layers,
            n_heads,
            d_ff,
            vocab,
            seq,
            batches: vec![1],
            grad: false,
            tp: Vec::new(),
            simulates: "native".to_string(),
            param_count,
            modules,
            dir: PathBuf::new(),
        }
    }

    pub fn module(&self, name: &str) -> Result<&ModuleSpec> {
        self.modules
            .get(name)
            .ok_or_else(|| anyhow!("model {} has no module '{name}'", self.name))
    }

    /// Path to a module's HLO artifact at a batch size.
    pub fn module_path(&self, module: &str, batch: usize) -> Result<PathBuf> {
        Ok(self.dir.join(self.module(module)?.file_for(batch)?))
    }

    /// The ordered module sequence of a forward pass.
    pub fn forward_sequence(&self) -> Vec<String> {
        let mut seq = vec!["embed".to_string()];
        for i in 0..self.n_layers {
            seq.push(format!("layer.{i}"));
        }
        seq.push("lm_head".to_string());
        seq
    }

    /// Map a hook point like `layer.3` to the executable module kind
    /// (`layer`) plus its weight key (`layer.3`). `embed`/`lm_head` map to
    /// themselves.
    pub fn module_kind(point: &str) -> &str {
        if point.starts_with("layer.") {
            "layer"
        } else {
            point
        }
    }

    /// Output dims of a forward module at a batch size.
    pub fn output_dims(&self, module_kind: &str, batch: usize) -> Vec<usize> {
        match module_kind {
            "embed" | "layer" | "layer_vjp" => vec![batch, self.seq, self.d_model],
            "lm_head" => vec![batch, self.seq, self.vocab],
            m if m.starts_with("attn_tp") || m.starts_with("mlp_tp") => {
                vec![batch, self.seq, self.d_model]
            }
            other => panic!("unknown module kind {other}"),
        }
    }

    /// All model names present under an artifacts directory.
    pub fn list(artifacts_dir: &Path) -> Vec<String> {
        let mut names = Vec::new();
        if let Ok(rd) = std::fs::read_dir(artifacts_dir) {
            for e in rd.flatten() {
                if e.path().join("manifest.json").exists() {
                    if let Some(n) = e.file_name().to_str() {
                        names.push(n.to_string());
                    }
                }
            }
        }
        names.sort();
        names
    }

    /// Total f32 weight bytes (for transfer/load accounting).
    pub fn weight_bytes(&self) -> usize {
        self.param_count * 4
    }

    /// Bytes of one hidden-state tensor at a batch size (netsim accounting).
    pub fn hidden_bytes(&self, batch: usize) -> usize {
        batch * self.seq * self.d_model * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_tiny_manifest() {
        let m = Manifest::load(&artifacts_dir(), "tiny-sim").unwrap();
        assert_eq!(m.d_model, 32);
        assert_eq!(m.n_layers, 2);
        assert!(m.grad);
        assert_eq!(m.tp, vec![2]);
        assert!(m.modules.contains_key("layer"));
        assert!(m.modules.contains_key("lm_head_grad"));
        let layer = m.module("layer").unwrap();
        assert_eq!(layer.params().count(), 13);
        assert_eq!(layer.inputs().count(), 1);
        assert_eq!(layer.outputs, 1);
        assert!(m.module_path("layer", 1).unwrap().exists());
        assert!(m.module_path("layer", 7).is_err());
    }

    #[test]
    fn arg_resolution() {
        let a = ArgSpec { kind: ArgKind::Input, name: "x".into(), shape: vec![-1, 16, 32] };
        assert_eq!(a.resolve(4), vec![4, 16, 32]);
    }

    #[test]
    fn forward_sequence_ordering() {
        let m = Manifest::load(&artifacts_dir(), "tiny-sim").unwrap();
        assert_eq!(m.forward_sequence(), vec!["embed", "layer.0", "layer.1", "lm_head"]);
        assert_eq!(Manifest::module_kind("layer.5"), "layer");
        assert_eq!(Manifest::module_kind("embed"), "embed");
    }

    #[test]
    fn lists_models() {
        let names = Manifest::list(&artifacts_dir());
        assert!(names.contains(&"tiny-sim".to_string()));
        assert!(names.contains(&"llama8b-sim".to_string()));
        assert!(names.len() >= 13);
    }
}
