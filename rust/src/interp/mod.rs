//! The intervention-graph interpreter: interleaves graph execution with
//! the model's forward pass.
//!
//! Execution is preceded by a compile stage: the drivers behind
//! [`crate::engine::Engine`] (with [`execute`], [`execute_stateful`], and
//! [`execute_stream`] as conveniences) run the submitted graph through
//! [`crate::graph::opt`] — DCE, constant folding, CSE, fusion — and
//! re-key the results back into the submitted node ids, so callers never
//! observe the rewrite. `ExecSpec::raw` (the crate-internal `*_raw`
//! drivers) executes a graph exactly as given; the server uses that for
//! graphs already compiled at admission (and for the `--no-opt` escape
//! hatch).
//!
//! Scheduling follows §B.1 of the paper: the graph is partitioned into
//! sub-graphs keyed by the *latest* module activation they (transitively)
//! depend on; each sub-graph executes when that module's hook fires.
//! Setters are pinned to the hook of the module they write (the validator
//! has already guaranteed their dependencies are available by then).
//! Nodes with no model dependencies run in a pre-phase; nodes depending on
//! gradients run in a post-phase after the backward pass.
//!
//! Memory behaviour matches the paper: every node's value is freed as soon
//! as its remaining listener count reaches zero, except values locked by a
//! Save node (LockProtocol). [`Executor::peak_live`] exposes the high-water
//! mark so tests can pin this behaviour down.
//!
//! **Session state** (paper Code Example 5): an executor built with
//! [`Executor::with_state`] resolves `Op::LoadState` nodes in the
//! pre-phase from the supplied [`StateView`] and collects `Op::StoreState`
//! values; [`Executor::into_outcome`] returns them alongside the saved
//! values so the session driver can commit them post-phase. Within one
//! trace every load observes the pre-trace value of its key; updates only
//! become visible to later traces.

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::graph::{
    opt::{self, OptReport, Prepared},
    plan::{self, ExecPlan, MemoryPlan},
    validate::{validate_stream, validate_with_state},
    GraphResult, InterventionGraph, NodeId, Op,
};
use crate::models::generate::Generation;
use crate::models::{Hooks, ModelRunner};
use crate::tensor::{logit_diff, Tensor};

/// The session-state snapshot a trace executes against: named tensors as
/// they were when the trace started. Also the type state updates commit
/// back into.
pub type StateView = HashMap<String, Tensor>;

/// Interprets one intervention graph against one model run.
///
/// The executor implements [`Hooks`], so the `ModelRunner` drives it at
/// module boundaries; everything else (pre/post phases, grads, saves) is
/// orchestrated by [`execute`] / [`Executor::run`].
pub struct Executor<'g> {
    graph: &'g InterventionGraph,
    /// forward-sequence index -> node ids to run at that hook (in id
    /// order). Keyed by position, not module name, so building and probing
    /// the schedule never clones module-name `String`s per node.
    schedule: Vec<Vec<NodeId>>,
    /// module name -> forward-sequence index (one entry per module).
    point_index: HashMap<String, usize>,
    pre: Vec<NodeId>,
    post: Vec<NodeId>,
    /// Value storage. Unplanned executors index this by node id (one cell
    /// per node); planned executors index through `mem`'s arena slots
    /// (one cell per slot, reused in place across last-use boundaries).
    values: Vec<Option<Tensor>>,
    /// AOT arena assignment; `None` for per-node storage.
    mem: Option<Arc<MemoryPlan>>,
    listeners: Vec<usize>,
    locked: Vec<bool>,
    saved: BTreeMap<NodeId, Tensor>,
    /// session-state snapshot loads resolve from (pre-trace values).
    state_in: StateView,
    /// state updates collected from StoreState nodes, committed by the
    /// session driver after the trace completes.
    state_out: BTreeMap<String, Tensor>,
    /// batch-group slice of this user within the running batch.
    row_offset: usize,
    rows: usize,
    /// memory accounting: current & peak live (unlocked) tensors.
    live: usize,
    peak_live: usize,
    /// runtime error captured inside a hook (hooks can't return Result).
    error: Option<anyhow::Error>,
}

impl<'g> Executor<'g> {
    /// Build an executor with no session state in scope; validates the
    /// graph against the model's forward sequence and computes the
    /// per-hook schedule.
    pub fn new(graph: &'g InterventionGraph, forward_sequence: &[String]) -> Result<Executor<'g>> {
        Executor::with_state(graph, forward_sequence, StateView::new())
    }

    /// Build an executor whose LoadState nodes resolve against `state`.
    pub fn with_state(
        graph: &'g InterventionGraph,
        forward_sequence: &[String],
        state: StateView,
    ) -> Result<Executor<'g>> {
        let keys = state.keys().cloned().collect();
        validate_with_state(graph, forward_sequence, &keys)?;
        Executor::prevalidated(graph, forward_sequence, state)
    }

    /// Build an executor for ONE decode step of a streaming request:
    /// `StepHook` markers are legal (validated by the stream rules) and
    /// collect into the per-step result exactly like `Save`.
    pub fn for_stream(
        graph: &'g InterventionGraph,
        forward_sequence: &[String],
    ) -> Result<Executor<'g>> {
        validate_stream(graph, forward_sequence)?;
        Executor::prevalidated(graph, forward_sequence, StateView::new())
    }

    /// Build without re-validating (the caller has already run the
    /// applicable rule set — per-request for traces, once per stream for
    /// the step-hook form). The decode engine re-enters here once per
    /// decode step, paying validation once per stream at admission.
    pub(crate) fn prevalidated(
        graph: &'g InterventionGraph,
        forward_sequence: &[String],
        state: StateView,
    ) -> Result<Executor<'g>> {
        // scheduling prep is shared with the AOT plan compiler (which
        // runs the same derivation once and caches it)
        let order = plan::execution_order(graph, forward_sequence)?;
        let locked = plan::locked_flags(graph);
        let n = graph.nodes.len();
        Ok(Executor::assemble(graph, forward_sequence, state, order, locked, n, None))
    }

    /// Build from a compiled [`ExecPlan`]: no validation, no scheduling
    /// prep — the schedule, lock flags, and arena assignment were all
    /// derived once at plan compile and are cloned (or shared) from the
    /// plan. `graph` must be the plan's bound template
    /// ([`ExecPlan::bind`] output), which structurally matches it by
    /// construction.
    pub(crate) fn planned(
        graph: &'g InterventionGraph,
        forward_sequence: &[String],
        state: StateView,
        exec_plan: &ExecPlan,
    ) -> Executor<'g> {
        debug_assert_eq!(graph.nodes.len(), exec_plan.template().nodes.len());
        let order = exec_plan.order().clone();
        let locked = exec_plan.locked().to_vec();
        let mem = Arc::clone(exec_plan.memory());
        let slots = mem.n_slots;
        Executor::assemble(graph, forward_sequence, state, order, locked, slots, Some(mem))
    }

    /// Shared tail of the constructors: wire the schedule into per-hook
    /// lists and size the value storage (`cells` = node count for
    /// per-node storage, arena slot count for planned storage).
    fn assemble(
        graph: &'g InterventionGraph,
        forward_sequence: &[String],
        state: StateView,
        order: plan::ExecOrder,
        locked: Vec<bool>,
        cells: usize,
        mem: Option<Arc<MemoryPlan>>,
    ) -> Executor<'g> {
        let point_index: HashMap<String, usize> = forward_sequence
            .iter()
            .enumerate()
            .map(|(i, m)| (m.clone(), i))
            .collect();
        let (row_offset, rows) = graph.batch_group.unwrap_or((0, graph.batch.max(1)));
        Executor {
            graph,
            schedule: order.fwd,
            point_index,
            pre: order.pre,
            post: order.post,
            values: vec![None; cells],
            mem,
            listeners: graph.listener_counts(),
            locked,
            saved: BTreeMap::new(),
            state_in: state,
            state_out: BTreeMap::new(),
            row_offset,
            rows,
            live: 0,
            peak_live: 0,
            error: None,
        }
    }

    /// The storage cell index of node `id`: the id itself for per-node
    /// storage, the planned arena slot otherwise (`None` = this value is
    /// never materialized).
    #[inline]
    fn cell(&self, id: NodeId) -> Option<usize> {
        match &self.mem {
            None => Some(id),
            Some(m) => m.slot_of[id],
        }
    }

    /// High-water mark of simultaneously-live unlocked values.
    pub fn peak_live(&self) -> usize {
        self.peak_live
    }

    /// Number of value storage cells: the node count for per-node
    /// storage, the planned arena's slot count when built from a plan.
    pub fn cells(&self) -> usize {
        self.values.len()
    }

    /// Does this executor store values in a planned arena?
    pub fn is_planned(&self) -> bool {
        self.mem.is_some()
    }

    /// Consume one listener's claim on a node's value. The last unlocked
    /// listener *moves* the tensor out instead of cloning it, so a chain
    /// of ops never copies the hidden state it is transforming.
    fn take_dep(&mut self, id: NodeId) -> Result<Tensor> {
        let Some(cell) = self.cell(id).filter(|&c| self.values[c].is_some()) else {
            return Err(anyhow!("node {id} value not available (freed or not computed)"));
        };
        self.listeners[id] = self.listeners[id].saturating_sub(1);
        if self.listeners[id] == 0 && !self.locked[id] {
            self.live = self.live.saturating_sub(1);
            let t = self.values[cell].take().expect("presence checked above");
            crate::obs::profile::value_dead(t.numel() * 4);
            Ok(t)
        } else {
            Ok(self.values[cell].as_ref().expect("presence checked above").clone())
        }
    }

    fn put(&mut self, id: NodeId, v: Tensor) {
        // a node with no listeners that isn't locked is dead on arrival
        // (the memory planner assigns such nodes no slot at all)
        if self.listeners[id] == 0 && !self.locked[id] {
            return;
        }
        let Some(cell) = self.cell(id) else {
            return;
        };
        crate::obs::profile::value_live(v.numel() * 4);
        debug_assert!(
            self.values[cell].is_none(),
            "arena slot {cell} still occupied when node {id} is born"
        );
        self.values[cell] = Some(v);
        self.live += 1;
        self.peak_live = self.peak_live.max(self.live);
    }

    /// Execute one node. `current` is the module activation in flight at
    /// this hook (None in pre/post phases).
    ///
    /// When the deep profiler is armed on this thread the node is timed
    /// and recorded; the disarmed path pays exactly one thread-local
    /// check per node (same discipline as `util/failpoint.rs`).
    fn exec_node(&mut self, id: NodeId, current: Option<&mut Tensor>) -> Result<()> {
        if !crate::obs::profile::armed() {
            return self.exec_node_inner(id, current);
        }
        let kind = op_kind(&self.graph.nodes[id].op);
        let t = std::time::Instant::now();
        let r = self.exec_node_inner(id, current);
        crate::obs::profile::record_op(kind, t);
        r
    }

    /// The untimed node body.
    ///
    /// Ops are matched by reference (the graph outlives the executor), so
    /// per-node execution clones no `Op` payloads — no module-name
    /// `String`s, no `Const` data, no range vectors. Unary transforms use
    /// the in-place kernels over the (usually moved-out) dependency.
    fn exec_node_inner(&mut self, id: NodeId, current: Option<&mut Tensor>) -> Result<()> {
        let graph = self.graph;
        let out = match &graph.nodes[id].op {
            Op::Getter { .. } => {
                let t = current.ok_or_else(|| anyhow!("getter outside hook"))?;
                // a merged co-tenant run hands each user only their rows
                self.slice_rows(t)
            }
            Op::Setter { arg, .. } => {
                let v = self.take_dep(*arg)?;
                let t = current.ok_or_else(|| anyhow!("setter outside hook"))?;
                self.write_rows(t, &v)?;
                v
            }
            Op::Grad { .. } => {
                // value injected by the post-phase driver before exec
                return Ok(());
            }
            Op::Const { dims, data } => Tensor::new(dims, data.clone()),
            Op::Slice { arg, ranges } => self.take_dep(*arg)?.slice(ranges),
            Op::Assign { dst, ranges, src } => {
                let mut d = self.take_dep(*dst)?;
                let s = self.take_dep(*src)?;
                d.slice_assign(ranges, &s);
                d
            }
            Op::Fill { dst, ranges, value } => {
                let mut d = self.take_dep(*dst)?;
                d.slice_fill(ranges, *value);
                d
            }
            Op::Add { a, b } => self.take_dep(*a)?.add(&self.take_dep(*b)?),
            Op::Sub { a, b } => self.take_dep(*a)?.sub(&self.take_dep(*b)?),
            Op::Mul { a, b } => self.take_dep(*a)?.mul(&self.take_dep(*b)?),
            Op::Matmul { a, b } => self.take_dep(*a)?.matmul(&self.take_dep(*b)?),
            Op::Scale { arg, factor } => {
                let mut t = self.take_dep(*arg)?;
                t.scale_inplace(*factor);
                t
            }
            Op::Gelu { arg } => {
                let mut t = self.take_dep(*arg)?;
                t.gelu_inplace();
                t
            }
            Op::Softmax { arg } => {
                let mut t = self.take_dep(*arg)?;
                t.softmax_last_inplace();
                t
            }
            Op::Argmax { arg } => self.take_dep(*arg)?.argmax_last(),
            Op::Mean { arg } => {
                let t = self.take_dep(*arg)?;
                if t.numel() == 0 {
                    return Err(anyhow!(
                        "mean of an empty tensor (node {id}); empty reductions are rejected \
                         rather than producing NaN (see docs/PROTOCOL.md)"
                    ));
                }
                Tensor::scalar(t.mean_all())
            }
            Op::Sum { arg } => {
                let t = self.take_dep(*arg)?;
                if t.numel() == 0 {
                    return Err(anyhow!(
                        "sum of an empty tensor (node {id}); empty reductions are rejected \
                         rather than producing a silent zero (see docs/PROTOCOL.md)"
                    ));
                }
                Tensor::scalar(t.sum_all())
            }
            Op::Transpose { arg } => {
                let t = self.take_dep(*arg)?;
                if t.rank() != 2 {
                    return Err(anyhow!("transpose needs a 2-D tensor, got {:?}", t.dims()));
                }
                t.transpose2()
            }
            Op::Reshape { arg, dims } => {
                let t = self.take_dep(*arg)?;
                let want: usize = dims.iter().product();
                if want != t.numel() {
                    return Err(anyhow!(
                        "reshape {:?} -> {dims:?} changes element count",
                        t.dims()
                    ));
                }
                t.reshape(dims)
            }
            Op::MeanAxis { arg, axis } => {
                let t = self.take_dep(*arg)?;
                if *axis >= t.rank() {
                    return Err(anyhow!("mean_axis axis {axis} out of rank {}", t.rank()));
                }
                if t.dims()[*axis] == 0 {
                    return Err(anyhow!(
                        "mean_axis over an empty axis {axis} (node {id}); empty reductions \
                         are rejected rather than producing NaN (see docs/PROTOCOL.md)"
                    ));
                }
                t.mean_axis(*axis)
            }
            // fused internal ops (graph::opt fusion pass): each dispatches
            // to the in-place kernel and is bit-identical to the unfused
            // pair it replaced
            Op::FusedScaleAdd { a, b, factor } => {
                let mut x = self.take_dep(*a)?;
                let y = self.take_dep(*b)?;
                if x.dims() == y.dims() {
                    x.scale_add_assign(*factor, &y);
                    x
                } else {
                    // broadcasting operands: same kernels as the unfused pair
                    let mut s = y;
                    s.scale_inplace(*factor);
                    x.add(&s)
                }
            }
            Op::FusedMatmulGelu { a, b } => {
                let mut t = self.take_dep(*a)?.matmul(&self.take_dep(*b)?);
                t.gelu_inplace();
                t
            }
            Op::FusedScaleSoftmax { arg, factor } => {
                let mut t = self.take_dep(*arg)?;
                t.scale_inplace(*factor);
                t.softmax_last_inplace();
                t
            }
            Op::LogitDiff { logits, target, foil } => {
                logit_diff(&self.take_dep(*logits)?, *target, *foil)
            }
            Op::LoadState { key } => self
                .state_in
                .get(key)
                .cloned()
                .ok_or_else(|| anyhow!("state key '{key}' not present in session state"))?,
            Op::StoreState { key, arg } => {
                let v = self.take_dep(*arg)?;
                // only keep a second copy when some downstream node reads
                // the store's own value; the update map otherwise takes
                // sole ownership
                if self.listeners[id] > 0 || self.locked[id] {
                    self.put(id, v.clone());
                }
                self.state_out.insert(key.clone(), v);
                return Ok(());
            }
            Op::Save { arg } | Op::StepHook { arg } => {
                let v = self
                    .cell(*arg)
                    .and_then(|c| self.values[c].as_ref())
                    .ok_or_else(|| anyhow!("save of unavailable node {arg}"))?
                    .clone();
                self.listeners[*arg] = self.listeners[*arg].saturating_sub(1);
                // only clone again if some downstream node reads the save's
                // own value; otherwise the result map takes sole ownership
                if self.listeners[id] > 0 || self.locked[id] {
                    self.put(id, v.clone());
                }
                self.saved.insert(id, v);
                return Ok(());
            }
        };
        self.put(id, out);
        Ok(())
    }

    /// Rows of the in-flight activation belonging to this user.
    fn slice_rows(&self, t: &Tensor) -> Tensor {
        if self.row_offset == 0 && self.rows == t.dims()[0] {
            return t.clone();
        }
        let mut ranges = vec![crate::tensor::Range1::all(); 1];
        ranges[0] = crate::tensor::Range1::new(self.row_offset, self.row_offset + self.rows);
        t.slice(&ranges)
    }

    /// Write a user-rows tensor back into the in-flight activation.
    fn write_rows(&self, t: &mut Tensor, v: &Tensor) -> Result<()> {
        if v.dims()[0] != self.rows {
            return Err(anyhow!(
                "setter value has {} rows, batch group has {}",
                v.dims()[0],
                self.rows
            ));
        }
        let ranges = vec![crate::tensor::Range1::new(
            self.row_offset,
            self.row_offset + self.rows,
        )];
        t.slice_assign(&ranges, v);
        Ok(())
    }

    fn run_list(&mut self, ids: &[NodeId], mut current: Option<&mut Tensor>) -> Result<bool> {
        let mut modified = false;
        for &id in ids {
            let is_setter = matches!(self.graph.nodes[id].op, Op::Setter { .. });
            self.exec_node(id, current.as_deref_mut())?;
            modified |= is_setter;
        }
        Ok(modified)
    }

    /// Run the pre-phase (Const chains etc.).
    pub fn run_pre(&mut self) -> Result<()> {
        let ids = self.pre.clone();
        self.run_list(&ids, None)?;
        Ok(())
    }

    /// Inject gradient values and run the post-phase.
    pub fn run_post(&mut self, grads: &HashMap<String, Tensor>) -> Result<()> {
        let ids = self.post.clone();
        for &id in &ids {
            if let Op::Grad { module } = &self.graph.nodes[id].op {
                let g = grads
                    .get(module)
                    .ok_or_else(|| anyhow!("no gradient computed for {module}"))?;
                self.put(id, self.slice_rows(g));
            }
        }
        // run non-grad post nodes (grad values already in place)
        let rest: Vec<NodeId> = ids
            .iter()
            .copied()
            .filter(|&id| !matches!(self.graph.nodes[id].op, Op::Grad { .. }))
            .collect();
        self.run_list(&rest, None)?;
        Ok(())
    }

    /// Take the saved values (consumes the executor's result map); state
    /// updates, if any, are discarded.
    pub fn into_result(self) -> Result<GraphResult> {
        Ok(self.into_outcome()?.0)
    }

    /// Take the saved values AND the session-state updates collected from
    /// StoreState nodes (the post-phase commit set).
    pub fn into_outcome(self) -> Result<(GraphResult, BTreeMap<String, Tensor>)> {
        if let Some(e) = self.error {
            return Err(e);
        }
        Ok((GraphResult { values: self.saved }, self.state_out))
    }

    pub fn had_error(&self) -> Option<&anyhow::Error> {
        self.error.as_ref()
    }

    /// Take a runtime error captured inside a hook, if any (hooks cannot
    /// return `Result`, so failures are parked on the executor).
    pub(crate) fn take_error(&mut self) -> Option<anyhow::Error> {
        self.error.take()
    }
}

impl Hooks for Executor<'_> {
    fn wants(&self, point: &str) -> bool {
        self.error.is_none()
            && self.point_index.get(point).is_some_and(|&k| !self.schedule[k].is_empty())
    }

    fn on_output(&mut self, point: &str, t: &mut Tensor) -> bool {
        let Some(&k) = self.point_index.get(point) else {
            return false;
        };
        if self.schedule[k].is_empty() {
            return false;
        }
        // tag ops recorded under this hook with its forward point
        // (no-op thread-local check when the profiler is disarmed)
        crate::obs::profile::set_point(point);
        let ids = self.schedule[k].clone();
        let r = match self.run_list(&ids, Some(t)) {
            Ok(modified) => modified,
            Err(e) => {
                self.error = Some(e);
                false
            }
        };
        crate::obs::profile::set_point("");
        r
    }
}

/// Stable profiler tag for an op (also the key of the fleet hot-op
/// table, so it must not carry per-request payload like module names).
fn op_kind(op: &Op) -> &'static str {
    match op {
        Op::Getter { .. } => "getter",
        Op::Setter { .. } => "setter",
        Op::Grad { .. } => "grad",
        Op::Const { .. } => "const",
        Op::Slice { .. } => "slice",
        Op::Assign { .. } => "assign",
        Op::Fill { .. } => "fill",
        Op::Add { .. } => "add",
        Op::Sub { .. } => "sub",
        Op::Mul { .. } => "mul",
        Op::Matmul { .. } => "matmul",
        Op::Scale { .. } => "scale",
        Op::Gelu { .. } => "gelu",
        Op::Softmax { .. } => "softmax",
        Op::Argmax { .. } => "argmax",
        Op::Mean { .. } => "mean",
        Op::Sum { .. } => "sum",
        Op::Transpose { .. } => "transpose",
        Op::Reshape { .. } => "reshape",
        Op::MeanAxis { .. } => "mean_axis",
        Op::FusedScaleAdd { .. } => "fused_scale_add",
        Op::FusedMatmulGelu { .. } => "fused_matmul_gelu",
        Op::FusedScaleSoftmax { .. } => "fused_scale_softmax",
        Op::LogitDiff { .. } => "logit_diff",
        Op::LoadState { .. } => "load_state",
        Op::StoreState { .. } => "store_state",
        Op::Save { .. } => "save",
        Op::StepHook { .. } => "step_hook",
    }
}

/// Execute a standalone graph against a loaded model: pre-phase → hooked
/// forward (sharded if requested) → backward/post-phase → saved values.
/// The graph is run through the admission compiler ([`crate::graph::opt`])
/// first. This is convenience sugar over the unified engine door —
/// [`crate::engine::Engine::run`] with [`crate::engine::ExecSpec`] exposes
/// the optimizer toggle, session state, and streaming.
pub fn execute(graph: &InterventionGraph, runner: &ModelRunner) -> Result<GraphResult> {
    Ok(execute_full(graph, runner, StateView::new(), true)?.0)
}

#[deprecated(note = "use engine::Engine::run(ExecSpec::trace(..)) — `.report` on the outcome")]
#[doc(hidden)]
pub fn execute_reported(
    graph: &InterventionGraph,
    runner: &ModelRunner,
    optimize: bool,
) -> Result<(GraphResult, Option<OptReport>)> {
    let (res, _, report) = execute_full(graph, runner, StateView::new(), optimize)?;
    Ok((res, report))
}

/// Execute a graph inside a session: loads resolve against `state`, and on
/// success the collected store updates are committed back into `state`
/// (the post-phase commit). On error `state` is left untouched. Sugar over
/// [`crate::engine::Engine::run_session`] for a single graph.
pub fn execute_stateful(
    graph: &InterventionGraph,
    runner: &ModelRunner,
    state: &mut StateView,
) -> Result<GraphResult> {
    execute_stateful_inner(graph, runner, state, true)
}

#[deprecated(note = "use engine::Engine::run_session")]
#[doc(hidden)]
pub fn execute_stateful_opt(
    graph: &InterventionGraph,
    runner: &ModelRunner,
    state: &mut StateView,
    optimize: bool,
) -> Result<GraphResult> {
    execute_stateful_inner(graph, runner, state, optimize)
}

/// The session-step driver: snapshot the loaded keys, execute, commit
/// updates on success.
pub(crate) fn execute_stateful_inner(
    graph: &InterventionGraph,
    runner: &ModelRunner,
    state: &mut StateView,
    optimize: bool,
) -> Result<GraphResult> {
    // clone only the keys this graph actually loads — the view is a
    // snapshot, so the trace observes pre-trace values throughout
    let mut view = StateView::new();
    for key in graph.state_loads() {
        if let Some(t) = state.get(&key) {
            view.insert(key, t.clone());
        }
    }
    // validation needs the full key set (a load of an uncloned-but-present
    // key is impossible: state_loads() covers every load)
    let (result, updates, _) = execute_full(graph, runner, view, optimize)?;
    for (k, v) in updates {
        state.insert(k, v);
    }
    Ok(result)
}

#[deprecated(note = "use engine::Engine::run(ExecSpec::trace(..).with_state(..))")]
#[doc(hidden)]
pub fn execute_with_view(
    graph: &InterventionGraph,
    runner: &ModelRunner,
    state_in: StateView,
) -> Result<(GraphResult, BTreeMap<String, Tensor>)> {
    let (res, updates, _) = execute_full(graph, runner, state_in, true)?;
    Ok((res, updates))
}

/// Core optimizing driver: validate the submitted graph, run it through
/// the compiler pipeline (unless `optimize` is false), execute, and re-key
/// the saved values back into the submitted graph's node ids. In-crate
/// only — external callers go through [`crate::engine::Engine`].
pub(crate) fn execute_full(
    graph: &InterventionGraph,
    runner: &ModelRunner,
    state_in: StateView,
    optimize: bool,
) -> Result<(GraphResult, BTreeMap<String, Tensor>, Option<OptReport>)> {
    if !optimize {
        let (res, updates) = execute_view_raw(graph, runner, state_in)?;
        return Ok((res, updates, None));
    }
    let fseq = runner.manifest.forward_sequence();
    // validate the graph AS SUBMITTED, so the optimized and unoptimized
    // paths reject exactly the same graphs (DCE could otherwise hide an
    // invalid-but-dead subgraph the raw path would refuse)
    let keys = state_in.keys().cloned().collect();
    validate_with_state(graph, &fseq, &keys)?;
    let o = opt::optimize(graph, &fseq)?;
    let (res, updates) = execute_view_raw(&o.graph, runner, state_in)?;
    Ok((o.remap_result(res), updates, Some(o.report)))
}

/// Execute a graph exactly as given — no optimization passes, no id
/// remapping. This is the executor the scheduler workers use for graphs
/// the server already compiled at admission, and the oracle the parity
/// tests compare against (via `ExecSpec::raw`).
pub(crate) fn execute_view_raw(
    graph: &InterventionGraph,
    runner: &ModelRunner,
    state_in: StateView,
) -> Result<(GraphResult, BTreeMap<String, Tensor>)> {
    let fseq = runner.manifest.forward_sequence();
    let ex = Executor::with_state(graph, &fseq, state_in)?;
    drive_to_outcome(graph, runner, ex)
}

/// Execute a [`Prepared`] trace. Plan-bound graphs run on a planned
/// executor — validation and scheduling prep are skipped, values live in
/// the plan's arena slots; everything else is the shared driver, so the
/// memory gauges and profiler attribution are identical to the raw path.
/// Results come back in *template* ids; callers re-key through
/// [`Prepared::remap_values`] as usual.
pub(crate) fn execute_view_prepared(
    prepared: &Prepared,
    runner: &ModelRunner,
    state_in: StateView,
) -> Result<(GraphResult, BTreeMap<String, Tensor>)> {
    match &prepared.plan {
        None => execute_view_raw(&prepared.graph, runner, state_in),
        Some(p) => {
            let fseq = runner.manifest.forward_sequence();
            let ex = Executor::planned(&prepared.graph, &fseq, state_in, p);
            drive_to_outcome(&prepared.graph, runner, ex)
        }
    }
}

/// The driver body shared by raw and planned execution: pre-phase →
/// hooked forward (sharded if requested) → backward/post-phase → outcome.
fn drive_to_outcome(
    graph: &InterventionGraph,
    runner: &ModelRunner,
    mut ex: Executor,
) -> Result<(GraphResult, BTreeMap<String, Tensor>)> {
    ex.run_pre()?;

    let seq = runner.manifest.seq;
    if graph.tokens.len() != graph.batch * seq {
        return Err(anyhow!(
            "tokens length {} != batch {} * seq {seq}",
            graph.tokens.len(),
            graph.batch
        ));
    }
    let tokens = Tensor::new(&[graph.batch, seq], graph.tokens.clone());
    let (padded, _) = runner.pad_tokens(&tokens)?;

    // phase timing is armed by the scheduler worker when the request is
    // observed; the clock reads are skipped entirely otherwise, so the
    // hooked computation is not perturbed (FlexModel's constraint)
    let timed = crate::obs::phases::armed();
    let profiled = crate::obs::profile::armed();
    let tf = (timed || profiled).then(std::time::Instant::now);
    if graph.shards > 1 {
        runner.forward_sharded(&padded, graph.shards, &mut ex)?;
    } else {
        runner.forward(&padded, &mut ex)?;
    }
    if let Some(t) = tf {
        if timed {
            crate::obs::phases::record("forward", t.elapsed().as_nanos() as u64);
        }
        if profiled {
            crate::obs::profile::record_phase("forward", t);
        }
    }
    if let Some(e) = ex.error.take() {
        return Err(e);
    }

    let grad_points = graph.grad_points();
    if !grad_points.is_empty() {
        let targets = graph
            .targets
            .as_ref()
            .ok_or_else(|| anyhow!("grad without targets"))?;
        let mut t = Tensor::new(&[targets.len()], targets.clone());
        if t.dims()[0] != padded.dims()[0] {
            // pad targets to the padded batch
            let mut data = t.into_data();
            data.resize(padded.dims()[0], 0.0);
            t = Tensor::new(&[data.len()], data);
        }
        let tb = (timed || profiled).then(std::time::Instant::now);
        let (_, grads) = runner.backward(&padded, &t, &grad_points)?;
        if let Some(t0) = tb {
            if timed {
                crate::obs::phases::record("backward", t0.elapsed().as_nanos() as u64);
            }
            if profiled {
                crate::obs::profile::record_phase("backward", t0);
            }
        }
        ex.run_post(&grads)?;
    }

    ex.into_outcome()
}

// ---------------------------------------------------------------------------
// Streaming generation
// ---------------------------------------------------------------------------

/// What one decode step of a streaming request produced: the greedy token,
/// its logit, and the values collected by `Save`/`StepHook` nodes during
/// that step's graph re-execution.
#[derive(Debug, Clone)]
pub struct StepOutcome {
    pub token: usize,
    pub score: f32,
    pub values: GraphResult,
}

/// Streaming decode with per-step interventions: greedy-generate `steps`
/// tokens from the graph's `[1, seq]` prompt, **re-entering the
/// intervention graph at every decode step** against that step's hidden
/// state (the paper's iterative `.generate()` + per-step hook execution).
/// `sink` receives each step's outcome as soon as the step completes and
/// returns `false` to stop decoding early (a gone consumer). Returns the
/// full greedy trajectory.
///
/// The window slides as in [`ModelRunner::generate`]: the exported modules
/// are shape-specialized, so each step is a full forward over the shifted
/// context rather than a KV-incremental one — the per-step *intervention*
/// semantics are identical either way.
///
/// The graph is compiled once per stream (not per step): dead getters are
/// gone before the first token, and `Const`-only subtrees are folded once
/// instead of re-evaluating at every decode step.
pub fn execute_stream(
    graph: &InterventionGraph,
    runner: &ModelRunner,
    steps: usize,
    sink: &mut dyn FnMut(usize, StepOutcome) -> bool,
) -> Result<Generation> {
    Ok(execute_stream_opt(graph, runner, steps, true, sink)?.0)
}

#[deprecated(note = "use engine::Engine::run_streaming(ExecSpec::trace(..).stream(steps), sink)")]
#[doc(hidden)]
pub fn execute_stream_full(
    graph: &InterventionGraph,
    runner: &ModelRunner,
    steps: usize,
    optimize: bool,
    sink: &mut dyn FnMut(usize, StepOutcome) -> bool,
) -> Result<(Generation, Option<OptReport>)> {
    execute_stream_opt(graph, runner, steps, optimize, sink)
}

/// [`execute_stream`] with the optimizer toggle exposed; also returns the
/// per-request optimization report (`None` when `optimize` is false).
pub(crate) fn execute_stream_opt(
    graph: &InterventionGraph,
    runner: &ModelRunner,
    steps: usize,
    optimize: bool,
    sink: &mut dyn FnMut(usize, StepOutcome) -> bool,
) -> Result<(Generation, Option<OptReport>)> {
    if !optimize {
        return Ok((execute_stream_raw(graph, runner, steps, sink)?, None));
    }
    let fseq = runner.manifest.forward_sequence();
    // validate AS SUBMITTED for error parity with the raw path
    validate_stream(graph, &fseq)?;
    let o = opt::optimize(graph, &fseq)?;
    let mut wrapped = |step: usize, mut out: StepOutcome| {
        out.values = o.remap_result(out.values);
        sink(step, out)
    };
    let gen = execute_stream_raw(&o.graph, runner, steps, &mut wrapped)?;
    Ok((gen, Some(o.report)))
}

/// Streaming decode of a graph exactly as given — no optimization, no id
/// remapping (the path for streams compiled at admission). Drives one
/// [`crate::engine::RunnerStream`] to completion; the continuous-batching
/// scheduler steps many such streams interleaved instead.
pub(crate) fn execute_stream_raw(
    graph: &InterventionGraph,
    runner: &ModelRunner,
    steps: usize,
    sink: &mut dyn FnMut(usize, StepOutcome) -> bool,
) -> Result<Generation> {
    let mut stream = crate::engine::RunnerStream::new(graph.clone(), runner, steps)?;
    let mut step = 0usize;
    while let Some(out) = stream.step(runner)? {
        let more = sink(step, out);
        step += 1;
        if !more {
            break;
        }
    }
    Ok(stream.into_generation())
}

/// Streaming decode of a [`Prepared`] graph: plan-bound graphs skip the
/// per-stream validation and run every decode step on a planned executor
/// (the arena is reused across steps' executor rebuilds). Step values
/// come back in template ids, exactly like [`execute_stream_raw`].
pub(crate) fn execute_stream_prepared(
    prepared: &Prepared,
    runner: &ModelRunner,
    steps: usize,
    sink: &mut dyn FnMut(usize, StepOutcome) -> bool,
) -> Result<Generation> {
    let mut stream = crate::engine::RunnerStream::with_plan(
        prepared.graph.clone(),
        runner,
        steps,
        prepared.plan.clone(),
    )?;
    let mut step = 0usize;
    while let Some(out) = stream.step(runner)? {
        let more = sink(step, out);
        step += 1;
        if !more {
            break;
        }
    }
    Ok(stream.into_generation())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Port;
    use crate::tensor::Range1;

    fn fseq() -> Vec<String> {
        vec!["embed".into(), "layer.0".into(), "layer.1".into(), "lm_head".into()]
    }

    /// Drive an executor by hand, simulating a model run — no PJRT needed.
    fn drive(ex: &mut Executor, acts: &mut BTreeMap<String, Tensor>) {
        for point in fseq() {
            if let Some(t) = acts.get_mut(&point) {
                if ex.wants(&point) {
                    ex.on_output(&point, t);
                }
            }
        }
    }

    fn acts(batch: usize) -> BTreeMap<String, Tensor> {
        let mut m = BTreeMap::new();
        m.insert("embed".to_string(), Tensor::iota(&[batch, 4]));
        m.insert("layer.0".to_string(), Tensor::iota(&[batch, 4]).scale(2.0));
        m.insert("layer.1".to_string(), Tensor::iota(&[batch, 4]).scale(3.0));
        m.insert("lm_head".to_string(), Tensor::iota(&[batch, 4]).scale(4.0));
        m
    }

    #[test]
    fn getter_save_round_trip() {
        let mut g = InterventionGraph::new("m");
        g.batch = 2;
        let get = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        let save = g.push(Op::Save { arg: get });
        let mut ex = Executor::new(&g, &fseq()).unwrap();
        ex.run_pre().unwrap();
        let mut a = acts(2);
        drive(&mut ex, &mut a);
        let res = ex.into_result().unwrap();
        assert_eq!(res.get(save).unwrap(), &Tensor::iota(&[2, 4]).scale(2.0));
    }

    #[test]
    fn setter_modifies_downstream_activation() {
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let c = g.push(Op::Const { dims: vec![1, 4], data: vec![9.0; 4] });
        g.push(Op::Setter { module: "layer.0".into(), port: Port::Output, arg: c });
        let mut ex = Executor::new(&g, &fseq()).unwrap();
        ex.run_pre().unwrap();
        let mut a = acts(1);
        drive(&mut ex, &mut a);
        assert_eq!(a["layer.0"].data(), &[9.0; 4]);
        assert!(ex.had_error().is_none());
    }

    #[test]
    fn input_port_maps_to_previous_module() {
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        // layer.1 input == layer.0 output
        let get = g.push(Op::Getter { module: "layer.1".into(), port: Port::Input });
        let save = g.push(Op::Save { arg: get });
        let mut ex = Executor::new(&g, &fseq()).unwrap();
        ex.run_pre().unwrap();
        let mut a = acts(1);
        drive(&mut ex, &mut a);
        let res = ex.into_result().unwrap();
        assert_eq!(res.get(save).unwrap(), &Tensor::iota(&[1, 4]).scale(2.0));
    }

    #[test]
    fn input_port_on_first_module_rejected() {
        let mut g = InterventionGraph::new("m");
        g.push(Op::Getter { module: "embed".into(), port: Port::Input });
        assert!(Executor::new(&g, &fseq()).is_err());
    }

    #[test]
    fn cross_module_patching() {
        // save layer.0 output, write it over layer.1 output
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let h0 = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        g.push(Op::Setter { module: "layer.1".into(), port: Port::Output, arg: h0 });
        let h1 = g.push(Op::Getter { module: "layer.1".into(), port: Port::Output });
        let save = g.push(Op::Save { arg: h1 });
        let mut ex = Executor::new(&g, &fseq()).unwrap();
        ex.run_pre().unwrap();
        let mut a = acts(1);
        drive(&mut ex, &mut a);
        // the getter at layer.1 sees the patched value
        let res = ex.into_result().unwrap();
        assert_eq!(res.get(save).unwrap(), &Tensor::iota(&[1, 4]).scale(2.0));
    }

    #[test]
    fn batch_group_isolation() {
        // user owns row 1 of a 3-row batch; getter sees only row 1 and
        // setter writes only row 1.
        let mut g = InterventionGraph::new("m");
        g.batch = 3;
        g.batch_group = Some((1, 1));
        let get = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        let save = g.push(Op::Save { arg: get });
        let z = g.push(Op::Const { dims: vec![1, 4], data: vec![-1.0; 4] });
        g.push(Op::Setter { module: "layer.0".into(), port: Port::Output, arg: z });
        let mut ex = Executor::new(&g, &fseq()).unwrap();
        ex.run_pre().unwrap();
        let mut a = acts(3);
        let before = a["layer.0"].clone();
        drive(&mut ex, &mut a);
        let after = &a["layer.0"];
        // rows 0 and 2 untouched
        assert_eq!(
            after.slice(&[Range1::one(0)]).data(),
            before.slice(&[Range1::one(0)]).data()
        );
        assert_eq!(
            after.slice(&[Range1::one(2)]).data(),
            before.slice(&[Range1::one(2)]).data()
        );
        assert_eq!(after.slice(&[Range1::one(1)]).data(), &[-1.0; 4]);
        // getter saw only its row
        let res = ex.into_result().unwrap();
        assert_eq!(res.get(save).unwrap().dims(), &[1, 4]);
    }

    #[test]
    fn values_freed_when_listeners_exhausted() {
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let get = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        let s1 = g.push(Op::Scale { arg: get, factor: 2.0 });
        let s2 = g.push(Op::Scale { arg: s1, factor: 2.0 });
        let s3 = g.push(Op::Scale { arg: s2, factor: 2.0 });
        g.push(Op::Save { arg: s3 });
        let mut ex = Executor::new(&g, &fseq()).unwrap();
        ex.run_pre().unwrap();
        let mut a = acts(1);
        drive(&mut ex, &mut a);
        // chain frees as it goes: at most 2 unlocked values live at once
        assert!(ex.peak_live() <= 2, "peak_live = {}", ex.peak_live());
        let res = ex.into_result().unwrap();
        assert_eq!(res.values.len(), 1);
    }

    #[test]
    fn save_locks_value_despite_consumption() {
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let get = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        let save = g.push(Op::Save { arg: get });
        let sc = g.push(Op::Scale { arg: get, factor: 5.0 });
        let save2 = g.push(Op::Save { arg: sc });
        let mut ex = Executor::new(&g, &fseq()).unwrap();
        ex.run_pre().unwrap();
        let mut a = acts(1);
        drive(&mut ex, &mut a);
        let res = ex.into_result().unwrap();
        assert_eq!(res.get(save).unwrap().data(), Tensor::iota(&[1, 4]).scale(2.0).data());
        assert_eq!(res.get(save2).unwrap().data(), Tensor::iota(&[1, 4]).scale(10.0).data());
    }

    #[test]
    fn arithmetic_pipeline_at_hook() {
        // mean(softmax(h * 2)) saved — mixed op chain on one hook
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let get = g.push(Op::Getter { module: "lm_head".into(), port: Port::Output });
        let sc = g.push(Op::Scale { arg: get, factor: 2.0 });
        let sm = g.push(Op::Softmax { arg: sc });
        let mn = g.push(Op::Mean { arg: sm });
        let save = g.push(Op::Save { arg: mn });
        let mut ex = Executor::new(&g, &fseq()).unwrap();
        ex.run_pre().unwrap();
        let mut a = acts(1);
        drive(&mut ex, &mut a);
        let res = ex.into_result().unwrap();
        let v = res.get(save).unwrap().item();
        assert!((v - 0.25).abs() < 1e-6); // softmax rows sum to 1, 4 entries
    }

    #[test]
    fn grad_post_phase() {
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        g.targets = Some(vec![1.0]);
        let gr = g.push(Op::Grad { module: "layer.0".into() });
        let n = g.push(Op::Scale { arg: gr, factor: -1.0 });
        let save = g.push(Op::Save { arg: n });
        let mut ex = Executor::new(&g, &fseq()).unwrap();
        ex.run_pre().unwrap();
        let mut a = acts(1);
        drive(&mut ex, &mut a);
        let mut grads = HashMap::new();
        grads.insert("layer.0".to_string(), Tensor::full(&[1, 4], 3.0));
        ex.run_post(&grads).unwrap();
        let res = ex.into_result().unwrap();
        assert_eq!(res.get(save).unwrap().data(), &[-3.0; 4]);
    }

    #[test]
    fn step_hook_collects_like_save_in_stream_mode() {
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let get = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        let sc = g.push(Op::Scale { arg: get, factor: 2.0 });
        let hook = g.push(Op::StepHook { arg: sc });
        // a plain executor refuses the graph; the stream executor runs it
        assert!(Executor::new(&g, &fseq()).is_err());
        let mut ex = Executor::for_stream(&g, &fseq()).unwrap();
        ex.run_pre().unwrap();
        let mut a = acts(1);
        drive(&mut ex, &mut a);
        let res = ex.into_result().unwrap();
        assert_eq!(res.get(hook).unwrap(), &Tensor::iota(&[1, 4]).scale(4.0));
    }

    #[test]
    fn state_load_sees_pre_trace_value_and_store_collects_update() {
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let w = g.push(Op::LoadState { key: "w".into() });
        let s = g.push(Op::Scale { arg: w, factor: 2.0 });
        g.push(Op::StoreState { key: "w".into(), arg: s });
        let save = g.push(Op::Save { arg: s });
        let mut state = StateView::new();
        state.insert("w".into(), Tensor::full(&[2], 3.0));
        let mut ex = Executor::with_state(&g, &fseq(), state).unwrap();
        ex.run_pre().unwrap();
        let (res, updates) = ex.into_outcome().unwrap();
        assert_eq!(res.get(save).unwrap().data(), &[6.0; 2]);
        assert_eq!(updates["w"].data(), &[6.0; 2]);
    }

    #[test]
    fn state_load_of_missing_key_rejected_at_build() {
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let w = g.push(Op::LoadState { key: "nope".into() });
        g.push(Op::Save { arg: w });
        let err = Executor::with_state(&g, &fseq(), StateView::new())
            .err()
            .expect("missing key must fail validation")
            .to_string();
        assert!(err.contains("load-before-store"), "{err}");
    }

    #[test]
    fn store_of_activation_runs_at_hook_phase() {
        // store a getter-derived value: the store executes at the hook,
        // the update is still only visible in the outcome (post-phase)
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let h = g.push(Op::Getter { module: "layer.0".into(), port: Port::Output });
        g.push(Op::StoreState { key: "h".into(), arg: h });
        let mut ex = Executor::new(&g, &fseq()).unwrap();
        ex.run_pre().unwrap();
        let mut a = acts(1);
        drive(&mut ex, &mut a);
        let (_, updates) = ex.into_outcome().unwrap();
        assert_eq!(updates["h"], Tensor::iota(&[1, 4]).scale(2.0));
    }

    #[test]
    fn shape_ops_execute() {
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let c = g.push(Op::Const { dims: vec![2, 3], data: vec![1., 2., 3., 4., 5., 6.] });
        let t = g.push(Op::Transpose { arg: c });
        let st = g.push(Op::Save { arg: t });
        let c2 = g.push(Op::Const { dims: vec![2, 3], data: vec![1., 2., 3., 4., 5., 6.] });
        let r = g.push(Op::Reshape { arg: c2, dims: vec![3, 2] });
        let sr = g.push(Op::Save { arg: r });
        let c3 = g.push(Op::Const { dims: vec![2, 3], data: vec![1., 2., 3., 4., 5., 6.] });
        let m = g.push(Op::MeanAxis { arg: c3, axis: 0 });
        let sm = g.push(Op::Save { arg: m });
        let mut ex = Executor::new(&g, &fseq()).unwrap();
        ex.run_pre().unwrap();
        let res = ex.into_result().unwrap();
        assert_eq!(res.get(st).unwrap(), &Tensor::new(&[3, 2], vec![1., 4., 2., 5., 3., 6.]));
        assert_eq!(res.get(sr).unwrap().dims(), &[3, 2]);
        assert_eq!(res.get(sm).unwrap(), &Tensor::new(&[3], vec![2.5, 3.5, 4.5]));
    }

    #[test]
    fn shape_op_errors_are_graceful() {
        // transpose of a 3-D tensor is an error, not a panic
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        let c = g.push(Op::Const { dims: vec![1, 2, 2], data: vec![0.0; 4] });
        let t = g.push(Op::Transpose { arg: c });
        g.push(Op::Save { arg: t });
        let mut ex = Executor::new(&g, &fseq()).unwrap();
        assert!(ex.run_pre().is_err());
    }

    #[test]
    fn error_inside_hook_is_captured() {
        // matmul with incompatible shapes triggers a panic-free error path?
        // tensor ops panic on shape mismatch, so use a save of freed value
        // instead: craft graph that saves a node never computed (grad
        // without post-phase).
        let mut g = InterventionGraph::new("m");
        g.batch = 1;
        g.targets = Some(vec![1.0]);
        let gr = g.push(Op::Grad { module: "layer.0".into() });
        let save = g.push(Op::Save { arg: gr });
        let mut ex = Executor::new(&g, &fseq()).unwrap();
        ex.run_pre().unwrap();
        let mut a = acts(1);
        drive(&mut ex, &mut a);
        // skip run_post: into_result has no saved value for the grad
        let res = ex.into_result().unwrap();
        assert!(res.get(save).is_none());
    }
}
