//! # nnscope
//!
//! A Rust + JAX + Pallas reproduction of **"NNsight and NDIF: Democratizing
//! Access to Open-Weight Foundation Model Internals"** (ICLR 2025).
//!
//! The crate implements, from scratch:
//!
//! * the **intervention graph** architecture (§3.1 of the paper): a
//!   portable, JSON-serializable representation of an experiment on a
//!   neural network's internals ([`graph`], [`interp`]), plus an
//!   **admission compiler** ([`graph::opt`]) that rewrites submitted
//!   graphs (DCE, constant folding, CSE, kernel fusion) while keeping
//!   every saved value bit-identical;
//! * an **NNsight-like tracing client** (§3.2): a deferred-execution builder
//!   DSL with proxies over module inputs/outputs, `.save()` locking, grad
//!   access, and sessions ([`client`]);
//! * the **NDIF inference service** (§3.3, §B.2): a multi-tenant server that
//!   preloads models, queues intervention requests from many users,
//!   interleaves their graphs with shared model execution (sequential and
//!   batch-grouped parallel co-tenancy), and returns only saved values
//!   ([`server`], [`scheduler`]);
//! * a **unified execution engine** ([`engine`]): one `Engine::run(ExecSpec)`
//!   door for traces, sessions, and streaming, plus a vLLM-style decode
//!   substrate — per-sequence KV cache, explicit prefill/decode split, and
//!   a continuous-batching loop interleaving single-token steps from many
//!   concurrent streams;
//! * the **L3 fleet coordinator** (§3.3, Fig. 4): a deployment registry
//!   with heartbeat-derived health states, pluggable routing policies
//!   (round-robin, least-loaded, latency-aware) with bounded-retry
//!   failover, and an HTTP front that mirrors the single-server API so
//!   clients are fleet-agnostic ([`coordinator`]);
//! * the model substrate: OPT-style decoder-only transformers AOT-compiled
//!   from JAX (+Pallas flash-attention / fused layernorm kernels) to HLO
//!   text, executed via the PJRT CPU client ([`runtime`], [`models`],
//!   [`shard`]);
//! * the paper's **baselines**: hook-based intervention mechanisms
//!   (baukit/pyvene/TransformerLens-like) and a Petals-like distributed
//!   swarm with client-side interventions ([`baselines`]);
//! * **fleet-wide observability** ([`obs`]): mergeable log-bucketed
//!   latency histograms (fleet percentiles from summed buckets), request
//!   tracing via the `x-nnscope-trace` header with per-stage spans, and
//!   JSON/Prometheus metrics exposition;
//! * the supporting substrates that are unavailable offline and that the
//!   paper's service depends on: JSON ([`json`]), an HTTP/1.1 server and
//!   client ([`server::http`]), a thread pool ([`threadpool`]), a simulated
//!   WAN link ([`netsim`]), PRNG/stats/tables ([`util`]), and a host tensor
//!   engine for intervention ops ([`tensor`]);
//! * the §2 research survey analyses (Figures 2 and 7) ([`survey`]).
//!
//! Python (JAX/Pallas) runs only at `make artifacts` time; the request path
//! is pure Rust over AOT-compiled artifacts.
//!
//! The request lifecycle and subsystem map live in `docs/ARCHITECTURE.md`;
//! the wire API is specified in `docs/PROTOCOL.md`.

pub mod util;
pub mod json;
pub mod tensor;
pub mod threadpool;
pub mod netsim;
pub mod obs;
pub mod graph;
pub mod interp;
pub mod engine;
pub mod client;
pub mod runtime;
pub mod models;
pub mod server;
pub mod scheduler;
pub mod coordinator;
pub mod shard;
pub mod baselines;
pub mod survey;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
